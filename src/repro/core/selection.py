"""Element-selection primitives for cut-layer sparsification.

All functions operate on the LAST axis (the instance feature axis `d` in the
paper) and are fully batched over leading axes. Top-k is by magnitude, as in
the paper ("preserve top-k elements ... in terms of magnitude").

TPU adaptation: the randomized selection of Eq. (7) — k sequential draws
without replacement, each draw picking the top-k pool w.p. (1 - alpha) — is
vectorized exactly:

  * the number of non-top-k picks is m ~ Binomial(k, alpha) (the per-draw pool
    choice in Eq. 7 is i.i.d. Bernoulli(alpha); only the *within-pool*
    distribution renormalizes as pools deplete), clipped to the pool sizes;
  * uniform-without-replacement within a pool == Gumbel-top-m on uniform
    weights (exponential race), which is branch-free and layout-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = float("-inf")


def topk_mask(x: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest-|x| elements along the last axis."""
    d = x.shape[-1]
    if k >= d:
        return jnp.ones_like(x, dtype=bool)
    mag = jnp.abs(x).astype(jnp.float32)
    kth = jax.lax.top_k(mag, k)[0][..., -1:]
    # Break ties deterministically: strictly-greater always in; equal-to-kth
    # admitted left-to-right until k elements are set.
    gt = mag > kth
    eq = mag == kth
    need = k - jnp.sum(gt, axis=-1, keepdims=True)
    eq_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1)
    return gt | (eq & (eq_rank <= need))


def topk_values_indices(x: jax.Array, k: int):
    """(values, indices) of the top-k |x| elements — the wire payload."""
    mag = jnp.abs(x).astype(jnp.float32)
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx


def mask_from_indices(idx: jax.Array, d: int) -> jax.Array:
    """Scatter boolean mask of shape (..., d) from integer indices (..., k)."""
    onehot = jax.nn.one_hot(idx, d, dtype=bool)
    return jnp.any(onehot, axis=-2)


def _select_m_from_pool(scores: jax.Array, pool: jax.Array, m: jax.Array, k: int):
    """Select exactly `m` elements uniformly w/o replacement from `pool`.

    scores : i.i.d. Gumbel noise, shape (..., d)
    pool   : bool  (..., d)
    m      : int32 (..., 1), 0 <= m <= min(k, pool size)
    Returns a bool mask. Uses the m-th largest in-pool Gumbel as threshold.
    """
    s = jnp.where(pool, scores, _NEG_INF)
    top = jax.lax.top_k(s, k)[0]                      # (..., k) sorted desc
    # threshold = m-th largest (1-based); m == 0 -> select nothing
    gather = jnp.clip(m - 1, 0, k - 1)
    thr = jnp.take_along_axis(top, gather, axis=-1)   # (..., 1)
    sel = s >= thr
    return jnp.where(m > 0, sel, jnp.zeros_like(sel))


def randtopk_mask(x: jax.Array, k: int, alpha: float, key: jax.Array) -> jax.Array:
    """Randomized top-k selection mask, Eq. (7) of the paper.

    Each of the k draws (without replacement) picks a top-k element with
    probability 1-alpha (uniform within the remaining top-k pool) and a
    non-top-k element with probability alpha (uniform within the remaining
    non-top-k pool). Exactly k elements are selected.
    """
    d = x.shape[-1]
    if k >= d:
        return jnp.ones_like(x, dtype=bool)
    kb, kg = jax.random.split(key)
    is_top = topk_mask(x, k)

    # m ~ Binomial(k, alpha), one per instance, clipped to the non-top pool.
    draws = jax.random.bernoulli(kb, alpha, x.shape[:-1] + (k,))
    m = jnp.sum(draws.astype(jnp.int32), axis=-1, keepdims=True)
    m = jnp.clip(m, 0, min(k, d - k))

    g = jax.random.gumbel(kg, x.shape, dtype=jnp.float32)
    sel_top = _select_m_from_pool(g, is_top, k - m, k)
    sel_non = _select_m_from_pool(g, ~is_top, m, k)
    return sel_top | sel_non


def kth_magnitude_threshold(x: jax.Array, k: int) -> jax.Array:
    """|x| value of the k-th largest element (the Pallas kernel's oracle)."""
    mag = jnp.abs(x).astype(jnp.float32)
    return jax.lax.top_k(mag, k)[0][..., -1]
