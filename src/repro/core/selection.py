"""Element-selection primitives for cut-layer sparsification.

All functions operate on the LAST axis (the instance feature axis `d` in the
paper) and are fully batched over leading axes. Top-k is by magnitude, as in
the paper ("preserve top-k elements ... in terms of magnitude").

TPU adaptation: the randomized selection of Eq. (7) — k sequential draws
without replacement, each draw picking the top-k pool w.p. (1 - alpha) — is
vectorized exactly:

  * the number of non-top-k picks is m ~ Binomial(k, alpha) (the per-draw pool
    choice in Eq. 7 is i.i.d. Bernoulli(alpha); only the *within-pool*
    distribution renormalizes as pools deplete), clipped to the pool sizes;
  * uniform-without-replacement within a pool == Gumbel-top-m on uniform
    weights (exponential race), which is branch-free and layout-friendly.

Backend dispatch: `topk_mask` / `randtopk_mask` accept `backend=`:

  * ``"xla"``    — `jax.lax.top_k`-based reference path (default off-TPU);
  * ``"pallas"`` — the bisection kernel in `kernels/randtopk` (interpret mode
    when not running on a TPU, Mosaic when on one), which also emits the
    Eq. (7) randomized mask in-kernel;
  * ``"auto"``   — pallas on a TPU runtime, xla elsewhere; the default, and
    overridable via the REPRO_SELECTION_BACKEND environment variable.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

_NEG_INF = float("-inf")

BACKENDS = ("auto", "xla", "pallas")


def _resolve_backend(backend):
    backend = backend or os.environ.get("REPRO_SELECTION_BACKEND", "auto")
    if backend not in BACKENDS:
        raise ValueError(f"selection backend {backend!r} not in {BACKENDS}")
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def _pallas_interpret() -> bool:
    # interpret-mode on CPU/GPU for validation; Mosaic on a real TPU runtime
    return jax.default_backend() != "tpu"


def topk_mask(x: jax.Array, k: int, *, backend: str = None) -> jax.Array:
    """Boolean mask of the k largest-|x| elements along the last axis."""
    d = x.shape[-1]
    if k >= d:
        return jnp.ones_like(x, dtype=bool)
    if _resolve_backend(backend) == "pallas":
        from repro.kernels.randtopk import ops as tk_ops

        return tk_ops.topk_mask(x, k, interpret=_pallas_interpret())
    mag = jnp.abs(x).astype(jnp.float32)
    kth = jax.lax.top_k(mag, k)[0][..., -1:]
    # Break ties deterministically: strictly-greater always in; equal-to-kth
    # admitted left-to-right until k elements are set.
    gt = mag > kth
    eq = mag == kth
    need = k - jnp.sum(gt, axis=-1, keepdims=True)
    eq_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1)
    return gt | (eq & (eq_rank <= need))


def mask_from_indices(idx: jax.Array, d: int) -> jax.Array:
    """Scatter boolean mask of shape (..., d) from integer indices (..., k)."""
    onehot = jax.nn.one_hot(idx, d, dtype=bool)
    return jnp.any(onehot, axis=-2)


def pack_mask_words(mask: jax.Array) -> jax.Array:
    """Pack a boolean support mask (..., d) into little-endian uint32 words
    (..., ceil(d/32)) — the device-resident layout of the `mask` payload
    kind (bit j of the row mask is bit j%32 of word j//32)."""
    d = mask.shape[-1]
    nw = (d + 31) // 32
    m = mask.astype(jnp.uint32)
    pad = nw * 32 - d
    if pad:
        m = jnp.pad(m, [(0, 0)] * (m.ndim - 1) + [(0, pad)])
    m = m.reshape(m.shape[:-1] + (nw, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    # set bits are disjoint across the lane axis, so a sum is a bitwise OR
    return jnp.sum(m << shifts, axis=-1, dtype=jnp.uint32)


def unpack_mask_words(words: jax.Array, d: int) -> jax.Array:
    """Inverse of `pack_mask_words`: uint32 words (..., ceil(d/32)) to a
    boolean mask (..., d). Bits at positions >= d are ignored."""
    nw = words.shape[-1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (jnp.asarray(words).astype(jnp.uint32)[..., None] >> shifts) \
        & jnp.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (nw * 32,))
    return flat[..., :d].astype(bool)


def _select_m_from_pool(scores: jax.Array, pool: jax.Array, m: jax.Array, k: int):
    """Select exactly `m` elements uniformly w/o replacement from `pool`.

    scores : i.i.d. Gumbel noise, shape (..., d)
    pool   : bool  (..., d)
    m      : int32 (..., 1), 0 <= m <= min(k, pool size)
    Returns a bool mask. Uses the m-th largest in-pool Gumbel as threshold.
    """
    s = jnp.where(pool, scores, _NEG_INF)
    top = jax.lax.top_k(s, k)[0]                      # (..., k) sorted desc
    # threshold = m-th largest (1-based); m == 0 -> select nothing
    gather = jnp.clip(m - 1, 0, k - 1)
    thr = jnp.take_along_axis(top, gather, axis=-1)   # (..., 1)
    sel = s >= thr
    return jnp.where(m > 0, sel, jnp.zeros_like(sel))


def binomial_nontop_count(key: jax.Array, alpha: float, k: int, d: int,
                          batch_shape) -> jax.Array:
    """m ~ Binomial(k, alpha) per instance, clipped to the pool sizes —
    the number of non-top-k picks in Eq. (7). Shape (*batch_shape, 1)."""
    draws = jax.random.bernoulli(key, alpha, tuple(batch_shape) + (k,))
    m = jnp.sum(draws.astype(jnp.int32), axis=-1, keepdims=True)
    return jnp.clip(m, 0, min(k, d - k))


def randtopk_mask(x: jax.Array, k: int, alpha: float, key: jax.Array,
                  *, backend: str = None) -> jax.Array:
    """Randomized top-k selection mask, Eq. (7) of the paper.

    Each of the k draws (without replacement) picks a top-k element with
    probability 1-alpha (uniform within the remaining top-k pool) and a
    non-top-k element with probability alpha (uniform within the remaining
    non-top-k pool). Exactly k elements are selected.
    """
    d = x.shape[-1]
    if k >= d:
        return jnp.ones_like(x, dtype=bool)
    if _resolve_backend(backend) == "pallas":
        from repro.kernels.randtopk import ops as tk_ops

        return tk_ops.randtopk_mask(x, k, alpha, key,
                                    interpret=_pallas_interpret())
    kb, kg = jax.random.split(key)
    is_top = topk_mask(x, k, backend="xla")
    m = binomial_nontop_count(kb, alpha, k, d, x.shape[:-1])
    g = jax.random.gumbel(kg, x.shape, dtype=jnp.float32)
    sel_top = _select_m_from_pool(g, is_top, k - m, k)
    sel_non = _select_m_from_pool(g, ~is_top, m, k)
    return sel_top | sel_non


def kth_magnitude_threshold(x: jax.Array, k: int) -> jax.Array:
    """|x| value of the k-th largest element (the Pallas kernel's oracle)."""
    mag = jnp.abs(x).astype(jnp.float32)
    return jax.lax.top_k(mag, k)[0][..., -1]
