"""Beyond-paper: error feedback (memory) for cut-layer sparsification.

EF is the standard companion of biased compressors in distributed SGD
(Stich et al. 2018 — cited by the paper but not applied to SL): the feature
owner keeps the residual e_t of what compression dropped and adds it back
before the next compression, so information is delayed rather than lost:

    c_t = Comp(o_t + e_t);   e_{t+1} = (o_t + e_t) - c_t

The paper never evaluates EF for split learning. It is NOT a free win here:
in SL the "signal" is a per-sample activation, not a shared gradient vector,
so the residual from one minibatch pairs with a DIFFERENT minibatch next
step. We evaluate a per-CLASS residual memory (tokens of the same label
share an error slot) — the closest meaningful SL analogue — and report
whether it helps at high compression (see benchmarks/error_feedback.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import selection


def ef_topk_forward(o, err, labels, k: int, n_slots: int):
    """Per-class error-feedback top-k.

    o: (B, d) cut activations; err: (n_slots, d) residual memory;
    labels: (B,) int — slot assignment. Returns (view, new_err).
    """
    e_b = jnp.take(err, labels, axis=0)                    # (B, d)
    corrected = o + e_b
    mask = selection.topk_mask(corrected, k)
    view = corrected * mask.astype(o.dtype)
    resid = corrected - view                               # what was dropped
    # scatter-mean residuals back into the per-class slots
    ones = jnp.ones((o.shape[0],), o.dtype)
    counts = jnp.zeros((n_slots,), o.dtype).at[labels].add(ones)
    sums = jnp.zeros((n_slots, o.shape[-1]), o.dtype).at[labels].add(resid)
    new_err = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts[:, None], 1.0),
                        err)
    return view, mask, new_err
