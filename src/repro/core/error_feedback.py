"""Beyond-paper: error feedback (memory) for cut-layer sparsification.

EF is the standard companion of biased compressors in distributed SGD
(Stich et al. 2018 — cited by the paper but not applied to SL): the feature
owner keeps the residual e_t of what compression dropped and adds it back
before the next compression, so information is delayed rather than lost:

    c_t = Comp(o_t + e_t);   e_{t+1} = (o_t + e_t) - c_t

The paper never evaluates EF for split learning, and it is NOT a free win
here: in SL the "signal" is a per-sample activation, not a shared gradient
vector, so the residual from one minibatch pairs with a DIFFERENT minibatch
next step. This module implements the closest meaningful SL analogue — a
per-CLASS residual memory (tokens of the same label share an error slot) —
and `benchmarks/error_feedback.py` reports whether it helps at high
compression. The full caveat discussion (including the label-leakage
implication of class-keyed state on the feature owner) is in
docs/beyond-paper.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import selection


def ef_topk_forward(o, err, labels, k: int, n_slots: int):
    """Per-class error-feedback top-k: one compression step with memory.

    Adds each sample's class residual to its activation, takes the top-k of
    the corrected signal, and scatter-means what was dropped back into the
    per-class slots (slots untouched by this batch keep their residual).

    Args:
      o:       (B, d) cut activations (the feature owner's bottom output).
      err:     (n_slots, d) residual memory carried across steps; start from
               zeros.
      labels:  (B,) int class ids in [0, n_slots) — the slot assignment.
               Using labels on the feature-owner side is itself a privacy
               concession; see docs/beyond-paper.md.
      k:       support size per sample.
      n_slots: number of residual slots (= number of classes).

    Returns:
      (view, mask, new_err): the compressed (B, d) view to send (top-k of
      o + residual, zeros elsewhere), the boolean support mask (apply it to
      the returning gradient so backward matches the forward support), and
      the updated residual memory to carry to the next step.

    Usage (one training step; see `benchmarks/error_feedback.py` for the
    full two-party loop)::

        err = jnp.zeros((n_classes, d))
        for x, y in batches:
            o = bottom_fn(bottom_params, x)
            view, mask, err = ef_topk_forward(o, err, y, k, n_classes)
            ...  # send `view`; mask the gradient with `mask` on the way back
    """
    e_b = jnp.take(err, labels, axis=0)                    # (B, d)
    corrected = o + e_b
    mask = selection.topk_mask(corrected, k)
    view = corrected * mask.astype(o.dtype)
    resid = corrected - view                               # what was dropped
    # scatter-mean residuals back into the per-class slots
    ones = jnp.ones((o.shape[0],), o.dtype)
    counts = jnp.zeros((n_slots,), o.dtype).at[labels].add(ones)
    sums = jnp.zeros((n_slots, o.shape[-1]), o.dtype).at[labels].add(resid)
    new_err = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts[:, None], 1.0),
                        err)
    return view, mask, new_err
