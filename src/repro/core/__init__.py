from repro.core.compressors import (
    Compressor,
    L1Reg,
    Quantization,
    RandTopK,
    SizeReduction,
    TopK,
    make_compressor,
)
from repro.core import selection, wire

__all__ = [
    "Compressor", "L1Reg", "Quantization", "RandTopK", "SizeReduction",
    "TopK", "make_compressor", "selection", "wire",
]
