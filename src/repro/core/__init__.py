from repro.core.compressors import (
    Compressor,
    L1Reg,
    Quantization,
    RandTopK,
    RandTopKQuant,
    SizeReduction,
    TopK,
    make_compressor,
    payload_to_dense,
)
from repro.core.payload import Payload, PayloadMeta
from repro.core import selection, wire

__all__ = [
    "Compressor", "L1Reg", "Payload", "PayloadMeta", "Quantization",
    "RandTopK", "RandTopKQuant", "SizeReduction", "TopK", "make_compressor",
    "payload_to_dense", "selection", "wire",
]
