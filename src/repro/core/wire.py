"""Byte-exact wire format for the compressed cut-layer payloads (Table 2).

The on-device compute path keeps dense/padded forms (TPUs have no sub-byte
addressing); this module is the host-side serialization that a real two-party
deployment puts on the socket, and the source of truth for the compressed-size
numbers reported in EXPERIMENTS.md. Offset/index encoding uses
r = ceil(log2 d) bits per index, bit-packed, exactly as the paper assumes.

Serialization is payload-typed: `encode_payload` / `decode_payload` map the
`core.payload.Payload` pytree (the same object `split.protocol` moves across
the pod boundary) to/from a bitstream, so the measured socket bytes, the
device transfer bytes, and the Table-2 analytic formulas are all derived from
one object and cross-checked in tests. Bit packing is vectorized numpy
(bit-shift matrix + `np.packbits`), little-endian within the stream —
byte-identical to the historical per-bit layout.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.payload import Payload, PayloadMeta

FLOAT_BITS = 32


def index_bits(d: int) -> int:
    return max(1, math.ceil(math.log2(d)))


def _pack_bits(vals: np.ndarray, width: int) -> bytes:
    """Pack unsigned ints (any shape) into a bitstream, `width` bits each.

    Value i occupies absolute bit positions [i*width, (i+1)*width), least
    significant bit first; bit j of the stream is bit j%8 of byte j//8.
    """
    vals = np.ascontiguousarray(vals).astype(np.uint64).ravel()
    if vals.size == 0 or width == 0:
        return b""
    shifts = np.arange(width, dtype=np.uint64)
    bits = ((vals[:, None] >> shifts) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits.ravel(), bitorder="little").tobytes()


def _unpack_bits(buf: bytes, width: int, count: int) -> np.ndarray:
    if count == 0 or width == 0:
        return np.zeros(count, dtype=np.uint64)
    arr = np.frombuffer(buf, dtype=np.uint8)
    bits = np.unpackbits(arr, bitorder="little")[: count * width]
    bits = bits.reshape(count, width).astype(np.uint64)
    shifts = np.arange(width, dtype=np.uint64)
    return np.bitwise_or.reduce(bits << shifts, axis=1)


def encode_sparse(values: np.ndarray, indices: np.ndarray, d: int) -> bytes:
    """Paper's Encode for top-k style payloads: k float32 + k packed indices."""
    assert values.shape == indices.shape
    vb = values.astype("<f4").tobytes()
    ib = _pack_bits(indices, index_bits(d))
    return vb + ib


def decode_sparse(buf: bytes, k_total: int, d: int):
    vb = buf[: 4 * k_total]
    values = np.frombuffer(vb, dtype="<f4").copy()
    indices = _unpack_bits(buf[4 * k_total:], index_bits(d), k_total)
    return values, indices.astype(np.int64)


def sparse_to_dense(values, indices, shape_last_d: int):
    dense = np.zeros(values.shape[:-1] + (shape_last_d,), dtype=np.float32)
    np.put_along_axis(dense, indices.astype(np.int64), values, axis=-1)
    return dense


def encode_quant(codes: np.ndarray, lo: np.ndarray, step: np.ndarray, bits: int) -> bytes:
    head = np.stack([lo, step], axis=-1).astype("<f4").tobytes()
    return head + _pack_bits(codes, bits)


def decode_quant(buf: bytes, n_instances: int, d: int, bits: int):
    head = np.frombuffer(buf[: 8 * n_instances], dtype="<f4").reshape(n_instances, 2)
    codes = _unpack_bits(buf[8 * n_instances:], bits, n_instances * d)
    codes = codes.reshape(n_instances, d).astype(np.float32)
    lo, step = head[:, :1], head[:, 1:]
    return lo + (codes + 0.5) * step


# ---------------------------------------------------------------------------
# Payload serialization — one codec for every compressor kind.
# ---------------------------------------------------------------------------

def encode_payload(p: Payload) -> bytes:
    """Serialize a Payload to the exact bitstream a two-party socket carries.

    Layout per kind (leading instance dims flattened, C order):
      dense/slice : values f32
      sparse      : values f32, then indices packed @ r = ceil(log2 d) bits
      quant       : header f32 (lo, step)/instance, then codes packed @ bits
      sparse_quant: header f32, then indices packed @ r, then codes @ bits
    """
    m = p.meta
    kind = m.kind
    if kind in ("dense", "slice"):
        return np.asarray(p.values).astype("<f4").tobytes()
    if kind == "sparse":
        return (np.asarray(p.values).astype("<f4").tobytes()
                + _pack_bits(np.asarray(p.indices), index_bits(m.d)))
    if kind == "quant":
        return (np.asarray(p.header).astype("<f4").tobytes()
                + _pack_bits(np.asarray(p.values), m.bits))
    if kind == "sparse_quant":
        return (np.asarray(p.header).astype("<f4").tobytes()
                + _pack_bits(np.asarray(p.indices), index_bits(m.d))
                + _pack_bits(np.asarray(p.values), m.bits))
    raise ValueError(kind)


def decode_payload(buf: bytes, meta: PayloadMeta, batch_shape) -> Payload:
    """Inverse of `encode_payload`; returns a Payload of numpy arrays."""
    n = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    kind, d, k = meta.kind, meta.d, meta.k
    if kind in ("dense", "slice"):
        w = d if kind == "dense" else k
        vals = np.frombuffer(buf, dtype="<f4", count=n * w).copy()
        return Payload(meta=meta, values=vals.reshape(*batch_shape, w))
    if kind == "sparse":
        vals = np.frombuffer(buf[: 4 * n * k], dtype="<f4").copy()
        idx = _unpack_bits(buf[4 * n * k:], index_bits(d), n * k)
        return Payload(meta=meta,
                       values=vals.reshape(*batch_shape, k),
                       indices=idx.astype(np.uint16).reshape(*batch_shape, k))
    if kind == "quant":
        head = np.frombuffer(buf[: 8 * n], dtype="<f4").copy()
        codes = _unpack_bits(buf[8 * n:], meta.bits, n * d)
        return Payload(meta=meta,
                       values=codes.astype(np.uint8).reshape(*batch_shape, d),
                       header=head.reshape(*batch_shape, 2))
    if kind == "sparse_quant":
        r = index_bits(d)
        head = np.frombuffer(buf[: 8 * n], dtype="<f4").copy()
        off = 8 * n
        idx_nbytes = (n * k * r + 7) // 8
        idx = _unpack_bits(buf[off: off + idx_nbytes], r, n * k)
        codes = _unpack_bits(buf[off + idx_nbytes:], meta.bits, n * k)
        return Payload(meta=meta,
                       values=codes.astype(np.uint8).reshape(*batch_shape, k),
                       indices=idx.astype(np.uint16).reshape(*batch_shape, k),
                       header=head.reshape(*batch_shape, 2))
    raise ValueError(kind)


def payload_nbytes(p: Payload) -> int:
    """Measured socket bytes of a payload (bit-packed, headers included)."""
    return len(encode_payload(p))


def payload_bits_per_instance(meta: PayloadMeta) -> float:
    """Analytic forward wire bits per instance for a payload kind — the
    codec-side counterpart of `table2_row` (cross-checked in tests)."""
    kind, d, k, r = meta.kind, meta.d, meta.k, index_bits(meta.d)
    if kind == "dense":
        return d * FLOAT_BITS
    if kind == "slice":
        return k * FLOAT_BITS
    if kind == "sparse":
        return k * (FLOAT_BITS + r)
    if kind == "quant":
        return d * meta.bits + 2 * FLOAT_BITS
    if kind == "sparse_quant":
        return k * (meta.bits + r) + 2 * FLOAT_BITS
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Table-2 analytic sizes (relative to d * 32 bits), per instance.
# ---------------------------------------------------------------------------

def table2_row(method: str, d: int, *, k: int = 0, bits: int = 0) -> dict:
    r = index_bits(d)
    n = FLOAT_BITS
    if method == "size_reduction":
        fwd = bwd = k / d
    elif method in ("topk", "randtopk"):
        fwd = k / d * (1 + r / n)
        bwd = k / d
    elif method == "quant":
        fwd = bits / n  # paper writes 2^b/N with b meaning bits-per-value grid
        bwd = 1.0
    elif method == "l1":
        fwd = k / d * (1 + r / n)  # k = measured nnz
        bwd = 1.0
    elif method == "randtopk_quant":
        fwd = (k * (bits + r) + 2 * n) / (d * n)
        bwd = k / d
    elif method == "identity":
        fwd = bwd = 1.0
    else:
        raise ValueError(method)
    return {"method": method, "fwd": fwd, "bwd": bwd}


def bytes_per_step(method: str, d: int, n_instances: int, *, k: int = 0,
                   bits: int = 0, training: bool = True) -> float:
    """Wire bytes for one batch step (fwd + optionally bwd)."""
    row = table2_row(method, d, k=k, bits=bits)
    per_inst = row["fwd"] + (row["bwd"] if training else 0.0)
    return per_inst * d * FLOAT_BITS / 8 * n_instances
