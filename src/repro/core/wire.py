"""Byte-exact wire format for the compressed cut-layer payloads (Table 2).

The on-device compute path keeps dense/padded forms (TPUs have no sub-byte
addressing); this module is the host-side serialization that a real two-party
deployment puts on the socket, and the source of truth for the compressed-size
numbers reported in EXPERIMENTS.md. Offset/index encoding uses
r = ceil(log2 d) bits per index, bit-packed, exactly as the paper assumes.

Serialization is payload-typed: `encode_payload` / `decode_payload` map the
`core.payload.Payload` pytree (the same object `split.protocol` moves across
the pod boundary) to/from a bitstream, so the measured socket bytes, the
device transfer bytes, and the Table-2 analytic formulas are all derived from
one object and cross-checked in tests. Bit packing is vectorized numpy
(two-aligned-uint64-word scheme in both directions, widths up to 64),
little-endian within the stream — byte-identical to the historical
per-bit layout.

On top of the bare payload bitstream sits a length-prefixed *frame* layer
(`encode_payload_frame` / `decode_frame` / `FrameReader`): the unit a
streaming session actually sends. A frame carries a session id, a sequence
number, and either a self-describing payload (kind / d / k / bits /
batch shape — everything `decode_payload` needs, so the receiver holds no
per-connection state), a token reply / close marker, or — in the training
direction — a `grad` frame carrying the compressed cut gradient as another
self-described payload plus the scalar step loss. `repro.runtime` builds
the multi-client serving loop and `repro.fedtrain` the split-training loop
on these frames; the normative layout spec (with executable examples) lives
in docs/wire-format.md.

Every frame carries a protocol-version byte and closes with a CRC32 trailer
over everything after the length prefix: a bit-packed index stream in which
one flipped bit silently decodes to *wrong indices* makes integrity
non-optional, so corruption surfaces as a typed `WireError`
(`ChecksumError` / `TruncatedFrame` / `UnknownKind` / `BadCount` /
`VersionMismatch`) and never as a plausible-but-wrong payload. Version and
CRC bytes are framing overhead — they land in `Frame.header_nbytes`, never
in `payload_nbytes`, so the Table-2 payload analytics are untouched.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import struct
import zlib
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.core.payload import KINDS, Payload, PayloadMeta

FLOAT_BITS = 32


# ---------------------------------------------------------------------------
# Typed wire-error taxonomy. Every defect a hostile/lossy byte stream can
# present decodes to one of these — never to a silently-wrong payload.
# WireError subclasses ValueError so pre-taxonomy callers keep working.
# ---------------------------------------------------------------------------

class WireError(ValueError):
    """Base class: the byte stream is not a well-formed frame."""


class ChecksumError(WireError):
    """CRC32 trailer disagrees with the frame bytes (corruption in flight)."""


class TruncatedFrame(WireError):
    """Frame body too short for its declared contents (or an absurd
    length prefix that could never be satisfied)."""


class UnknownKind(WireError):
    """Unrecognized frame kind or payload kind index."""


class BadCount(WireError):
    """A count/shape field (token count, d, k, bits, batch shape) is out of
    range or disagrees with the body length."""


class VersionMismatch(WireError):
    """Frame carries a protocol version this decoder does not speak."""


def index_bits(d: int) -> int:
    return max(1, math.ceil(math.log2(d)))


def mask_words(d: int) -> int:
    """u32 words per packed d-bit support bitmask (the device row layout the
    `mask` payload kind keeps in its `indices` leaf)."""
    return (d + 31) // 32


def mask_row_nbytes(d: int) -> int:
    """Socket bytes per packed d-bit support bitmask (byte-aligned per row)."""
    return (d + 7) // 8


def mask_words_to_bytes(words: np.ndarray, d: int) -> bytes:
    """Serialize (..., W) u32 mask words to the per-row byte-aligned wire
    layout: bit j of a row's mask is bit j%8 of its byte j//8 — i.e. the
    little-endian byte view of the little-endian words, truncated to
    `mask_row_nbytes(d)` per row."""
    w = np.ascontiguousarray(np.asarray(words).astype("<u4", copy=False))
    w = w.reshape(-1, mask_words(d))
    rows = w.view(np.uint8).reshape(w.shape[0], -1)
    return rows[:, :mask_row_nbytes(d)].tobytes()


def mask_bytes_to_words(buf, n: int, d: int) -> np.ndarray:
    """Inverse of `mask_words_to_bytes`: (n, mask_words(d)) uint32 words."""
    mb, nw = mask_row_nbytes(d), mask_words(d)
    raw = np.frombuffer(buf, dtype=np.uint8, count=n * mb)
    padded = np.zeros((n, 4 * nw), dtype=np.uint8)
    padded[:, :mb] = raw.reshape(n, mb)
    return padded.view("<u4").astype(np.uint32)


def _pack_bits(vals: np.ndarray, width: int) -> bytes:
    """Pack unsigned ints (any shape) into a bitstream, `width` bits each.

    Value i occupies absolute bit positions [i*width, (i+1)*width), least
    significant bit first; bit j of the stream is bit j%8 of byte j//8.

    Mirror of `_unpack_bits`'s two-aligned-word scheme: values are grouped
    64 per row so a group spans exactly `width` uint64 words, and a static
    loop over the 64 lanes ORs each lane into its (at most two) aligned
    words — no `(count, width)` bit matrix is ever materialized (the
    historical `>> shifts` + `np.packbits` path cost ~9 x `count x width`
    bytes of intermediates). Byte-identical outputs are pinned by
    `benchmarks/wire_packing` against the per-bit reference loop.
    """
    vals = np.ascontiguousarray(vals).astype(np.uint64).ravel()
    if vals.size == 0 or width == 0:
        return b""
    assert width <= 64
    n = vals.size
    groups = (n + 63) // 64
    lanes = np.zeros((groups, 64), dtype=np.uint64)
    lanes.ravel()[:n] = vals & np.uint64((1 << width) - 1)
    words = np.zeros((groups, width), dtype=np.uint64)
    for i in range(min(64, n)):
        start = i * width
        j, off = start // 64, start % 64
        words[:, j] |= lanes[:, i] << np.uint64(off)
        if off and off + width > 64:
            # spill into the next word; j+1 < width holds whenever a lane
            # spills (start + width <= 64 * width)
            words[:, j + 1] |= lanes[:, i] >> np.uint64(64 - off)
    return words.astype("<u8", copy=False).tobytes()[:(n * width + 7) // 8]


def _unpack_bits(buf: bytes, width: int, count: int) -> np.ndarray:
    """Inverse of `_pack_bits` (same little-endian bit layout).

    Value i is assembled from at most two aligned uint64 words of the
    stream (`lo = word >> bit_offset`, `hi` the spill from the next word) —
    no `(count, width)` bit matrix is ever materialized (the historical
    implementation's `unpackbits` + uint64 shift-matrix reduction cost
    ~9 x `count x width` bytes of intermediates and a per-bit reduction
    pass). Byte-identical outputs are pinned by `benchmarks/wire_packing`
    against the per-bit reference loop.
    """
    if count == 0 or width == 0:
        return np.zeros(count, dtype=np.uint64)
    assert width <= 64
    arr = np.frombuffer(buf, dtype=np.uint8)
    nbytes = (count * width + 7) // 8
    if arr.size < nbytes:
        raise ValueError(f"bit-packed buffer holds {arr.size} B, "
                         f"{count} x {width}-bit values need {nbytes} B")
    padded = np.zeros((nbytes // 8 + 2) * 8, dtype=np.uint8)
    padded[:nbytes] = arr[:nbytes]
    words = padded.view("<u8")
    starts = np.arange(count, dtype=np.uint64) * np.uint64(width)
    wi = (starts >> np.uint64(6)).astype(np.int64)
    bit = starts & np.uint64(63)
    lo = words[wi] >> bit
    hi = words[wi + 1] << ((np.uint64(64) - bit) & np.uint64(63))
    hi = np.where(bit == np.uint64(0), np.uint64(0), hi)
    return (lo | hi) & np.uint64((1 << width) - 1)


def encode_sparse(values: np.ndarray, indices: np.ndarray, d: int) -> bytes:
    """Paper's Encode for top-k style payloads: k float32 + k packed indices."""
    assert values.shape == indices.shape
    vb = values.astype("<f4").tobytes()
    ib = _pack_bits(indices, index_bits(d))
    return vb + ib


def decode_sparse(buf: bytes, k_total: int, d: int):
    """`buf` must be caller-owned (see `decode_payload`); values alias it."""
    values = np.frombuffer(buf, dtype="<f4", count=k_total)
    indices = _unpack_bits(buf[4 * k_total:], index_bits(d), k_total)
    return values, indices.astype(np.int64)


def sparse_to_dense(values, indices, shape_last_d: int):
    dense = np.zeros(values.shape[:-1] + (shape_last_d,), dtype=np.float32)
    np.put_along_axis(dense, indices.astype(np.int64), values, axis=-1)
    return dense


def encode_quant(codes: np.ndarray, lo: np.ndarray, step: np.ndarray, bits: int) -> bytes:
    head = np.stack([lo, step], axis=-1).astype("<f4").tobytes()
    return head + _pack_bits(codes, bits)


def decode_quant(buf: bytes, n_instances: int, d: int, bits: int):
    head = np.frombuffer(buf[: 8 * n_instances], dtype="<f4").reshape(n_instances, 2)
    codes = _unpack_bits(buf[8 * n_instances:], bits, n_instances * d)
    codes = codes.reshape(n_instances, d).astype(np.float32)
    lo, step = head[:, :1], head[:, 1:]
    return lo + (codes + 0.5) * step


# ---------------------------------------------------------------------------
# Payload serialization — one codec for every compressor kind.
# ---------------------------------------------------------------------------

def encode_payload(p: Payload) -> bytes:
    """Serialize a Payload to the exact bitstream a two-party socket carries.

    Layout per kind (leading instance dims flattened, C order):
      dense/slice : values f32
      sparse      : values f32, then indices packed @ r = ceil(log2 d) bits
      quant       : header f32 (lo, step)/instance, then codes packed @ bits
      sparse_quant: header f32, then indices packed @ r, then codes @ bits
      mask        : values f32 (ascending-index order), then one packed
                    d-bit support mask per instance, byte-aligned per row
    """
    m = p.meta
    kind = m.kind
    if kind in ("dense", "slice"):
        return np.asarray(p.values).astype("<f4").tobytes()
    if kind == "mask":
        return (np.asarray(p.values).astype("<f4").tobytes()
                + mask_words_to_bytes(np.asarray(p.indices), m.d))
    if kind == "sparse":
        return (np.asarray(p.values).astype("<f4").tobytes()
                + _pack_bits(np.asarray(p.indices), index_bits(m.d)))
    if kind == "quant":
        return (np.asarray(p.header).astype("<f4").tobytes()
                + _pack_bits(np.asarray(p.values), m.bits))
    if kind == "sparse_quant":
        return (np.asarray(p.header).astype("<f4").tobytes()
                + _pack_bits(np.asarray(p.indices), index_bits(m.d))
                + _pack_bits(np.asarray(p.values), m.bits))
    raise ValueError(kind)


def decode_payload(buf: bytes, meta: PayloadMeta, batch_shape) -> Payload:
    """Inverse of `encode_payload`; returns a Payload of numpy arrays.

    `buf` must be exclusively owned by the caller and never mutated after
    this call: the float leaves are zero-copy `np.frombuffer` views into it
    (the frame layer hands each payload a fresh body slice, so the hot
    receive path does one copy — the slice — instead of one per leaf).
    """
    n = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    kind, d, k = meta.kind, meta.d, meta.k
    if kind in ("dense", "slice"):
        w = d if kind == "dense" else k
        vals = np.frombuffer(buf, dtype="<f4", count=n * w)
        return Payload(meta=meta, values=vals.reshape(*batch_shape, w))
    if kind == "sparse":
        vals = np.frombuffer(buf, dtype="<f4", count=n * k)
        idx = _unpack_bits(buf[4 * n * k:], index_bits(d), n * k)
        return Payload(meta=meta,
                       values=vals.reshape(*batch_shape, k),
                       indices=idx.astype(np.uint16).reshape(*batch_shape, k))
    if kind == "mask":
        vals = np.frombuffer(buf, dtype="<f4", count=n * k)
        words = mask_bytes_to_words(buf[4 * n * k:], n, d)
        return Payload(meta=meta,
                       values=vals.reshape(*batch_shape, k),
                       indices=words.reshape(*batch_shape, mask_words(d)))
    if kind == "quant":
        head = np.frombuffer(buf, dtype="<f4", count=2 * n)
        codes = _unpack_bits(buf[8 * n:], meta.bits, n * d)
        return Payload(meta=meta,
                       values=codes.astype(np.uint8).reshape(*batch_shape, d),
                       header=head.reshape(*batch_shape, 2))
    if kind == "sparse_quant":
        r = index_bits(d)
        head = np.frombuffer(buf, dtype="<f4", count=2 * n)
        off = 8 * n
        idx_nbytes = (n * k * r + 7) // 8
        idx = _unpack_bits(buf[off: off + idx_nbytes], r, n * k)
        codes = _unpack_bits(buf[off + idx_nbytes:], meta.bits, n * k)
        return Payload(meta=meta,
                       values=codes.astype(np.uint8).reshape(*batch_shape, k),
                       indices=idx.astype(np.uint16).reshape(*batch_shape, k),
                       header=head.reshape(*batch_shape, 2))
    raise ValueError(kind)


def payload_nbytes(p: Payload) -> int:
    """Measured socket bytes of a payload (bit-packed, headers included)."""
    return len(encode_payload(p))


def payload_bits_per_instance(meta: PayloadMeta) -> float:
    """Analytic forward wire bits per instance for a payload kind — the
    codec-side counterpart of `table2_row` (cross-checked in tests)."""
    kind, d, k, r = meta.kind, meta.d, meta.k, index_bits(meta.d)
    if kind == "dense":
        return d * FLOAT_BITS
    if kind == "slice":
        return k * FLOAT_BITS
    if kind == "sparse":
        return k * (FLOAT_BITS + r)
    if kind == "mask":
        return k * FLOAT_BITS + 8 * mask_row_nbytes(d)
    if kind == "quant":
        return d * meta.bits + 2 * FLOAT_BITS
    if kind == "sparse_quant":
        return k * (meta.bits + r) + 2 * FLOAT_BITS
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Table-2 analytic sizes (relative to d * 32 bits), per instance.
# ---------------------------------------------------------------------------

def table2_row(method: str, d: int, *, k: int = 0, bits: int = 0) -> dict:
    r = index_bits(d)
    n = FLOAT_BITS
    if method == "size_reduction":
        fwd = bwd = k / d
    elif method in ("topk", "randtopk"):
        fwd = k / d * (1 + r / n)
        bwd = k / d
    elif method == "randtopk_mask":
        # mask-encoded sparsification: k floats + one packed d-bit support
        # mask (byte-aligned) replaces the per-index stream; beats
        # u16-index sparse whenever k/d > 1/16
        fwd = (k * n + 8 * mask_row_nbytes(d)) / (d * n)
        bwd = k / d
    elif method == "quant":
        fwd = bits / n  # paper writes 2^b/N with b meaning bits-per-value grid
        bwd = 1.0
    elif method == "l1":
        fwd = k / d * (1 + r / n)  # k = measured nnz
        bwd = 1.0
    elif method == "randtopk_quant":
        fwd = (k * (bits + r) + 2 * n) / (d * n)
        bwd = k / d
    elif method == "identity":
        fwd = bwd = 1.0
    else:
        raise ValueError(method)
    return {"method": method, "fwd": fwd, "bwd": bwd}


def bytes_per_step(method: str, d: int, n_instances: int, *, k: int = 0,
                   bits: int = 0, training: bool = True) -> float:
    """Wire bytes for one batch step (fwd + optionally bwd)."""
    row = table2_row(method, d, k=k, bits=bits)
    per_inst = row["fwd"] + (row["bwd"] if training else 0.0)
    return per_inst * d * FLOAT_BITS / 8 * n_instances


# ---------------------------------------------------------------------------
# Frame layer — the length-prefixed unit a streaming session sends.
# Normative spec (with executable examples): docs/wire-format.md.
# ---------------------------------------------------------------------------

#: version 2 = CRC32 trailer appended and counted in body_len (v1 had no
#: trailer); a v1 peer's frames fail the version gate, not the CRC gate
WIRE_VERSION = 2

#: frame kinds
FRAME_PAYLOAD = 1   # client -> server: one compressed cut activation
FRAME_TOKENS = 2    # server -> client: greedy-decoded next token(s)
FRAME_CLOSE = 3     # either direction: end of session
FRAME_GRAD = 4      # server -> client: compressed cut gradient + step loss
FRAME_ERROR = 5     # either direction: typed rejection, connection is dying

# <u32 body_len> <u8 version> <u8 frame_kind> <u32 session> <u32 seq>
_FRAME_HEAD = struct.Struct("<IBBII")
# payload-frame subheader: <u8 kind_idx> <u32 d> <u32 k> <u8 bits> <u8 ndim>
_PAYLOAD_HEAD = struct.Struct("<BIIBB")
_TOKENS_HEAD = struct.Struct("<I")       # <u32 count>, then count x i32
_GRAD_TAIL = struct.Struct("<f")         # <f32 loss> closing a grad subheader
_ERROR_HEAD = struct.Struct("<BH")       # <u8 code> <u16 msg_len>, then msg
_CRC = struct.Struct("<I")               # crc32 trailer closing every frame

#: fixed per-frame byte overhead before any payload/token body
FRAME_HEAD_NBYTES = _FRAME_HEAD.size
#: integrity bytes per frame: the version byte + the crc32 trailer
FRAME_INTEGRITY_NBYTES = 1 + _CRC.size
#: a length prefix beyond this is treated as corrupt rather than waited on
MAX_FRAME_BODY = 1 << 27
#: max batch-shape rank a payload subheader may declare
MAX_PAYLOAD_NDIM = 8

#: error-frame codes, one per WireError subclass
ERR_CHECKSUM, ERR_TRUNCATED, ERR_UNKNOWN_KIND, ERR_BAD_COUNT, \
    ERR_VERSION, ERR_PROTOCOL = 1, 2, 3, 4, 5, 6

_ERROR_CODES = ((ChecksumError, ERR_CHECKSUM), (TruncatedFrame, ERR_TRUNCATED),
                (UnknownKind, ERR_UNKNOWN_KIND), (BadCount, ERR_BAD_COUNT),
                (VersionMismatch, ERR_VERSION))


def error_code(exc: BaseException) -> int:
    """Map a WireError (or any rejection) to its error-frame code."""
    for cls, code in _ERROR_CODES:
        if isinstance(exc, cls):
            return code
    return ERR_PROTOCOL


@dataclasses.dataclass(frozen=True)
class Frame:
    """One decoded wire frame.

    `header_nbytes` counts every byte that is framing/metadata (length
    prefix, version, kind, session, seq, payload subheader);
    `payload_nbytes` counts only the payload bitstream (token bytes for
    FRAME_TOKENS). Byte accounting in `repro.runtime` keeps the two apart so
    compression ratios are computed from the payload bytes the codec actually
    produced, with framing overhead reported separately.
    """

    kind: int
    session: int
    seq: int
    payload: Optional[Payload] = None       # FRAME_PAYLOAD / FRAME_GRAD
    tokens: Optional[np.ndarray] = None     # FRAME_TOKENS, int32
    loss: Optional[float] = None            # FRAME_GRAD, training step loss
    error_code: Optional[int] = None        # FRAME_ERROR, ERR_* code
    error_msg: Optional[str] = None         # FRAME_ERROR, short description
    header_nbytes: int = 0
    payload_nbytes: int = 0

    @property
    def nbytes(self) -> int:
        return self.header_nbytes + self.payload_nbytes


def _frame(kind: int, session: int, seq: int, body: bytes) -> bytes:
    head = _FRAME_HEAD.pack(
        len(body) + _FRAME_HEAD.size - 4 + _CRC.size, WIRE_VERSION,
        kind, session, seq)
    buf = head + body
    # crc32 covers version..body (everything after the length prefix)
    return buf + _CRC.pack(zlib.crc32(memoryview(buf)[4:]))


def payload_frame_header_nbytes(p: Payload) -> int:
    """Framing bytes of `encode_payload_frame(p)` — everything that is not
    the payload bitstream (deterministic; used for byte accounting without
    re-encoding the payload)."""
    return (_FRAME_HEAD.size + _PAYLOAD_HEAD.size + 4 * len(p.batch_shape)
            + _CRC.size)


# memoized: a streaming session re-frames the SAME (meta, batch_shape)
# every step, and the subheader/byte-count recompute was a measurable
# slice of the per-frame host pack time (benchmarks/serve_throughput.py's
# encode gate). Bounded: one entry per distinct payload meta in the process.
@functools.lru_cache(maxsize=4096)
def _meta_subheader(m: PayloadMeta, bshape) -> bytes:
    sub = _PAYLOAD_HEAD.pack(KINDS.index(m.kind), m.d, m.k, m.bits,
                             len(bshape))
    return sub + (struct.pack(f"<{len(bshape)}I", *bshape) if bshape else b"")


def _payload_subheader(p: Payload) -> bytes:
    return _meta_subheader(p.meta, p.batch_shape)


def encode_payload_frame(session: int, seq: int, p: Payload) -> bytes:
    """Frame a payload: self-describing subheader + `encode_payload` bytes."""
    return _frame(FRAME_PAYLOAD, session, seq,
                  _payload_subheader(p) + encode_payload(p))


def encode_payload_frame_from_bytes(session: int, seq: int, m: PayloadMeta,
                                    batch_shape, body: bytes) -> bytes:
    """Frame an already-serialized payload bitstream (the device encode
    path: `kernels/encode` packs the wire sections on device, so the host's
    only work is this subheader + CRC wrap of the pulled buffer). `body`
    must be exactly the bytes `encode_payload` would produce — the length
    is checked here, byte equality is pinned in tests."""
    expect = payload_expected_nbytes(m, batch_shape)
    if len(body) != expect:
        raise BadCount(f"{m.kind} payload of batch shape "
                       f"{tuple(batch_shape)} needs {expect} B, device "
                       f"buffer holds {len(body)} B")
    return _frame(FRAME_PAYLOAD, session, seq,
                  _meta_subheader(m, tuple(batch_shape)) + body)


def grad_frame_header_nbytes(p: Payload) -> int:
    """Framing bytes of `encode_grad_frame(p)` — the payload-frame header
    plus the f32 loss the training reply carries."""
    return payload_frame_header_nbytes(p) + _GRAD_TAIL.size


def encode_grad_frame(session: int, seq: int, p: Payload,
                      loss: float = 0.0) -> bytes:
    """Frame a backward cut-gradient payload (training direction).

    The subheader mirrors the payload frame (the gradient is itself a
    `Payload` — `slice` of k floats for sparse forward kinds, `dense`
    otherwise, per Table 2 bwd), followed by one f32 `loss`: the label
    owner's scalar step loss, which the feature owner needs for logging and
    adaptive-k scheduling. The loss is framing metadata, not codec
    bitstream — byte accounting keeps it out of `payload_nbytes`.
    """
    return _frame(FRAME_GRAD, session, seq,
                  _payload_subheader(p) + _GRAD_TAIL.pack(loss)
                  + encode_payload(p))


def encode_token_frame(session: int, seq: int, tokens) -> bytes:
    toks = np.asarray(tokens, dtype="<i4").ravel()
    return _frame(FRAME_TOKENS, session, seq,
                  _TOKENS_HEAD.pack(toks.size) + toks.tobytes())


def encode_close_frame(session: int, seq: int = 0) -> bytes:
    return _frame(FRAME_CLOSE, session, seq, b"")


def encode_error_frame(session: int, seq: int, code: int,
                       msg: str = "") -> bytes:
    """Frame a typed rejection: the receiver of a malformed frame reports
    the `ERR_*` code + a short reason, then closes the connection. The
    session may then be resumed over a fresh connection (seq replay)."""
    mb = msg.encode("utf-8", "replace")[:512]
    return _frame(FRAME_ERROR, session, seq, _ERROR_HEAD.pack(code, len(mb))
                  + mb)


def payload_expected_nbytes(meta: PayloadMeta, batch_shape) -> int:
    """Exact `encode_payload` byte count for (meta, batch_shape) — each
    bit-packed section rounds up to whole bytes independently."""
    return _expected_nbytes(meta, tuple(batch_shape))


@functools.lru_cache(maxsize=4096)
def _expected_nbytes(meta: PayloadMeta, batch_shape) -> int:
    n = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    kind, d, k, r = meta.kind, meta.d, meta.k, index_bits(meta.d)
    if kind == "dense":
        return 4 * n * d
    if kind == "slice":
        return 4 * n * k
    if kind == "sparse":
        return 4 * n * k + (n * k * r + 7) // 8
    if kind == "mask":
        return 4 * n * k + n * mask_row_nbytes(d)
    if kind == "quant":
        return 8 * n + (n * d * meta.bits + 7) // 8
    if kind == "sparse_quant":
        return 8 * n + (n * k * r + 7) // 8 + (n * k * meta.bits + 7) // 8
    raise UnknownKind(kind)


def _validated_meta(kind_idx: int, d: int, k: int, bits: int) -> PayloadMeta:
    if kind_idx >= len(KINDS):
        raise UnknownKind(f"payload kind index {kind_idx}")
    kind = KINDS[kind_idx]
    if not 1 <= d <= 65536:                 # uint16 indices bound d
        raise BadCount(f"payload d={d} out of range")
    if kind in ("slice", "sparse", "sparse_quant", "mask") and not 1 <= k <= d:
        raise BadCount(f"{kind} payload k={k} out of range for d={d}")
    if kind in ("quant", "sparse_quant") and not 1 <= bits <= 8:
        raise BadCount(f"{kind} payload bits={bits} out of range")
    return PayloadMeta(kind, d=d, k=k, bits=bits)


def decode_frame(buf, offset: int = 0) -> Optional[Tuple[Frame, int]]:
    """Parse one frame starting at `offset` (bytes or bytearray).

    Returns (frame, next_offset), or None if the buffer does not yet hold a
    complete frame (stream reassembly — see `FrameReader`). A frame that is
    complete per its length prefix but malformed raises a typed `WireError`:
    the CRC32 trailer is verified before anything else is trusted, so a
    flipped bit anywhere surfaces as `ChecksumError`, never as silently
    wrong indices/values.
    """
    if len(buf) - offset < 4:
        return None
    (body_len,) = struct.unpack_from("<I", buf, offset)
    if body_len < _FRAME_HEAD.size - 4 + _CRC.size:
        raise TruncatedFrame(f"frame body length {body_len} below the "
                             f"head+crc minimum")
    if body_len > MAX_FRAME_BODY:
        raise TruncatedFrame(f"frame body length {body_len} exceeds "
                             f"MAX_FRAME_BODY ({MAX_FRAME_BODY})")
    end = offset + 4 + body_len
    if len(buf) < end:
        return None
    body_end = end - _CRC.size
    _, version, kind, session, seq = _FRAME_HEAD.unpack_from(buf, offset)
    # version gate BEFORE the checksum gate: a peer speaking another layout
    # (e.g. v1, whose frames carry no CRC trailer) must surface as a
    # version skew, not as phantom corruption
    if version != WIRE_VERSION:
        raise VersionMismatch(f"wire version {version}, expected "
                              f"{WIRE_VERSION}")
    (crc_stored,) = _CRC.unpack_from(buf, body_end)
    crc = zlib.crc32(memoryview(buf)[offset + 4: body_end])
    if crc != crc_stored:
        raise ChecksumError(f"frame crc32 {crc_stored:#010x} != computed "
                            f"{crc:#010x}")
    pos = offset + _FRAME_HEAD.size
    if kind in (FRAME_PAYLOAD, FRAME_GRAD):
        if pos + _PAYLOAD_HEAD.size > body_end:
            raise TruncatedFrame("payload subheader overruns frame body")
        kind_idx, d, k, bits, ndim = _PAYLOAD_HEAD.unpack_from(buf, pos)
        pos += _PAYLOAD_HEAD.size
        if ndim > MAX_PAYLOAD_NDIM:
            raise BadCount(f"payload batch rank {ndim} exceeds "
                           f"{MAX_PAYLOAD_NDIM}")
        if pos + 4 * ndim > body_end:
            raise TruncatedFrame("payload batch shape overruns frame body")
        bshape = struct.unpack_from(f"<{ndim}I", buf, pos) if ndim else ()
        pos += 4 * ndim
        if any(dim < 1 for dim in bshape):
            raise BadCount(f"payload batch shape {bshape} has a zero dim")
        loss = None
        if kind == FRAME_GRAD:
            if pos + _GRAD_TAIL.size > body_end:
                raise TruncatedFrame("grad loss field overruns frame body")
            (loss,) = _GRAD_TAIL.unpack_from(buf, pos)
            pos += _GRAD_TAIL.size
        meta = _validated_meta(kind_idx, d, k, bits)
        expect = payload_expected_nbytes(meta, bshape)
        if body_end - pos != expect:
            raise BadCount(f"{meta.kind} payload of batch shape {bshape} "
                           f"needs {expect} B, frame carries "
                           f"{body_end - pos} B")
        payload = decode_payload(buf[pos:body_end], meta, bshape)
        return (Frame(kind, session, seq, payload=payload, loss=loss,
                      header_nbytes=pos - offset + _CRC.size,
                      payload_nbytes=body_end - pos), end)
    if kind == FRAME_TOKENS:
        if pos + _TOKENS_HEAD.size > body_end:
            raise TruncatedFrame("token count field overruns frame body")
        (count,) = _TOKENS_HEAD.unpack_from(buf, pos)
        pos += _TOKENS_HEAD.size
        if pos + 4 * count != body_end:
            raise BadCount(f"token frame count {count} disagrees with "
                           f"body length {body_end - pos}")
        toks = np.frombuffer(buf, dtype="<i4", count=count, offset=pos).copy()
        return (Frame(kind, session, seq, tokens=toks,
                      header_nbytes=(_FRAME_HEAD.size + _TOKENS_HEAD.size
                                     + _CRC.size),
                      payload_nbytes=4 * count), end)
    if kind == FRAME_CLOSE:
        if pos != body_end:
            raise BadCount(f"close frame carries {body_end - pos} "
                           f"unexpected body bytes")
        return (Frame(kind, session, seq,
                      header_nbytes=_FRAME_HEAD.size + _CRC.size), end)
    if kind == FRAME_ERROR:
        if pos + _ERROR_HEAD.size > body_end:
            raise TruncatedFrame("error frame header overruns frame body")
        code, msg_len = _ERROR_HEAD.unpack_from(buf, pos)
        pos += _ERROR_HEAD.size
        if pos + msg_len != body_end:
            raise BadCount(f"error frame msg_len {msg_len} disagrees with "
                           f"body length {body_end - pos}")
        msg = bytes(buf[pos:body_end]).decode("utf-8", "replace")
        return (Frame(kind, session, seq, error_code=code, error_msg=msg,
                      header_nbytes=end - offset), end)
    raise UnknownKind(f"unknown frame kind {kind}")


class FrameReader:
    """Incremental stream reassembler: feed byte chunks, iterate frames.

    Chunk boundaries need not align with frame boundaries — partial frames
    are buffered until complete, and consumed prefixes are dropped.

    A `WireError` raised mid-iteration poisons the reader: frame boundaries
    downstream of a corrupt length/CRC cannot be trusted, so every later
    `frames()` call re-raises and the connection must be torn down (the
    session itself can resume over a fresh connection — see
    `repro.runtime`).
    """

    def __init__(self):
        self._buf = bytearray()
        self._broken: Optional[WireError] = None

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    def frames(self) -> Iterator[Frame]:
        if self._broken is not None:
            raise self._broken
        while True:
            # decode straight off the bytearray (no full-buffer copy);
            # decode_payload copies out every array it returns
            try:
                got = decode_frame(self._buf)
            except WireError as e:
                self._broken = e
                raise
            if got is None:
                return
            frame, consumed = got
            # trim BEFORE yielding: an abandoned iterator must not re-yield
            del self._buf[:consumed]
            yield frame
