"""Byte-exact wire format for the compressed cut-layer payloads (Table 2).

The on-device compute path keeps dense/padded forms (TPUs have no sub-byte
addressing); this module is the host-side serialization that a real two-party
deployment puts on the socket, and the source of truth for the compressed-size
numbers reported in EXPERIMENTS.md. Offset/index encoding uses
r = ceil(log2 d) bits per index, bit-packed, exactly as the paper assumes.
"""
from __future__ import annotations

import math

import numpy as np

FLOAT_BITS = 32


def index_bits(d: int) -> int:
    return max(1, math.ceil(math.log2(d)))


def _pack_bits(vals: np.ndarray, width: int) -> bytes:
    """Pack unsigned ints (any shape) into a bitstream, `width` bits each."""
    vals = vals.astype(np.uint64).ravel()
    nbits = int(vals.size) * width
    out = np.zeros((nbits + 7) // 8, dtype=np.uint8)
    for i, v in enumerate(vals.tolist()):
        base = i * width
        for b in range(width):
            if (v >> b) & 1:
                out[(base + b) >> 3] |= 1 << ((base + b) & 7)
    return out.tobytes()


def _unpack_bits(buf: bytes, width: int, count: int) -> np.ndarray:
    arr = np.frombuffer(buf, dtype=np.uint8)
    out = np.zeros(count, dtype=np.uint64)
    for i in range(count):
        base = i * width
        v = 0
        for b in range(width):
            if arr[(base + b) >> 3] & (1 << ((base + b) & 7)):
                v |= 1 << b
        out[i] = v
    return out


def encode_sparse(values: np.ndarray, indices: np.ndarray, d: int) -> bytes:
    """Paper's Encode for top-k style payloads: k float32 + k packed indices."""
    assert values.shape == indices.shape
    vb = values.astype("<f4").tobytes()
    ib = _pack_bits(indices, index_bits(d))
    return vb + ib


def decode_sparse(buf: bytes, k_total: int, d: int):
    vb = buf[: 4 * k_total]
    values = np.frombuffer(vb, dtype="<f4").copy()
    indices = _unpack_bits(buf[4 * k_total:], index_bits(d), k_total)
    return values, indices.astype(np.int64)


def sparse_to_dense(values, indices, shape_last_d: int):
    dense = np.zeros(values.shape[:-1] + (shape_last_d,), dtype=np.float32)
    np.put_along_axis(dense, indices.astype(np.int64), values, axis=-1)
    return dense


def encode_quant(codes: np.ndarray, lo: np.ndarray, step: np.ndarray, bits: int) -> bytes:
    head = np.stack([lo, step], axis=-1).astype("<f4").tobytes()
    return head + _pack_bits(codes, bits)


def decode_quant(buf: bytes, n_instances: int, d: int, bits: int):
    head = np.frombuffer(buf[: 8 * n_instances], dtype="<f4").reshape(n_instances, 2)
    codes = _unpack_bits(buf[8 * n_instances:], bits, n_instances * d)
    codes = codes.reshape(n_instances, d).astype(np.float32)
    lo, step = head[:, :1], head[:, 1:]
    return lo + (codes + 0.5) * step


# ---------------------------------------------------------------------------
# Table-2 analytic sizes (relative to d * 32 bits), per instance.
# ---------------------------------------------------------------------------

def table2_row(method: str, d: int, *, k: int = 0, bits: int = 0) -> dict:
    r = index_bits(d)
    n = FLOAT_BITS
    if method == "size_reduction":
        fwd = bwd = k / d
    elif method in ("topk", "randtopk"):
        fwd = k / d * (1 + r / n)
        bwd = k / d
    elif method == "quant":
        fwd = bits / n  # paper writes 2^b/N with b meaning bits-per-value grid
        bwd = 1.0
    elif method == "l1":
        fwd = k / d * (1 + r / n)  # k = measured nnz
        bwd = 1.0
    elif method == "randtopk_quant":
        fwd = (k * (bits + r) + 2 * n) / (d * n)
        bwd = k / d
    elif method == "identity":
        fwd = bwd = 1.0
    else:
        raise ValueError(method)
    return {"method": method, "fwd": fwd, "bwd": bwd}


def bytes_per_step(method: str, d: int, n_instances: int, *, k: int = 0,
                   bits: int = 0, training: bool = True) -> float:
    """Wire bytes for one batch step (fwd + optionally bwd)."""
    row = table2_row(method, d, k=k, bits=bits)
    per_inst = row["fwd"] + (row["bwd"] if training else 0.0)
    return per_inst * d * FLOAT_BITS / 8 * n_instances
