"""Cut-layer compressors for split learning (paper Sections 3-4).

Each compressor is a frozen config object implementing the packed-payload
codec that defines everything that crosses the cut layer:

    payload = comp.encode(x, key=key, training=True)   # wire-dtype pytree
    y       = comp.decode(payload, shape=x.shape)      # dense far-side view
    y, aux  = comp.forward(x, key=key, training=True)  # decode(encode(x))

`x` is the cut-layer activation `(..., d)`. `encode` produces a
`core.payload.Payload` — float32 values / uint8 codes / uint16 indices /
float32 range headers, exactly what a two-party socket (core.wire) or the
pod-boundary ppermute (split.protocol) moves. `decode` is
compressor-independent: any party holding a payload can reconstruct the
dense view from the payload alone. `forward` is kept as the composition
`decode(encode(x))` for backward compatibility; `aux` carries the support
mask where one exists.

Backward semantics follow the paper exactly:
  * size-reduction / top-k / randtopk: the gradient is masked with the SAME
    support that was used in the forward pass (the label owner sends only the
    k gradient values; indices are already known to the feature owner).
    Realized by gather-from-support in encode + scatter in decode (whose
    adjoints are scatter/gather), or explicitly by `split.protocol`'s
    payload-typed backward rules.
  * quantization: forward quantize-dequantize; the backward gradient is sent
    uncompressed, and the chain through the quantizer is the straight-through
    estimator (identity), via the `_ste` custom_vjp.
  * L1: identity at training time + a `loss_penalty(x)` term; at inference the
    support is the empirically-nonzero set (|x| > tol after training shrinks
    activations toward zero).

Compression ratios are reported by `fwd_bits`/`bwd_bits` (Table 2), which
tests cross-check against the measured `wire.encode_payload` byte counts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.core.payload import Payload, PayloadMeta

FLOAT_BITS = 32  # N in the paper
MAX_INDEX = 2 ** 16  # uint16 wire indices


def _index_bits(d: int) -> int:
    return max(1, math.ceil(math.log2(d)))


@jax.custom_vjp
def _ste(x, y):
    """Value `y`, gradient identity to `x` (straight-through estimator)."""
    return y


def _ste_fwd(x, y):
    return y, None


def _ste_bwd(_, g):
    return g, jnp.zeros_like(g)


_ste.defvjp(_ste_fwd, _ste_bwd)


def _scatter_rows(vals, idx, d: int, backend):
    """Dense (..., d) scatter of a sparse support — backend-dispatched.

    ``"pallas"`` runs the VMEM compare-and-select kernel
    (`kernels.randtopk.ops.scatter_rows`); ``"xla"`` (and the off-TPU
    ``"auto"`` default) is `put_along_axis`. Same dispatch contract as
    `selection.topk_mask`.
    """
    if selection._resolve_backend(backend) == "pallas":
        from repro.kernels.randtopk import ops as tk_ops

        return tk_ops.scatter_rows(jnp.asarray(vals), jnp.asarray(idx), d,
                                   interpret=selection._pallas_interpret())
    out = jnp.zeros(vals.shape[:-1] + (d,), vals.dtype)
    return jnp.put_along_axis(out, jnp.asarray(idx).astype(jnp.int32), vals,
                              axis=-1, inplace=False)


def mask_expand_rows(vals, words, d: int):
    """Dense (..., d) expansion of a mask payload — the XLA reference for
    `kernels.decode`'s mask branch.

    `vals` holds the k selected values in ascending-index order; `words` the
    packed support bitmask. Each set bit takes the next value in the scan
    (position = cumsum of the mask); rows with extra set bits beyond k (a
    hostile frame) zero the overflow rather than mis-indexing.
    """
    mask = selection.unpack_mask_words(jnp.asarray(words), d)
    k = vals.shape[-1]
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=-1) - 1
    take = jnp.take_along_axis(vals, jnp.clip(pos, 0, k - 1), axis=-1)
    return jnp.where(mask & (pos < k), take, jnp.zeros_like(take))


def payload_to_dense(p: Payload, shape=None, dtype=None, *, backend=None,
                     project=None):
    """Dense view (..., d) of any payload — the label-owner-side Decode.

    Compressor-independent: dispatches on `p.meta.kind` only, so the far
    side of the wire never needs the compressor object itself. `backend`
    follows the `selection` dispatch contract (None/"auto" -> Pallas on
    TPU, XLA elsewhere): ``"pallas"`` runs the fused one-pass
    `kernels.decode` kernel for EVERY kind (dequant + scatter in one VMEM
    pass), ``"xla"`` the two-pass dequant->scatter below. Dense/slice/
    sparse results are bit-identical either way (wire floats verbatim);
    quant kinds may differ by 1 ulp of the dequant product (FMA
    contraction — see `_dequant`).

    `project` is an optional (d, p) cut-projection matrix: the Pallas path
    fuses `rows @ project` as a kernel epilogue (the decoded rows never
    materialize); the XLA path applies the same matmul after decoding.
    """
    dtype = dtype or jnp.float32
    m = p.meta
    if selection._resolve_backend(backend) == "pallas":
        from repro.kernels.decode import ops as dec_ops

        return dec_ops.decode_rows(p, dtype=dtype, project=project,
                                   interpret=selection._pallas_interpret())
    if m.kind == "dense":
        out = p.values.astype(dtype)
    elif m.kind == "slice":
        pad = [(0, 0)] * (p.values.ndim - 1) + [(0, m.d - m.k)]
        out = jnp.pad(p.values.astype(dtype), pad)
    elif m.kind == "sparse":
        out = _scatter_rows(p.values.astype(dtype), p.indices, m.d, backend)
    elif m.kind == "mask":
        out = mask_expand_rows(p.values.astype(dtype), p.indices, m.d)
    elif m.kind == "quant":
        out = _dequant(p).astype(dtype)
    elif m.kind == "sparse_quant":
        out = _scatter_rows(_dequant(p).astype(dtype), p.indices, m.d,
                            backend)
    else:
        raise ValueError(m.kind)
    if project is not None:
        out = (out @ project.astype(jnp.float32)).astype(dtype)
    return out


def _dequant(p: Payload):
    """`lo + (code + 0.5) * step`.

    Rounding note: under jit the XLA backend may contract the multiply-add
    into an FMA, so compiled dequant (`protocol.server_decode_device`, the
    fused `cut_boundary` path) can differ from eager/host dequant by 1 ulp
    of the step product. Sparse scatter and dense passthrough carry wire
    values verbatim and are bit-exact in every mode; the dequant ulp is
    pinned (and shown not to move served tokens) in tests/test_arena.py.
    """
    lo, step = p.header[..., :1], p.header[..., 1:]
    return lo + (jnp.asarray(p.values).astype(jnp.float32) + 0.5) * step


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: identity (vanilla split learning, 'No compression')."""

    name: str = "identity"
    backend: Optional[str] = None   # selection backend: None->auto, xla, pallas

    wire_kind = "dense"             # payload kind this compressor emits

    # -- codec ---------------------------------------------------------------
    def encode(self, x, *, key=None, training=False) -> Payload:
        return Payload(meta=PayloadMeta("dense", d=x.shape[-1]),
                       values=x.astype(jnp.float32))

    def decode(self, p: Payload, shape=None, dtype=None):
        return payload_to_dense(p, shape=shape, dtype=dtype)

    def forward(self, x, *, key=None, training=False):
        p = self.encode(x, key=key, training=training)
        y = self.decode(p, shape=x.shape, dtype=x.dtype)
        return y, self._aux(p, x, training)

    def _aux(self, p: Payload, x, training) -> dict:
        return {}

    def loss_penalty(self, x):
        return jnp.zeros((), dtype=jnp.float32)

    # -- wire accounting (bits per instance of dimension d) ------------------
    def fwd_bits(self, d: int) -> float:
        return d * FLOAT_BITS

    def bwd_bits(self, d: int) -> float:
        return d * FLOAT_BITS

    def compressed_size(self, d: int) -> float:
        """Mean of forward+backward relative compressed size (inference uses
        fwd only; Table 2 reports the two separately — see wire.table2_row)."""
        return 0.5 * (self.fwd_bits(d) + self.bwd_bits(d)) / (d * FLOAT_BITS)


@dataclasses.dataclass(frozen=True)
class SizeReduction(Compressor):
    """Keep the first k features (mask-based cut-layer slimming, Eq. 1)."""

    k: int = 8
    name: str = "size_reduction"

    wire_kind = "slice"

    def encode(self, x, *, key=None, training=False):
        d = x.shape[-1]
        k = min(self.k, d)
        return Payload(meta=PayloadMeta("slice", d=d, k=k),
                       values=x[..., :k].astype(jnp.float32))

    def _aux(self, p, x, training):
        mask = jnp.arange(p.meta.d) < p.meta.k
        return {"mask": jnp.broadcast_to(mask, x.shape)}

    def fwd_bits(self, d):
        return self.k * FLOAT_BITS

    def bwd_bits(self, d):
        return self.k * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Magnitude top-k sparsification (Eq. 3)."""

    k: int = 8
    name: str = "topk"

    wire_kind = "sparse"

    def _mask(self, x, key, training):
        return selection.topk_mask(x, self.k, backend=self.backend)

    def _support(self, x, key, training):
        """uint16 indices of the selected support (stop-gradient),
        ascending-index order — the canonical wire order shared with the
        fused encode kernels (`kernels.encode`), so host and device encodes
        serialize byte-identically."""
        d = x.shape[-1]
        assert d <= MAX_INDEX, "uint16 wire indices need d <= 65536"
        k = min(self.k, d)
        mask = self._mask(x, key, training)
        score = jnp.where(mask, jnp.abs(x.astype(jnp.float32)), -1.0)
        _, idx = jax.lax.top_k(score, k)
        idx = jnp.sort(idx, axis=-1)
        return jax.lax.stop_gradient(idx), mask

    def encode(self, x, *, key=None, training=False):
        d = x.shape[-1]
        idx, _ = self._support(x, key, training)
        vals = jnp.take_along_axis(x, idx, axis=-1).astype(jnp.float32)
        return Payload(meta=PayloadMeta("sparse", d=d, k=idx.shape[-1]),
                       values=vals, indices=idx.astype(jnp.uint16))

    def _aux(self, p, x, training):
        return {"mask": selection.mask_from_indices(
            p.indices.astype(jnp.int32), p.meta.d)}

    def fwd_bits(self, d):
        return self.k * (FLOAT_BITS + _index_bits(d))

    def bwd_bits(self, d):
        # feature owner already holds the indices
        return self.k * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class RandTopK(TopK):
    """Randomized top-k sparsification — the paper's contribution (Eq. 7).

    alpha=0 -> TopK; alpha=1 -> Dropout-like. Randomness only in training.
    """

    alpha: float = 0.1
    name: str = "randtopk"

    def _mask(self, x, key, training):
        if not training:
            return selection.topk_mask(x, self.k, backend=self.backend)
        if key is None:
            raise ValueError("RandTopK.forward(training=True) needs a PRNG key")
        return selection.randtopk_mask(x, self.k, self.alpha, key,
                                       backend=self.backend)


@dataclasses.dataclass(frozen=True)
class RandTopKMask(RandTopK):
    """RandTopK with a mask-encoded wire format (Zhou et al. 2024,
    ROADMAP item 5): the u16 index stream is replaced by one packed d-bit
    support bitmask per instance, and the k values are shipped in
    ascending-index order (the mask's scan order). Wins over the
    u16-index sparse layout whenever k/d > 16/(32*16) = 1/16 per
    wire.table2_row("randtopk_mask"); selection semantics (Eq. 7) are
    identical to RandTopK, so accuracy is untouched."""

    name: str = "randtopk_mask"

    wire_kind = "mask"

    def encode(self, x, *, key=None, training=False):
        d = x.shape[-1]
        idx, mask = self._support(x, key, training)   # ascending order
        vals = jnp.take_along_axis(x, idx, axis=-1).astype(jnp.float32)
        words = selection.pack_mask_words(jax.lax.stop_gradient(mask))
        return Payload(meta=PayloadMeta("mask", d=d, k=idx.shape[-1]),
                       values=vals, indices=words)

    def _aux(self, p, x, training):
        return {"mask": selection.unpack_mask_words(p.indices, p.meta.d)}

    def fwd_bits(self, d):
        return self.k * FLOAT_BITS + 8 * ((d + 7) // 8)

    def bwd_bits(self, d):
        return self.k * FLOAT_BITS


def _quant_encode(x, bits: int):
    """Uniform quantization (Eq. 2) with per-instance [min, max] range.

    Returns (codes int32, header f32 (..., 2)); both stop-gradient.
    """
    xf = jax.lax.stop_gradient(x.astype(jnp.float32))
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    n_bins = 2 ** bits
    step = (hi - lo) / n_bins
    step = jnp.where(step <= 0, 1.0, step)
    code = jnp.clip(jnp.floor((xf - lo) / step), 0, n_bins - 1)
    return code.astype(jnp.int32), jnp.concatenate([lo, step], axis=-1)


@dataclasses.dataclass(frozen=True)
class Quantization(Compressor):
    """b-bit uniform quantization of the forward activation; backward is the
    full-precision gradient (paper applies quantization forward-only, with a
    straight-through estimator through the quantizer)."""

    bits: int = 4
    name: str = "quant"

    wire_kind = "quant"

    def encode(self, x, *, key=None, training=False):
        assert self.bits <= 8, "uint8 wire codes need bits <= 8"
        code, header = _quant_encode(x, self.bits)
        return Payload(meta=PayloadMeta("quant", d=x.shape[-1],
                                        bits=self.bits),
                       values=code.astype(jnp.uint8), header=header)

    def forward(self, x, *, key=None, training=False):
        p = self.encode(x, key=key, training=training)
        y = self.decode(p, shape=x.shape, dtype=x.dtype)
        return _ste(x, y), {}

    def fwd_bits(self, d):
        # codes + the (lo, step) range floats, amortized over the instance
        return d * self.bits + 2 * FLOAT_BITS

    def bwd_bits(self, d):
        return d * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class L1Reg(Compressor):
    """L1 regularization on the cut activation. Identity transport during
    training (+ penalty in the loss); at inference the wire carries the
    empirically non-zero support."""

    lam: float = 1e-3
    tol: float = 1e-6
    name: str = "l1"

    def encode(self, x, *, key=None, training=False):
        vals = x if training else x * (jnp.abs(x) > self.tol).astype(x.dtype)
        return Payload(meta=PayloadMeta("dense", d=x.shape[-1]),
                       values=vals.astype(jnp.float32))

    def _aux(self, p, x, training):
        if training:
            return {}
        return {"mask": jnp.abs(x) > self.tol}

    def loss_penalty(self, x):
        return self.lam * jnp.sum(jnp.abs(x.astype(jnp.float32))) / x.shape[0]

    def measured_fwd_bits(self, x) -> jax.Array:
        """Data-dependent compressed size (the paper reports its std)."""
        d = x.shape[-1]
        nnz = jnp.sum((jnp.abs(x) > self.tol).astype(jnp.float32), axis=-1)
        return nnz * (FLOAT_BITS + _index_bits(d))

    def fwd_bits(self, d):  # not statically known; report worst case
        return d * (FLOAT_BITS + _index_bits(d))

    def bwd_bits(self, d):
        return d * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class RandTopKQuant(RandTopK):
    """Beyond-paper: RandTopk + b-bit quantization of the surviving values
    (the combination the paper's conclusion names as promising future work).

    Wire: k codes of `bits` + k uint16 indices + per-instance (lo, step)
    header; at matched bytes this affords a ~(32+r)/(bits+r) times larger
    support k. Backward: gradient on the selected support, full precision
    (masked), STE through the value quantizer.
    """

    bits: int = 8
    name: str = "randtopk_quant"

    wire_kind = "sparse_quant"

    def encode(self, x, *, key=None, training=False):
        assert self.bits <= 8, "uint8 wire codes need bits <= 8"
        d = x.shape[-1]
        idx, _ = self._support(x, key, training)
        vals = jnp.take_along_axis(x, idx, axis=-1).astype(jnp.float32)
        # quantize using the range of the SELECTED values only (tighter bins)
        vals = jax.lax.stop_gradient(vals)
        lo = jnp.min(vals, axis=-1, keepdims=True)
        hi = jnp.max(vals, axis=-1, keepdims=True)
        n_bins = 2 ** self.bits
        step = jnp.where(hi > lo, (hi - lo) / n_bins, 1.0)
        code = jnp.clip(jnp.floor((vals - lo) / step), 0, n_bins - 1)
        return Payload(meta=PayloadMeta("sparse_quant", d=d,
                                        k=idx.shape[-1], bits=self.bits),
                       values=code.astype(jnp.uint8),
                       indices=idx.astype(jnp.uint16),
                       header=jnp.concatenate([lo, step], axis=-1))

    def _aux(self, p, x, training):
        return {"mask": selection.mask_from_indices(
            p.indices.astype(jnp.int32), p.meta.d)}

    def forward(self, x, *, key=None, training=False):
        p = self.encode(x, key=key, training=training)
        y = self.decode(p, shape=x.shape, dtype=x.dtype)
        aux = self._aux(p, x, training)
        maskf = jax.lax.stop_gradient(aux["mask"].astype(x.dtype))
        return _ste(x * maskf, y), aux   # STE on values, masked support

    def fwd_bits(self, d):
        return self.k * (self.bits + _index_bits(d)) + 2 * FLOAT_BITS

    def bwd_bits(self, d):
        return self.k * FLOAT_BITS


def make_compressor(spec: Optional[str], **kw) -> Compressor:
    """Factory: 'randtopk:k=8,alpha=0.1' style strings or kwargs."""
    if spec is None or spec == "none" or spec == "identity":
        return Compressor(**kw)
    if ":" in spec:
        name, args = spec.split(":", 1)
        for item in args.split(","):
            key, val = item.split("=")
            kw.setdefault(key, float(val) if "." in val else int(val))
    else:
        name = spec
    table = {
        "size_reduction": SizeReduction,
        "topk": TopK,
        "randtopk": RandTopK,
        "randtopk_mask": RandTopKMask,
        "quant": Quantization,
        "l1": L1Reg,
        "randtopk_quant": RandTopKQuant,
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}")
    return table[name](**kw)
