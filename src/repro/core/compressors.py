"""Cut-layer compressors for split learning (paper Sections 3-4).

Each compressor is a frozen config object with a functional interface:

    y, aux = comp.forward(x, key=key, training=True)

`x` is the cut-layer activation `(..., d)`; `y` is the label-owner-side view
(dense, with zeros in dropped slots, or dequantized values); `aux` carries
whatever the backward pass and the wire-format need (mask / indices / scale).

Backward semantics follow the paper exactly:
  * size-reduction / top-k / randtopk: the gradient is masked with the SAME
    support that was used in the forward pass (the label owner sends only the
    k gradient values; indices are already known to the feature owner).
    Realized naturally by autodiff through `x * stop_gradient(mask)`.
  * quantization: forward quantize-dequantize; the backward gradient is sent
    uncompressed, and the chain through the quantizer is the straight-through
    estimator (identity), via jax.custom_vjp.
  * L1: identity at training time + a `loss_penalty(x)` term; at inference the
    support is the empirically-nonzero set (|x| > tol after training shrinks
    activations toward zero).

Compression ratios are reported by `fwd_bits`/`bwd_bits` (Table 2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import selection

FLOAT_BITS = 32  # N in the paper


def _index_bits(d: int) -> int:
    return max(1, math.ceil(math.log2(d)))


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: identity (vanilla split learning, 'No compression')."""

    name: str = "identity"

    def forward(self, x, *, key=None, training=False):
        return x, {}

    def loss_penalty(self, x):
        return jnp.zeros((), dtype=jnp.float32)

    # -- wire accounting (bits per instance of dimension d) ------------------
    def fwd_bits(self, d: int) -> float:
        return d * FLOAT_BITS

    def bwd_bits(self, d: int) -> float:
        return d * FLOAT_BITS

    def compressed_size(self, d: int) -> float:
        """Mean of forward+backward relative compressed size (inference uses
        fwd only; Table 2 reports the two separately — see wire.table2_row)."""
        return 0.5 * (self.fwd_bits(d) + self.bwd_bits(d)) / (d * FLOAT_BITS)


@dataclasses.dataclass(frozen=True)
class SizeReduction(Compressor):
    """Keep the first k features (mask-based cut-layer slimming, Eq. 1)."""

    k: int = 8
    name: str = "size_reduction"

    def forward(self, x, *, key=None, training=False):
        d = x.shape[-1]
        mask = jnp.arange(d) < self.k
        mask = jnp.broadcast_to(mask, x.shape)
        y = x * jax.lax.stop_gradient(mask.astype(x.dtype))
        return y, {"mask": mask}

    def fwd_bits(self, d):
        return self.k * FLOAT_BITS

    def bwd_bits(self, d):
        return self.k * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Magnitude top-k sparsification (Eq. 3)."""

    k: int = 8
    name: str = "topk"

    def _mask(self, x, key, training):
        return selection.topk_mask(x, self.k)

    def forward(self, x, *, key=None, training=False):
        mask = self._mask(x, key, training)
        y = x * jax.lax.stop_gradient(mask.astype(x.dtype))
        return y, {"mask": mask}

    def fwd_bits(self, d):
        return self.k * (FLOAT_BITS + _index_bits(d))

    def bwd_bits(self, d):
        # feature owner already holds the indices
        return self.k * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class RandTopK(TopK):
    """Randomized top-k sparsification — the paper's contribution (Eq. 7).

    alpha=0 -> TopK; alpha=1 -> Dropout-like. Randomness only in training.
    """

    alpha: float = 0.1
    name: str = "randtopk"

    def _mask(self, x, key, training):
        if not training:
            return selection.topk_mask(x, self.k)
        if key is None:
            raise ValueError("RandTopK.forward(training=True) needs a PRNG key")
        return selection.randtopk_mask(x, self.k, self.alpha, key)


def _quant_fwd(x, bits: int):
    """Uniform quantization (Eq. 2) with per-instance [min, max] range."""
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    n_bins = 2 ** bits
    step = (hi - lo) / n_bins
    step = jnp.where(step <= 0, 1.0, step)
    code = jnp.clip(jnp.floor((xf - lo) / step), 0, n_bins - 1)
    deq = lo + (code + 0.5) * step
    return deq.astype(x.dtype), code.astype(jnp.int32), lo, step


@jax.custom_vjp
def _quant_ste(x, bits: int):
    return _quant_fwd(x, bits)[0]


def _quant_ste_fwd(x, bits):
    return _quant_ste(x, bits), None


def _quant_ste_bwd(_, g):
    return (g, None)


_quant_ste.defvjp(_quant_ste_fwd, _quant_ste_bwd)


@dataclasses.dataclass(frozen=True)
class Quantization(Compressor):
    """b-bit uniform quantization of the forward activation; backward is the
    full-precision gradient (paper applies quantization forward-only)."""

    bits: int = 4
    name: str = "quant"

    def forward(self, x, *, key=None, training=False):
        y = _quant_ste(x, self.bits)
        return y, {}

    def fwd_bits(self, d):
        # codes + the (lo, step) range floats, amortized over the instance
        return d * self.bits + 2 * FLOAT_BITS

    def bwd_bits(self, d):
        return d * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class L1Reg(Compressor):
    """L1 regularization on the cut activation. Identity transport during
    training (+ penalty in the loss); at inference the wire carries the
    empirically non-zero support."""

    lam: float = 1e-3
    tol: float = 1e-6
    name: str = "l1"

    def forward(self, x, *, key=None, training=False):
        if training:
            return x, {}
        mask = jnp.abs(x) > self.tol
        return x * mask.astype(x.dtype), {"mask": mask}

    def loss_penalty(self, x):
        return self.lam * jnp.sum(jnp.abs(x.astype(jnp.float32))) / x.shape[0]

    def measured_fwd_bits(self, x) -> jax.Array:
        """Data-dependent compressed size (the paper reports its std)."""
        d = x.shape[-1]
        nnz = jnp.sum((jnp.abs(x) > self.tol).astype(jnp.float32), axis=-1)
        return nnz * (FLOAT_BITS + _index_bits(d))

    def fwd_bits(self, d):  # not statically known; report worst case
        return d * (FLOAT_BITS + _index_bits(d))

    def bwd_bits(self, d):
        return d * FLOAT_BITS


@dataclasses.dataclass(frozen=True)
class RandTopKQuant(RandTopK):
    """Beyond-paper: RandTopk + b-bit quantization of the surviving values
    (the combination the paper's conclusion names as promising future work).

    Wire: k codes of `bits` + k indices + per-instance (lo, step) header;
    at matched bytes this affords a ~(32+r)/(bits+r) times larger support k.
    Backward: gradient on the selected support, full precision (masked),
    STE through the value quantizer.
    """

    bits: int = 8
    name: str = "randtopk_quant"

    def forward(self, x, *, key=None, training=False):
        mask = self._mask(x, key, training)
        maskf = jax.lax.stop_gradient(mask.astype(x.dtype))
        # quantize using the range of the SELECTED values only (tighter bins)
        sel = jnp.where(mask, x, jnp.nan)
        lo = jnp.nanmin(sel.astype(jnp.float32), axis=-1, keepdims=True)
        hi = jnp.nanmax(sel.astype(jnp.float32), axis=-1, keepdims=True)
        n_bins = 2 ** self.bits
        step = jnp.where(hi > lo, (hi - lo) / n_bins, 1.0)
        code = jnp.clip(jnp.floor((x.astype(jnp.float32) - lo) / step),
                        0, n_bins - 1)
        deq = (lo + (code + 0.5) * step).astype(x.dtype)
        y = jax.lax.stop_gradient(deq - x) + x        # STE on values
        return y * maskf, {"mask": mask}

    def fwd_bits(self, d):
        return self.k * (self.bits + _index_bits(d)) + 2 * FLOAT_BITS

    def bwd_bits(self, d):
        return self.k * FLOAT_BITS


def make_compressor(spec: Optional[str], **kw) -> Compressor:
    """Factory: 'randtopk:k=8,alpha=0.1' style strings or kwargs."""
    if spec is None or spec == "none" or spec == "identity":
        return Compressor()
    if ":" in spec:
        name, args = spec.split(":", 1)
        for item in args.split(","):
            key, val = item.split("=")
            kw.setdefault(key, float(val) if "." in val else int(val))
    else:
        name = spec
    table = {
        "size_reduction": SizeReduction,
        "topk": TopK,
        "randtopk": RandTopK,
        "quant": Quantization,
        "l1": L1Reg,
        "randtopk_quant": RandTopKQuant,
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}")
    return table[name](**kw)
