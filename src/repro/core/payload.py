"""Typed cut-layer payload — the single object that crosses the wire.

A `Payload` is a pytree of device arrays already in their *wire* dtypes:
float32 values, uint8 quantization codes, uint16 support indices, and a
per-instance float32 `(lo, step)` range header. It is produced by
`Compressor.encode`, moved leaf-by-leaf across the pod boundary by
`split.protocol`, reconstructed to a dense activation by `Compressor.decode`,
and serialized bit-exactly by `core.wire.encode_payload`. Every byte count
the repo reports (Table 2 analytic formulas, roofline collective bytes,
measured socket bytes) is derived from this one object, so the three can be
cross-checked against each other.

Payload kinds:

  dense        values f32 (..., d)                    identity / L1
  slice        values f32 (..., k)                    size reduction (first k)
  sparse       values f32 (..., k) + indices u16      top-k / randtop-k
  quant        codes  u8  (..., d) + header f32 (..,2)  uniform quantization
  sparse_quant codes  u8  (..., k) + indices u16
               + header f32 (..., 2)                  randtopk + quant
  mask         values f32 (..., k) + indices u32      randtopk, mask-encoded
               (indices = packed d-bit support bitmask, ceil(d/32) words;
               values in ascending-index order — Zhou et al. 2024)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax

# "mask" is appended last: the wire subheader serializes the kind as its
# index into this tuple, so insertion anywhere else would re-number the
# historical kinds and break every golden frame.
KINDS = ("dense", "slice", "sparse", "quant", "sparse_quant", "mask")

#: wire-leaf field names, in transfer order
WIRE_FIELDS = ("values", "indices", "header")


@dataclasses.dataclass(frozen=True)
class PayloadMeta:
    """Static (hashable) payload descriptor; rides along as pytree metadata."""

    kind: str                       # one of KINDS
    d: int                          # dense feature width of the decoded view
    k: int = 0                      # support size (slice/sparse kinds)
    bits: int = 0                   # code width (quant kinds)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown payload kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class Payload:
    """Pytree of wire-dtype device arrays + static meta.

    `values` carries f32 values (dense/slice/sparse/mask) or u8 codes (quant
    kinds); `indices` the u16 support (sparse kinds) or the packed u32
    bitmask words (mask kind); `header` the f32 per-instance `(lo, step)`
    quantization range (quant kinds).
    """

    meta: PayloadMeta
    values: Any
    indices: Optional[Any] = None
    header: Optional[Any] = None

    # -- wire-leaf access ----------------------------------------------------
    def wire_leaves(self) -> Tuple[Tuple[str, Any], ...]:
        """(name, array) pairs of the leaves that actually cross the wire."""
        return tuple((f, getattr(self, f)) for f in WIRE_FIELDS
                     if getattr(self, f) is not None)

    def with_leaves(self, **leaves) -> "Payload":
        return dataclasses.replace(self, **leaves)

    def device_nbytes(self) -> int:
        """Bytes of the device-resident (byte-aligned) representation.

        The bit-packed socket size is `wire.payload_nbytes`; this is the
        upper bound the TPU fabric actually moves (no sub-byte addressing).
        """
        return sum(int(a.size) * a.dtype.itemsize for _, a in
                   self.wire_leaves())

    @property
    def batch_shape(self):
        return self.values.shape[:-1]


jax.tree_util.register_dataclass(
    Payload, data_fields=list(WIRE_FIELDS), meta_fields=["meta"])
