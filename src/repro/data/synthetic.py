"""Synthetic many-class classification datasets for the paper-scale
experiments (offline stand-in for CIFAR-100 / DBPedia / Tiny-ImageNet).

Construction: each class c gets a fixed random template t_c in R^{in_dim};
a sample is `rotate(t_c) + noise` pushed through a fixed random nonlinear
mixing layer, which makes the task non-linearly-separable (an MLP must learn
real features) while keeping difficulty controllable via `noise`.
The generator is deterministic in (seed, n_classes, dims).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ManyClassDataset:
    n_classes: int = 100
    in_dim: int = 64
    n_train: int = 20000
    n_test: int = 4000
    noise: float = 0.9
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        self.templates = rng.randn(self.n_classes, self.in_dim).astype(np.float32)
        self.templates /= np.linalg.norm(self.templates, axis=1, keepdims=True)
        self.mix_w = (rng.randn(self.in_dim, self.in_dim) /
                      np.sqrt(self.in_dim)).astype(np.float32)
        self.mix_b = (0.1 * rng.randn(self.in_dim)).astype(np.float32)
        self.x_train, self.y_train = self._make(rng, self.n_train)
        self.x_test, self.y_test = self._make(rng, self.n_test)

    def _make(self, rng, n):
        y = rng.randint(0, self.n_classes, size=n)
        base = self.templates[y]
        x = base + self.noise * rng.randn(n, self.in_dim).astype(np.float32)
        x = np.tanh(x @ self.mix_w + self.mix_b)  # fixed nonlinear mixing
        return x.astype(np.float32), y.astype(np.int32)

    def batches(self, batch_size: int, *, rng: np.random.RandomState):
        idx = rng.permutation(self.n_train)
        for i in range(0, self.n_train - batch_size + 1, batch_size):
            sel = idx[i: i + batch_size]
            yield self.x_train[sel], self.y_train[sel]
