"""Deterministic synthetic token pipeline with device-sharded global batches.

Real deployments plug a tokenized corpus in here; the contract is only that
`next_batch(step)` returns the per-step global batch dict, deterministically
derived from (seed, step) so every host computes its own shard without
coordination — the standard multi-pod data-loading pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig, Runtime


def make_lm_batch(key, cfg: ArchConfig, batch: int, seq: int) -> Dict:
    """Markov-ish synthetic LM data: tokens with learnable local structure."""
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (batch, seq), 0, cfg.vocab, dtype=jnp.int32)
    # inject copy structure so the loss is reducible: every even position
    # repeats the previous token with high probability
    coin = jax.random.bernoulli(k2, 0.7, (batch, seq))
    shifted = jnp.roll(base, 1, axis=1)
    tokens = jnp.where(coin, shifted, base).astype(jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    out = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            jax.random.fold_in(key, 7),
            (batch, cfg.n_image_tokens, cfg.d_model), cfg.adtype()) * 0.02
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            jax.random.fold_in(key, 8),
            (batch, cfg.n_frames, cfg.d_model), cfg.adtype()) * 0.02
    return out


@dataclasses.dataclass
class TokenPipeline:
    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    rt: Optional[Runtime] = None

    def next_batch(self, step: int) -> Dict:
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        b = make_lm_batch(key, self.cfg, self.batch, self.seq)
        if self.rt is not None and self.rt.mesh is not None:
            b = {k: self.rt.shard(v, "batch", *([None] * (v.ndim - 1)))
                 for k, v in b.items()}
        return b
