from repro.data.pipeline import TokenPipeline, make_lm_batch
from repro.data.synthetic import ManyClassDataset

__all__ = ["TokenPipeline", "make_lm_batch", "ManyClassDataset"]
