"""repro: Randomized Top-k Sparsification for Split Learning (IJCAI'23) —
a production-grade JAX training/inference framework with cut-layer
compression as a first-class feature."""
__version__ = "1.0.0"
