"""Pytree checkpointing: flat-key npz files with dtype/shape fidelity.

Single-file-per-step layout; multi-host deployments write per-process shards
(`proc{n}` suffix) — here process count is 1 so there is one shard.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)  # exact widening; restore re-narrows
        flat[key] = arr
    return flat


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as data:
        flat = dict(data)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_keys, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        arr = flat[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def latest_step(ckpt_dir: str) -> int:
    if not os.path.isdir(ckpt_dir):
        return -1
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else -1
