"""Chrome-trace-event JSON export + validation for `obs.trace` tracers.

`chrome_trace(tracer)` turns a tracer's raw events (ts/dur in clock
seconds) into the Chrome trace-event "JSON object format": a dict with a
`traceEvents` list whose entries carry `ph` ("X" complete span, "i"
instant, "M" metadata), microsecond `ts`/`dur`, and `pid`/`tid` track
coordinates. The output loads directly in Perfetto (https://ui.perfetto.dev)
or `chrome://tracing` — see docs/observability.md for the how-to.

Determinism contract: `dump_json` emits sorted keys, compact separators,
and microsecond stamps rounded to 3 decimals, so a tracer driven by a
`VirtualClock` over a seeded run serializes to byte-identical files across
runs. `tests/test_obs.py` and `scripts/trace_smoke.py` pin this.

`validate_chrome_trace` is the schema check CI runs against emitted files;
`check_span_nesting` asserts the laminar-family property (any two spans on
one track are either disjoint or properly nested) that makes the trace
readable as a flame graph.
"""
from __future__ import annotations

import json
from typing import List, Tuple

_REQUIRED = ("name", "ph", "ts", "pid", "tid")
_PHASES = ("X", "i", "M")


def chrome_trace(tracer) -> dict:
    """Chrome trace-event object for `tracer` (µs timestamps)."""
    events = []
    for evt in tracer.events():
        out = dict(evt)
        ts = round(out["ts"] * 1e6, 3)
        out["ts"] = ts
        if "dur" in out:
            # derive dur from the ROUNDED endpoints: abutting spans (one's
            # end is the next's start) must stay abutting after rounding,
            # or the nesting check would see phantom sub-µs straddles
            t1 = round((evt["ts"] + out["dur"]) * 1e6, 3)
            out["dur"] = max(0.0, round(t1 - ts, 3))
        events.append(out)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump_json(tracer) -> str:
    """Deterministic serialization of `chrome_trace(tracer)`."""
    return json.dumps(chrome_trace(tracer), sort_keys=True,
                      separators=(",", ":")) + "\n"


def write_trace(tracer, path) -> int:
    """Write the Chrome-trace JSON to `path`; returns the event count."""
    text = dump_json(tracer)
    with open(path, "w") as f:
        f.write(text)
    return len(tracer.events())


def validate_chrome_trace(obj) -> List[str]:
    """Schema problems in a parsed Chrome-trace object ([] when clean).

    Checks the subset of the trace-event format this repo emits and
    Perfetto requires: the `traceEvents` wrapper, per-event required
    fields, known phases, numeric non-negative ts/dur, and instant events
    carrying a scope.
    """
    problems: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["missing top-level 'traceEvents' object"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    for i, evt in enumerate(events):
        if not isinstance(evt, dict):
            problems.append(f"event {i}: not an object")
            continue
        missing = [f for f in _REQUIRED if f not in evt]
        if missing:
            problems.append(f"event {i}: missing fields {missing}")
            continue
        ph = evt["ph"]
        if ph not in _PHASES:
            problems.append(f"event {i}: unknown phase {ph!r}")
        if not isinstance(evt["ts"], (int, float)) or evt["ts"] < 0:
            problems.append(f"event {i}: bad ts {evt['ts']!r}")
        if ph == "X":
            dur = evt.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X event bad dur {dur!r}")
        if ph == "i" and evt.get("s") not in ("t", "p", "g"):
            problems.append(f"event {i}: instant missing scope 's'")
        if ph == "M" and "args" not in evt:
            problems.append(f"event {i}: metadata event missing args")
    return problems


def check_span_nesting(events) -> List[str]:
    """Well-formedness of span intervals per (pid, tid) track.

    Any two "X" spans sharing a track must be disjoint or properly nested
    (the laminar-family property a flame graph needs). Spans in a trace
    arrive unordered, so sort by (start, -end) and sweep with a stack.
    Returns human-readable violations ([] when well-formed).

    Comparisons tolerate half the 0.001 µs export quantum: endpoints are
    quantized by `chrome_trace`, and `ts + dur` on wall-clock-sized µs
    stamps (~1e10) carries float error far below the quantum but above
    exact equality — abutting spans must not read as straddling.
    """
    eps = 5e-4
    tracks: dict = {}
    for evt in events:
        if evt.get("ph") != "X":
            continue
        key = (evt.get("pid", 0), evt.get("tid", 0))
        t0 = evt["ts"]
        tracks.setdefault(key, []).append((t0, t0 + evt.get("dur", 0.0),
                                           evt.get("name", "?")))
    problems: List[str] = []
    for key, spans in sorted(tracks.items()):
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: List[Tuple[float, float, str]] = []
        for t0, t1, name in spans:
            while stack and stack[-1][1] <= t0 + eps:
                stack.pop()
            if stack and t1 > stack[-1][1] + eps:
                problems.append(
                    f"track {key}: span {name!r} [{t0}, {t1}] straddles "
                    f"{stack[-1][2]!r} [{stack[-1][0]}, {stack[-1][1]}]")
                continue
            stack.append((t0, t1, name))
    return problems
