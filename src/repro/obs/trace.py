"""Frame-lifecycle tracing — nestable spans, instant events, Chrome export.

A `Tracer` records what the serving/training stack *did* as a flat list of
events that `obs.export` serializes into Chrome-trace-event JSON (loadable
in Perfetto or `chrome://tracing`). Two event shapes cover everything the
runtime needs:

  * spans — an interval with a name, a track, and args. Emitted either via
    the `span()` context manager (timestamps read from the injected
    `testing.clock.Clock` on entry/exit) or via `complete()` with explicit
    start/end times (how the server turns "this frame was enqueued at t0
    and flushed at t1" into a `server.queue_wait` span without the tracer
    ever blocking anything);
  * instants — a point event (`instant()`): QoS rung moves, ARQ
    retransmits/reconnects, admission rejections, slot admit/evict.

Time is *injected*: a tracer built over a `VirtualClock` (the loadgen
co-simulation) stamps virtual seconds, so two runs at the same seed write
byte-identical trace files — the determinism `tests/test_obs.py` pins,
clean and under `FaultInjector` chaos. Under the default `SystemClock` the
stamps are wall monotonic time and the trace shows real durations.

Tracks: Chrome traces group events by (pid, tid). The runtime's convention
(docs/observability.md) puts the serve loop on tid `SERVE_TID` (0) and each
session on `session_tid(sid)` = sid + 1, so one session's whole lifecycle —
encode, send, queue wait, accept, plus its QoS/ARQ instants — reads as one
horizontal track in Perfetto, with the server's decode/step/reply spans on
the serve track above it. Events emitted without an explicit `tid` get a
stable per-thread id (assigned in first-use order, offset far above any
session track).

The disabled default is `NULL_TRACER`: every method is a no-op and `span()`
returns a single reusable null context manager, so an uninstrumented hot
path pays one attribute check (`tracer.enabled`) or one empty call. The
overhead is measured and gated in `benchmarks/serve_throughput.py` (the
`obs` section of BENCH_serve.json: tracing-on/off throughput ratio).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, TYPE_CHECKING

if TYPE_CHECKING:    # deferred at runtime: `repro.testing.__init__` pulls
    # in `testing.faults` -> `runtime.transport` -> `runtime.server`, and
    # importing that chain from here would re-enter a partially-initialized
    # `repro.obs` when obs is the first repro package imported
    from repro.testing.clock import Clock

# -- span taxonomy (docs/observability.md) ------------------------------------
# the seven frame-lifecycle stages, in wire order
SPAN_CLIENT_ENCODE = "client.encode"    # bottom step + payload pull to host
SPAN_WIRE_SEND = "client.send"          # framing + uplink transmission
SPAN_QUEUE_WAIT = "server.queue_wait"   # enqueue -> flush pickup
SPAN_DECODE = "server.decode"           # host staging + device decode
SPAN_STEP = "server.step"               # donated arena / fused top step
SPAN_REPLY = "server.reply"             # token framing + downlink send
SPAN_ARQ_ACCEPT = "client.arq_accept"   # reply classified + accepted by ARQ

LIFECYCLE_SPANS = (SPAN_CLIENT_ENCODE, SPAN_WIRE_SEND, SPAN_QUEUE_WAIT,
                   SPAN_DECODE, SPAN_STEP, SPAN_REPLY, SPAN_ARQ_ACCEPT)

# instant events
EVT_QOS_TRANSITION = "qos.transition"   # (k, bits) rung move
EVT_ARQ_RETRANSMIT = "arq.retransmit"   # timeout/error-triggered replay
EVT_ARQ_RECONNECT = "arq.reconnect"     # fresh connection onto the session
EVT_ADMISSION_REJECT = "admission.reject"   # arrival turned away
EVT_SLOT_ADMIT = "slot.admit"           # session pinned to an arena slot
EVT_SLOT_EVICT = "slot.evict"           # closed session's slot reclaimed

INSTANT_EVENTS = (EVT_QOS_TRANSITION, EVT_ARQ_RETRANSMIT, EVT_ARQ_RECONNECT,
                  EVT_ADMISSION_REJECT, EVT_SLOT_ADMIT, EVT_SLOT_EVICT)

#: the serve loop's track; sessions live on `session_tid(sid)`
SERVE_TID = 0
#: auto-assigned per-thread tracks start here, clear of any session id
_THREAD_TID_BASE = 1_000_000


def session_tid(sid: int) -> int:
    """Track id of session `sid` — one Perfetto row per session."""
    return sid + 1


class _NullSpan:
    """Reusable no-op context manager (`NULL_TRACER.span(...)` result)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer — the default everywhere. All methods are no-ops;
    hot paths additionally guard arg construction on `tracer.enabled`."""

    enabled = False

    def span(self, name: str, *, cat: str = "lifecycle",
             tid: Optional[int] = None, **args):
        return _NULL_SPAN

    def complete(self, name: str, t0: float, t1: float, *,
                 cat: str = "lifecycle", tid: Optional[int] = None,
                 **args) -> None:
        pass

    def instant(self, name: str, *, cat: str = "event",
                tid: Optional[int] = None, **args) -> None:
        pass

    def name_track(self, tid: int, name: str) -> None:
        pass

    def events(self) -> List[dict]:
        return []


#: process-wide disabled tracer; components default to this
NULL_TRACER = NullTracer()


class _Span:
    """Context manager emitted by `Tracer.span` — stamps entry/exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_tid", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 tid: Optional[int], args: dict):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._tid = tid
        self._args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = self._tracer._clock.monotonic()
        return self

    def __exit__(self, *exc):
        self._tracer.complete(self._name, self._t0,
                              self._tracer._clock.monotonic(),
                              cat=self._cat, tid=self._tid, **self._args)
        return False


class Tracer:
    """Collects span/instant events against an injected clock.

    Thread-safe: the threaded runtime appends from reader threads, client
    threads, and the serve loop; the single-threaded loadgen appends in
    event-loop order (which, with a `VirtualClock`, makes the exported
    JSON a deterministic function of the seed).
    """

    enabled = True

    def __init__(self, clock: Optional["Clock"] = None, *, pid: int = 0):
        if clock is None:
            from repro.testing.clock import SYSTEM_CLOCK
            clock = SYSTEM_CLOCK
        self._clock = clock
        self.pid = pid
        self._events: List[dict] = []
        self._lock = threading.Lock()
        self._thread_tids: Dict[int, int] = {}
        self._named_tracks: Dict[int, str] = {}

    # -- emission ------------------------------------------------------------

    def span(self, name: str, *, cat: str = "lifecycle",
             tid: Optional[int] = None, **args) -> _Span:
        """Nestable span: stamps the clock on enter and exit."""
        return _Span(self, name, cat, tid, args)

    def complete(self, name: str, t0: float, t1: float, *,
                 cat: str = "lifecycle", tid: Optional[int] = None,
                 **args) -> None:
        """Explicitly-timed span [t0, t1] — for intervals whose endpoints
        were observed elsewhere (queue wait, modeled service time)."""
        evt = {"name": name, "cat": cat, "ph": "X", "pid": self.pid,
               "tid": self._resolve_tid(tid), "ts": t0,
               "dur": max(0.0, t1 - t0)}
        if args:
            evt["args"] = args
        with self._lock:
            self._events.append(evt)

    def instant(self, name: str, *, cat: str = "event",
                tid: Optional[int] = None, **args) -> None:
        evt = {"name": name, "cat": cat, "ph": "i", "s": "t",
               "pid": self.pid, "tid": self._resolve_tid(tid),
               "ts": self._clock.monotonic()}
        if args:
            evt["args"] = args
        with self._lock:
            self._events.append(evt)

    def name_track(self, tid: int, name: str) -> None:
        """Label a (pid, tid) track — rendered as the row name in Perfetto.
        Idempotent: the first name wins."""
        with self._lock:
            if tid in self._named_tracks:
                return
            self._named_tracks[tid] = name
            self._events.append({"name": "thread_name", "ph": "M",
                                 "pid": self.pid, "tid": tid, "ts": 0.0,
                                 "args": {"name": name}})

    # -- inspection ----------------------------------------------------------

    def events(self) -> List[dict]:
        """Snapshot of the raw event list (ts/dur in clock seconds)."""
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    # -- internals -----------------------------------------------------------

    def _resolve_tid(self, tid: Optional[int]) -> int:
        if tid is not None:
            return tid
        ident = threading.get_ident()
        with self._lock:
            got = self._thread_tids.get(ident)
            if got is None:
                got = _THREAD_TID_BASE + len(self._thread_tids)
                self._thread_tids[ident] = got
            return got
