"""Unified metrics registry — labeled counters, gauges, P²-backed histograms.

One surface for every number the runtime keeps: `SessionStats` byte
accounting, the fault/duplicate/replay counters previously summed ad hoc by
`engine.fault_summary`, `protocol.HOST_DENSIFY_COUNT`, QoS rung switches,
admission rejections, slot churn. A metric is (name, labels) → instrument:

    reg = MetricsRegistry()
    reg.counter("frames_total", party="client", direction="up").inc()
    reg.gauge("queue_depth").set(5)
    reg.histogram("token_latency_ms").observe(12.5)

Counters only go up; gauges are set; histograms feed the existing
streaming `P2Quantile` estimators (`runtime/metrics.py`) at fixed
quantiles, so a histogram is O(1) memory no matter how many observations —
the same trick `LatencyStats` uses at fleet scale.

`snapshot()` returns a plain nested dict (deterministic key order — safe
to embed in loadgen's seeded reports), `render_text()` a Prometheus-style
text form (`name{k="v"} value`, sorted lines) for periodic dumps during
long runs. Metric names and label conventions are cataloged in
docs/observability.md.

`DEFAULT_REGISTRY` is the process-wide instance; globals with no run
context (the host-densify guard-rail counter in `split/protocol.py`) land
there. Run harnesses (`engine.run_streaming`, `loadgen.run_loadgen`,
`fedtrain`) build a fresh registry per run so reports stay isolated and
deterministic.

The `P2Quantile` import is deferred into `Histogram` on purpose:
`split/protocol.py` imports this module at import time, and a top-level
import of `repro.runtime.metrics` from here would re-enter
`repro.runtime.__init__` → `runtime.server` → `split.protocol` while the
latter is still half-initialized.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

#: default quantiles tracked per histogram (matches `LatencyStats`)
HIST_QS = (0.50, 0.95, 0.99)


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing count (float increments allowed for bytes)."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    @property
    def value(self):
        return self._value


class Gauge:
    """A value that can move both ways (queue depth, QoS rung, occupancy)."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    def inc(self, amount: float = 1) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self):
        return self._value


class Histogram:
    """Streaming distribution: count/sum/min/max plus P² quantile markers.

    Memory is O(len(qs)); `quantile(q)` is exact below 5 observations
    (P² warm-up keeps raw samples) and an estimate after.
    """

    __slots__ = ("_qs", "_p2", "_n", "_sum", "_min", "_max", "_lock")

    def __init__(self, qs: Iterable[float] = HIST_QS):
        # deferred: see module docstring (protocol -> obs import chain)
        from repro.runtime.metrics import P2Quantile
        self._qs = tuple(qs)
        self._p2 = {q: P2Quantile(q) for q in self._qs}
        self._n = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, x: float) -> None:
        with self._lock:
            self._n += 1
            self._sum += x
            if x < self._min:
                self._min = x
            if x > self._max:
                self._max = x
            for p2 in self._p2.values():
                p2.add(x)

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._n if self._n else float("nan")

    def quantile(self, q: float) -> float:
        return self._p2[q].value()

    def summary(self) -> dict:
        with self._lock:
            out = {"count": self._n, "sum": self._sum}
            if self._n:
                out["min"] = self._min
                out["max"] = self._max
                out["mean"] = self._sum / self._n
            for q in self._qs:
                out[f"p{int(q * 100)}"] = self._p2[q].value()
            return out


class MetricsRegistry:
    """Get-or-create store of labeled instruments.

    A (name, labels) pair always resolves to the same instrument; asking
    for the same name with a different instrument kind is an error (it
    would silently fork the metric).
    """

    def __init__(self):
        self._lock = threading.Lock()
        # name -> (kind, {label_key -> instrument})
        self._metrics: Dict[str, Tuple[str, Dict[LabelKey, object]]] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, object],
             factory):
        key = _label_key(labels)
        with self._lock:
            entry = self._metrics.get(name)
            if entry is None:
                entry = (kind, {})
                self._metrics[name] = entry
            elif entry[0] != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {entry[0]}, "
                    f"requested as {kind}")
            inst = entry[1].get(key)
            if inst is None:
                inst = factory()
                entry[1][key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, qs: Iterable[float] = HIST_QS,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels, lambda: Histogram(qs))

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        """Nested dict, deterministic order: name -> [{labels, ...value}]."""
        with self._lock:
            items = [(name, kind, dict(series))
                     for name, (kind, series) in self._metrics.items()]
        out = {}
        for name, kind, series in sorted(items):
            rows = []
            for key in sorted(series):
                inst = series[key]
                row: dict = {"labels": dict(key)} if key else {"labels": {}}
                if kind == "histogram":
                    row.update(inst.summary())
                else:
                    row["value"] = inst.value
                rows.append(row)
            out[name] = {"kind": kind, "series": rows}
        return out

    def render_text(self) -> str:
        """Prometheus-style lines, sorted: `name{k="v",...} value`."""
        lines: List[str] = []
        snap = self.snapshot()
        for name, metric in snap.items():
            for row in metric["series"]:
                base = name
                labels = row["labels"]
                if metric["kind"] == "histogram":
                    for field, val in sorted(row.items()):
                        if field == "labels":
                            continue
                        lines.append(_line(f"{name}_{field}", labels, val))
                else:
                    lines.append(_line(base, labels, row["value"]))
        return "\n".join(lines) + ("\n" if lines else "")


def _line(name: str, labels: Dict[str, str], value) -> str:
    if labels:
        body = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
        return f"{name}{{{body}}} {value}"
    return f"{name} {value}"


#: process-wide registry for context-free globals (e.g. host-densify);
#: per-run harnesses construct their own instead of using this
DEFAULT_REGISTRY = MetricsRegistry()
