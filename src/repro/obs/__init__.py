"""Observability layer: frame-lifecycle tracing + unified metrics registry.

Three pieces (docs/observability.md is the catalog):

  * `obs.trace` — `Tracer`/`NULL_TRACER`, span taxonomy for the seven
    frame-lifecycle stages and the QoS/ARQ/admission/slot instant events;
  * `obs.registry` — `MetricsRegistry` of labeled counters/gauges/
    P²-backed histograms with text/dict export;
  * `obs.export` — Chrome-trace-event JSON (Perfetto-loadable) writer and
    the schema/nesting validators CI runs.
"""
from repro.obs.registry import (Counter, DEFAULT_REGISTRY, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.trace import (EVT_ADMISSION_REJECT, EVT_ARQ_RECONNECT,
                             EVT_ARQ_RETRANSMIT, EVT_QOS_TRANSITION,
                             EVT_SLOT_ADMIT, EVT_SLOT_EVICT, INSTANT_EVENTS,
                             LIFECYCLE_SPANS, NULL_TRACER, NullTracer,
                             SERVE_TID, SPAN_ARQ_ACCEPT, SPAN_CLIENT_ENCODE,
                             SPAN_DECODE, SPAN_QUEUE_WAIT, SPAN_REPLY,
                             SPAN_STEP, SPAN_WIRE_SEND, Tracer, session_tid)
from repro.obs.export import (chrome_trace, check_span_nesting, dump_json,
                              validate_chrome_trace, write_trace)

__all__ = [
    "Counter", "DEFAULT_REGISTRY", "Gauge", "Histogram", "MetricsRegistry",
    "EVT_ADMISSION_REJECT", "EVT_ARQ_RECONNECT", "EVT_ARQ_RETRANSMIT",
    "EVT_QOS_TRANSITION", "EVT_SLOT_ADMIT", "EVT_SLOT_EVICT",
    "INSTANT_EVENTS", "LIFECYCLE_SPANS", "NULL_TRACER", "NullTracer",
    "SERVE_TID", "SPAN_ARQ_ACCEPT", "SPAN_CLIENT_ENCODE", "SPAN_DECODE",
    "SPAN_QUEUE_WAIT", "SPAN_REPLY", "SPAN_STEP", "SPAN_WIRE_SEND",
    "Tracer", "session_tid", "chrome_trace", "check_span_nesting",
    "dump_json", "validate_chrome_trace", "write_trace",
]
