"""Three-term roofline model from a compiled dry-run artifact.

    compute   = HLO_FLOPs / (chips * peak_FLOPs)
    memory    = HLO_bytes / (chips * HBM_bw)
    collective= collective_link_bytes / (chips * link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

cost_analysis() FLOPs/bytes on the host backend are whole-program (all
partitions) for the replicated program: we detect per-device vs global by
dividing by chips. Collective bytes come from the HLO parser (per-device link
bytes already).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.roofline import hlo as hlo_mod

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # whole-program FLOPs (all chips)
    hlo_bytes: float          # whole-program bytes accessed
    coll_bytes: float         # per-chip link bytes
    coll_detail: Dict[str, float]
    model_flops: float = 0.0  # 6*N*D (or 6*N_active*D)
    peak_memory: float = 0.0  # per-device bytes (from memory_analysis)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_flops_ratio,
            "peak_mem_gb": self.peak_memory / 1e9,
            "coll_detail": self.coll_detail,
        }


def from_compiled(compiled, *, arch: str, shape: str, mesh_desc: str,
                  chips: int, model_flops: float = 0.0,
                  hlo_text: Optional[str] = None,
                  bf16_target: bool = True) -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    # xla's cost_analysis() counts while bodies ONCE; our HLO walker applies
    # loop trip counts (scan over layers/chunks), so it is the source of truth.
    # The parsed numbers are per-partition (post-SPMD shapes); scale to the
    # whole program by multiplying with the chip count.
    flops_pp, bytes_pp = hlo_mod.program_costs(text, f32_deflate=bf16_target)
    flops = flops_pp * chips
    byts = bytes_pp * chips
    stats = hlo_mod.collective_bytes(text, f32_deflate=bf16_target)
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
            mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes=stats.total_link_bytes, coll_detail=stats.raw_bytes,
        model_flops=model_flops, peak_memory=peak)


# --------------------------------------------------------------------------
# MODEL_FLOPS = 6 * N_active * D  (D = tokens processed in the step)
# --------------------------------------------------------------------------

def active_param_count(cfg) -> int:
    """Active params per token (MoE counts topk experts, not all)."""
    d, ff, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d

    if cfg.family in ("dense",):
        per_layer = attn + 3 * d * ff
        total = L * per_layer
    elif cfg.family == "moe":
        expert = 3 * d * ff
        per_layer = attn + cfg.topk_experts * expert + d * cfg.n_experts
        total = L * per_layer
    elif cfg.family == "hybrid":
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        mamba = d * 2 * di + d * (2 * N + H) + di * d
        n_attn = sum((i + 1) % cfg.attn_every == 0 for i in range(L))
        total = L * mamba + n_attn * (attn + 3 * d * ff)
    elif cfg.family == "ssm":
        total = L * (4 * d * d + d * d) + L * (2 * d * ff + d * d)
    elif cfg.family == "vlm":
        n_cross = L // cfg.cross_attn_every
        n_self = L - n_cross
        total = n_self * (attn + 3 * d * ff) + n_cross * (attn + 3 * d * ff)
    elif cfg.family == "audio":
        enc = cfg.n_enc_layers * (attn + 3 * d * ff)
        dec = L * (2 * attn + 3 * d * ff)
        total = enc + dec
    else:
        total = 0
    total += 2 * V * d  # embed + unembed
    return int(total)


def model_flops(cfg, *, tokens: int, training: bool) -> float:
    mult = 6.0 if training else 2.0
    return mult * active_param_count(cfg) * tokens


# --------------------------------------------------------------------------
# Serving-kernel audit: predicted (flops, bytes) for the streaming server's
# compiled programs, under the SAME conventions as `hlo.program_costs`
# (flops = dots only, loop-amplified; bytes = 2x every materialized
# instruction output, fusion internals excluded). Tolerances are calibrated
# against the XLA:CPU smoke programs and documented in docs/performance.md.
# --------------------------------------------------------------------------

#: measured decode bytes / predicted floor — XLA materializes scatter
#: staging (zeros + one-hot accumulate) on top of the decoded update slice;
#: dense decode sits at ~1.0x, sparse kinds at ~2.7x, and the mask kind at
#: ~4.2x (bitmask unpack + the prefix-sum position map are both staged).
DECODE_BYTES_BAND = (1.0, 5.0)
#: measured fused-step bytes / predicted floor — per-layer activation
#: intermediates (attention scores, FFN hidden states, residual copies,
#: all materialized per arena row) land on top of the state-update floor
#: (cache + xbuf); the XLA:CPU smoke programs calibrate at ~10x.
FUSED_BYTES_BAND = (1.0, 16.0)
#: fused-step dot flops are fully predictable: matmul params + attention
#: score/mix dots; everything else in the program is elementwise.
FUSED_FLOPS_RTOL = 0.05
#: measured encode bytes / predicted floor — the fused device encode
#: (`split.protocol.client_encode_device`: selection -> gather -> quantize
#: -> bit-pack) materializes the selection machinery on top of the
#: activation-in / packed-words-out floor: dense sits at 1.0x exactly,
#: full-row quant at ~2.5x (code staging before the pack), and the top-k
#: kinds at ~6.6-6.8x (sort/threshold selection staging) on the XLA:CPU
#: smoke programs.
ENCODE_BYTES_BAND = (1.0, 10.0)


def top_matmul_params(cfg, cut: int) -> int:
    """Matmul (dot-contributing) params of the label owner's top model:
    attention + FFN projections of layers [cut, n_layers) plus the unembed
    over the padded vocab. Embedding gathers and norms contribute no dots,
    so this matches `hlo.program_costs` flops, not the byte-count param
    total. Dense-family only (the serving bench's arch)."""
    d, ff = cfg.d_model, cfg.d_ff
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
    return (cfg.n_layers - cut) * (attn + 3 * d * ff) + d * cfg.padded_vocab


def serving_decode_costs(rows: int, d: int, *, dtype_bytes: int = 4):
    """Predicted (flops, bytes floor) of the slot-decode program.

    No dots -> 0 flops exactly. The byte floor is the decoded update slice
    written + read (2 * rows * d); measured lands within
    `DECODE_BYTES_BAND` of it depending on how much scatter staging the
    payload kind makes XLA materialize."""
    return 0.0, 2.0 * rows * d * dtype_bytes


def serving_encode_costs(rows: int, d: int, *, dtype_bytes: int = 4):
    """Predicted (flops, bytes floor) of the client's fused device-encode
    program (`protocol.client_encode_device`: selection mask -> gather ->
    quantize -> bit-pack into wire words).

    No dots -> 0 flops exactly (selection, gather, quantization, and the
    bit-pack are all elementwise/compare/shift work — the kernels'
    zero-dot-flops budget, see `kernels.encode`). The byte floor is the
    activation read + an output write of the same order (2 * rows * d);
    measured lands within `ENCODE_BYTES_BAND` of it depending on how much
    selection/pack staging the payload kind makes XLA materialize."""
    return 0.0, 2.0 * rows * d * dtype_bytes


def serving_step_costs(cfg, cut: int, capacity: int, max_len: int,
                       state_nbytes: int):
    """Predicted (flops, bytes floor) of the fused decode+step program.

    flops: every arena row computes (inactive rows are masked afterwards),
    each paying the top matmul params plus the two decode-attention dots
    against a `max_len` KV cache — exact to `FUSED_FLOPS_RTOL`.
    bytes floor: the arena state written + read (`state_nbytes` = cache
    leaves + xbuf, measured off the live arrays so an int8 KV arena
    predicts its smaller traffic automatically); measured lands within
    `FUSED_BYTES_BAND` of it."""
    score_dots = 2 * cfg.n_heads * cfg.hd * max_len
    flops = 2.0 * capacity * (top_matmul_params(cfg, cut) + score_dots)
    return flops, 2.0 * state_nbytes


def serving_collective_costs(cfg, capacity: int, mesh_axes,
                             *, dtype_bytes: int = 4):
    """Predicted per-device collective bytes of the SHARDED arena step
    (`runtime.steps._make_sharded_arena_step`), per HLO op, under the same
    conventions as `hlo.collective_bytes`: raw bytes are each collective
    instruction's per-device output size, and the returned total applies
    the per-op ring factors (`hlo.RING_FACTOR`).

    The sharded step's collectives are fully enumerable from its
    decomposition (docs/sharding.md):

      * 'model' axis: the Megatron-SP row gather (`tp.gather_seq_local`,
        one all-gather of the rank's hidden row block) plus the exact
        vocab-parallel argmax (one f32 pmax + one s32 pmin, each an
        all-reduce over a scalar per gathered row).
      * 'pod' axis: the cut-boundary ring crossing — one collective-permute
        of the local activation row block forward and one of the gathered
        token rows back (`protocol.pod_ring_perm` and its inverse).

    `mesh_axes` is the mesh's `{axis: size}` mapping; `capacity` the padded
    arena row count. Rows shard over all axes flattened, so the per-device
    row block is `capacity / n_devices` and the model-group gathered block
    is that times the model-axis size."""
    sizes = dict(mesh_axes)
    n_model = sizes.get("model", 1)
    n_pod = sizes.get("pod", 1)
    n_dev = 1
    for s in sizes.values():
        n_dev *= s
    rows_local = capacity // n_dev          # per-device row shard
    rows_group = rows_local * n_model       # rows a model group reassembles
    d = cfg.d_model
    per_op: Dict[str, float] = {}
    if n_model > 1:
        per_op["all-gather"] = float(rows_group * d * dtype_bytes)
        # pmax f32[rows, 1] + pmin s32[rows, 1]: 4 bytes each per row
        per_op["all-reduce"] = float(2 * rows_group * 4)
    if n_pod > 1:
        per_op["collective-permute"] = float(
            rows_local * d * dtype_bytes     # activation block forward
            + rows_group * 4)                # s32 token rows back
    total = sum(hlo_mod.RING_FACTOR.get(op, 1.0) * b
                for op, b in per_op.items())
    return per_op, total


def serving_collective_slack(cfg, capacity: int, mesh_axes,
                             *, dtype_bytes: int = 4):
    """Per-op byte SLACK the sharded-step collective audit allows on top of
    `serving_collective_costs` — non-intrinsic traffic XLA's partitioner
    adds, each with a closed-form bound (calibrated exact on the XLA:CPU
    smoke programs):

      * collective-permute: the replicated `xbuf`'s live-row slice enters
        shard_map row-sharded, and the partitioner stages that reshard as a
        permute chain instead of a local slice — bounded by ONE full copy
        of the live xbuf rows (`capacity * d_model * dtype_bytes`).
      * all-reduce (model axis == 1 only): the vocab-parallel argmax's
        pmax/pmin legalize to degenerate single-device-group all-reduces —
        two 4-byte scalars per local row, zero actual link traffic. With a
        real model axis the all-reduce bytes are intrinsic and must match
        the prediction exactly, so no slack.

    The audit gate is `predicted <= measured <= predicted + slack` per op.
    """
    sizes = dict(mesh_axes)
    n_dev = 1
    for s in sizes.values():
        n_dev *= s
    rows_group = (capacity // n_dev) * sizes.get("model", 1)
    slack = {"collective-permute":
             float(capacity * cfg.d_model * dtype_bytes)}
    if sizes.get("model", 1) == 1:
        slack["all-reduce"] = float(2 * 4 * rows_group)
    return slack
