"""Collective-byte extraction from post-SPMD optimized HLO text.

`cost_analysis()` has no collective traffic, so we parse `compiled.as_text()`:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction contributes its (per-device, post-partition)
output bytes times an op-specific ring factor. Instructions living inside
`while` bodies (lax.scan over layers / chunks) are multiplied by the loop trip
count, recovered from the `compare(..., constant(N))` in the loop condition —
nested loops compose multiplicatively.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# bytes moved over links per device ~= factor * local output bytes
_RING_FACTOR = {
    "all-gather": 1.0,          # receives (N-1)/N of the gathered result
    "all-reduce": 2.0,          # reduce-scatter + all-gather phases
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

#: public alias — `roofline.analysis` prices its analytic collective
#: predictions with the same per-op ring factors this parser applies
RING_FACTOR = _RING_FACTOR

_COLL_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s*\((.*?)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines.

    Headers are non-indented `[ENTRY] %name (args...) -> result {` lines;
    args may contain nested tuple parens, so we key on indentation + brace.
    """
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if (line and not line[0].isspace() and stripped.endswith("{")
                and "(" in line):
            m = _COMPUTATION_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _while_trips(line: str, comps: Dict[str, List[str]], cond: str) -> int:
    """Trip count: prefer XLA's known_trip_count, fall back to the condition
    computation's compare constant."""
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    return _loop_bound(comps.get(cond, []))


def _loop_bound(cond_lines: List[str]) -> int:
    """Trip count from a scan-style loop condition (max constant compared)."""
    consts = [int(m.group(1)) for line in cond_lines
              for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else 1


def _collectives_in(lines: List[str], f32_deflate: bool = False):
    out = []
    for line in lines:
        # XLA:CPU's float-normalization legalizes bf16 arrays/collectives to
        # f32 (and promotes reduction apply fns). The TPU target keeps them
        # bf16, so with f32_deflate every f32 collective is counted at half
        # width. Genuinely-f32 traffic (optimizer moments) is loop-free and
        # small by comparison; the approximation is documented in DESIGN.md.
        m = _COLL_RE.search(line)
        if m:
            dtype, dims, op = m.groups()
            w = 0.5 if (f32_deflate and dtype == "f32") else 1.0
            if "_promoted" in line and not f32_deflate:
                w *= 0.5
            out.append((op, w * _shape_bytes(dtype, dims)))
            continue
        m = _TUPLE_COLL_RE.search(line)
        if m:
            shapes, op = m.groups()
            b = 0.0
            for d, sh in _SHAPE_RE.findall(shapes):
                w = 0.5 if (f32_deflate and d == "f32") else 1.0
                b += w * _shape_bytes(d, sh)
            # tuple shape of -start ops lists (operand, result[, ...]); halve
            out.append((op, b / 2))
    return out


def _whiles_in(lines: List[str]):
    return [(line, m.group(1), m.group(2)) for line in lines
            for m in _WHILE_RE.finditer(line)]


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "while", "conditional", "call",
}


def _parse_shape(text: str):
    """'f32[16,32]{1,0}' or tuple -> total bytes and first shape dims."""
    total = 0.0
    first_dims = None
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.groups()
        total += _shape_bytes(dtype, dims)
        if first_dims is None:
            first_dims = (dtype, dims)
    return total, first_dims


def _dims_list(dims: str):
    return [int(d) for d in dims.split(",") if d]


def program_costs(hlo: str, f32_deflate: bool = False):
    """Loop-amplified (flops, bytes) estimate for the whole program.

    flops: 2 * prod(out) * prod(contracted lhs dims) for every dot,
    including dots inside fusion bodies, times enclosing while trip counts.
    bytes: every materialized instruction output counted twice (write+read),
    fusion internals excluded (only the fusion's output materializes).
    """
    comps = split_computations(hlo)
    if not comps:
        return 0.0, 0.0

    # symbol tables: per computation, instruction name -> shape-text
    symtab: Dict[str, Dict[str, str]] = {}
    parsed: Dict[str, list] = {}
    for cname, lines in comps.items():
        tab: Dict[str, str] = {}
        plist = []
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shape_text, op = m.groups()
            tab[name] = shape_text
            plist.append((name, shape_text, op, line))
        symtab[cname] = tab
        parsed[cname] = plist

    entry = next((n for n in comps if "main" in n), None) or \
        max(comps, key=lambda n: len(comps[n]))

    flops_memo: Dict[str, float] = {}
    bytes_memo: Dict[str, float] = {}

    def flops_of(cname: str, depth=0) -> float:
        if cname in flops_memo:
            return flops_memo[cname]
        if cname not in parsed or depth > 16:
            return 0.0
        flops_memo[cname] = 0.0  # cycle guard
        total = 0.0
        tab = symtab[cname]
        for name, shape_text, op, line in parsed[cname]:
            if op == "dot":
                out_b, out_first = _parse_shape(shape_text)
                if out_first is None:
                    continue
                out_elems = 1
                for d in _dims_list(out_first[1]):
                    out_elems *= d
                cm = _CONTRACT_RE.search(line)
                contracted = 1
                if cm:
                    ops = _OPERAND_RE.findall(line.split("dot(")[1])
                    lhs = ops[0] if ops else None
                    lhs_shape = tab.get(lhs)
                    if lhs_shape:
                        _, first = _parse_shape(lhs_shape)
                        dims = _dims_list(first[1]) if first else []
                        for c in _dims_list(cm.group(1)):
                            if c < len(dims):
                                contracted *= dims[c]
                total += 2.0 * out_elems * contracted
            elif op == "while":
                bm = _WHILE_RE.search(line)
                if bm:
                    trips = _while_trips(line, comps, bm.group(1))
                    total += trips * flops_of(bm.group(2), depth + 1)
            elif op in ("fusion", "call", "conditional"):
                for sub in _CALLS_RE.findall(line):
                    total += flops_of(sub, depth + 1)
                bb = _BRANCHES_RE.search(line)
                if bb:
                    subs = _OPERAND_RE.findall(bb.group(1))
                    if subs:
                        total += max(flops_of(s, depth + 1) for s in subs)
        flops_memo[cname] = total
        return total

    def _dus_update_bytes(cname: str, line: str):
        """kLoop fusions rooted at dynamic-update-slice write only the update
        slice (the big buffer is aliased in place by scan stacking) — count
        the update operand, not the full output, or a 256-trip scan inflates
        its output buffer 256x."""
        for sub in _CALLS_RE.findall(line):
            for fline in comps.get(sub, []):
                if " dynamic-update-slice(" in fline:
                    ops = _OPERAND_RE.findall(
                        fline.split("dynamic-update-slice(")[1])
                    if len(ops) >= 2:
                        upd = symtab.get(sub, {}).get(ops[1])
                        if upd:
                            return _parse_shape(upd)
        return None

    def bytes_of(cname: str, depth=0) -> float:
        if cname in bytes_memo:
            return bytes_memo[cname]
        if cname not in parsed or depth > 16:
            return 0.0
        bytes_memo[cname] = 0.0
        total = 0.0
        tab = symtab[cname]
        for name, shape_text, op, line in parsed[cname]:
            if op == "while":
                bm = _WHILE_RE.search(line)
                if bm:
                    trips = _while_trips(line, comps, bm.group(1))
                    total += trips * bytes_of(bm.group(2), depth + 1)
                continue
            if op == "conditional":
                bb = _BRANCHES_RE.search(line)
                if bb:
                    subs = _OPERAND_RE.findall(bb.group(1))
                    if subs:
                        total += max(bytes_of(s, depth + 1) for s in subs)
            if op in _SKIP_BYTES_OPS:
                continue
            parsed_shape = None
            if op == "fusion":
                parsed_shape = _dus_update_bytes(cname, line)
            elif op == "dynamic-update-slice":
                ops = _OPERAND_RE.findall(line.split("(", 1)[1])
                if len(ops) >= 2 and ops[1] in tab:
                    parsed_shape = _parse_shape(tab[ops[1]])
            if parsed_shape is None:
                parsed_shape = _parse_shape(shape_text)
            out_b, first = parsed_shape
            if f32_deflate and first and first[0] == "f32":
                out_b *= 0.5              # bf16 on the TPU target
            total += 2.0 * out_b          # write + one read
        bytes_memo[cname] = total
        return total

    return flops_of(entry), bytes_of(entry)


@dataclasses.dataclass
class CollectiveStats:
    per_op_bytes: Dict[str, float]

    @property
    def total_link_bytes(self) -> float:
        return sum(_RING_FACTOR.get(op, 1.0) * b
                   for op, b in self.per_op_bytes.items())

    @property
    def raw_bytes(self) -> Dict[str, float]:
        return dict(self.per_op_bytes)


def collective_bytes(hlo: str, entry: str = None,
                     f32_deflate: bool = False) -> CollectiveStats:
    comps = split_computations(hlo)
    if not comps:
        return CollectiveStats({})
    if entry is None:
        entry = next((n for n in comps if "main" in n), None) or \
            max(comps, key=lambda n: len(comps[n]))

    memo: Dict[str, Dict[str, float]] = {}

    def walk(name: str, depth=0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 12:
            return {}
        lines = comps[name]
        acc: Dict[str, float] = defaultdict(float)
        for op, b in _collectives_in(lines, f32_deflate):
            acc[op] += b
        for line_, cond, body in _whiles_in(lines):
            trips = _while_trips(line_, comps, cond)
            inner = walk(body, depth + 1)
            for op, b in inner.items():
                acc[op] += trips * b
        memo[name] = dict(acc)
        return memo[name]

    # also include called computations (fusion/conditional) reachable from
    # entry via calls; approximate by walking every computation referenced
    # as body/branch from the entry chain — scan loops dominate in practice.
    stats = walk(entry)
    return CollectiveStats(dict(stats))


def attention_score_bytes(hlo: str, seq: int, f32_deflate: bool = False):
    """Traffic attributable to materialized attention-score tensors:
    instruction outputs whose trailing two dims look like (q-block, S) with
    S == the model sequence length. This is the traffic a fused
    flash-attention kernel keeps in VMEM (kernel-adjusted roofline)."""
    comps = split_computations(hlo)
    if not comps:
        return 0.0
    parsed = {}
    for cname, lines in comps.items():
        plist = []
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                plist.append(m.groups() + (line,))
        parsed[cname] = plist
    entry = next((n for n in comps if "main" in n), None) or \
        max(comps, key=lambda n: len(comps[n]))
    memo = {}

    def walk(cname, depth=0):
        if cname in memo:
            return memo[cname]
        if cname not in parsed or depth > 16:
            return 0.0
        memo[cname] = 0.0
        total = 0.0
        for name, shape_text, op, line in parsed[cname]:
            if op == "while":
                bm = _WHILE_RE.search(line)
                if bm:
                    trips = _while_trips(line, comps, bm.group(1))
                    total += trips * walk(bm.group(2), depth + 1)
                continue
            if op in _SKIP_BYTES_OPS:
                continue
            b, first = _parse_shape(shape_text)
            if first is None:
                continue
            dims = _dims_list(first[1])
            if len(dims) >= 4 and dims[-1] == seq and dims[-2] >= 256:
                if f32_deflate and first[0] == "f32":
                    b *= 0.5
                total += 2.0 * b
        memo[cname] = total
        return total

    return walk(entry)
