"""Version-compat aliases for jax APIs that moved between releases.

The repo targets current jax, but must also run on 0.4.x containers where
`shard_map` still lives under `jax.experimental` (with `check_rep` instead
of `check_vma`) and `jax.make_mesh` takes no `axis_types` (see
`launch.mesh.make_mesh` for the latter).
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
