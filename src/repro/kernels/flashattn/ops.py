"""jit'd wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flashattn import kernel


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, interpret=True):
    return kernel.flash_attention(q, k, v, causal=causal, window=window,
                                  interpret=interpret)
