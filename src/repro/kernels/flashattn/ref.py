"""Pure-jnp oracle for the flash-attention kernel (the model's _sdpa)."""
from __future__ import annotations

import jax.numpy as jnp


def attention(q, k, v, *, causal=True, window=0):
    """q: (B,S,Hq,hd), k/v: (B,S,Hkv,hd) -> (B,S,Hq,hd); f32 softmax."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, S, Hkv, g, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    pos = jnp.arange(S)
    mask = pos[None, :] <= pos[:, None] if causal else jnp.ones((S, S), bool)
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jnp.exp(logits - logits.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, Hq, hd).astype(q.dtype)
