"""Pallas TPU kernel: fused causal flash attention (forward).

The roofline analysis (EXPERIMENTS.md §Roofline) shows prefill/train memory
terms dominated by materialized (q-block x S) score tensors — the pure-JAX
attention writes them to HBM. This kernel keeps score tiles in VMEM with the
standard online-softmax recurrence:

  grid = (batch, q_heads, S/BQ); the kernel body loops over KV blocks with
  running (max, sumexp, acc) carries; only q/k/v tiles and the (BQ, hd)
  output ever touch HBM. GQA is handled by indexing the kv head = q_head //
  (Hq/Hkv) in the BlockSpec index map. Supports causal masking and sliding
  windows. bf16 in / f32 accumulate (MXU semantics).

Validated against `ref.py` (the model's `_sdpa` oracle) in interpret mode;
on a TPU runtime pass interpret=False for the Mosaic kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, seq: int,
                  window: int, causal: bool):
    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)             # (BQ, hd)
    hd = q.shape[-1]
    q = q * (hd ** -0.5)

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, hd), jnp.float32)

    n_kv = seq // bk

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        s = q @ k.T                                 # (BQ, BK)
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (B, S, Hq, hd); k/v: (B, S, Hkv, hd) -> (B, S, Hq, hd).

    S must divide bq and bk (pad upstream); GQA via head-index mapping.
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0

    # layout: (B, H, S, hd) blocks
    qt = q.swapaxes(1, 2)                           # (B, Hq, S, hd)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, seq=S,
                               window=window, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, S // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.swapaxes(1, 2)
