"""Fused payload-encode Pallas kernels: gather + quantize + bit-pack.

The encode-side mirror of `kernels.decode`. Two kernel families:

  * `encode_rows_kernel` — (rows, d) activation [+ selection mask] -> the
    payload's wire leaves in one lane-parallel VMEM pass per row tile:
    support gather (the transpose of the decode scatter: positions from a
    log-step lane prefix-sum over the mask), in-kernel uniform quantization
    (identical arithmetic to `core.compressors`, same 1-ulp FMA convention
    as the decode side), and for the `mask` kind the packed u32 bitmask
    words. One dispatch per payload kind.
  * `pack_bits_kernel` — the device bit-packer: a flat stream of unsigned
    ints at `width` bits each becomes little-endian u32 words, bit j of the
    stream landing at bit j%32 of word j//32 — the exact bitstream
    `core.wire._pack_bits` produces on host (its two-aligned-word scheme at
    32-bit granularity: 32 values span exactly `width` words, and a static
    loop over the 32 lanes ORs each value into its at-most-two words).

Neither family touches `jnp.dot`, so the compiled encode programs cost
zero dot-flops — `roofline.analysis.serving_encode_costs` budgets them as
pure byte movement, audited in `benchmarks/serve_throughput.py`.

Values cross the gather verbatim (bit-exact vs the XLA encode for
dense/slice/sparse/mask); quant kinds re-run the host's min/max + floor
grid, which either compiler may contract/reassociate — the <= 1-ulp
convention pinned by tests/test_encode_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.decode.kernel import _cumsum_lanes

#: wire leaves each payload kind's encode kernel emits, in
#: `payload.WIRE_FIELDS` order (dtypes are the kernel-friendly wide forms;
#: `ops.encode_rows` narrows them to the wire dtypes)
KIND_OUTPUTS = {
    "dense": ("values",),
    "slice": ("values",),
    "sparse": ("values", "indices"),
    "quant": ("values", "header"),
    "sparse_quant": ("values", "indices", "header"),
    "mask": ("values", "indices"),
}


def _gather_block(x, mask, k: int):
    """Compact the masked lanes of a (br, d) tile into (br, k) values +
    (br, k) int32 indices, ascending-index order — the transpose of
    `kernels.decode._scatter_block` (compare-and-select, no gather op)."""
    d = x.shape[-1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, x.shape[:-1] + (d,),
                                     x.ndim - 1)
    pos = _cumsum_lanes(mask.astype(jnp.int32)) - 1
    hit = mask & (pos < k)

    def body(j, acc):
        vals, idx = acc
        sel = hit & (pos == j)
        vj = jnp.sum(jnp.where(sel, x, 0.0), axis=-1, keepdims=True)
        ij = jnp.sum(jnp.where(sel, lanes, 0), axis=-1, keepdims=True)
        vals = jax.lax.dynamic_update_slice_in_dim(vals, vj, j, axis=-1)
        idx = jax.lax.dynamic_update_slice_in_dim(idx, ij, j, axis=-1)
        return vals, idx

    init = (jnp.zeros(x.shape[:-1] + (k,), jnp.float32),
            jnp.zeros(x.shape[:-1] + (k,), jnp.int32))
    return jax.lax.fori_loop(0, k, body, init)


def _mask_words_block(mask, d: int):
    """Pack a (br, d) boolean tile into (br, ceil(d/32)) u32 words — the
    `mask` payload's device row layout (bit l%32 of word l//32)."""
    nw = (d + 31) // 32
    m = mask.astype(jnp.uint32)
    pad = nw * 32 - d
    if pad:
        m = jnp.concatenate(
            [m, jnp.zeros(m.shape[:-1] + (pad,), jnp.uint32)], axis=-1)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    cols = []
    for j in range(nw):
        seg = m[..., 32 * j: 32 * (j + 1)]
        cols.append(jnp.sum(seg << shifts, axis=-1, keepdims=True,
                            dtype=jnp.uint32))
    return jnp.concatenate(cols, axis=-1)


def _quant_block(vals, bits: int, *, selected: bool):
    """In-kernel uniform quantization of a (br, w) tile.

    `selected=False` is `core.compressors._quant_encode` (full-row range,
    degenerate step -> 1.0 via `step <= 0`); `selected=True` is the
    RandTopKQuant variant (range over the selected values, `hi > lo`
    guard). Same formulas, so host and kernel agree to the FMA ulp.
    """
    lo = jnp.min(vals, axis=-1, keepdims=True)
    hi = jnp.max(vals, axis=-1, keepdims=True)
    n_bins = 2 ** bits
    if selected:
        step = jnp.where(hi > lo, (hi - lo) / n_bins, 1.0)
    else:
        step = (hi - lo) / n_bins
        step = jnp.where(step <= 0, 1.0, step)
    code = jnp.clip(jnp.floor((vals - lo) / step), 0, n_bins - 1)
    return code.astype(jnp.int32), jnp.concatenate([lo, step], axis=-1)


def _encode_block(kind: str, x, mask, d: int, k: int, bits: int):
    """(br, d) activation tile -> wire-leaf tile(s), dispatched on kind."""
    if kind == "dense":
        return (x.astype(jnp.float32),)
    if kind == "slice":
        return (x[..., :k].astype(jnp.float32),)
    if kind == "sparse":
        vals, idx = _gather_block(x.astype(jnp.float32), mask, k)
        return vals, idx
    if kind == "quant":
        codes, hdr = _quant_block(x.astype(jnp.float32), bits,
                                  selected=False)
        return codes, hdr
    if kind == "sparse_quant":
        vals, idx = _gather_block(x.astype(jnp.float32), mask, k)
        codes, hdr = _quant_block(vals, bits, selected=True)
        return codes, idx, hdr
    if kind == "mask":
        vals, _ = _gather_block(x.astype(jnp.float32), mask, k)
        return vals, _mask_words_block(mask, d)
    raise ValueError(kind)


def _rows_blocks(leading_shape, block_rows: int):
    rows = 1
    for s in leading_shape:
        rows *= s
    br = min(block_rows, rows)
    pad = (-rows) % br
    return rows, br, pad


def _out_descr(kind: str, d: int, k: int):
    """(width, dtype) per output leaf of `_encode_block`, in order."""
    nw = (d + 31) // 32
    return {
        "dense": ((d, jnp.float32),),
        "slice": ((k, jnp.float32),),
        "sparse": ((k, jnp.float32), (k, jnp.int32)),
        "quant": ((d, jnp.int32), (2, jnp.float32)),
        "sparse_quant": ((k, jnp.int32), (k, jnp.int32), (2, jnp.float32)),
        "mask": ((k, jnp.float32), (nw, jnp.uint32)),
    }[kind]


@functools.partial(jax.jit, static_argnames=("kind", "k", "bits",
                                             "block_rows", "interpret"))
def encode_rows_kernel(x, mask=None, *, kind: str, k: int = 0,
                       bits: int = 0, block_rows: int = 128,
                       interpret: bool = True):
    """Fused one-pass encode: activation rows -> wire-leaf arrays.

    x    : (..., d) activation
    mask : (..., d) selection mask (int32/bool; required for the sparse /
           sparse_quant / mask kinds, ignored otherwise) — produced by
           `core.selection`'s kernels, so mask -> gather -> quantize ->
           (bit)pack never leaves the device
    Returns the tuple of leaf arrays named by `KIND_OUTPUTS[kind]`, common
    leading shape `x.shape[:-1]`.
    """
    d = x.shape[-1]
    assert d <= 16384, "dense row must fit a VMEM row tile"
    lead = x.shape[:-1]
    rows, br, pad = _rows_blocks(lead, block_rows)
    flat = [x.reshape((rows, d))]
    needs_mask = kind in ("sparse", "sparse_quant", "mask")
    if needs_mask:
        assert mask is not None, f"{kind} encode needs a selection mask"
        flat.append(mask.reshape((rows, d)).astype(jnp.int32))
    if pad:
        flat = [jnp.pad(a, ((0, pad), (0, 0))) for a in flat]
    grid = (flat[0].shape[0] // br,)
    descr = _out_descr(kind, d, k)

    def kernel(*refs):
        if needs_mask:
            x_ref, m_ref, *o_refs = refs
            m = m_ref[...] != 0
        else:
            x_ref, *o_refs = refs
            m = None
        outs = _encode_block(kind, x_ref[...], m, d, k, bits)
        for o_ref, o in zip(o_refs, outs):
            o_ref[...] = o.astype(o_ref.dtype)

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, a.shape[-1]), lambda i: (i, 0))
                  for a in flat],
        out_specs=[pl.BlockSpec((br, w), lambda i: (i, 0))
                   for w, _ in descr],
        out_shape=[jax.ShapeDtypeStruct((flat[0].shape[0], w), dt)
                   for w, dt in descr],
        interpret=interpret,
    )(*flat)
    outs = [o[:rows].reshape(lead + (o.shape[-1],)) if pad
            else o.reshape(lead + (o.shape[-1],)) for o in outs]
    return tuple(outs)


def _pack_block(lanes, width: int):
    """(bg, 32) value tile -> (bg, width) u32 words: a static loop over the
    32 lanes ORs each value's low/high parts into its aligned word(s) —
    `core.wire._pack_bits`'s scheme at 32-bit granularity."""
    v = lanes.astype(jnp.uint32)
    if width < 32:
        v = v & jnp.uint32((1 << width) - 1)
    cols = [jnp.zeros(v.shape[:-1] + (1,), jnp.uint32)
            for _ in range(width)]
    for i in range(32):
        start = i * width
        j, off = start // 32, start % 32
        vi = v[..., i:i + 1]
        cols[j] = cols[j] | (vi << jnp.uint32(off))
        if off and off + width > 32:
            # spill into the next word; j+1 < width whenever a lane spills
            cols[j + 1] = cols[j + 1] | (vi >> jnp.uint32(32 - off))
    return jnp.concatenate(cols, axis=-1)


@functools.partial(jax.jit, static_argnames=("width", "block_groups",
                                             "interpret"))
def pack_bits_kernel(vals, width: int, *, block_groups: int = 256,
                     interpret: bool = True):
    """Device bit-pack: flat unsigned ints -> little-endian u32 words.

    The returned (ceil(n/32) * width,) u32 buffer's first
    `ceil(n * width / 8)` bytes are exactly `core.wire._pack_bits(vals,
    width)` (padding values are zero and land strictly after the real
    bits, so host truncation is a suffix cut).
    """
    assert 1 <= width <= 32
    vals = vals.reshape(-1)
    n = vals.shape[0]
    groups = (n + 31) // 32
    bg = min(block_groups, groups)
    gpad = (-groups) % bg
    v = jnp.pad(vals.astype(jnp.uint32), (0, (groups + gpad) * 32 - n))
    v = v.reshape(groups + gpad, 32)

    def kernel(v_ref, o_ref):
        o_ref[...] = _pack_block(v_ref[...], width)

    out = pl.pallas_call(
        kernel,
        grid=((groups + gpad) // bg,),
        in_specs=[pl.BlockSpec((bg, 32), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bg, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((groups + gpad, width), jnp.uint32),
        interpret=interpret,
    )(v)
    return out[:groups].reshape(groups * width)
