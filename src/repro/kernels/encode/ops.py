"""jit'd public wrappers around the fused encode kernels.

Three layers, mirroring `kernels.decode.ops` on the opposite side of the
wire:

  * `encode_rows` — the Pallas twin of `Compressor.encode`: activation
    rows [+ selection mask] -> a wire-dtype `Payload` in one fused pass
    (parity vs the XLA compressor encode pinned in
    tests/test_encode_kernels.py).
  * `pack_bits` — device bit-pack of a flat int stream into u32 words
    (`backend=` dispatch per the `core.selection` contract: Pallas kernel
    or the pure-jnp fallback; both produce `core.wire._pack_bits`'s exact
    bitstream).
  * `pack_payload` / `section_nbytes` / `sections_to_bytes` — the device
    wire path: every bit-packed section of `core.wire.encode_payload`'s
    layout is assembled on device as u32 words, so the host's only work
    per frame is pulling the packed buffers, truncating each to its exact
    byte length, and wrapping them in a subheader + CRC
    (`wire.encode_payload_frame_from_bytes`). Byte equality with the host
    codec is pinned in tests.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core.payload import Payload, PayloadMeta
from repro.kernels.encode import kernel


#: wire dtype each kernel output leaf narrows to, per kind
_WIRE_DTYPES = {
    "dense": (jnp.float32,),
    "slice": (jnp.float32,),
    "sparse": (jnp.float32, jnp.uint16),
    "quant": (jnp.uint8, jnp.float32),
    "sparse_quant": (jnp.uint8, jnp.uint16, jnp.float32),
    "mask": (jnp.float32, jnp.uint32),
}


def encode_rows(x, kind: str, *, k: int = 0, bits: int = 0, mask=None,
                interpret: bool = True) -> Payload:
    """Fused one-pass encode of activation rows to a wire-dtype Payload.

    `mask` is the (..., d) selection mask (from `core.selection`'s
    kernels) for the sparse / sparse_quant / mask kinds; values come back
    in ascending-index order, matching `Compressor.encode`.
    """
    d = x.shape[-1]
    outs = kernel.encode_rows_kernel(x, mask, kind=kind, k=k, bits=bits,
                                     interpret=interpret)
    outs = tuple(o.astype(dt) for o, dt in zip(outs, _WIRE_DTYPES[kind]))
    meta = PayloadMeta(kind, d=d, k=k if kind != "quant" else 0,
                       bits=bits if kind in ("quant", "sparse_quant")
                       else 0)
    names = kernel.KIND_OUTPUTS[kind]
    return Payload(meta=meta, **dict(zip(names, outs)))


def _pack_words_xla(vals, width: int):
    """Pure-jnp fallback of `kernel.pack_bits_kernel`: same two-aligned-
    word scheme, same (ceil(n/32) * width,) u32 buffer."""
    vals = vals.reshape(-1).astype(jnp.uint32)
    if width < 32:
        vals = vals & jnp.uint32((1 << width) - 1)
    n = vals.shape[0]
    groups = (n + 31) // 32
    v = jnp.pad(vals, (0, groups * 32 - n)).reshape(groups, 32)
    cols = [jnp.zeros((groups, 1), jnp.uint32) for _ in range(width)]
    for i in range(32):
        start = i * width
        j, off = start // 32, start % 32
        vi = v[:, i:i + 1]
        cols[j] = cols[j] | (vi << jnp.uint32(off))
        if off and off + width > 32:
            cols[j + 1] = cols[j + 1] | (vi >> jnp.uint32(32 - off))
    return jnp.concatenate(cols, axis=-1).reshape(groups * width)


def pack_bits(vals, width: int, *, backend=None):
    """Device bit-pack dispatch: flat ints -> u32 words whose first
    `ceil(n * width / 8)` bytes equal `core.wire._pack_bits`."""
    from repro.core import selection

    if selection._resolve_backend(backend) == "pallas":
        return kernel.pack_bits_kernel(
            vals, width, interpret=selection._pallas_interpret())
    return _pack_words_xla(vals, width)


def _f32_words(a):
    """f32 leaf -> its little-endian u32 bit pattern, flattened."""
    return jax.lax.bitcast_convert_type(
        jnp.asarray(a).astype(jnp.float32), jnp.uint32).reshape(-1)


def pack_payload(p: Payload, *, backend=None):
    """Assemble `encode_payload(p)`'s bitstream on device as u32 sections.

    Sections split exactly where a bit-packed stream ends on a non-word
    byte boundary (so each device buffer's wire bytes are a prefix of its
    own bytes): dense/slice/sparse/quant are ONE buffer (their interior
    section seams are word-aligned), sparse_quant is two (the r-bit index
    stream ends mid-word before the codes), and mask is two (the
    per-instance bitmask rows are byte- but not word-aligned; the second
    section stays (n, W) for the host's per-row byte slice).
    """
    m = p.meta
    kind, d = m.kind, m.d
    if kind in ("dense", "slice"):
        return (_f32_words(p.values),)
    if kind == "sparse":
        idx_words = pack_bits(jnp.asarray(p.indices), wire.index_bits(d),
                              backend=backend)
        return (jnp.concatenate([_f32_words(p.values), idx_words]),)
    if kind == "quant":
        code_words = pack_bits(jnp.asarray(p.values), m.bits,
                               backend=backend)
        return (jnp.concatenate([_f32_words(p.header), code_words]),)
    if kind == "sparse_quant":
        idx_words = pack_bits(jnp.asarray(p.indices), wire.index_bits(d),
                              backend=backend)
        code_words = pack_bits(jnp.asarray(p.values), m.bits,
                               backend=backend)
        return (jnp.concatenate([_f32_words(p.header), idx_words]),
                code_words)
    if kind == "mask":
        n = 1
        for s in p.batch_shape:
            n *= s
        words = jnp.asarray(p.indices).reshape(n, wire.mask_words(d))
        return (_f32_words(p.values), words)
    raise ValueError(kind)


def section_nbytes(meta: PayloadMeta, batch_shape):
    """Exact wire bytes of each `pack_payload` section — their sum is
    `wire.payload_expected_nbytes(meta, batch_shape)`."""
    return _section_nbytes(meta, tuple(batch_shape))


# memoized for the per-frame host pack path (see wire._meta_subheader)
@lru_cache(maxsize=4096)
def _section_nbytes(meta: PayloadMeta, batch_shape):
    n = 1
    for s in batch_shape:
        n *= s
    kind, d, k, r = meta.kind, meta.d, meta.k, wire.index_bits(meta.d)
    if kind == "dense":
        return (4 * n * d,)
    if kind == "slice":
        return (4 * n * k,)
    if kind == "sparse":
        return (4 * n * k + (n * k * r + 7) // 8,)
    if kind == "quant":
        return (8 * n + (n * d * meta.bits + 7) // 8,)
    if kind == "sparse_quant":
        return (8 * n + (n * k * r + 7) // 8, (n * k * meta.bits + 7) // 8)
    if kind == "mask":
        return (4 * n * k, n * wire.mask_row_nbytes(d))
    raise ValueError(kind)


def sections_to_bytes(meta: PayloadMeta, batch_shape, sections) -> bytes:
    """Host side of the device wire path: pull each packed section and
    truncate it to its exact byte length. The result is byte-identical to
    `wire.encode_payload` on the equivalent host payload; frame it with
    `wire.encode_payload_frame_from_bytes`."""
    nbytes = section_nbytes(meta, batch_shape)
    parts = []
    for arr, nb in zip(sections, nbytes):
        a = np.asarray(arr)
        if meta.kind == "mask" and a.ndim == 2:
            parts.append(wire.mask_words_to_bytes(a, meta.d))
        else:
            parts.append(a.tobytes()[:nb])
    return b"".join(parts)


@partial(jax.jit, static_argnames=("kind", "k", "bits", "interpret"))
def _encode_rows_jit(x, mask, *, kind, k, bits, interpret):
    return encode_rows(x, kind, k=k, bits=bits, mask=mask,
                       interpret=interpret)
