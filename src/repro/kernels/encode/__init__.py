"""Fused payload-encode Pallas kernels (the encode-side mirror of
`kernels.decode`): selection-mask -> value gather -> quantize -> bit-pack
into device u32 words, so the client's only host crossing is the final
packed wire buffer."""
