"""Fused payload-decode kernels (dequant + scatter + cut-projection)."""
from repro.kernels.decode import kernel, ops  # noqa: F401
