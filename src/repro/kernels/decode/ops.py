"""jit'd public wrappers around the fused decode kernels.

These are the `backend="pallas"` implementations behind
`core.compressors.payload_to_dense` (every payload kind, optional fused
cut-projection) and `split.protocol.server_decode_to_slots` (the serving
arena's decode->xbuf seam). Interpret mode off-TPU, Mosaic on a TPU
runtime — the same dispatch contract as `core.selection`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.payload import Payload
from repro.kernels.decode import kernel


def _wire_leaves(p: Payload):
    """Payload wire leaves in kernel order, validated against the kind."""
    names = kernel.KIND_LEAVES[p.meta.kind]
    return tuple(jnp.asarray(getattr(p, n)) for n in names)


def decode_rows(p: Payload, *, dtype=None, project=None,
                interpret: bool = True):
    """Fused dequant+scatter decode of any payload to dense (..., d) rows;
    with `project` ((d, p) matrix) the cut-projection epilogue runs inside
    the same kernel and (..., p) comes back instead."""
    dtype = jnp.dtype(dtype or jnp.float32)
    return kernel.decode_rows_kernel(
        _wire_leaves(p), p.meta.kind, p.meta.d, project,
        dtype=dtype.name, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def decode_rows_to_slots(xbuf, p: Payload, slots, *, interpret: bool = True):
    """Decode a stacked flush payload straight into `xbuf[slots]`.

    xbuf is ALIASED through the kernel (`input_output_aliases`): treat the
    input handle as consumed and keep the returned array — the arena's
    donation contract. Rows shape-agnostic: xbuf (C+1, ..., d) is flattened
    to (C+1, d) around the kernel call.
    """
    cap1 = xbuf.shape[0]
    d = p.meta.d
    out = kernel.decode_to_slots_kernel(
        xbuf.reshape(cap1, d), _wire_leaves(p), slots, p.meta.kind,
        interpret=interpret)
    return out.reshape(xbuf.shape)
