"""Fused payload-decode Pallas kernels: dequant + scatter + projection.

One kernel family replaces the decode-side seam that used to be two XLA
passes (dequantize the u8 codes, then scatter/pad into dense rows): for
every payload kind the wire leaves become dense f32 rows in a single
lane-parallel pass over a VMEM-resident row tile, with an optional
cut-projection epilogue (`rows @ w`) fused behind the scatter so the
decoded activation can leave the kernel already projected.

Two entry points:

  * `decode_rows_kernel` — flat (rows, d) decode, gridded over row blocks;
    the `backend="pallas"` implementation behind every kind of
    `core.compressors.payload_to_dense` (the scatter-only kernel in
    `kernels.randtopk` covered just the sparse kinds).
  * `decode_to_slots_kernel` — the serving-arena variant: one grid step per
    flush row, the slot ids streamed in via scalar prefetch
    (`pltpu.PrefetchScalarGridSpec`) drive the OUTPUT block index map, and
    the arena's cut-activation buffer is passed through
    `input_output_aliases` so untouched slot rows keep their contents and
    the decoded rows land in `xbuf[slots]` without a separate scatter pass
    (on TPU the buffer is updated in place; interpret mode copies).

Numerics match the two-pass XLA decode bit-for-bit for dense/slice/sparse
kinds (values cross the kernel verbatim; the compare-and-select scatter
adds exact zeros elsewhere). Quant kinds run the same `lo + (code + 0.5) *
step` multiply-add, which either compiler may contract into an FMA — the
1-ulp convention pinned by tests/test_arena.py and docs/performance.md.

Layout notes: the feature axis lives whole in VMEM (d <= 16k f32), rows
tile over the grid; the k-wide support loop is the branch-free
compare-and-select accumulate of `kernels.randtopk._scatter_rows_kernel`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: wire leaves each payload kind carries, in `payload.WIRE_FIELDS` order
KIND_LEAVES = {
    "dense": ("values",),
    "slice": ("values",),
    "sparse": ("values", "indices"),
    "quant": ("values", "header"),
    "sparse_quant": ("values", "indices", "header"),
    "mask": ("values", "indices"),   # indices = packed u32 bitmask words
}


def _dequant_block(codes, hdr):
    """`lo + (code + 0.5) * step` on a (br, k) tile — identical arithmetic
    to `core.compressors._dequant` (see the 1-ulp FMA note there)."""
    lo, step = hdr[..., 0:1], hdr[..., 1:2]
    return lo + (codes.astype(jnp.float32) + 0.5) * step


def _scatter_block(vals, idx, d: int):
    """Branch-free compare-and-select scatter of a (br, k) support onto
    (br, d) lanes; exact for unique per-row indices (duplicates sum)."""
    lanes = jax.lax.broadcasted_iota(jnp.int32, vals.shape[:-1] + (d,),
                                     vals.ndim - 1)

    def body(j, acc):
        ij = jax.lax.dynamic_slice_in_dim(idx, j, 1, axis=-1)
        vj = jax.lax.dynamic_slice_in_dim(vals, j, 1, axis=-1)
        return acc + jnp.where(lanes == ij, vj, 0.0)

    return jax.lax.fori_loop(0, vals.shape[-1], body,
                             jnp.zeros(vals.shape[:-1] + (d,), jnp.float32))


def _mask_bits_block(words, d: int):
    """Per-lane support bits of a (br, W) packed-u32 tile -> bool (br, d).

    Lane l's bit lives at bit l%32 of word l//32; the W-step loop broadcasts
    each word across the lanes it owns (compare-and-select, no gather)."""
    lanes = jax.lax.broadcasted_iota(jnp.int32, words.shape[:-1] + (d,),
                                     words.ndim - 1)
    wi = lanes // 32
    sh = (lanes % 32).astype(jnp.uint32)

    def body(j, acc):
        wj = jax.lax.dynamic_slice_in_dim(words, j, 1, axis=-1)
        bit = (wj >> sh) & jnp.uint32(1)
        return acc | ((wi == j) & (bit != 0))

    return jax.lax.fori_loop(0, words.shape[-1], body,
                             jnp.zeros(lanes.shape, bool))


def _cumsum_lanes(x):
    """Inclusive prefix sum along lanes via log-step shifted adds
    (Hillis-Steele) — static pad+slice only, no scan/reduce_window
    primitives and no dots (the decode roofline budgets zero dot-flops)."""
    d = x.shape[-1]
    step = 1
    while step < d:
        shifted = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(step, 0)])[..., :d]
        x = x + shifted
        step *= 2
    return x


def _mask_expand_block(vals, words, d: int):
    """Mask-driven expand of a (br, k) value tile onto (br, d) lanes: the
    j-th value lands on the lane of the (j+1)-th set bit (ascending-index
    value order). Set bits beyond k (a hostile mask) expand to zero, exactly
    like `core.compressors.mask_expand_rows`."""
    mask = _mask_bits_block(words, d)
    pos = _cumsum_lanes(mask.astype(jnp.int32)) - 1

    def body(j, acc):
        vj = jax.lax.dynamic_slice_in_dim(vals, j, 1, axis=-1)
        return acc + jnp.where(mask & (pos == j), vj, 0.0)

    return jax.lax.fori_loop(0, vals.shape[-1], body,
                             jnp.zeros(mask.shape, jnp.float32))


def _decode_block(kind: str, leaf_refs, d: int):
    """Wire-leaf tile(s) -> dense f32 (br, d) tile, dispatched on kind."""
    if kind == "dense":
        (v_ref,) = leaf_refs
        return v_ref[...].astype(jnp.float32)
    if kind == "slice":
        (v_ref,) = leaf_refs
        v = v_ref[...].astype(jnp.float32)
        k = v.shape[-1]
        if k == d:
            return v
        return jnp.concatenate(
            [v, jnp.zeros(v.shape[:-1] + (d - k,), jnp.float32)], axis=-1)
    if kind == "sparse":
        v_ref, i_ref = leaf_refs
        return _scatter_block(v_ref[...].astype(jnp.float32),
                              i_ref[...].astype(jnp.int32), d)
    if kind == "quant":
        c_ref, h_ref = leaf_refs
        return _dequant_block(c_ref[...], h_ref[...])
    if kind == "sparse_quant":
        c_ref, i_ref, h_ref = leaf_refs
        return _scatter_block(_dequant_block(c_ref[...], h_ref[...]),
                              i_ref[...].astype(jnp.int32), d)
    if kind == "mask":
        v_ref, w_ref = leaf_refs
        return _mask_expand_block(v_ref[...].astype(jnp.float32),
                                  w_ref[...], d)
    raise ValueError(kind)


def _make_rows_kernel(kind: str, d: int, project: bool, out_dtype):
    def kernel(*refs):
        if project:
            *leaf_refs, w_ref, o_ref = refs
            rows = _decode_block(kind, leaf_refs, d)
            rows = jnp.dot(rows, w_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)
        else:
            *leaf_refs, o_ref = refs
            rows = _decode_block(kind, leaf_refs, d)
        o_ref[...] = rows.astype(out_dtype)

    return kernel


def _rows_blocks(leading_shape, block_rows: int):
    rows = 1
    for s in leading_shape:
        rows *= s
    br = min(block_rows, rows)
    pad = (-rows) % br
    return rows, br, pad


@functools.partial(jax.jit, static_argnames=("kind", "d", "dtype",
                                             "block_rows", "interpret"))
def decode_rows_kernel(leaves, kind: str, d: int, w=None, *,
                       dtype=jnp.float32, block_rows: int = 128,
                       interpret: bool = True):
    """Fused one-pass decode: wire leaves -> dense (or projected) rows.

    leaves : tuple of wire arrays in `KIND_LEAVES[kind]` order, common
             leading shape (...,) + trailing (k|d|2)
    w      : optional (d, p) cut-projection matrix — fused epilogue, the
             decoded rows never materialize when it is given
    Returns (..., d) [or (..., p)] in `dtype`.
    """
    assert d <= 16384, "dense row must fit a VMEM row tile"
    lead = leaves[0].shape[:-1]
    rows, br, pad = _rows_blocks(lead, block_rows)
    flat = [a.reshape((rows, a.shape[-1])) for a in leaves]
    if pad:
        flat = [jnp.pad(a, ((0, pad), (0, 0))) for a in flat]
    grid = (flat[0].shape[0] // br,)
    in_specs = [pl.BlockSpec((br, a.shape[-1]), lambda i: (i, 0))
                for a in flat]
    operands = list(flat)
    project = w is not None
    p_out = d
    if project:
        p_out = w.shape[-1]
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        operands.append(w)

    out = pl.pallas_call(
        _make_rows_kernel(kind, d, project, jnp.dtype(dtype)),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, p_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((flat[0].shape[0], p_out),
                                       jnp.dtype(dtype)),
        interpret=interpret,
    )(*operands)
    if pad:
        out = out[:rows]
    return out.reshape(lead + (p_out,))


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def decode_to_slots_kernel(xbuf, leaves, slots, kind: str, *,
                           interpret: bool = True):
    """Decode flush rows straight into `xbuf[slots]`, one fused pass.

    xbuf   : (C + 1, d) arena cut-activation buffer (last row = scratch);
             ALIASED into the output — untouched rows keep their contents,
             and on TPU the update is in place (pair with a donated jit).
    leaves : tuple of stacked wire arrays, leading dim = flush rows n
    slots  : (n,) int32 arena slot per flush row (scalar-prefetched: the
             slot ids drive the output block index map, so row i's decoded
             tile is written directly to block `slots[i]` — no host-side
             dense staging and no separate scatter pass)

    Rows aimed at the same slot (the scratch-row padding convention) write
    identical zero rows, so duplicate targets are benign.
    """
    cap1, d = xbuf.shape
    assert d <= 16384, "dense row must fit a VMEM row tile"
    n = leaves[0].shape[0]
    flat = [a.reshape((n, a.shape[-1])) for a in leaves]

    def kernel(s_ref, x_ref, *rest):
        *leaf_refs, o_ref = rest
        o_ref[...] = _decode_block(kind, leaf_refs, d).astype(xbuf.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i, s: (s[i], 0))]
                 + [pl.BlockSpec((1, a.shape[-1]), lambda i, s: (i, 0))
                    for a in flat],
        out_specs=pl.BlockSpec((1, d), lambda i, s: (s[i], 0)))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cap1, d), xbuf.dtype),
        input_output_aliases={1: 0},    # xbuf (operand 1, after slots) -> out
        interpret=interpret,
    )(jnp.asarray(slots, jnp.int32), xbuf, *flat)
