"""jit'd public wrapper for the quantization kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.quant import kernel


@partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_dequantize(x, bits: int = 8, *, interpret: bool = True):
    _, deq, _, _ = kernel.quantize(x, bits, interpret=interpret)
    return deq
