"""Pallas TPU kernel: fused per-instance uniform quantize / dequantize.

One VMEM pass computes the per-row [min, max] range, the b-bit codes, and
the dequantized values (Eq. 2 of the paper) — on GPU this is three kernel
launches; on TPU it is one VMEM-resident fusion per row tile. Codes are
emitted as uint8 (TPU has no sub-byte addressing; wire packing to b bits is
host-side, core/wire.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, code_ref, deq_ref, lo_ref, step_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                 # (br, d)
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    n_bins = 2 ** bits
    step = (hi - lo) / n_bins
    step = jnp.where(step <= 0, 1.0, step)
    code = jnp.clip(jnp.floor((x - lo) / step), 0, n_bins - 1)
    code_ref[...] = code.astype(jnp.uint8)
    deq_ref[...] = (lo + (code + 0.5) * step).astype(x_ref.dtype)
    lo_ref[...] = lo[..., 0]
    step_ref[...] = step[..., 0]


@functools.partial(jax.jit, static_argnames=("bits", "block_rows",
                                             "interpret"))
def quantize(x, bits: int = 8, *, block_rows: int = 128,
             interpret: bool = True):
    """x: (..., d) -> (codes uint8, dequantized, lo (...,), step (...,))."""
    assert bits <= 8, "codes are uint8 on-device"
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    code, deq, lo, step = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br,), lambda i: (i,)),
                   pl.BlockSpec((br,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((x2.shape[0], d), jnp.uint8),
                   jax.ShapeDtypeStruct((x2.shape[0], d), x.dtype),
                   jax.ShapeDtypeStruct((x2.shape[0],), jnp.float32),
                   jax.ShapeDtypeStruct((x2.shape[0],), jnp.float32)],
        interpret=interpret,
    )(x2)
    if pad:
        code, deq, lo, step = (code[:rows], deq[:rows], lo[:rows],
                               step[:rows])
    return (code.reshape(orig_shape), deq.reshape(orig_shape),
            lo.reshape(orig_shape[:-1]), step.reshape(orig_shape[:-1]))
