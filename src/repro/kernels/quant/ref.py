"""Pure-jnp oracle for the quantization kernel (Eq. 2)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize(x, bits: int = 8):
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    n_bins = 2 ** bits
    step = (hi - lo) / n_bins
    step = jnp.where(step <= 0, 1.0, step)
    code = jnp.clip(jnp.floor((xf - lo) / step), 0, n_bins - 1)
    deq = (lo + (code + 0.5) * step).astype(x.dtype)
    return (code.astype(jnp.uint8), deq, lo[..., 0], step[..., 0])
