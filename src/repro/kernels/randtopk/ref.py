"""Pure-jnp oracle for the randtopk kernels (always the XLA path, so the
kernels can be validated against it regardless of the ambient backend)."""
from __future__ import annotations

import jax

from repro.core import selection


def topk_mask(x, k: int):
    return selection.topk_mask(x, k, backend="xla")


def kth_threshold(x, k: int):
    return selection.kth_magnitude_threshold(x, k)


def randtopk_mask(x, k: int, alpha: float, key):
    return selection.randtopk_mask(x, k, alpha, key, backend="xla")
