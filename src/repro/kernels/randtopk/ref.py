"""Pure-jnp oracle for the randtopk kernels."""
from __future__ import annotations

import jax

from repro.core import selection


def topk_mask(x, k: int):
    return selection.topk_mask(x, k)


def kth_threshold(x, k: int):
    return selection.kth_magnitude_threshold(x, k)


def randtopk_mask(x, k: int, alpha: float, key):
    return selection.randtopk_mask(x, k, alpha, key)
