"""Pallas TPU kernel: per-row top-k threshold + mask by bisection.

TPU adaptation of the paper's top-k selection. GPU implementations sort (or
warp-shuffle); sorting is hostile to the VPU/MXU lane layout. Instead we
bisect the magnitude range: 26 rounds of branch-free vectorized
compare-and-count over a VMEM-resident row tile converge the k-th-largest
|x| threshold to ~2^-26 of the row max, then a final compare emits the mask.
O(26 d) elementwise work per row, no data movement, fully lane-parallel.

Layout: rows tiled over the grid, the feature axis lives in VMEM whole
(d <= 16k floats per row = 64 KiB). Outputs: bool mask (rows, d) and the
threshold (rows,) — the wire payload (values, indices) is extracted by the
caller where needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_ITERS = 26


def _topk_mask_kernel(x_ref, mask_ref, thr_ref, *, k: int):
    x = x_ref[...]                                     # (br, d) in VMEM
    mag = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(mag, axis=-1, keepdims=True)          # (br, 1)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        ge = cnt >= k
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_ITERS, body, (lo, hi))
    mask = mag >= lo
    # tie clean-up: admit left-to-right among elements equal to the threshold
    gt = mag > lo
    need = k - jnp.sum(gt.astype(jnp.int32), axis=-1, keepdims=True)
    eq = mask & ~gt
    eq_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1)
    mask_ref[...] = gt | (eq & (eq_rank <= need))
    thr_ref[...] = lo[..., 0]


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_mask_threshold(x, k: int, *, block_rows: int = 128,
                        interpret: bool = True):
    """x: (..., d) -> (mask bool (..., d), thr f32 (...,)).

    interpret=True executes the kernel body on CPU for validation; on a TPU
    runtime pass interpret=False to emit the Mosaic kernel.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    assert d <= 16384, "feature axis must fit a VMEM row tile"
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)

    mask, thr = pl.pallas_call(
        functools.partial(_topk_mask_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((x2.shape[0], d), jnp.bool_),
                   jax.ShapeDtypeStruct((x2.shape[0],), jnp.float32)],
        interpret=interpret,
    )(x2)
    if pad:
        mask, thr = mask[:rows], thr[:rows]
    return mask.reshape(orig_shape), thr.reshape(orig_shape[:-1])
