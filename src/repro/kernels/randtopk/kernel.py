"""Pallas TPU kernels: per-row top-k threshold/mask and the Eq. (7)
randomized-selection mask, both by bisection.

TPU adaptation of the paper's top-k selection. GPU implementations sort (or
warp-shuffle); sorting is hostile to the VPU/MXU lane layout. Instead we
bisect a score range: 32 rounds of branch-free vectorized compare-and-count
over a VMEM-resident row tile converge the target-count threshold to
~2^-32 of the row range, then a final compare emits the mask. O(32 d)
elementwise work per row, no data movement, fully lane-parallel.

The same count-bisection primitive runs three times for the randomized
selection of Eq. (7): once on |x| for the deterministic top-k pool, then on
i.i.d. Gumbel scores restricted to the top-k pool (k - m picks) and to its
complement (m picks) — uniform-without-replacement via the Gumbel race, with
m ~ Binomial(k, alpha) precomputed per row by the caller. This is the
`backend="pallas"` implementation behind `core.selection.randtopk_mask`.

Exact-count guarantee: after bisection, elements >= hi are always admitted
(provably fewer than the target), elements in the final [lo, hi) band are
admitted left-to-right until the target is met — so every row selects
exactly `target` elements even under ties or unconverged bisection.

Layout: rows tiled over the grid, the feature axis lives in VMEM whole
(d <= 16k floats per row = 64 KiB). Outputs: bool mask (rows, d) and (for
the deterministic kernel) the threshold (rows,) — the wire payload
(values, indices) is extracted by the caller where needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_ITERS = 32
_BIG = 1e30  # finite +/- sentinel; keeps bisection arithmetic NaN-free


def _count_select(scores, pool, target):
    """Mask of exactly `target` largest `scores` within `pool`, per row.

    scores : f32 (br, d); pool : bool (br, d); target : int32 (br, 1).
    Bisection invariants: count(s >= lo) >= target, count(s >= hi) < target.
    `target` must not exceed the pool size; target == 0 selects nothing.
    """
    s = jnp.where(pool, scores, -_BIG)
    hi0 = jnp.max(s, axis=-1, keepdims=True)
    lo = jnp.min(jnp.where(pool, scores, _BIG), axis=-1, keepdims=True)
    lo = jnp.minimum(lo, hi0)  # empty pool: collapse to a sane interval
    # start strictly above the max so count(>= hi) == 0 < target holds
    hi = hi0 + (jnp.abs(hi0) + (hi0 - lo) + 1.0) * 1e-6

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((s >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        ge = cnt >= target
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_ITERS, body, (lo, hi))
    # elements above the final band are always in; the band fills the rest
    # left-to-right (exact-k even under ties / unconverged bisection)
    gt = s >= hi
    band = (s >= lo) & ~gt
    need = target - jnp.sum(gt.astype(jnp.int32), axis=-1, keepdims=True)
    band_rank = jnp.cumsum(band.astype(jnp.int32), axis=-1)
    sel = gt | (band & (band_rank <= need))
    return jnp.where(target > 0, sel, jnp.zeros_like(sel)), lo


def _topk_mask_kernel(x_ref, mask_ref, thr_ref, *, k: int):
    x = x_ref[...]                                     # (br, d) in VMEM
    mag = jnp.abs(x.astype(jnp.float32))
    target = jnp.full(mag.shape[:-1] + (1,), k, jnp.int32)
    mask, thr = _count_select(mag, jnp.ones_like(mag, dtype=bool), target)
    mask_ref[...] = mask
    thr_ref[...] = thr[..., 0]


def _randtopk_mask_kernel(x_ref, g_ref, m_ref, mask_ref, *, k: int):
    """Eq. (7) in-kernel: top-k pool by |x| bisection, then two Gumbel-race
    pool selections (k - m from the top pool, m from its complement)."""
    x = x_ref[...]
    g = g_ref[...]                                     # i.i.d. Gumbel (br, d)
    m = m_ref[...].astype(jnp.int32)                   # (br, 1) non-top picks
    mag = jnp.abs(x.astype(jnp.float32))
    k_arr = jnp.full(mag.shape[:-1] + (1,), k, jnp.int32)
    is_top, _ = _count_select(mag, jnp.ones_like(mag, dtype=bool), k_arr)
    sel_top, _ = _count_select(g, is_top, k_arr - m)
    sel_non, _ = _count_select(g, ~is_top, m)
    mask_ref[...] = sel_top | sel_non


def _scatter_rows_kernel(v_ref, i_ref, o_ref, *, k: int):
    """Per-row sparse scatter: o[r, i[r, j]] = v[r, j] for j < k.

    The decode-side counterpart of the selection kernels: (values, indices)
    off the wire become the dense cut view without ever leaving the device.
    No gather/scatter unit is used — each of the k support elements is
    placed by one branch-free lane-parallel compare-and-select over the
    VMEM-resident row tile, accumulated in f32 (O(k d) elementwise work,
    same layout-friendliness as the bisection kernels above). Support
    indices are unique per row by construction (a top-k support); duplicate
    indices would *sum* here where XLA's put_along_axis keeps one write.
    """
    v = v_ref[...].astype(jnp.float32)                 # (br, k)
    idx = i_ref[...].astype(jnp.int32)                 # (br, k)
    lanes = jax.lax.broadcasted_iota(jnp.int32, o_ref.shape, 1)

    def body(j, acc):
        ij = jax.lax.dynamic_slice_in_dim(idx, j, 1, axis=1)   # (br, 1)
        vj = jax.lax.dynamic_slice_in_dim(v, j, 1, axis=1)
        return acc + jnp.where(lanes == ij, vj, 0.0)

    o_ref[...] = jax.lax.fori_loop(
        0, k, body, jnp.zeros(o_ref.shape, jnp.float32))


def _rows_blocks(x, block_rows: int):
    orig_shape = x.shape
    d = orig_shape[-1]
    assert d <= 16384, "feature axis must fit a VMEM row tile"
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    br = min(block_rows, rows)
    pad = (-rows) % br
    return orig_shape, d, rows, br, pad


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_mask_threshold(x, k: int, *, block_rows: int = 128,
                        interpret: bool = True):
    """x: (..., d) -> (mask bool (..., d), thr f32 (...,)).

    interpret=True executes the kernel body on CPU for validation; on a TPU
    runtime pass interpret=False to emit the Mosaic kernel.
    """
    orig_shape, d, rows, br, pad = _rows_blocks(x, block_rows)
    x2 = x.reshape(rows, d)
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)

    mask, thr = pl.pallas_call(
        functools.partial(_topk_mask_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((x2.shape[0], d), jnp.bool_),
                   jax.ShapeDtypeStruct((x2.shape[0],), jnp.float32)],
        interpret=interpret,
    )(x2)
    if pad:
        mask, thr = mask[:rows], thr[:rows]
    return mask.reshape(orig_shape), thr.reshape(orig_shape[:-1])


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def randtopk_mask_kernel(x, gumbel, m, k: int, *, block_rows: int = 128,
                         interpret: bool = True):
    """Eq. (7) randomized-selection mask, fused in one Pallas kernel.

    x      : (..., d) activations
    gumbel : (..., d) f32 i.i.d. Gumbel noise
    m      : (..., 1) int32 non-top-k pick counts, pre-clipped to
             [0, min(k, d - k)] (see selection.binomial_nontop_count)
    Returns a bool mask with exactly k selected per row.
    """
    orig_shape, d, rows, br, pad = _rows_blocks(x, block_rows)
    x2 = x.reshape(rows, d)
    g2 = gumbel.reshape(rows, d).astype(jnp.float32)
    m2 = m.reshape(rows, 1).astype(jnp.int32)
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        g2 = jnp.pad(g2, ((0, pad), (0, 0)))
        m2 = jnp.pad(m2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)

    mask = pl.pallas_call(
        functools.partial(_randtopk_mask_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], d), jnp.bool_),
        interpret=interpret,
    )(x2, g2, m2)
    if pad:
        mask = mask[:rows]
    return mask.reshape(orig_shape)


@functools.partial(jax.jit,
                   static_argnames=("d", "block_rows", "interpret"))
def scatter_rows_kernel(values, indices, d: int, *, block_rows: int = 128,
                        interpret: bool = True):
    """Sparse wire payload -> dense rows, fused on device.

    values  : (..., k) selected values (any float dtype; accumulated f32)
    indices : (..., k) support indices (uint16/int32)
    Returns the dense (..., d) scatter with values.dtype, zeros elsewhere.
    This is the `backend="pallas"` implementation behind the sparse branch
    of `core.compressors.payload_to_dense` — the decode half that
    `runtime.server` runs per flush straight into the slot arena.
    """
    orig_shape, k, rows, br, pad = _rows_blocks(values, block_rows)
    assert d <= 16384, "dense row must fit a VMEM row tile"
    v2 = values.reshape(rows, k)
    i2 = indices.reshape(rows, k).astype(jnp.int32)
    if pad:
        v2 = jnp.pad(v2, ((0, pad), (0, 0)))
        i2 = jnp.pad(i2, ((0, pad), (0, 0)))
    grid = (v2.shape[0] // br,)

    dense = pl.pallas_call(
        functools.partial(_scatter_rows_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((br, k), lambda i: (i, 0)),
                  pl.BlockSpec((br, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v2.shape[0], d), jnp.float32),
        interpret=interpret,
    )(v2, i2)
    if pad:
        dense = dense[:rows]
    return dense.reshape(orig_shape[:-1] + (d,)).astype(values.dtype)
