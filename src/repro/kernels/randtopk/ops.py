"""jit'd public wrappers around the randtopk Pallas kernels.

These are the `backend="pallas"` implementations behind
`core.selection.topk_mask` / `randtopk_mask` (interpret mode off-TPU,
Mosaic on a TPU runtime). The deterministic support and the Eq. (7)
randomization (Binomial pool split + Gumbel race) both run in-kernel; only
the PRNG draws (Gumbel noise, Binomial counts) are generated outside with
`jax.random` and streamed in as kernel operands.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.randtopk import kernel


@partial(jax.jit, static_argnames=("k", "interpret"))
def topk_mask(x, k: int, *, interpret: bool = True):
    if k >= x.shape[-1]:
        return jnp.ones_like(x, dtype=bool)
    mask, _ = kernel.topk_mask_threshold(x, k, interpret=interpret)
    return mask


@partial(jax.jit, static_argnames=("d", "interpret"))
def scatter_rows(values, indices, d: int, *, interpret: bool = True):
    """Dense (..., d) rows from a sparse (values, indices) wire payload.

    The decode-side kernel: what `sparse_to_dense`/`put_along_axis` does on
    the host happens in VMEM instead, so a compressed payload is densified
    only on device (the serving arena's `decode_to_slots` path). Support
    indices must be unique per row (any top-k support is); duplicates sum.
    """
    return kernel.scatter_rows_kernel(values, indices, d,
                                      interpret=interpret)


@partial(jax.jit, static_argnames=("k", "alpha", "interpret"))
def randtopk_mask(x, k: int, alpha: float, key, *, interpret: bool = True):
    """Kernel-backed Eq. (7) selection mask (fused top-k + Gumbel race)."""
    from repro.core import selection

    d = x.shape[-1]
    if k >= d:
        return jnp.ones_like(x, dtype=bool)
    kb, kg = jax.random.split(key)
    m = selection.binomial_nontop_count(kb, alpha, k, d, x.shape[:-1])
    g = jax.random.gumbel(kg, x.shape, dtype=jnp.float32)
    return kernel.randtopk_mask_kernel(x, g, m, k, interpret=interpret)
