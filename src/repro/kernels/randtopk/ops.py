"""jit'd public wrappers around the randtopk Pallas kernel.

The kernel produces the deterministic top-k support; the Eq. (7)
randomization (Binomial pool split + Gumbel race) composes on top in plain
jnp — it is O(d) elementwise and not a hot spot.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.kernels.randtopk import kernel


@partial(jax.jit, static_argnames=("k", "interpret"))
def topk_mask(x, k: int, *, interpret: bool = True):
    mask, _ = kernel.topk_mask_threshold(x, k, interpret=interpret)
    return mask


@partial(jax.jit, static_argnames=("k", "alpha", "interpret"))
def randtopk_mask(x, k: int, alpha: float, key, *, interpret: bool = True):
    """Kernel-backed Eq. (7) selection mask."""
    d = x.shape[-1]
    if k >= d:
        return jnp.ones_like(x, dtype=bool)
    is_top, _ = kernel.topk_mask_threshold(x, k, interpret=interpret)
    kb, kg = jax.random.split(key)
    draws = jax.random.bernoulli(kb, alpha, x.shape[:-1] + (k,))
    m = jnp.clip(jnp.sum(draws.astype(jnp.int32), axis=-1, keepdims=True),
                 0, min(k, d - k))
    g = jax.random.gumbel(kg, x.shape, dtype=jnp.float32)
    sel_top = selection._select_m_from_pool(g, is_top, k - m, k)
    sel_non = selection._select_m_from_pool(g, ~is_top, m, k)
    return sel_top | sel_non
