"""Shared layers: norms, RoPE, initializers, param-spec helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def normal_init(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, params, kind="rms"):
    if kind == "layer":
        return layer_norm(x, params["scale"], params["bias"])
    return rms_norm(x, params["scale"])


def init_norm(d, dtype, kind="rms"):
    if kind == "layer":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def norm_spec(kind="rms"):
    if kind == "layer":
        return {"scale": P(), "bias": P()}
    return {"scale": P()}


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int):
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Param tree helpers
# --------------------------------------------------------------------------

def stack_layer_params(per_layer):
    """List of identical pytrees -> single pytree with leading layer axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_layer)


def stacked_spec(spec, n_prefix=1):
    """Prepend `None` axes to every PartitionSpec in a tree (layer stacking)."""
    return jax.tree_util.tree_map(
        lambda s: P(*([None] * n_prefix), *tuple(s)),
        spec,
        is_leaf=lambda s: isinstance(s, P),
    )


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
