"""Mamba2 block (SSD — structured state-space duality), chunked scan form.

Training/prefill uses the chunked SSD algorithm: within a chunk of length c
the contribution is a masked quadratic form (MXU-friendly einsums); across
chunks a sequential `lax.scan` carries the (B, H, P, N) state. All decay
factors are exp(non-positive) so the computation is overflow-free. Decode is
the exact one-step recurrence with a depthwise-conv ring buffer.

Single KV-group (G=1) variant; head dim P = cfg.ssm_head_dim, state N =
cfg.ssm_state, inner width = ssm_expand * d_model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common, tp
from repro.models.config import ArchConfig, Runtime


def init_mamba(key, cfg: ArchConfig):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    dt = cfg.pdtype()
    ks = jax.random.split(key, 8)
    return {
        "norm": common.init_norm(d, dt, cfg.norm),
        "w_xz": common.normal_init(ks[0], (d, 2 * di), dt),
        "w_bc": common.normal_init(ks[1], (d, 2 * N), dt),
        "w_dt": common.normal_init(ks[2], (d, H), dt),
        "conv_x": common.normal_init(ks[3], (K, di), dt, scale=0.1),
        "conv_b": common.normal_init(ks[4], (K, N), dt, scale=0.1),
        "conv_c": common.normal_init(ks[5], (K, N), dt, scale=0.1),
        "A_log": jnp.zeros((H,), dt),            # A = -exp(A_log) in (-inf, 0)
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.full((H,), -2.0, dt),     # softplus(-2) ~ 0.13
        "norm_g": common.init_norm(di, dt, "rms"),
        "w_out": common.normal_init(ks[6], (di, d), dt,
                                    scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }


def mamba_spec(cfg: ArchConfig):
    return {
        "norm": common.norm_spec(cfg.norm),
        "w_xz": P("data", "model"),
        "w_bc": P("data", None),
        "w_dt": P("data", None),
        "conv_x": P(None, "model"),
        "conv_b": P(None, None),
        "conv_c": P(None, None),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm_g": {"scale": P("model")},
        "w_out": P("model", "data"),
    }


def _causal_conv(u, w):
    """Depthwise causal conv. u: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pads = [jnp.pad(u, ((0, 0), (K - 1 - i, 0), (0, 0)))[:, : u.shape[1]] if i < K - 1
            else u for i in range(K)]
    acc = sum(pads[i] * w[i].astype(u.dtype) for i in range(K))
    return jax.nn.silu(acc)


def _project(p, cfg: ArchConfig, x):
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xz = x @ p["w_xz"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                     # (B,S,di) each
    bc = x @ p["w_bc"].astype(x.dtype)
    b, c = jnp.split(bc, 2, axis=-1)                      # (B,S,N)
    dt_raw = x @ p["w_dt"].astype(x.dtype)                # (B,S,H)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return xs, z, b, c, dt


def _ssd_chunk(h, inputs, *, H, Pd, N):
    """One SSD chunk. h: (B,H,P,N) f32 carry.

    inputs: xs (B,c,H,P) f32, b/cm (B,c,N) f32, dt (B,c,H) f32, la (B,c,H) f32
    (la = log decay per step, <= 0). Returns (h', y (B,c,H,P) f32).
    """
    xs, b, cm, dt, la = inputs
    L = jnp.cumsum(la, axis=1)                            # (B,c,H) <= 0, decr.
    tot = L[:, -1]                                        # (B,H)
    # state contribution: y1[t] = C_t . (exp(L_t) * h)
    y1 = jnp.einsum("bcn,bch,bhpn->bchp", cm, jnp.exp(L), h)
    # intra-chunk: decay(t,s) = exp(L_t - L_s) for s <= t  (<= 1, safe)
    dec = jnp.exp(L[:, :, None, :] - L[:, None, :, :])    # (B,t,s,H)
    mask = jnp.tril(jnp.ones((L.shape[1], L.shape[1]), bool))
    dec = jnp.where(mask[None, :, :, None], dec, 0.0)
    y2 = jnp.einsum("btn,bsn,btsh,bsh,bshp->bthp", cm, b, dec, dt, xs)
    # new state: h' = exp(tot) h + sum_s exp(tot - L_s) dt_s B_s x_s
    carry_dec = jnp.exp(tot[:, None, :] - L)              # (B,c,H) <= 1
    h_new = jnp.exp(tot)[:, :, None, None] * h + jnp.einsum(
        "bsn,bsh,bsh,bshp->bhpn", b, carry_dec, dt, xs)
    return h_new, y1 + y2


def mamba(p, cfg: ArchConfig, rt: Runtime, x):
    """Full-sequence Mamba2 mixer. x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xs, z, b, c, dt = _project(p, cfg, x)
    xs = _causal_conv(xs, p["conv_x"])
    b = _causal_conv(b, p["conv_b"])
    c = _causal_conv(c, p["conv_c"])
    xs = rt.shard(xs, "batch", None, "model")

    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H,)
    la = dt * A[None, None, :]                            # (B,S,H) log-decay
    xs4 = xs.reshape(B, S, H, Pd).astype(jnp.float32)
    bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)

    cl = min(rt.ssm_chunk, S)
    assert S % cl == 0, f"seq {S} must divide ssm_chunk {cl}"
    nc = S // cl

    def to_chunks(a):
        return a.reshape(B, nc, cl, *a.shape[2:]).swapaxes(0, 1)

    seq = (to_chunks(xs4), to_chunks(bf), to_chunks(cf), to_chunks(dt),
           to_chunks(la))

    def body(h, chunk_in):
        return _ssd_chunk(h, chunk_in, H=H, Pd=Pd, N=N)

    body_fn = jax.checkpoint(body) if rt.remat else body
    h0 = jnp.zeros((B, H, Pd, N), jnp.float32)
    _, ys = jax.lax.scan(body_fn, h0, seq)
    y = ys.swapaxes(0, 1).reshape(B, S, H, Pd)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs4
    y = y.reshape(B, S, di).astype(x.dtype)
    y = common.rms_norm(y * jax.nn.silu(z), p["norm_g"]["scale"])
    out = tp.out_proj_rs(y, p["w_out"], rt)
    return rt.shard(out, "batch", "seq", None)


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def init_mamba_cache(cfg: ArchConfig, batch: int):
    di, N, H, Pd, K = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_head_dim, cfg.ssm_conv)
    return {
        "h": jnp.zeros((batch, H, Pd, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, di + 2 * N), cfg.adtype()),
    }


def mamba_cache_spec(rt: Runtime):
    return {"h": rt.pspec("batch", None, None, None),
            "conv": rt.pspec("batch", None, None)}


def mamba_decode(p, cfg: ArchConfig, rt: Runtime, x_tok, cache):
    """One-step recurrence. x_tok: (B, 1, d)."""
    B = x_tok.shape[0]
    di, N, H, Pd, K = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_head_dim, cfg.ssm_conv)
    xs, z, b, c, dt = _project(p, cfg, x_tok)
    u = jnp.concatenate([xs, b, c], axis=-1)[:, 0]        # (B, di+2N)
    hist = jnp.concatenate([cache["conv"], u[:, None]], axis=1)  # (B,K,di+2N)
    w = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=-1)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                                      w.astype(jnp.float32)))
    xs1, b1, c1 = jnp.split(conv_out, [di, di + N], axis=-1)
    new_conv = hist[:, 1:].astype(cache["conv"].dtype)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt1 = dt[:, 0]                                        # (B,H)
    a = jnp.exp(dt1 * A[None, :])                         # (B,H)
    xh = xs1.reshape(B, H, Pd)
    h = cache["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt1, b1, xh)
    y = jnp.einsum("bn,bhpn->bhp", c1, h)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, di).astype(x_tok.dtype)
    y = common.rms_norm(y * jax.nn.silu(z), p["norm_g"]["scale"])
    out = y @ p["w_out"].astype(x_tok.dtype)
    return rt.shard(out, "batch", None, None), {"h": h, "conv": new_conv}
