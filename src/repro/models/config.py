"""Architecture + runtime configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SplitConfig:
    """Cut-layer placement + compression — the paper's technique as a
    first-class framework feature."""

    cut_layer: int = 0              # residual-stream boundary after this block index
    compressor: str = "randtopk"    # see core.make_compressor
    k: int = 64                     # non-zeros per token vector
    alpha: float = 0.1              # RandTopk randomness (Eq. 7)
    quant_bits: int = 4
    l1_lam: float = 1e-4
    transfer_over_pod: bool = True  # ppermute payload across the pod axis
    backend: Optional[str] = None   # selection backend: None->auto (pallas on
                                    # TPU, xla elsewhere), 'xla', 'pallas'


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm: str = "rms"               # rms | layer
    # --- MoE ---
    n_experts: int = 0
    topk_experts: int = 0
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0             # zamba2: shared attn block every N mamba layers
    # --- RWKV6 ---
    rwkv: bool = False
    rwkv_lora: int = 64
    # --- VLM ---
    cross_attn_every: int = 0       # a cross-attn layer every N layers
    n_image_tokens: int = 0
    # --- audio enc-dec ---
    encdec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 0
    # --- attention variants ---
    sliding_window: int = 0         # 0 = full causal attention
    # --- numerics ---
    param_dtype: str = "float32"
    dtype: str = "float32"
    kv_cache_bits: int = 0          # serving-arena KV cache width: 8 ->
    #   int8 codes + f32 per-(token, head) scale rows in the arena
    #   (attention.init_kv_cache); 0 -> the Runtime default (f32). Applies
    #   to the label owner's top-model cache only — clients keep f32.
    # --- split learning ---
    split: Optional[SplitConfig] = None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding tables are padded to a multiple of 256
        (model-axis x lane-width friendly); logits over the pad slots train
        toward -inf and are never sampled (labels < vocab)."""
        return (self.vocab + 255) // 256 * 256

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def adtype(self):
        return jnp.dtype(self.dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution-environment knobs threaded through model code."""

    mesh: Optional[jax.sharding.Mesh] = None
    remat: bool = True
    attn_chunk: int = 1024          # query-chunk length for long-sequence attention
    ssm_chunk: int = 128            # SSD chunk length
    rwkv_chunk: int = 16
    rwkv_mode: str = "chunk"        # chunk (matrix form) | scan (sequential)
    moe_capacity: float = 1.25
    use_pallas: bool = False        # Pallas kernels (interpret on CPU) for hot spots
    training: bool = True
    seq_shard: bool = True          # Megatron-style sequence parallelism on the
                                    # residual stream at layer boundaries (shards
                                    # saved activations over 'model')
    kv_cache_bits: int = 16         # 8 -> int8 KV cache (+ f32 scales): halves
                                    # decode HBM footprint, ~1e-2 logit error
    flash_decode: bool = True       # shard decode KV caches over 'model' on the
                                    # SEQUENCE dim (GQA head counts can't split a
                                    # 16-way axis; replication costs 16x memory)
    dp_only: bool = False           # ZeRO-3 mode: the 'model' mesh axis joins the
                                    # batch axes; params are fully sharded over all
                                    # axes and gathered per use; no TP activation
                                    # collectives (best for small-d archs)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    @property
    def batch_axes(self):
        names = (("pod", "data", "model") if self.dp_only
                 else ("pod", "data"))
        ax = tuple(a for a in names if a in self.axis_names)
        return ax if ax else None

    @property
    def has_model_axis(self) -> bool:
        return "model" in self.axis_names

    def pspec(self, *logical):
        """Translate logical axis names -> PartitionSpec for the ambient mesh.

        Logical names: 'batch' (pod+data), 'model', 'data', 'seq' (model axis
        iff seq_shard — sequence parallelism), None.
        """
        from jax.sharding import PartitionSpec as P

        if self.mesh is None:
            return P()
        out = []
        for name in logical:
            if name == "batch":
                out.append(self.batch_axes)
            elif name == "seq":
                out.append("model" if (self.seq_shard and not self.dp_only and
                                       "model" in self.axis_names) else None)
            elif name == "flashdecode":
                out.append("model" if (self.flash_decode and not self.dp_only
                                       and "model" in self.axis_names)
                           else None)
            elif name == "model" and self.dp_only:
                out.append(None)
            elif name in ("model", "data", "pod"):
                out.append(name if name in self.axis_names else None)
            else:
                out.append(None)
        return P(*out)

    def shard(self, x, *logical):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, self.pspec(*logical))
        )
