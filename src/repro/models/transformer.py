"""Unified model: embeds -> family-specific layer stack(s) -> LM head.

Layers are stacked and driven by `lax.scan` (compile time is O(1) in depth).
Heterogeneous stacks (zamba2 hybrid, VLM cross-attn interleave, whisper
enc-dec) scan over their repeating group. The split-learning cut is a
first-class residual-stream boundary: `apply_layers(..., lo, hi)` runs any
contiguous layer range, and the SplitModel (repro.split) composes
bottom-range -> compress -> transfer -> top-range.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention, common, mlp, moe, rwkv, ssm
from repro.models.config import ArchConfig, Runtime


# ==========================================================================
# Init / specs
# ==========================================================================

def _layer_init(key, cfg: ArchConfig):
    """One decoder layer's params for dense/moe families."""
    k1, k2 = jax.random.split(key)
    p = {"attn": attention.init_attention(k1, cfg)}
    if cfg.family == "moe":
        p["moe"] = moe.init_moe(k2, cfg)
    else:
        p["mlp"] = mlp.init_mlp(k2, cfg)
    return p


def _layer_spec(cfg: ArchConfig):
    p = {"attn": attention.attention_spec(cfg)}
    if cfg.family == "moe":
        p["moe"] = moe.moe_spec(cfg)
    else:
        p["mlp"] = mlp.mlp_spec(cfg)
    return p


def init_model(key, cfg: ArchConfig):
    keys = jax.random.split(key, 8)
    dt = cfg.pdtype()
    params: Dict[str, Any] = {
        "embed": common.normal_init(keys[0], (cfg.padded_vocab, cfg.d_model),
                                    dt),
        "final_norm": common.init_norm(cfg.d_model, dt, cfg.norm),
        "unembed": common.normal_init(keys[1], (cfg.d_model, cfg.padded_vocab),
                                      dt),
    }
    L = cfg.n_layers

    def stack(init_fn, n, key):
        return common.stack_layer_params(
            [init_fn(k) for k in jax.random.split(key, n)])

    if cfg.family in ("dense", "moe"):
        params["layers"] = stack(lambda k: _layer_init(k, cfg), L, keys[2])
    elif cfg.family == "hybrid":
        params["layers"] = stack(lambda k: ssm.init_mamba(k, cfg), L, keys[2])
        params["shared_attn"] = attention.init_attention(keys[3], cfg)
        params["shared_mlp"] = mlp.init_mlp(keys[4], cfg)
    elif cfg.family == "ssm":  # rwkv6
        params["layers"] = stack(
            lambda k: {"time": rwkv.init_rwkv_time(jax.random.fold_in(k, 0), cfg),
                       "chan": rwkv.init_rwkv_channel(jax.random.fold_in(k, 1), cfg)},
            L, keys[2])
    elif cfg.family == "vlm":
        n_cross = L // cfg.cross_attn_every
        n_self = L - n_cross
        params["layers"] = stack(lambda k: _layer_init(k, cfg), n_self, keys[2])
        params["cross_layers"] = stack(
            lambda k: {"attn": attention.init_attention(
                           jax.random.fold_in(k, 0), cfg, cross=True, gated=True),
                       "mlp": mlp.init_mlp(jax.random.fold_in(k, 1), cfg, gated=True)},
            n_cross, keys[3])
    elif cfg.family == "audio":
        params["enc_layers"] = stack(lambda k: {
            "attn": attention.init_attention(jax.random.fold_in(k, 0), cfg),
            "mlp": mlp.init_mlp(jax.random.fold_in(k, 1), cfg)},
            cfg.n_enc_layers, keys[2])
        params["enc_norm"] = common.init_norm(cfg.d_model, dt, cfg.norm)
        params["layers"] = stack(lambda k: {
            "attn": attention.init_attention(jax.random.fold_in(k, 0), cfg),
            "cross": attention.init_attention(jax.random.fold_in(k, 1), cfg),
            "mlp": mlp.init_mlp(jax.random.fold_in(k, 2), cfg)},
            L, keys[3])
    else:
        raise ValueError(cfg.family)
    return params


def param_spec(cfg: ArchConfig) -> Dict[str, Any]:
    spec: Dict[str, Any] = {
        "embed": P("model", "data"),
        "final_norm": common.norm_spec(cfg.norm),
        "unembed": P("data", "model"),
    }
    st = common.stacked_spec
    if cfg.family in ("dense", "moe"):
        spec["layers"] = st(_layer_spec(cfg))
    elif cfg.family == "hybrid":
        spec["layers"] = st(ssm.mamba_spec(cfg))
        spec["shared_attn"] = attention.attention_spec(cfg)
        spec["shared_mlp"] = mlp.mlp_spec(cfg)
    elif cfg.family == "ssm":
        spec["layers"] = st({"time": rwkv.rwkv_time_spec(cfg),
                             "chan": rwkv.rwkv_channel_spec(cfg)})
    elif cfg.family == "vlm":
        spec["layers"] = st(_layer_spec(cfg))
        spec["cross_layers"] = st({
            "attn": attention.attention_spec(cfg, cross=True, gated=True),
            "mlp": mlp.mlp_spec(cfg, gated=True)})
    elif cfg.family == "audio":
        spec["enc_layers"] = st({"attn": attention.attention_spec(cfg),
                                 "mlp": mlp.mlp_spec(cfg)})
        spec["enc_norm"] = common.norm_spec(cfg.norm)
        spec["layers"] = st({"attn": attention.attention_spec(cfg),
                             "cross": attention.attention_spec(cfg),
                             "mlp": mlp.mlp_spec(cfg)})
    return spec


def _norm(cfg, rt: Runtime = None):
    """Pre-norm in the sequence-sharded domain; the normalized bf16 output is
    then gathered to full-S (Megatron SP ordering: AG happens AFTER the norm
    and in the activation dtype, not on an f32 upcast of the residual)."""
    if rt is None:
        return lambda x, p: common.apply_norm(x, p, cfg.norm)

    from repro.models import tp

    def nf(x, p):
        y = common.apply_norm(x, p, cfg.norm)
        if x.ndim == 3 and x.shape[1] > 1:
            y = tp.gather_seq(y, rt)
        return y

    return nf


def _tree_slice(tree, lo, hi):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


# ==========================================================================
# Full-sequence forward (training / prefill)
# ==========================================================================

def _dense_layer_fwd(pl, cfg, rt, x, extras):
    nf = _norm(cfg, rt)
    x = x + attention.full_attention(pl["attn"], cfg, rt,
                                     nf(x, pl["attn"]["norm"]))
    if cfg.family == "moe" and "moe" in pl:
        y, aux = moe.moe(pl["moe"], cfg, rt, nf(x, pl["moe"]["norm"]))
        return x + y, aux
    x = x + mlp.mlp(pl["mlp"], cfg, rt, nf(x, pl["mlp"]["norm"]))
    return x, jnp.zeros((), jnp.float32)


def _scan_layers(body, params_stack, x, rt: Runtime):
    """scan body(x, layer_params) -> (x, aux); accumulates aux."""
    def f(carry, pl):
        x, aux = carry
        # sequence-parallel boundary: saved (rematerialization-checkpoint)
        # activations are sharded over 'model' instead of replicated
        x = rt.shard(x, "batch", "seq", None)
        x2, a = body(x, pl)
        return (x2, aux + a), None

    wrapped = jax.checkpoint(f) if rt.remat else f
    (x, aux), _ = jax.lax.scan(wrapped, (x, jnp.zeros((), jnp.float32)),
                               params_stack)
    return x, aux


def apply_layers(params, cfg: ArchConfig, rt: Runtime, x, extras, lo: int,
                 hi: int):
    """Run layers [lo, hi) over x: (B, S, d). Returns (x, aux_loss)."""
    nf = _norm(cfg, rt)
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "moe"):
        stack = _tree_slice(params["layers"], lo, hi)
        return _scan_layers(
            lambda x, pl: _dense_layer_fwd(pl, cfg, rt, x, extras),
            stack, x, rt)

    if cfg.family == "hybrid":
        flags = jnp.array([(i + 1) % cfg.attn_every == 0
                           for i in range(cfg.n_layers)])[lo:hi]
        stack = _tree_slice(params["layers"], lo, hi)
        sa, sm = params["shared_attn"], params["shared_mlp"]

        def body(x, inp):
            pl, flag = inp
            x = x + ssm.mamba(pl, cfg, rt, nf(x, pl["norm"]))

            def with_attn(x):
                h = x + attention.full_attention(sa, cfg, rt,
                                                 nf(x, sa["norm"]))
                return h + mlp.mlp(sm, cfg, rt, nf(h, sm["norm"]))

            x = jax.lax.cond(flag, with_attn, lambda x: x, x)
            return x, jnp.zeros((), jnp.float32)

        return _scan_layers(body, (stack, flags), x, rt)

    if cfg.family == "ssm":
        stack = _tree_slice(params["layers"], lo, hi)

        def body(x, pl):
            y, _ = rwkv.rwkv_time_mix(pl["time"], cfg, rt,
                                      nf(x, pl["time"]["norm"]))
            x = x + y
            y2, _ = rwkv.rwkv_channel_mix(pl["chan"], cfg, rt,
                                          nf(x, pl["chan"]["norm"]))
            return x + y2, jnp.zeros((), jnp.float32)

        return _scan_layers(body, stack, x, rt)

    if cfg.family == "vlm":
        g = cfg.cross_attn_every
        assert lo % g == 0 and hi % g == 0, "vlm cut must align to groups"
        glo, ghi = lo // g, hi // g
        n_groups = ghi - glo
        self_stack = jax.tree_util.tree_map(
            lambda a: a.reshape(cfg.n_layers // g, g - 1, *a.shape[1:])
                       [glo:ghi], params["layers"])
        cross_stack = _tree_slice(params["cross_layers"], glo, ghi)
        patches = extras["patches"]

        def body(x, inp):
            selfs, crossp = inp

            def inner(x, pl):
                y, _ = _dense_layer_fwd(pl, cfg, rt, x, extras)
                return y, None

            x, _ = jax.lax.scan(inner, x, selfs)
            h = nf(x, crossp["attn"]["norm"])
            x = x + attention.cross_attention(crossp["attn"], cfg, rt, h,
                                              patches, gated=True)
            x = x + mlp.mlp(crossp["mlp"], cfg, rt,
                            nf(x, crossp["mlp"]["norm"]), gated=True)
            return x, jnp.zeros((), jnp.float32)

        return _scan_layers(body, (self_stack, cross_stack), x, rt)

    if cfg.family == "audio":
        enc_out = extras["enc_out"]
        stack = _tree_slice(params["layers"], lo, hi)

        def body(x, pl):
            x = x + attention.full_attention(pl["attn"], cfg, rt,
                                             nf(x, pl["attn"]["norm"]))
            x = x + attention.cross_attention(pl["cross"], cfg, rt,
                                              nf(x, pl["cross"]["norm"]),
                                              enc_out)
            x = x + mlp.mlp(pl["mlp"], cfg, rt, nf(x, pl["mlp"]["norm"]))
            return x, jnp.zeros((), jnp.float32)

        return _scan_layers(body, stack, x, rt)

    raise ValueError(cfg.family)


def run_encoder(params, cfg: ArchConfig, rt: Runtime, frames):
    """Whisper encoder over stubbed frame embeddings (B, F, d)."""
    pos = common.sinusoidal_positions(frames.shape[1], cfg.d_model)
    x = frames + pos[None].astype(frames.dtype)
    nf = _norm(cfg, rt)

    def body(x, pl):
        x = x + attention.full_attention(pl["attn"], cfg, rt,
                                         nf(x, pl["attn"]["norm"]),
                                         causal=False, rope=False)
        x = x + mlp.mlp(pl["mlp"], cfg, rt, nf(x, pl["mlp"]["norm"]))
        return x, jnp.zeros((), jnp.float32)

    x, _ = _scan_layers(body, params["enc_layers"], x, rt)
    return common.apply_norm(x, params["enc_norm"], cfg.norm)


def embed(params, cfg: ArchConfig, rt: Runtime, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())
    return rt.shard(x, "batch", None, None)


def lm_head(params, cfg: ArchConfig, rt: Runtime, x):
    x = common.apply_norm(x, params["final_norm"], cfg.norm)
    logits = x @ params["unembed"].astype(x.dtype)
    return rt.shard(logits, "batch", None, "model")


def make_extras(params, cfg: ArchConfig, rt: Runtime, batch):
    """Family-specific side inputs from the batch dict."""
    if cfg.family == "vlm":
        return {"patches": batch["patches"]}
    if cfg.family == "audio":
        return {"enc_out": run_encoder(params, cfg, rt, batch["frames"])}
    return {}


def forward(params, cfg: ArchConfig, rt: Runtime, batch,
            *, key=None) -> Tuple[jax.Array, jax.Array]:
    """Full forward (no split). Returns (logits, aux_loss)."""
    extras = make_extras(params, cfg, rt, batch)
    x = embed(params, cfg, rt, batch["tokens"])
    x, aux = apply_layers(params, cfg, rt, x, extras, 0, cfg.n_layers)
    return lm_head(params, cfg, rt, x), aux


def cross_entropy(logits, labels, rt: Runtime):
    """CE with model-sharded vocab; reductions lower to psums."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ==========================================================================
# Decode (one token against a cache)
# ==========================================================================

def init_cache(params, cfg: ArchConfig, rt: Runtime, batch: int, max_len: int,
               extras_batch: Optional[dict] = None):
    """Build the decode cache pytree (zeros; caches are donated each step)."""
    L = cfg.n_layers
    mk_kv = lambda n: jax.vmap(
        lambda _: attention.init_kv_cache(
            cfg, batch, max_len, bits=rt.kv_cache_bits))(jnp.arange(n))
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe"):
        cache["kv"] = mk_kv(L)
    elif cfg.family == "hybrid":
        n_sites = sum((i + 1) % cfg.attn_every == 0 for i in range(L))
        cache["mamba"] = jax.vmap(
            lambda _: ssm.init_mamba_cache(cfg, batch))(jnp.arange(L))
        cache["kv"] = mk_kv(n_sites)
    elif cfg.family == "ssm":
        cache["rwkv"] = jax.vmap(
            lambda _: rwkv.init_rwkv_cache(cfg, batch))(jnp.arange(L))
    elif cfg.family == "vlm":
        g = cfg.cross_attn_every
        n_groups = L // g
        cache["kv"] = mk_kv(L - n_groups)
        patches = (extras_batch or {}).get(
            "patches", jnp.zeros((batch, cfg.n_image_tokens, cfg.d_model),
                                 cfg.adtype()))
        cache["cross_kv"] = jax.vmap(
            lambda pl: jnp.stack(attention.cross_kv(pl["attn"], cfg, patches)))(
            params["cross_layers"])
    elif cfg.family == "audio":
        cache["kv"] = mk_kv(L)
        enc = (extras_batch or {}).get(
            "enc_out", jnp.zeros((batch, cfg.n_frames, cfg.d_model),
                                 cfg.adtype()))
        cache["cross_kv"] = jax.vmap(
            lambda pl: jnp.stack(attention.cross_kv(pl["cross"], cfg, enc)))(
            params["layers"])
    return cache


def cache_spec(cfg: ArchConfig, rt: Runtime):
    kv = common.stacked_spec(attention.kv_cache_spec(
        rt, bits=rt.kv_cache_bits))
    spec: Dict[str, Any] = {"pos": P()}
    if cfg.family in ("dense", "moe"):
        spec["kv"] = kv
    elif cfg.family == "hybrid":
        spec["mamba"] = common.stacked_spec(
            {"h": P(*rt.pspec("batch", "model", None, None)),
             "conv": P(*rt.pspec("batch", None, None))})
        spec["kv"] = kv
    elif cfg.family == "ssm":
        spec["rwkv"] = common.stacked_spec(
            {"S": P(*rt.pspec("batch", "model", None, None)),
             "x_tm": P(*rt.pspec("batch", None)),
             "x_cm": P(*rt.pspec("batch", None))})
    elif cfg.family == "vlm":
        spec["kv"] = kv
        spec["cross_kv"] = P(None, None, *rt.pspec("batch", "flashdecode", None,
                                                    None))
    elif cfg.family == "audio":
        spec["kv"] = kv
        spec["cross_kv"] = P(None, None, *rt.pspec("batch", "flashdecode", None,
                                                   None))
    return spec


def decode_layers(params, cfg: ArchConfig, rt: Runtime, x, cache, lo, hi):
    """One-token pass through layers [lo, hi). Returns (x, partial caches)."""
    nf = _norm(cfg)
    pos = cache["pos"]
    new_cache: Dict[str, Any] = {}

    if cfg.family in ("dense", "moe"):
        stack = _tree_slice(params["layers"], lo, hi)
        kv = _tree_slice(cache["kv"], lo, hi)

        def body(x, inp):
            pl, kvl = inp
            y, kv_new = attention.decode_attention(
                pl["attn"], cfg, rt, nf(x, pl["attn"]["norm"]), kvl, pos)
            x = x + y
            if cfg.family == "moe":
                y2, _ = moe.moe(pl["moe"], cfg, rt, nf(x, pl["moe"]["norm"]))
            else:
                y2 = mlp.mlp(pl["mlp"], cfg, rt, nf(x, pl["mlp"]["norm"]))
            return x + y2, kv_new

        x, kv_out = jax.lax.scan(body, x, (stack, kv))
        new_cache["kv"] = kv_out
        return x, new_cache

    if cfg.family == "hybrid":
        flags = [(i + 1) % cfg.attn_every == 0 for i in range(cfg.n_layers)]
        site_of = []
        s = 0
        for f in flags:
            site_of.append(s if f else -1)
            s += int(f)
        stack = _tree_slice(params["layers"], lo, hi)
        mcache = _tree_slice(cache["mamba"], lo, hi)
        sites = [site_of[i] for i in range(lo, hi) if flags[i]]
        s_lo, s_hi = (sites[0], sites[-1] + 1) if sites else (0, 0)
        kv = _tree_slice(cache["kv"], s_lo, s_hi)
        sa, sm = params["shared_attn"], params["shared_mlp"]
        flag_arr = jnp.array(flags[lo:hi])
        site_arr = jnp.array([max(site_of[i] - s_lo, 0) for i in range(lo, hi)])

        def body(carry, inp):
            x, kv_all = carry
            pl, mc, flag, site = inp
            y, mc_new = ssm.mamba_decode(pl, cfg, rt, nf(x, pl["norm"]), mc)
            x = x + y

            def with_attn(x, kv_all):
                kvl = jax.tree_util.tree_map(lambda a: a[site], kv_all)
                y, kv_new = attention.decode_attention(
                    sa, cfg, rt, nf(x, sa["norm"]), kvl, pos)
                h = x + y
                h = h + mlp.mlp(sm, cfg, rt, nf(h, sm["norm"]))
                kv_all = jax.tree_util.tree_map(
                    lambda a, n: a.at[site].set(n), kv_all, kv_new)
                return h, kv_all

            x, kv_all = jax.lax.cond(flag, with_attn,
                                     lambda x, kv: (x, kv), x, kv_all)
            return (x, kv_all), mc_new

        (x, kv_out), mc_out = jax.lax.scan(
            body, (x, kv), (stack, mcache, flag_arr, site_arr))
        new_cache["mamba"] = mc_out
        new_cache["kv"] = kv_out
        return x, new_cache

    if cfg.family == "ssm":
        stack = _tree_slice(params["layers"], lo, hi)
        rcache = _tree_slice(cache["rwkv"], lo, hi)

        def body(x, inp):
            pl, rc = inp
            x, rc_new = rwkv.rwkv_decode(pl["time"], pl["chan"], cfg, rt,
                                         x, rc, _norm(cfg))
            return x, rc_new

        x, rc_out = jax.lax.scan(body, x, (stack, rcache))
        new_cache["rwkv"] = rc_out
        return x, new_cache

    if cfg.family == "vlm":
        g = cfg.cross_attn_every
        glo, ghi = lo // g, hi // g
        self_stack = jax.tree_util.tree_map(
            lambda a: a.reshape(cfg.n_layers // g, g - 1, *a.shape[1:])
                       [glo:ghi], params["layers"])
        cross_stack = _tree_slice(params["cross_layers"], glo, ghi)
        kv = jax.tree_util.tree_map(
            lambda a: a.reshape(cfg.n_layers // g, g - 1, *a.shape[1:])
                       [glo:ghi], cache["kv"])
        cross_kv = cache["cross_kv"][glo:ghi]

        def body(x, inp):
            selfs, crossp, kvg, ckv = inp

            def inner(x, inp2):
                pl, kvl = inp2
                y, kv_new = attention.decode_attention(
                    pl["attn"], cfg, rt, nf(x, pl["attn"]["norm"]), kvl, pos)
                x = x + y
                x = x + mlp.mlp(pl["mlp"], cfg, rt, nf(x, pl["mlp"]["norm"]))
                return x, kv_new

            x, kv_new = jax.lax.scan(inner, x, (selfs, kvg))
            h = nf(x, crossp["attn"]["norm"])
            x = x + attention.cross_attention(
                crossp["attn"], cfg, rt, h, kv_cache=(ckv[0], ckv[1]),
                gated=True)
            x = x + mlp.mlp(crossp["mlp"], cfg, rt,
                            nf(x, crossp["mlp"]["norm"]), gated=True)
            return x, kv_new

        x, kv_out = jax.lax.scan(body, x, (self_stack, cross_stack, kv,
                                           cross_kv))
        new_cache["kv"] = jax.tree_util.tree_map(
            lambda a: a.reshape(-1, *a.shape[2:]), kv_out)
        return x, new_cache

    if cfg.family == "audio":
        stack = _tree_slice(params["layers"], lo, hi)
        kv = _tree_slice(cache["kv"], lo, hi)
        ckv = cache["cross_kv"][lo:hi]

        def body(x, inp):
            pl, kvl, ck = inp
            y, kv_new = attention.decode_attention(
                pl["attn"], cfg, rt, nf(x, pl["attn"]["norm"]), kvl, pos)
            x = x + y
            x = x + attention.cross_attention(
                pl["cross"], cfg, rt, nf(x, pl["cross"]["norm"]),
                kv_cache=(ck[0], ck[1]))
            x = x + mlp.mlp(pl["mlp"], cfg, rt, nf(x, pl["mlp"]["norm"]))
            return x, kv_new

        x, kv_out = jax.lax.scan(body, x, (stack, kv, ckv))
        new_cache["kv"] = kv_out
        return x, new_cache

    raise ValueError(cfg.family)


def decode_step(params, cfg: ArchConfig, rt: Runtime, token, cache):
    """token: (B, 1) int32. Returns (logits (B, 1, V), new cache)."""
    x = embed(params, cfg, rt, token)
    x, new_partial = decode_layers(params, cfg, rt, x, cache, 0, cfg.n_layers)
    logits = lm_head(params, cfg, rt, x)
    new_cache = dict(cache)
    new_cache.update(new_partial)
    new_cache["pos"] = cache["pos"] + 1
    return logits, new_cache
