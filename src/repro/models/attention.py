"""GQA attention: RoPE, qk-norm, sliding window, KV cache, chunked prefill.

Long-sequence training/prefill uses a query-chunked formulation (scan over
query blocks, full softmax per block over the visible KV range) with per-chunk
rematerialization, bounding peak memory at O(S * chunk) instead of O(S^2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common, tp
from repro.models.config import ArchConfig, Runtime


def init_attention(key, cfg: ArchConfig, *, cross=False, gated=False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.pdtype()
    ks = jax.random.split(key, 5)
    p = {
        "norm": common.init_norm(d, dt, cfg.norm),
        "wq": common.normal_init(ks[0], (d, hq * hd), dt),
        "wk": common.normal_init(ks[1], (d, hkv * hd), dt),
        "wv": common.normal_init(ks[2], (d, hkv * hd), dt),
        "wo": common.normal_init(ks[3], (hq * hd, d), dt,
                                 scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dt)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dt)}
    if gated:
        p["gate"] = jnp.zeros((), dt)
    return p


def attention_spec(cfg: ArchConfig, *, cross=False, gated=False):
    p = {
        "norm": common.norm_spec(cfg.norm),
        "wq": P("data", "model"),
        "wk": P("data", "model"),
        "wv": P("data", "model"),
        "wo": P("model", "data"),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": P()}
        p["k_norm"] = {"scale": P()}
    if gated:
        p["gate"] = P()
    return p


def _project_qkv(p, cfg: ArchConfig, xq, xkv, q_positions, kv_positions, *, rope=True):
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (xq @ p["wq"].astype(xq.dtype)).reshape(*xq.shape[:-1], hq, hd)
    k = (xkv @ p["wk"].astype(xkv.dtype)).reshape(*xkv.shape[:-1], hkv, hd)
    v = (xkv @ p["wv"].astype(xkv.dtype)).reshape(*xkv.shape[:-1], hkv, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"]["scale"])
        k = common.rms_norm(k, p["k_norm"]["scale"])
    if rope:
        q = common.apply_rope(q, q_positions, cfg.rope_theta)
        k = common.apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ArchConfig):
    """q: (B,Sq,Hq,hd), k/v: (B,Skv,Hkv,hd), mask: (B?,1?,Sq,Skv) bool.

    bf16 operands with f32 accumulation (MXU semantics) — avoids hauling
    f32 copies of q/k/v through HBM and collectives."""
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = hq // hkv
    B, Sq = q.shape[0], q.shape[1]
    qg = q.reshape(B, Sq, hkv, g, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, hq, hd).astype(q.dtype)


def _causal_mask(q_pos, kv_pos, window: int):
    """(Sq,) x (Skv,) -> (Sq, Skv) bool; window=0 means unbounded."""
    m = kv_pos[None, :] <= q_pos[:, None]
    if window:
        m &= kv_pos[None, :] > q_pos[:, None] - window
    return m


def full_attention(p, cfg: ArchConfig, rt: Runtime, x, *, causal=True, rope=True):
    """Training / prefill self-attention over (B, S, d)."""
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q, k, v = _project_qkv(p, cfg, x, x, pos[None], pos[None], rope=rope)
    q = rt.shard(q, "batch", None, "model", None)
    k = rt.shard(k, "batch", None, None, None)
    v = rt.shard(v, "batch", None, None, None)

    window = cfg.sliding_window
    if S <= rt.attn_chunk or S % rt.attn_chunk != 0:
        mask = _causal_mask(pos, pos, window) if causal else jnp.ones((S, S), bool)
        out = _sdpa(q, k, v, jnp.broadcast_to(mask, (B, S, S)), cfg)
    else:
        c = rt.attn_chunk
        assert S % c == 0, f"seq {S} must divide attn_chunk {c}"
        qs = q.reshape(B, S // c, c, *q.shape[2:]).swapaxes(0, 1)

        def chunk_body(carry, inp):
            i, qc = inp
            qpos = i * c + jnp.arange(c)
            if causal:
                mask = _causal_mask(qpos, pos, window)
            else:
                mask = jnp.ones((c, S), bool)
            o = _sdpa(qc, k, v, jnp.broadcast_to(mask, (B, c, S)), cfg)
            return carry, o

        body = jax.checkpoint(chunk_body) if rt.remat else chunk_body
        _, outs = jax.lax.scan(body, (), (jnp.arange(S // c), qs))
        out = outs.swapaxes(0, 1).reshape(B, S, cfg.n_heads, cfg.hd)

    y = tp.out_proj_rs(out.reshape(B, S, cfg.n_heads * cfg.hd), p["wo"], rt)
    # reduce-scattered into the sequence-parallel domain (Megatron SP)
    return rt.shard(y, "batch", "seq", None)


def cross_attention(p, cfg: ArchConfig, rt: Runtime, x, kv_tokens=None, *,
                    kv_cache=None, gated=False):
    """Cross-attention: q from x (B,S,d); kv from kv_tokens (B,N,d) or a
    precomputed (k, v) cache. No RoPE on cross attention."""
    B, S, _ = x.shape
    if kv_cache is not None:
        k, v = kv_cache
        N = k.shape[1]
        q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, cfg.hd)
        if cfg.qk_norm:
            q = common.rms_norm(q, p["q_norm"]["scale"])
    else:
        N = kv_tokens.shape[1]
        q, k, v = _project_qkv(p, cfg, x, kv_tokens, None, None, rope=False)
    mask = jnp.ones((B, S, N), bool)
    out = _sdpa(q, k, v, mask, cfg)
    y = tp.out_proj_rs(out.reshape(B, S, cfg.n_heads * cfg.hd), p["wo"], rt)
    if gated:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(y.dtype) * y
    return rt.shard(y, "batch", "seq", None)


def cross_kv(p, cfg: ArchConfig, kv_tokens):
    """Precompute the cross-attention KV cache from encoder/image tokens."""
    B, N, _ = kv_tokens.shape
    k = (kv_tokens @ p["wk"].astype(kv_tokens.dtype)).reshape(B, N, cfg.n_kv_heads, cfg.hd)
    v = (kv_tokens @ p["wv"].astype(kv_tokens.dtype)).reshape(B, N, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = common.rms_norm(k, p["k_norm"]["scale"])
    return k, v


# --------------------------------------------------------------------------
# Decode (single new token against a cache)
# --------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  *, bits: int = 16):
    """Rolling cache; for sliding-window archs max_len = window size.

    bits=8 stores int8 codes + per-(token, head) f32 scales (symmetric
    quantization) — halves decode HBM footprint; dequantized on read."""
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.hd)
    if bits == 8:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:3], jnp.float32),
            "v_scale": jnp.zeros(shape[:3], jnp.float32),
        }
    return {
        "k": jnp.zeros(shape, cfg.adtype()),
        "v": jnp.zeros(shape, cfg.adtype()),
    }


def _quantize_kv(x):
    """x: (B, 1, H, hd) -> (int8 codes, (B, 1, H) scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-9)
    code = jnp.clip(jnp.round(x.astype(jnp.float32) / safe[..., None]),
                    -127, 127).astype(jnp.int8)
    return code, scale.astype(jnp.float32)


def _dequantize_kv(code, scale, dtype):
    return (code.astype(jnp.float32) * scale[..., None]).astype(dtype)


def kv_cache_spec(rt: Runtime, *, bits: int = 16):
    # flash-decode layout: the cache SEQUENCE dim is sharded over 'model'
    # (GQA kv-head counts of 4-8 cannot split a 16-way axis and would force
    # full replication -> 16x the per-chip cache); each rank attends over its
    # sequence slice and the softmax reductions lower to psums.
    spec = {"k": rt.pspec("batch", "flashdecode", None, None),
            "v": rt.pspec("batch", "flashdecode", None, None)}
    if bits == 8:
        spec["k_scale"] = rt.pspec("batch", "flashdecode", None)
        spec["v_scale"] = rt.pspec("batch", "flashdecode", None)
    return spec


def decode_attention(p, cfg: ArchConfig, rt: Runtime, x_tok, cache, pos):
    """x_tok: (B, 1, d); cache: {'k','v'} rolling buffers; pos: scalar int32
    (absolute position of the new token). Returns (y, new_cache)."""
    B = x_tok.shape[0]
    size = cache["k"].shape[1]
    quant = "k_scale" in cache
    q, k_new, v_new = _project_qkv(
        p, cfg, x_tok, x_tok, jnp.full((1, 1), pos), jnp.full((1, 1), pos))
    slot = (pos % size).astype(jnp.int32)
    new_cache = {}
    if quant:
        kc, ks = _quantize_kv(k_new)
        vc, vs = _quantize_kv(v_new)
        kcode = jax.lax.dynamic_update_slice(cache["k"], kc, (0, slot, 0, 0))
        vcode = jax.lax.dynamic_update_slice(cache["v"], vc, (0, slot, 0, 0))
        kscale = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                              (0, slot, 0))
        vscale = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                              (0, slot, 0))
        new_cache.update(k=kcode, v=vcode, k_scale=kscale, v_scale=vscale)
        k = _dequantize_kv(kcode, kscale, x_tok.dtype)
        v = _dequantize_kv(vcode, vscale, x_tok.dtype)
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        new_cache.update(k=k, v=v)
    k = rt.shard(k, "batch", "flashdecode", None, None)
    v = rt.shard(v, "batch", "flashdecode", None, None)

    # valid slots: absolute positions of each slot given the ring layout
    idx = jnp.arange(size)
    wraps = jnp.where(idx <= slot, pos - slot, pos - size - slot)
    abs_pos = idx + wraps              # absolute position stored in each slot
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if cfg.sliding_window:
        valid &= abs_pos > pos - cfg.sliding_window
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, size))
    out = _sdpa(q, k, v, mask, cfg)
    y = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"].astype(x_tok.dtype)
    return rt.shard(y, "batch", None, None), new_cache
