"""Explicit tensor-parallel output projection with sequence-parallel
reduce-scatter (Megatron SP).

GSPMD on the host pipeline lowers `psum -> reshard(seq)` as
all-reduce + dynamic-slice; the production pattern is a single
reduce-scatter. We emit it explicitly with shard_map so the dry-run HLO
carries the real collective schedule:

    h (B, S, ff/model) @ w (ff/model, d/data)  ->  y (B, S/model, d)

i.e. each model-rank computes its partial product and `psum_scatter`s it
along the sequence axis. Falls back to a plain matmul (+ GSPMD psum) when
there is no model axis, sequence parallelism is off, or S is indivisible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.config import Runtime


def gather_seq_local(y_l, axis_name: str = "model"):
    """Per-shard body of `gather_seq`: all-gather a seq-sharded activation
    along `axis_name` back to full S. Callable from inside any enclosing
    `shard_map` body (the sharded arena step reuses it) as well as from the
    GSPMD wrapper below."""
    return jax.lax.all_gather(y_l, axis_name, axis=1, tiled=True)


def gather_seq(y, rt: Runtime):
    """Explicit bf16 all-gather of a (B, S/model, d) seq-sharded activation
    to full-S replicated. GSPMD left to its own devices hoists this gather
    above the norm's f32->bf16 convert, doubling the bytes; doing it in
    shard_map pins both dtype and placement. Transpose (backward) is the
    matching psum_scatter."""
    mesh = rt.mesh
    B, S, d = y.shape
    usable = (
        mesh is not None and rt.seq_shard and not rt.dp_only
        and "model" in rt.axis_names
        and mesh.shape["model"] > 1 and S % mesh.shape["model"] == 0
    )
    if not usable:
        return rt.shard(y, "batch", None, None)
    batch_axes = rt.batch_axes or ()
    in_spec = P(batch_axes if batch_axes else None, "model", None)
    out_spec = P(batch_axes if batch_axes else None, None, None)

    return shard_map(gather_seq_local, mesh=mesh, in_specs=(in_spec,),
                     out_specs=out_spec, check_vma=False)(y)


def out_proj_rs(h, w, rt: Runtime, *, w_spec=P("model", "data")):
    """h: (B, S, ff) with ff sharded over 'model'; w: (ff, d) sharded w_spec.
    Returns (B, S, d) sharded over 'seq'=model on S."""
    mesh = rt.mesh
    B, S, ff = h.shape
    usable = (
        mesh is not None and rt.seq_shard and not rt.dp_only
        and "model" in rt.axis_names
        and mesh.shape["model"] > 1 and S % mesh.shape["model"] == 0
        and ff % mesh.shape["model"] == 0
    )
    if not usable:
        y = h @ w.astype(h.dtype)
        return rt.shard(y, "batch", "seq", None)

    batch_axes = rt.batch_axes or ()
    h_spec = P(batch_axes if batch_axes else None, None, "model")
    o_spec = P(batch_axes if batch_axes else None, "model", None)

    def f(h_l, w_l):
        return out_proj_rs_local(h_l, w_l, w_spec=w_spec)

    return shard_map(f, mesh=mesh, in_specs=(h_spec, w_spec),
                     out_specs=o_spec)(h, w)


def out_proj_rs_local(h_l, w_l, *, w_spec=P("model", "data"),
                      axis_name: str = "model"):
    """Per-shard body of `out_proj_rs`: partial product over the local ff
    shard, reduce-scattered along the sequence axis. Exposed so an
    enclosing `shard_map` (training/prefill fusions) can emit the same
    collective schedule without nesting shard_maps."""
    if "data" in tuple(w_spec):
        axis = tuple(w_spec).index("data")
        w_l = jax.lax.all_gather(w_l, "data", axis=axis, tiled=True)
    y = h_l @ w_l.astype(h_l.dtype)                # partial over `axis_name`
    return jax.lax.psum_scatter(y, axis_name, scatter_dimension=1,
                                tiled=True)


def vocab_parallel_argmax(logits_l, axis_name: str = "model"):
    """Exact greedy argmax over a vocab-sharded last axis, inside shard_map.

    Each rank holds a contiguous (..., V/model) shard of the logits (the
    unembed matmul with the vocab dimension split is NOT a contraction
    split, so the shards themselves are bit-identical to columns of the
    single-device logits). The global argmax is then recovered without
    materializing full logits anywhere:

      1. per-rank max + argmax over the local shard;
      2. `pmax` for the global max;
      3. every rank whose local max equals the global max proposes its
         local argmax offset by its shard's base column; `pmin` over the
         proposals returns the LOWEST global index attaining the max —
         exactly `jnp.argmax`'s first-occurrence tie-breaking.

    Two scalar-per-row collectives replace an all-gather of the vocab axis.
    Bit-exact at any model-axis size (pmax over disjoint column maxima is
    order-insensitive; index selection never compares floats across ranks
    beyond equality with the global max).
    """
    v_local = logits_l.shape[-1]
    base = jax.lax.axis_index(axis_name).astype(jnp.int32) * v_local
    local_max = jnp.max(logits_l, axis=-1)
    global_max = jax.lax.pmax(local_max, axis_name)
    local_idx = jnp.argmax(logits_l, axis=-1).astype(jnp.int32) + base
    proposal = jnp.where(local_max == global_max, local_idx,
                         jnp.iinfo(jnp.int32).max)
    return jax.lax.pmin(proposal, axis_name).astype(jnp.int32)
