"""Explicit tensor-parallel output projection with sequence-parallel
reduce-scatter (Megatron SP).

GSPMD on the host pipeline lowers `psum -> reshard(seq)` as
all-reduce + dynamic-slice; the production pattern is a single
reduce-scatter. We emit it explicitly with shard_map so the dry-run HLO
carries the real collective schedule:

    h (B, S, ff/model) @ w (ff/model, d/data)  ->  y (B, S/model, d)

i.e. each model-rank computes its partial product and `psum_scatter`s it
along the sequence axis. Falls back to a plain matmul (+ GSPMD psum) when
there is no model axis, sequence parallelism is off, or S is indivisible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.config import Runtime


def gather_seq(y, rt: Runtime):
    """Explicit bf16 all-gather of a (B, S/model, d) seq-sharded activation
    to full-S replicated. GSPMD left to its own devices hoists this gather
    above the norm's f32->bf16 convert, doubling the bytes; doing it in
    shard_map pins both dtype and placement. Transpose (backward) is the
    matching psum_scatter."""
    mesh = rt.mesh
    B, S, d = y.shape
    usable = (
        mesh is not None and rt.seq_shard and not rt.dp_only
        and "model" in rt.axis_names
        and mesh.shape["model"] > 1 and S % mesh.shape["model"] == 0
    )
    if not usable:
        return rt.shard(y, "batch", None, None)
    batch_axes = rt.batch_axes or ()
    in_spec = P(batch_axes if batch_axes else None, "model", None)
    out_spec = P(batch_axes if batch_axes else None, None, None)

    def f(y_l):
        return jax.lax.all_gather(y_l, "model", axis=1, tiled=True)

    return shard_map(f, mesh=mesh, in_specs=(in_spec,),
                     out_specs=out_spec, check_vma=False)(y)


def out_proj_rs(h, w, rt: Runtime, *, w_spec=P("model", "data")):
    """h: (B, S, ff) with ff sharded over 'model'; w: (ff, d) sharded w_spec.
    Returns (B, S, d) sharded over 'seq'=model on S."""
    mesh = rt.mesh
    B, S, ff = h.shape
    usable = (
        mesh is not None and rt.seq_shard and not rt.dp_only
        and "model" in rt.axis_names
        and mesh.shape["model"] > 1 and S % mesh.shape["model"] == 0
        and ff % mesh.shape["model"] == 0
    )
    if not usable:
        y = h @ w.astype(h.dtype)
        return rt.shard(y, "batch", "seq", None)

    batch_axes = rt.batch_axes or ()
    h_spec = P(batch_axes if batch_axes else None, None, "model")
    o_spec = P(batch_axes if batch_axes else None, "model", None)

    def f(h_l, w_l):
        if "data" in tuple(w_spec):
            axis = tuple(w_spec).index("data")
            w_l = jax.lax.all_gather(w_l, "data", axis=axis, tiled=True)
        y = h_l @ w_l.astype(h_l.dtype)                    # partial over model
        return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                    tiled=True)

    return shard_map(f, mesh=mesh, in_specs=(h_spec, w_spec),
                     out_specs=o_spec)(h, w)
