"""Mixture-of-Experts block with expert parallelism over the `model` axis.

Design (TPU-native, no (T, E, C) one-hot):
  * expert weights are sharded E -> 'model' (E_loc per rank) and d -> 'data'
    (FSDP); inside `shard_map` the d shards are all-gathered per use;
  * activations enter replicated across 'model' (standard Megatron residual
    stream); every rank computes only the tokens routed to ITS local experts
    via a capacity-C gather (sorted by intra-expert arrival order), grouped
    einsum, scatter-add, then a psum over 'model' combines expert outputs;
  * router is computed redundantly on every rank (cheap, avoids a broadcast).

Falls back to the identical local computation without collectives when no
mesh / no 'model' axis is present (single-device smoke tests).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models import common
from repro.models.config import ArchConfig, Runtime


def init_moe(key, cfg: ArchConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.pdtype()
    ks = jax.random.split(key, 4)
    return {
        "norm": common.init_norm(d, dt, cfg.norm),
        "router": common.normal_init(ks[0], (d, E), dt),
        "w_gate": common.normal_init(ks[1], (E, d, ff), dt),
        "w_up": common.normal_init(ks[2], (E, d, ff), dt),
        "w_down": common.normal_init(ks[3], (E, ff, d), dt,
                                     scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }


def moe_spec(cfg: ArchConfig):
    return {
        "norm": common.norm_spec(cfg.norm),
        "router": P(None, None),
        "w_gate": P("model", "data", None),
        "w_up": P("model", "data", None),
        "w_down": P("model", None, "data"),
    }


def _local_moe(x_flat, router_w, wg, wu, wd, *, cfg: ArchConfig, e_offset,
               capacity: int):
    """Per-rank MoE over local experts. x_flat: (T, d) [replicated copy].

    Returns (partial_y (T, d), router_probs (T, E)).
    """
    T, d = x_flat.shape
    E, topk = cfg.n_experts, cfg.topk_experts
    E_loc = wg.shape[0]

    logits = (x_flat @ router_w.astype(x_flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    top_p, top_i = jax.lax.top_k(probs, topk)                   # (T, topk)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)      # renormalize

    def one_expert(e_local):
        gid = e_offset + e_local
        hit_slots = top_i == gid                                # (T, topk)
        hit = jnp.any(hit_slots, axis=-1)                       # (T,)
        w_tok = jnp.sum(jnp.where(hit_slots, top_p, 0.0), axis=-1)
        order_rank = jnp.cumsum(hit.astype(jnp.int32)) - 1      # arrival order
        prio = jnp.where(hit, order_rank, T + 1)
        order = jnp.argsort(prio)[:capacity]                    # (C,) token ids
        valid = jnp.take(prio, order) <= capacity - 1
        scatter_to = jnp.where(valid, order, T)                 # T -> dropped
        return order, scatter_to, (jnp.take(w_tok, order) * valid)

    order, scatter_to, w_tok = jax.vmap(one_expert)(jnp.arange(E_loc))
    x_e = jnp.take(x_flat, order.reshape(-1), axis=0)
    x_e = x_e.reshape(E_loc, capacity, d)                       # (E_loc, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_e, wg.astype(x_e.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", x_e, wu.astype(x_e.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, wd.astype(x_e.dtype))
    out = out * w_tok[..., None].astype(out.dtype)
    y = jnp.zeros((T, d), out.dtype).at[scatter_to.reshape(-1)].add(
        out.reshape(-1, d), mode="drop")
    return y, probs


def _capacity(t_local: int, cfg: ArchConfig, factor: float) -> int:
    c = math.ceil(t_local * cfg.topk_experts / cfg.n_experts * factor)
    return min(t_local, max(4, c))  # decode floor of 4, never above T_local


def moe(p, cfg: ArchConfig, rt: Runtime, x):
    """x: (B, S, d) replicated over 'model', batch-sharded. Returns (y, aux)."""
    B, S, d = x.shape
    topk = cfg.topk_experts

    if (rt.mesh is not None and rt.has_model_axis
            and rt.mesh.shape["model"] > 1 and not rt.dp_only):
        mesh = rt.mesh
        n_model = mesh.shape["model"]
        assert cfg.n_experts % n_model == 0, "experts must divide model axis"
        batch_axes = rt.batch_axes or ()
        n_batch = 1
        for a in batch_axes:
            n_batch *= mesh.shape[a]
        if B % n_batch != 0:  # tiny decode batches: replicate over data
            batch_axes, n_batch = (), 1
        t_loc = (B * S) // n_batch
        cap = _capacity(t_loc, cfg, rt.moe_capacity)
        bspec = P(batch_axes if batch_axes else None, None, None)

        n_model_ax = mesh.shape["model"]
        scatter_seq = (rt.seq_shard and S > 1
                       and (B * S) % (n_batch * n_model_ax) == 0)

        def ranked(xb, router_w, wg, wu, wd):
            e_loc = wg.shape[0]
            rank = jax.lax.axis_index("model")
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
            xf = xb.reshape(-1, d)
            y, probs = _local_moe(xf, router_w, wg, wu, wd, cfg=cfg,
                                  e_offset=rank * e_loc, capacity=cap)
            if scatter_seq:
                # combine experts with a reduce-scatter into the sequence-
                # parallel domain (matches attention/MLP output projections);
                # a full psum here costs 16x the link bytes. Scatter along
                # the SEQUENCE axis (scattering the flat (b,s) axis would
                # permute batch rows across ranks).
                y = y.reshape(xb.shape[0], -1, d)
                y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                         tiled=True)
            else:
                y = jax.lax.psum(y, "model")
            # aux loss from the (replicated) router stats, averaged over batch
            _, top_i = jax.lax.top_k(probs, topk)
            f = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, cfg.n_experts,
                                                dtype=jnp.float32), axis=1), axis=0)
            pbar = jnp.mean(probs, axis=0)
            aux = cfg.n_experts * jnp.sum(f * pbar)
            if batch_axes:
                aux = jax.lax.pmean(aux, batch_axes)
            if scatter_seq:
                return y, aux
            return y.reshape(xb.shape), aux

        out_bspec = (P(batch_axes if batch_axes else None, "model", None)
                     if scatter_seq else bspec)
        y, aux = shard_map(
            ranked, mesh=mesh,
            in_specs=(bspec, P(None, None), P("model", "data", None),
                      P("model", "data", None), P("model", None, "data")),
            out_specs=(out_bspec, P()), check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    else:
        cap = _capacity(B * S, cfg, rt.moe_capacity)
        xf = x.reshape(-1, d)
        y, probs = _local_moe(xf, p["router"], p["w_gate"], p["w_up"],
                              p["w_down"], cfg=cfg, e_offset=0, capacity=cap)
        _, top_i = jax.lax.top_k(probs, topk)
        f = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, cfg.n_experts,
                                            dtype=jnp.float32), axis=1), axis=0)
        aux = cfg.n_experts * jnp.sum(f * jnp.mean(probs, axis=0))
        y = y.reshape(B, S, d)

    return rt.shard(y, "batch", "seq", None), aux
