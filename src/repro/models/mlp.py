"""SwiGLU / GELU MLP blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common, tp
from repro.models.config import ArchConfig, Runtime


def init_mlp(key, cfg: ArchConfig, *, gated=False):
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.pdtype()
    ks = jax.random.split(key, 3)
    p = {
        "norm": common.init_norm(d, dt, cfg.norm),
        "w_gate": common.normal_init(ks[0], (d, ff), dt),
        "w_up": common.normal_init(ks[1], (d, ff), dt),
        "w_down": common.normal_init(ks[2], (ff, d), dt,
                                     scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }
    if gated:
        p["gate"] = jnp.zeros((), dt)
    return p


def mlp_spec(cfg: ArchConfig, *, gated=False):
    p = {
        "norm": common.norm_spec(cfg.norm),
        "w_gate": P("data", "model"),
        "w_up": P("data", "model"),
        "w_down": P("model", "data"),
    }
    if gated:
        p["gate"] = P()
    return p


def mlp(p, cfg: ArchConfig, rt: Runtime, x, *, gated=False):
    h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    h = rt.shard(h, "batch", None, "model")
    # reduce-scatter into the sequence-parallel domain (Megatron SP)
    y = tp.out_proj_rs(h, p["w_down"], rt)
    if gated:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(y.dtype) * y
    return rt.shard(y, "batch", "seq", None)
