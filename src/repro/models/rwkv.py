"""RWKV6 ("Finch") block: data-dependent per-channel decay linear attention.

Time-mix uses the exact WKV6 recurrence, evaluated as a chunk-rematerialized
sequential scan (outer scan over chunks with jax.checkpoint, inner scan over
steps) — numerically exact in f32 with no exp(+L) blow-ups, O(1)-in-depth
compile via lax.scan, and O(chunk) backward memory. Decode is the one-step
recurrence. Channel-mix is the token-shifted squared-ReLU FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common, tp
from repro.models.config import ArchConfig, Runtime


def init_rwkv_time(key, cfg: ArchConfig):
    d = cfg.d_model
    H = d // 64
    lora = cfg.rwkv_lora
    dt = cfg.pdtype()
    ks = jax.random.split(key, 8)
    return {
        "norm": common.init_norm(d, dt, cfg.norm),
        "mu": common.normal_init(ks[0], (5, d), dt, scale=0.2),  # r,k,v,g,w mixes
        "w_r": common.normal_init(ks[1], (d, d), dt),
        "w_k": common.normal_init(ks[2], (d, d), dt),
        "w_v": common.normal_init(ks[3], (d, d), dt),
        "w_g": common.normal_init(ks[4], (d, d), dt),
        "w0": jnp.full((d,), -0.7, dt),
        "w1": common.normal_init(ks[5], (d, lora), dt),
        "w2": common.normal_init(ks[6], (lora, d), dt),
        "u": common.normal_init(ks[7], (H, 64), dt, scale=0.5),
        "ln_x": {"scale": jnp.ones((d,), dt)},
        "w_out": common.normal_init(jax.random.fold_in(key, 9), (d, d), dt,
                                    scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }


def rwkv_time_spec(cfg: ArchConfig):
    return {
        "norm": common.norm_spec(cfg.norm),
        "mu": P(None, None),
        "w_r": P("data", "model"),
        "w_k": P("data", "model"),
        "w_v": P("data", "model"),
        "w_g": P("data", "model"),
        "w0": P(None), "w1": P("data", None), "w2": P(None, None),
        "u": P(None, None),
        "ln_x": {"scale": P(None)},
        "w_out": P("model", "data"),
    }


def init_rwkv_channel(key, cfg: ArchConfig):
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.pdtype()
    ks = jax.random.split(key, 3)
    return {
        "norm": common.init_norm(d, dt, cfg.norm),
        "mu": common.normal_init(ks[0], (2, d), dt, scale=0.2),  # k, r mixes
        "w_k": common.normal_init(ks[1], (d, ff), dt),
        "w_v": common.normal_init(ks[2], (ff, d), dt,
                                  scale=0.02 / max(1, cfg.n_layers) ** 0.5),
        "w_r": common.normal_init(jax.random.fold_in(key, 3), (d, d), dt),
    }


def rwkv_channel_spec(cfg: ArchConfig):
    return {
        "norm": common.norm_spec(cfg.norm),
        "mu": P(None, None),
        "w_k": P("data", "model"),
        "w_v": P("model", "data"),
        "w_r": P("data", None),
    }


def _token_shift(x, x_prev_tok=None):
    """x: (B,S,d) -> x shifted right by one; first slot from x_prev_tok."""
    if x.shape[1] == 1 and x_prev_tok is not None:
        return x_prev_tok[:, None, :]
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev_tok is not None:
        shifted = shifted.at[:, 0].set(x_prev_tok)
    return shifted


def _time_mix_inputs(p, cfg: ArchConfig, x, x_prev_tok=None):
    B, S, d = x.shape
    H, hd = d // 64, 64
    xp = _token_shift(x, x_prev_tok)
    mu = p["mu"].astype(x.dtype)
    mix = [x + mu[i] * (xp - x) for i in range(5)]
    r = (mix[0] @ p["w_r"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (mix[1] @ p["w_k"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (mix[2] @ p["w_v"].astype(x.dtype)).reshape(B, S, H, hd)
    g = mix[3] @ p["w_g"].astype(x.dtype)
    ww = p["w0"].astype(jnp.float32) + jnp.tanh(
        mix[4].astype(jnp.float32) @ p["w1"].astype(jnp.float32)
    ) @ p["w2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww)).reshape(B, S, H, hd)        # per-channel decay
    # r/k/v stay in the activation dtype (bf16 on TPU) — the chunked WKV
    # einsums accumulate in f32; only the decay chain needs f32 precision.
    return r, k, v, g, w


def _wkv_step(S, rkvw, u):
    """S: (B,H,K,V); r,k,v,w: (B,H,hd). Exact RWKV6 recurrence."""
    r, k, v, w = [a.astype(jnp.float32) for a in rkvw]
    kv = k[..., :, None] * v[..., None, :]                # (B,H,K,V)
    out = jnp.einsum("bhk,bhkv->bhv", r, S + u[None, :, :, None] * kv)
    S_new = w[..., :, None] * S + kv
    return S_new, out


def _wkv_chunk_parallel(S0, rc, kc, vc, wc, u):
    """Matrix-form WKV6 over one chunk (MXU-friendly, no per-step scan).

    rc/kc/vc/wc: (B, c, H, K) f32. Decay exponents are clamped to
    [-5, 0] per step so exp(-L) stays inside f32 range for c*5 < 88;
    a per-step decay below e^-5 is numerically-forgotten state anyway.
    Returns (S_new, y (B, c, H, V)).
    """
    B, c, H, K = rc.shape
    f32 = jnp.float32
    la = jnp.clip(jnp.log(jnp.maximum(wc, 1e-38)), -5.0, 0.0)  # (B,c,H,K) f32
    L = jnp.cumsum(la, axis=1)                                 # inclusive
    L_prev = L - la                                            # exclusive
    r_t = rc.astype(f32) * jnp.exp(L_prev)                     # <= |rc|
    k_s = kc.astype(f32) * jnp.exp(-L)                         # bounded by clamp
    A = jnp.einsum("bthk,bshk->btsh", r_t, k_s,
                   preferred_element_type=f32)                 # (B,t,s,H)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)              # strict s<t
    A = jnp.where(mask[None, :, :, None], A, 0.0)
    diag = jnp.einsum("bthk,hk->bth", (rc * kc).astype(f32), u)
    vf = vc.astype(f32)
    y = (jnp.einsum("btsh,bshv->bthv", A, vf,
                    preferred_element_type=f32)
         + jnp.einsum("bthk,bhkv->bthv", r_t, S0,
                      preferred_element_type=f32)
         + diag[..., None] * vf)
    decay_to_end = jnp.exp(L[:, -1:] - L)                      # <= 1
    S_new = (S0 * jnp.exp(L[:, -1])[..., None]
             + jnp.einsum("bshk,bshv->bhkv", kc.astype(f32) * decay_to_end,
                          vf, preferred_element_type=f32))
    return S_new, y


def rwkv_time_mix(p, cfg: ArchConfig, rt: Runtime, x, state=None,
                  x_prev_tok=None):
    """Full-sequence WKV6. x: (B,S,d). Returns (y, (S_state, last_x)).

    rt.rwkv_mode selects the evaluation strategy:
      'chunk' (default): matrix-form chunks — state hits HBM once per chunk,
          intra-chunk work runs on the MXU;
      'scan': exact sequential recurrence (naive baseline; kept for §Perf
          comparison and as the numerics oracle under clamp-free decay).
    """
    B, S, d = x.shape
    H, hd = d // 64, 64
    r, k, v, g, w = _time_mix_inputs(p, cfg, x, x_prev_tok)
    u = p["u"].astype(jnp.float32)

    cl = min(rt.rwkv_chunk, S)
    assert S % cl == 0, f"seq {S} must divide rwkv_chunk {cl}"
    nc = S // cl

    def to_chunks(a):  # (B,S,H,hd) -> (nc,B,cl,H,hd)
        return a.reshape(B, nc, cl, H, hd).swapaxes(0, 1)

    seq = tuple(map(to_chunks, (r, k, v, w)))

    if rt.rwkv_mode == "chunk":
        def chunk_body(Sst, chunk):
            rc, kc, vc, wc = chunk
            S_new, y = _wkv_chunk_parallel(Sst, rc, kc, vc, wc, u)
            return S_new, y.swapaxes(0, 1)                 # (cl,B,H,hd)
    else:
        def chunk_body(Sst, chunk):
            rc, kc, vc, wc = chunk

            def step(Si, t):
                return _wkv_step(Si, (rc[:, t], kc[:, t], vc[:, t],
                                      wc[:, t]), u)

            Sst, outs = jax.lax.scan(step, Sst, jnp.arange(cl))
            return Sst, outs                               # (cl,B,H,hd)

    body = jax.checkpoint(chunk_body) if rt.remat else chunk_body
    S0 = state if state is not None else jnp.zeros((B, H, hd, hd), jnp.float32)
    S_fin, outs = jax.lax.scan(body, S0, seq)              # (nc,cl,B,H,hd)
    y = outs.swapaxes(1, 2).swapaxes(0, 1).reshape(B, S, H, hd)

    # per-head group norm, then gate and project
    y = common.rms_norm(y, jnp.ones((hd,), jnp.float32)).reshape(B, S, d)
    y = y * p["ln_x"]["scale"].astype(jnp.float32)
    y = tp.out_proj_rs(y.astype(x.dtype) * jax.nn.silu(g), p["w_out"], rt)
    return rt.shard(y, "batch", "seq", None), (S_fin, x[:, -1])


def rwkv_channel_mix(p, cfg: ArchConfig, rt: Runtime, x, x_prev_tok=None):
    xp = _token_shift(x, x_prev_tok)
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (xp - x)
    xr = x + mu[1] * (xp - x)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
    kk = rt.shard(kk, "batch", None, "model")
    vv = tp.out_proj_rs(kk, p["w_v"], rt)
    r = jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype))
    return rt.shard(r * vv, "batch", "seq", None), x[:, -1]


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def init_rwkv_cache(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    H, hd = d // 64, 64
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((batch, d), cfg.adtype()),
        "x_cm": jnp.zeros((batch, d), cfg.adtype()),
    }


def rwkv_cache_spec(rt: Runtime):
    return {"S": rt.pspec("batch", None, None, None),
            "x_tm": rt.pspec("batch", None),
            "x_cm": rt.pspec("batch", None)}


def rwkv_decode(p_time, p_chan, cfg: ArchConfig, rt: Runtime, x_tok, cache,
                norm_fn):
    """One token through time-mix + channel-mix with their pre-norms."""
    h = norm_fn(x_tok, p_time["norm"])
    B, _, d = x_tok.shape
    H, hd = d // 64, 64
    r, k, v, g, w = _time_mix_inputs(p_time, cfg, h, cache["x_tm"])
    u = p_time["u"].astype(jnp.float32)
    S_new, out = _wkv_step(cache["S"],
                           (r[:, 0], k[:, 0], v[:, 0], w[:, 0]), u)
    y = common.rms_norm(out[:, None], jnp.ones((hd,), jnp.float32))
    y = y.reshape(B, 1, d) * p_time["ln_x"]["scale"].astype(jnp.float32)
    y = (y.astype(x_tok.dtype) * jax.nn.silu(g)) @ p_time["w_out"].astype(x_tok.dtype)
    x1 = x_tok + y
    h2 = norm_fn(x1, p_chan["norm"])
    y2, _ = rwkv_channel_mix(p_chan, cfg, rt, h2, cache["x_cm"])
    x2 = x1 + y2
    new_cache = {"S": S_new, "x_tm": h[:, -1], "x_cm": h2[:, -1]}
    return x2, new_cache
