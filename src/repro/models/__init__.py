from repro.models import attention, common, config, mlp, moe, rwkv, ssm, transformer
from repro.models.config import ArchConfig, Runtime, SplitConfig

__all__ = ["attention", "common", "config", "mlp", "moe", "rwkv", "ssm",
           "transformer", "ArchConfig", "Runtime", "SplitConfig"]
