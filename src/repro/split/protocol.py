"""Cut-layer transfer protocol — one generic encode/transfer/decode path.

Maps the split-learning party-to-party socket onto the TPU fabric: the two
parties are the two pods of the production mesh, and the compressed payload
crosses the pod boundary with a `ppermute` along the 'pod' axis inside
`shard_map` (the TPU-native point-to-point send).

Placement is *symmetrized SPMD split learning*: the batch is sharded over
('pod', 'data'), so each pod acts as feature owner for its half of the batch
and as label owner for the other half — every sample's cut activation crosses
the pod boundary exactly once per direction, so pod-boundary traffic per
sample is identical to classic two-party SL while keeping both pods busy
(bidirectional split learning).

The transfer is payload-typed: `cut_boundary` calls `Compressor.encode`,
ppermutes every wire leaf of the resulting `core.payload.Payload` (so
quantization moves uint8 codes + a 2-float header per token — not the dense
dequantized tensor), and `Compressor.decode`s on the far side. There are no
per-compressor branches; the payload's static `meta.kind` drives both the
forward transfer and the backward gradient routing:

  forward wire   = payload leaves            (Table 2 'Compressed size fwd')
  backward wire  = k masked gradient floats for sparse/slice kinds (the
                   feature owner already holds the indices), the dense
                   gradient for dense/quant kinds (STE through the
                   quantizer)                (Table 2 'Compressed size bwd')

realized with a custom VJP whose backward rule ppermutes exactly those
leaves back. On a single-pod mesh (or no mesh) the transfer is the identity
— parties are co-located and the savings show up as reduced cut-boundary
tensor bytes only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import compressors
from repro.core.payload import Payload
from repro.models.config import ArchConfig, Runtime, SplitConfig


def make_cut_compressor(sc: SplitConfig) -> compressors.Compressor:
    """Config -> codec object (factory; the protocol itself is generic)."""
    kw = {}
    if sc.compressor in ("topk", "randtopk", "randtopk_quant",
                         "size_reduction"):
        kw["k"] = sc.k
    if sc.compressor in ("randtopk", "randtopk_quant"):
        kw["alpha"] = sc.alpha
    if sc.compressor in ("quant", "randtopk_quant"):
        kw["bits"] = sc.quant_bits
    if sc.compressor == "l1":
        kw["lam"] = sc.l1_lam
    if sc.backend is not None:
        kw["backend"] = sc.backend
    return compressors.make_compressor(sc.compressor, **kw)


def _pod_permute(rt: Runtime, *leaves, inverse: bool = False):
    """ppermute every array along the pod axis (0 <-> 1).

    `inverse=True` applies the inverse permutation (used by the backward
    wire so cotangents return to the pod that produced the activation).
    """
    mesh = rt.mesh
    if mesh is None or "pod" not in mesh.axis_names or mesh.shape["pod"] < 2:
        return leaves
    n_pod = mesh.shape["pod"]
    step = -1 if inverse else 1
    perm = [(i, (i + step) % n_pod) for i in range(n_pod)]

    def spec_for(a):
        # batch axis is dim 0, sharded over (pod, data); rest replicated/model
        return P(("pod", "data"), *([None] * (a.ndim - 1)))

    def body(*xs):
        return tuple(jax.lax.ppermute(x, "pod", perm) for x in xs)

    out = shard_map(
        body, mesh=mesh,
        in_specs=tuple(spec_for(a) for a in leaves),
        out_specs=tuple(spec_for(a) for a in leaves),
    )(*leaves)
    return out


def _transfer_payload(rt: Runtime, p: Payload, inverse: bool = False) -> Payload:
    """Move every wire leaf of a payload across the pod boundary."""
    names = [n for n, _ in p.wire_leaves()]
    arrs = _pod_permute(rt, *[a for _, a in p.wire_leaves()], inverse=inverse)
    return p.with_leaves(**dict(zip(names, arrs)))


# ---------------------------------------------------------------------------
# Backward wire rules, dispatched on the payload kind (not the compressor).
# ---------------------------------------------------------------------------

def _grad_to_wire(kind: str, g, idx_far, k: int):
    """Label-owner side: the gradient leaves that cross back (Table 2 bwd)."""
    if kind in ("sparse", "sparse_quant"):
        return jnp.take_along_axis(g, idx_far.astype(jnp.int32), axis=-1)
    if kind == "slice":
        return g[..., :k]
    return g  # dense / quant: full-precision dense gradient


def _grad_from_wire(kind: str, gw, idx_local, d: int):
    """Feature-owner side: route the wire gradient onto the activation.

    Sparse/slice kinds scatter onto the forward support (the paper's
    same-mask backward); dense/quant kinds are the identity (STE)."""
    if kind in ("sparse", "sparse_quant"):
        out = jnp.zeros(gw.shape[:-1] + (d,), gw.dtype)
        return jnp.put_along_axis(out, idx_local.astype(jnp.int32), gw,
                                  axis=-1, inplace=False)
    if kind == "slice":
        pad = [(0, 0)] * (gw.ndim - 1) + [(0, d - gw.shape[-1])]
        return jnp.pad(gw, pad)
    return gw


def _transport(comp: compressors.Compressor, x, rt: Runtime, key,
               over_pod: bool):
    """encode -> ppermute payload leaves -> decode, with the payload-typed
    backward wire attached via custom VJP."""
    kind = comp.wire_kind
    d = x.shape[-1]
    k_eff = min(getattr(comp, "k", 0), d)

    def _encode_transfer(x):
        p = comp.encode(x, key=key, training=rt.training)
        pt = _transfer_payload(rt, p) if over_pod else p
        return p, pt

    @jax.custom_vjp
    def run(x):
        _, pt = _encode_transfer(x)
        return comp.decode(pt, shape=x.shape, dtype=x.dtype)

    def run_fwd(x):
        p, pt = _encode_transfer(x)
        y = comp.decode(pt, shape=x.shape, dtype=x.dtype)
        return y, (p.indices, pt.indices)

    def run_bwd(res, g):
        idx_local, idx_far = res
        gw = _grad_to_wire(kind, g, idx_far, k_eff)
        if over_pod:
            (gw,) = _pod_permute(rt, gw, inverse=True)
        return (_grad_from_wire(kind, gw, idx_local, d),)

    run.defvjp(run_fwd, run_bwd)
    return run(x)


def cut_boundary(x, cfg: ArchConfig, rt: Runtime, key) -> tuple:
    """Compress the cut activation (B, S, d), move the packed payload across
    the pod boundary, decode on the far side. Returns (x_top, l1_penalty).

    One generic path for every compressor — the payload object is the whole
    interface between the compressor, the wire, and the far side."""
    sc = cfg.split
    comp = make_cut_compressor(sc)
    d = x.shape[-1]
    pen = comp.loss_penalty(x.reshape(-1, d))
    y = _transport(comp, x, rt, key, over_pod=sc.transfer_over_pod)
    return rt.shard(y, "batch", None, None), pen


def wire_bytes_per_step(cfg: ArchConfig, batch: int, seq: int,
                        *, training: bool) -> float:
    """Paper-exact cut-layer wire bytes for one step (Table 2)."""
    from repro.core import wire

    sc = cfg.split
    if sc is None:
        return 0.0
    method = sc.compressor
    return wire.bytes_per_step(method, cfg.d_model, batch * seq, k=sc.k,
                               bits=sc.quant_bits, training=training)


def measured_payload_bytes(cfg: ArchConfig, batch: int, seq: int,
                           *, training: bool = False, key=None) -> int:
    """Byte-exact forward payload size for one (batch, seq) step, measured by
    actually encoding a probe activation and serializing it with
    `wire.encode_payload` — the codec-side cross-check of
    `wire_bytes_per_step`'s analytic formula."""
    import numpy as np

    from repro.core import wire

    sc = cfg.split
    if sc is None:
        return 0
    comp = make_cut_compressor(sc)
    probe = jax.random.normal(jax.random.key(0), (batch, seq, cfg.d_model))
    p = comp.encode(probe, key=key, training=training)
    return wire.payload_nbytes(jax.tree.map(np.asarray, p))
