"""Cut-layer transfer protocol — one generic encode/transfer/decode path.

Two entry points, one codec:

  * `cut_boundary` — the fused in-graph path (encode -> ppermute the payload
    leaves across the 'pod' mesh axis -> decode), used by `split.model`
    inside jit, with the payload-typed backward wire attached via custom VJP.
  * `client_encode` / `server_decode` — the same two halves exposed for
    out-of-process use: a feature owner that holds only the bottom model
    encodes its cut activation to a host-side `Payload` (ready for
    `core.wire.encode_payload_frame`), and a label owner decodes a received
    payload to the dense view without ever seeing the compressor object.
    `repro.runtime`'s streaming client/server is built on these halves.

Placement, the symmetrized-SPMD mapping of the two parties onto the two
pods, and the forward/backward wire-size rules (Table 2) are specified in
docs/protocol.md — the normative companion of this module.
"""
from __future__ import annotations

import contextlib
import functools
import threading

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import compressors, selection
from repro.core.payload import Payload, PayloadMeta
from repro.models.config import ArchConfig, Runtime, SplitConfig


def make_cut_compressor(sc: SplitConfig) -> compressors.Compressor:
    """Config -> codec object (factory; the protocol itself is generic)."""
    kw = {}
    if sc.compressor in ("topk", "randtopk", "randtopk_quant",
                         "randtopk_mask", "size_reduction"):
        kw["k"] = sc.k
    if sc.compressor in ("randtopk", "randtopk_quant", "randtopk_mask"):
        kw["alpha"] = sc.alpha
    if sc.compressor in ("quant", "randtopk_quant"):
        kw["bits"] = sc.quant_bits
    if sc.compressor == "l1":
        kw["lam"] = sc.l1_lam
    if sc.backend is not None:
        kw["backend"] = sc.backend
    return compressors.make_compressor(sc.compressor, **kw)


def pod_ring_perm(n_pod: int, *, inverse: bool = False):
    """The cut-boundary ring permutation along the 'pod' axis.

    Forward sends pod i's leaves to pod i+1 (mod n); inverse returns them.
    Shared by `_pod_permute` (the in-graph training transfer) and the
    sharded serving step (`runtime.steps.make_arena_top_step` with a
    pod-axis mesh), so both paths carry the identical collective schedule.
    """
    step = -1 if inverse else 1
    return [(i, (i + step) % n_pod) for i in range(n_pod)]


def _pod_permute(rt: Runtime, *leaves, inverse: bool = False):
    """ppermute every array along the pod axis (0 <-> 1).

    `inverse=True` applies the inverse permutation (used by the backward
    wire so cotangents return to the pod that produced the activation).
    """
    mesh = rt.mesh
    if mesh is None or "pod" not in mesh.axis_names or mesh.shape["pod"] < 2:
        return leaves
    perm = pod_ring_perm(mesh.shape["pod"], inverse=inverse)

    def spec_for(a):
        # batch axis is dim 0, sharded over (pod, data); rest replicated/model
        return P(("pod", "data"), *([None] * (a.ndim - 1)))

    def body(*xs):
        return tuple(jax.lax.ppermute(x, "pod", perm) for x in xs)

    out = shard_map(
        body, mesh=mesh,
        in_specs=tuple(spec_for(a) for a in leaves),
        out_specs=tuple(spec_for(a) for a in leaves),
    )(*leaves)
    return out


def _transfer_payload(rt: Runtime, p: Payload, inverse: bool = False) -> Payload:
    """Move every wire leaf of a payload across the pod boundary."""
    names = [n for n, _ in p.wire_leaves()]
    arrs = _pod_permute(rt, *[a for _, a in p.wire_leaves()], inverse=inverse)
    return p.with_leaves(**dict(zip(names, arrs)))


# ---------------------------------------------------------------------------
# Backward wire rules, dispatched on the payload kind (not the compressor).
# ---------------------------------------------------------------------------

def _grad_to_wire(kind: str, g, idx_far, k: int):
    """Label-owner side: the gradient leaves that cross back (Table 2 bwd)."""
    if kind in ("sparse", "sparse_quant"):
        return jnp.take_along_axis(g, idx_far.astype(jnp.int32), axis=-1)
    if kind == "mask":
        # idx_far = the packed support bitmask words; gather the k supported
        # gradient values in ascending-index order (the mask payload's value
        # order, so the feature owner can expand with the same mask)
        mask = selection.unpack_mask_words(idx_far, g.shape[-1])
        idx = jnp.argsort(~mask, axis=-1, stable=True)[..., :k]
        return jnp.take_along_axis(g, idx, axis=-1)
    if kind == "slice":
        return g[..., :k]
    return g  # dense / quant: full-precision dense gradient


def _grad_from_wire(kind: str, gw, idx_local, d: int):
    """Feature-owner side: route the wire gradient onto the activation.

    Sparse/slice/mask kinds scatter onto the forward support (the paper's
    same-mask backward); dense/quant kinds are the identity (STE)."""
    if kind in ("sparse", "sparse_quant"):
        out = jnp.zeros(gw.shape[:-1] + (d,), gw.dtype)
        return jnp.put_along_axis(out, idx_local.astype(jnp.int32), gw,
                                  axis=-1, inplace=False)
    if kind == "mask":
        # idx_local = packed support words; mask-driven expand, ascending
        return compressors.mask_expand_rows(gw, idx_local, d)
    if kind == "slice":
        pad = [(0, 0)] * (gw.ndim - 1) + [(0, d - gw.shape[-1])]
        return jnp.pad(gw, pad)
    return gw


def _transport(comp: compressors.Compressor, x, rt: Runtime, key,
               over_pod: bool):
    """encode -> ppermute payload leaves -> decode, with the payload-typed
    backward wire attached via custom VJP."""
    kind = comp.wire_kind
    d = x.shape[-1]
    k_eff = min(getattr(comp, "k", 0), d)

    def _encode_transfer(x):
        p = comp.encode(x, key=key, training=rt.training)
        pt = _transfer_payload(rt, p) if over_pod else p
        return p, pt

    @jax.custom_vjp
    def run(x):
        _, pt = _encode_transfer(x)
        return comp.decode(pt, shape=x.shape, dtype=x.dtype)

    def run_fwd(x):
        p, pt = _encode_transfer(x)
        y = comp.decode(pt, shape=x.shape, dtype=x.dtype)
        return y, (p.indices, pt.indices)

    def run_bwd(res, g):
        idx_local, idx_far = res
        gw = _grad_to_wire(kind, g, idx_far, k_eff)
        if over_pod:
            (gw,) = _pod_permute(rt, gw, inverse=True)
        return (_grad_from_wire(kind, gw, idx_local, d),)

    run.defvjp(run_fwd, run_bwd)
    return run(x)


# ---------------------------------------------------------------------------
# Out-of-process halves — the wire interface for parties that are NOT in the
# same jit program (streaming clients/servers, real sockets).
# ---------------------------------------------------------------------------

class HostDensifyCounter:
    """Registry-backed count of host-side dense materializations.

    Incremented by every `server_decode` call. The serving/training hot
    paths must keep it flat (they decode on device via
    `server_decode_device` / `server_decode_to_slots`), and it is read and
    written across server reader threads, the serve loop, and test threads
    — hence a locked counter, not a bare module global.

    The count itself lives in the process-wide metrics registry
    (`obs.registry.DEFAULT_REGISTRY`, metric `host_densify_total`) so it
    shows up in registry snapshots next to every other runtime metric;
    this class is the legacy surface over it. The registry metric stays
    monotonic (Prometheus counter semantics); `reset()` and `watch()` are
    implemented as baseline offsets on top of it.

    The registry binding happens at first use, not import: this module is
    imported by `runtime/server.py` while `repro.runtime.__init__` may be
    mid-execution, and pulling in `repro.obs` (which reaches
    `repro.testing` → `runtime.transport`) during *this* module's import
    would re-enter that cycle.

    Use `watch()` to pin a region flat (deprecated: new code should read
    `host_densify_total` from the registry snapshot instead; kept as a
    thin shim for existing callers)::

        with protocol.HOST_DENSIFY_COUNT.watch() as w:
            run_streaming(...)
        assert w.delta == 0

    `reset()` zeroes the counter and returns the prior value. `int(...)`
    and equality against ints keep one-off reads ergonomic.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counter = None
        self._offset = 0

    def _bind(self):
        if self._counter is None:
            from repro.obs.registry import DEFAULT_REGISTRY
            self._counter = DEFAULT_REGISTRY.counter("host_densify_total")
        return self._counter

    @property
    def value(self) -> int:
        with self._lock:
            return int(self._bind().value) - self._offset

    def increment(self) -> None:
        self._bind().inc()

    def reset(self) -> int:
        with self._lock:
            total = int(self._bind().value)
            prior = total - self._offset
            self._offset = total
            return prior

    @contextlib.contextmanager
    def watch(self):
        # deprecated shim: prefer DEFAULT_REGISTRY.counter(
        # "host_densify_total").value deltas / registry snapshots
        outer = self

        class _Watch:
            start = outer.value

            @property
            def delta(self) -> int:
                return outer.value - self.start

        yield _Watch()

    def __int__(self) -> int:
        return self.value

    def __eq__(self, other) -> bool:
        # duck-typed: anything int()-able compares by count (this module
        # bans type-dispatch branches, pinned in tests/test_payload.py)
        try:
            return self.value == int(other)
        except (TypeError, ValueError):
            return NotImplemented

    def __repr__(self) -> str:
        return f"HostDensifyCounter({self.value})"


#: host-side dense materializations performed by `server_decode` — see
#: `HostDensifyCounter`; tests watch it around an engine run to pin "zero
#: host-side densification".
HOST_DENSIFY_COUNT = HostDensifyCounter()


def client_encode(comp: compressors.Compressor, x, *, key=None,
                  training: bool = False) -> Payload:
    """Feature-owner half: compress a cut activation to a host Payload.

    Returns the payload with numpy leaves, ready to be framed by
    `core.wire.encode_payload_frame` and put on a socket. The device-side
    `comp.encode` may be jitted by the caller; this helper just pulls the
    leaves to host afterwards.
    """
    import numpy as np

    p = comp.encode(x, key=key, training=training)
    return jax.tree.map(np.asarray, p)


def client_encode_device(comp: compressors.Compressor, x, *, key=None,
                         training: bool = False):
    """Device variant of `client_encode`: the wire bitstream is assembled
    on device (`kernels.encode.ops.pack_payload`), so the only host
    crossing is the final packed buffer(s) — no f32 dense pull, no numpy
    bit matrix.

    Returns `(payload, sections)`: `payload` keeps DEVICE leaves (the
    support leaf stays available for the training-direction grad decode
    without a dense pull), `sections` are the packed u32 buffers. Frame
    them with::

        body = enc_ops.sections_to_bytes(p.meta, p.batch_shape, sections)
        wire.encode_payload_frame_from_bytes(sid, seq, p.meta,
                                             p.batch_shape, body)

    When the backend resolves to Pallas (on-TPU default), the sparse /
    quant / mask kinds run the fused `kernels.encode` kernel (selection
    mask -> gather -> quantize -> pack in one pass); elsewhere the XLA
    `comp.encode` feeds the XLA bit-packer. Byte equality of the two
    paths with the host codec is pinned in tests/test_encode_kernels.py.
    """
    from repro.kernels.encode import ops as enc_ops

    kind = comp.wire_kind
    backend = selection._resolve_backend(comp.backend)
    if backend == "pallas" and kind in ("sparse", "sparse_quant", "mask",
                                        "quant", "slice"):
        d = x.shape[-1]
        k = min(getattr(comp, "k", 0) or 0, d)
        mask = (comp._mask(x, key, training)
                if kind in ("sparse", "sparse_quant", "mask") else None)
        p = enc_ops.encode_rows(x, kind, k=k,
                                bits=getattr(comp, "bits", 0), mask=mask,
                                interpret=selection._pallas_interpret())
    else:
        p = comp.encode(x, key=key, training=training)
    return p, enc_ops.pack_payload(p, backend=comp.backend)


def server_decode(p: Payload, *, dtype=None):
    """Label-owner half: dense (..., d) view of a received payload.

    Dispatches on `p.meta.kind` only (`compressors.payload_to_dense`) — the
    server needs no compressor object and no per-session codec state; the
    frame's subheader fully describes the payload.

    This is the *host-side* decode (counted in `HOST_DENSIFY_COUNT`): fine
    for warmup probes, tests, and one-off decodes. The serving/training hot
    loops use `server_decode_device` / `server_decode_to_slots` instead, so
    only the compressed wire leaves ever cross host->device.
    """
    HOST_DENSIFY_COUNT.increment()
    return compressors.payload_to_dense(p, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("dtype", "backend"))
def _decode_device_jit(p: Payload, *, dtype: str, backend):
    return compressors.payload_to_dense(p, dtype=jnp.dtype(dtype),
                                        backend=backend)


def server_decode_device(p: Payload, *, dtype=None, backend=None):
    """`server_decode`, but the densification happens on device under jit.

    The host moves only the payload's wire leaves (k floats + packed
    indices, not the dense tensor) to the device; the scatter/dequant runs
    compiled (Pallas scatter kernel or XLA `put_along_axis` per `backend`).
    Jit caches by (meta, leaf shapes, dtype, backend) — one compile per
    distinct payload meta. Bit-identical to `server_decode`.
    """
    dt = jnp.dtype(dtype or jnp.float32).name
    return _decode_device_jit(p, dtype=dt, backend=backend)


def decode_to_slots_in_jit(xbuf, p: Payload, slots, *, dtype, backend):
    """Trace-time body of the slot decode — shared by `_decode_to_slots_jit`
    and the serving runtime's fused decode+step program
    (`runtime.steps.make_fused_decode_step`), so both paths have identical
    numerics by construction. `backend="pallas"` runs the fused one-kernel
    path (dequant + scatter + slot placement in a single pass, xbuf aliased
    straight through the kernel); XLA decodes then scatters `xbuf[slots]`.
    """
    from repro.core import selection

    if selection._resolve_backend(backend) == "pallas":
        from repro.kernels.decode import ops as dec_ops

        return dec_ops.decode_rows_to_slots(
            xbuf, p, slots, interpret=selection._pallas_interpret())
    rows = compressors.payload_to_dense(p, dtype=jnp.dtype(dtype),
                                        backend=backend)
    return xbuf.at[slots].set(rows)


@functools.partial(jax.jit, static_argnames=("dtype", "backend"),
                   donate_argnums=(0,))
def _decode_to_slots_jit(xbuf, p: Payload, slots, *, dtype: str, backend):
    return decode_to_slots_in_jit(xbuf, p, slots, dtype=dtype,
                                  backend=backend)


def server_decode_to_slots(xbuf, p: Payload, slots, *, dtype=None,
                           backend=None):
    """Device/slot variant of `server_decode`: decode a *stacked* payload
    (leading batch axis = flush rows) and scatter the dense rows straight
    into `xbuf[slots]` — the serving arena's cut-activation buffer.

    `xbuf` is DONATED: the caller must treat its handle as consumed and keep
    the returned array (on TPU the update is in place; no (S, ..., d) dense
    staging array exists on the host at any point). `slots` maps flush row i
    -> arena slot; rows padded onto a scratch slot are how the server keeps
    one compile per payload meta. Jit caches by (meta, shapes, dtype,
    backend).
    """
    dt = jnp.dtype(dtype or jnp.float32).name
    return _decode_to_slots_jit(xbuf, p, jnp.asarray(slots, jnp.int32),
                                dtype=dt, backend=backend)


def server_grad_encode(p: Payload, g) -> Payload:
    """Label-owner backward half: compress the dense cut gradient (..., d)
    to the wire payload the *forward* payload's kind dictates (Table 2 bwd).

    Sparse forward kinds send only the k gradient floats at the forward
    support (the feature owner already holds the indices), `slice` the first
    k, dense/quant kinds the full-precision dense gradient — the same rules
    `_grad_to_wire` applies inside the fused custom-VJP path. The returned
    payload has numpy leaves, ready for `core.wire.encode_grad_frame`.
    """
    import numpy as np

    kind = p.meta.kind
    d = p.meta.d
    k = min(p.meta.k or d, d)
    idx = None if p.indices is None else jnp.asarray(p.indices)
    gw = _grad_to_wire(kind, jnp.asarray(g), idx, k)
    sparse_bwd = kind in ("sparse", "sparse_quant", "slice", "mask")
    meta = (PayloadMeta("slice", d=d, k=k) if sparse_bwd
            else PayloadMeta("dense", d=d))
    return Payload(meta=meta, values=np.asarray(gw, np.float32))


def client_grad_decode(gp: Payload, *, fwd_kind: str, indices=None,
                       d: int):
    """Feature-owner backward half: dense (..., d) cut gradient from a
    received grad payload, routed onto the support of the forward payload
    the client sent (scatter for sparse kinds, pad for slice, identity for
    dense/quant — the paper's same-mask backward / STE rules)."""
    idx = None if indices is None else jnp.asarray(indices)
    return _grad_from_wire(fwd_kind, jnp.asarray(gp.values), idx, d)


def cut_boundary(x, cfg: ArchConfig, rt: Runtime, key) -> tuple:
    """Compress the cut activation (B, S, d), move the packed payload across
    the pod boundary, decode on the far side. Returns (x_top, l1_penalty).

    One generic path for every compressor — the payload object is the whole
    interface between the compressor, the wire, and the far side."""
    sc = cfg.split
    comp = make_cut_compressor(sc)
    d = x.shape[-1]
    pen = comp.loss_penalty(x.reshape(-1, d))
    y = _transport(comp, x, rt, key, over_pod=sc.transfer_over_pod)
    return rt.shard(y, "batch", None, None), pen


def wire_bytes_per_step(cfg: ArchConfig, batch: int, seq: int,
                        *, training: bool) -> float:
    """Paper-exact cut-layer wire bytes for one step (Table 2)."""
    from repro.core import wire

    sc = cfg.split
    if sc is None:
        return 0.0
    method = sc.compressor
    return wire.bytes_per_step(method, cfg.d_model, batch * seq, k=sc.k,
                               bits=sc.quant_bits, training=training)


def measured_payload_bytes(cfg: ArchConfig, batch: int, seq: int,
                           *, training: bool = False, key=None) -> int:
    """Byte-exact forward payload size for one (batch, seq) step, measured by
    actually encoding a probe activation and serializing it with
    `wire.encode_payload` — the codec-side cross-check of
    `wire_bytes_per_step`'s analytic formula."""
    from repro.core import wire

    sc = cfg.split
    if sc is None:
        return 0
    comp = make_cut_compressor(sc)
    probe = jax.random.normal(jax.random.key(0), (batch, seq, cfg.d_model))
    return wire.payload_nbytes(client_encode(comp, probe, key=key,
                                             training=training))
