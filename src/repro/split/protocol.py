"""Cut-layer transfer protocol.

Maps the split-learning party-to-party socket onto the TPU fabric: the two
parties are the two pods of the production mesh, and the compressed payload
crosses the pod boundary with a `ppermute` along the 'pod' axis inside
`shard_map` (the TPU-native point-to-point send).

Placement is *symmetrized SPMD split learning*: the batch is sharded over
('pod', 'data'), so each pod acts as feature owner for its half of the batch
and as label owner for the other half — every sample's cut activation crosses
the pod boundary exactly once per direction, so pod-boundary traffic per
sample is identical to classic two-party SL while keeping both pods busy
(bidirectional split learning). Wire bytes therefore scale with the paper's
compressed size: k float values + k uint16 indices per token forward, k float
values backward (Table 2).

On a single-pod mesh (or no mesh) the transfer is the identity — parties are
co-located and the savings show up as reduced cut-boundary tensor bytes only.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compressors, selection
from repro.models.config import ArchConfig, Runtime, SplitConfig


def make_cut_compressor(sc: SplitConfig) -> compressors.Compressor:
    if sc.compressor in ("topk", "randtopk"):
        kw = {"k": sc.k}
        if sc.compressor == "randtopk":
            kw["alpha"] = sc.alpha
        return compressors.make_compressor(sc.compressor, **kw)
    if sc.compressor == "size_reduction":
        return compressors.SizeReduction(k=sc.k)
    if sc.compressor == "quant":
        return compressors.Quantization(bits=sc.quant_bits)
    if sc.compressor == "l1":
        return compressors.L1Reg(lam=sc.l1_lam)
    return compressors.Compressor()


def _pod_permute(rt: Runtime, *leaves):
    """ppermute every array along the pod axis (0 <-> 1)."""
    mesh = rt.mesh
    if mesh is None or "pod" not in mesh.axis_names or mesh.shape["pod"] < 2:
        return leaves
    n_pod = mesh.shape["pod"]
    perm = [(i, (i + 1) % n_pod) for i in range(n_pod)]

    def spec_for(a):
        # batch axis is dim 0, sharded over (pod, data); rest replicated/model
        return P(("pod", "data"), *([None] * (a.ndim - 1)))

    def body(*xs):
        return tuple(jax.lax.ppermute(x, "pod", perm) for x in xs)

    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=tuple(spec_for(a) for a in leaves),
        out_specs=tuple(spec_for(a) for a in leaves),
    )(*leaves)
    return out


def cut_boundary(x, cfg: ArchConfig, rt: Runtime, key) -> tuple:
    """Compress the cut activation (B, S, d), move it across the pod
    boundary, decompress on the far side. Returns (x_top, l1_penalty)."""
    sc = cfg.split
    comp = make_cut_compressor(sc)
    B, S, d = x.shape
    zero = jnp.zeros((), jnp.float32)

    if isinstance(comp, compressors.L1Reg):
        pen = comp.loss_penalty(x.reshape(-1, d))
        if rt.training:
            (y,) = _pod_permute(rt, x) if sc.transfer_over_pod else (x,)
            return rt.shard(y, "batch", None, None), pen
        y, _ = comp.forward(x, training=False)
        (y,) = _pod_permute(rt, y) if sc.transfer_over_pod else (y,)
        return rt.shard(y, "batch", None, None), pen

    if isinstance(comp, compressors.Quantization):
        y, _ = comp.forward(x, training=rt.training)  # STE through quantize
        # wire = int codes + per-token range; we model it by sending the
        # dequantized tensor in int8-equivalent width is not expressible, so
        # the pod transfer moves the dense dequantized tensor; roofline
        # accounting uses wire.py for the paper-exact byte count.
        (y,) = _pod_permute(rt, y) if sc.transfer_over_pod else (y,)
        return rt.shard(y, "batch", None, None), zero

    if isinstance(comp, compressors.SizeReduction):
        vals = x[..., : sc.k]                                    # (B,S,k)
        (vals,) = _pod_permute(rt, vals) if sc.transfer_over_pod else (vals,)
        y = jnp.pad(vals, ((0, 0), (0, 0), (0, d - sc.k)))
        return rt.shard(y, "batch", None, None), zero

    if isinstance(comp, compressors.TopK):  # TopK or RandTopK
        if isinstance(comp, compressors.RandTopK) and rt.training:
            mask = selection.randtopk_mask(x, sc.k, sc.alpha, key)
        else:
            mask = selection.topk_mask(x, sc.k)
        mask = jax.lax.stop_gradient(mask)
        # payload: k values + k uint16 indices per token (d_model < 65536)
        score = jnp.where(mask, jnp.abs(x.astype(jnp.float32)), -1.0)
        _, idx = jax.lax.top_k(score, sc.k)                      # (B,S,k)
        vals = jnp.take_along_axis(x, idx, axis=-1)
        idx16 = idx.astype(jnp.uint16)
        if sc.transfer_over_pod:
            vals, idx16 = _pod_permute(rt, vals, idx16)
        idx = idx16.astype(jnp.int32)
        y = jnp.zeros_like(x).at[
            jnp.arange(B)[:, None, None],
            jnp.arange(S)[None, :, None],
            idx,
        ].set(vals)
        return rt.shard(y, "batch", None, None), zero

    # identity / vanilla SL
    (y,) = _pod_permute(rt, x) if sc.transfer_over_pod else (x,)
    return rt.shard(y, "batch", None, None), zero


def wire_bytes_per_step(cfg: ArchConfig, batch: int, seq: int,
                        *, training: bool) -> float:
    """Paper-exact cut-layer wire bytes for one step (Table 2)."""
    from repro.core import wire

    sc = cfg.split
    if sc is None:
        return 0.0
    method = sc.compressor
    return wire.bytes_per_step(method, cfg.d_model, batch * seq, k=sc.k,
                               bits=sc.quant_bits, training=training)
