from repro.split import model, protocol

__all__ = ["model", "protocol"]
