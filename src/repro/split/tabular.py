"""Explicit two-party split-learning trainer for the paper-scale experiments.

This mirrors the paper's Figure 1 protocol *literally* — the forward/backward
boundary is realized with jax.vjp so the bytes that cross the party boundary
are exactly the compressed payload (no autodiff shortcut through the wire):

  feature owner:  O_b = M_b(X)            -> Comp(O_b) ------> wire
  label owner:    C[O_b] -> M_t -> loss;  G = dL/dC[O_b]
                  Comp_bwd(G) <----------------------------- wire
  feature owner:  dM_b = (dO_b/dtheta_b)^T G_masked

The cut layer is the last hidden layer and the top model is a linear+softmax
classifier, exactly the setting of the paper's analysis (Section 4.1).
Wire bytes per step are accounted with the Table-2 formulas (core.wire).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressors as C, selection, wire
from repro.optim import adamw_init, adamw_update
from repro.split import protocol


@dataclasses.dataclass
class SplitSpec:
    in_dim: int = 64
    hidden: int = 256
    cut_dim: int = 128          # d — bottom model output (paper: 128 for CIFAR)
    n_classes: int = 100
    method: str = "none"  # none|topk|randtopk|randtopk_mask|size_reduction|quant|l1
    k: int = 3
    alpha: float = 0.1
    quant_bits: int = 4
    l1_lam: float = 1e-3
    lr: float = 1e-3


def init_parties(key, spec: SplitSpec):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s1 = (2.0 / spec.in_dim) ** 0.5
    s2 = (2.0 / spec.hidden) ** 0.5
    s3 = (2.0 / spec.cut_dim) ** 0.5
    bottom = {
        "w1": s1 * jax.random.normal(k1, (spec.in_dim, spec.hidden)),
        "b1": jnp.zeros((spec.hidden,)),
        "w2": s2 * jax.random.normal(k2, (spec.hidden, spec.cut_dim)),
        "b2": jnp.zeros((spec.cut_dim,)),
    }
    top = {
        "w": s3 * jax.random.normal(k3, (spec.cut_dim, spec.n_classes)),
        "b": jnp.zeros((spec.n_classes,)),
    }
    return bottom, top


def bottom_fn(bp, x):
    h = jax.nn.relu(x @ bp["w1"] + bp["b1"])
    # post-ReLU cut activation, like the paper's ResNet/TextCNN cut layers;
    # non-negative and naturally sparse-able
    return jax.nn.relu(h @ bp["w2"] + bp["b2"])


def top_fn(tp, o, y):
    logits = o @ tp["w"] + tp["b"]
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return loss, logits


def _forward_view(o_b, spec: SplitSpec, key, training: bool):
    """Label-owner-side view of the cut activation + the backward mask."""
    d = spec.cut_dim
    if spec.method == "none" or spec.method == "l1":
        return o_b, None
    if spec.method == "topk":
        mask = selection.topk_mask(o_b, spec.k)
    elif spec.method == "randtopk_quant":
        from repro.core.compressors import RandTopKQuant
        comp = RandTopKQuant(k=spec.k, alpha=spec.alpha,
                             bits=spec.quant_bits)
        y, aux = comp.forward(o_b, key=key, training=training)
        return y, aux["mask"]
    elif spec.method in ("randtopk", "randtopk_mask"):
        # randtopk_mask differs only in wire encoding (packed support
        # bitmask instead of u16 indices); the selection math is shared
        mask = (selection.randtopk_mask(o_b, spec.k, spec.alpha, key)
                if training else selection.topk_mask(o_b, spec.k))
    elif spec.method == "size_reduction":
        mask = jnp.broadcast_to(jnp.arange(d) < spec.k, o_b.shape)
    elif spec.method == "quant":
        comp = C.Quantization(bits=spec.quant_bits)
        deq = comp.decode(comp.encode(o_b), dtype=o_b.dtype)
        return deq, None
    else:
        raise ValueError(spec.method)
    return o_b * mask.astype(o_b.dtype), mask


def make_train_step(spec: SplitSpec):
    """One explicit two-party step: returns new params + (loss, wire_bytes)."""

    def step(bottom, top, opt_b, opt_t, x, y, key):
        # ---- feature owner forward
        o_b, vjp_bottom = jax.vjp(lambda bp: bottom_fn(bp, x), bottom)
        # ---- wire: forward payload
        view, mask = _forward_view(o_b, spec, key, training=True)
        view = jax.lax.stop_gradient(view)  # crossing the trust boundary
        # ---- label owner forward + backward
        (loss, _), vjp_top = jax.vjp(
            lambda tp, o: top_fn(tp, o, y), top, view)
        dtp, dview = vjp_top((jnp.ones(()),
                              jnp.zeros((x.shape[0], spec.n_classes))))
        # ---- wire: backward payload (masked per Table 2)
        if mask is not None:
            g_cut = dview * mask.astype(dview.dtype)
        else:
            g_cut = dview
        if spec.method == "l1":
            g_cut = g_cut + spec.l1_lam * jnp.sign(o_b) / x.shape[0]
        # ---- feature owner backward
        (dbp,) = vjp_bottom(g_cut)
        new_b, new_ob, _ = adamw_update(bottom, dbp, opt_b, lr=spec.lr,
                                        grad_clip=0.0)
        new_t, new_ot, _ = adamw_update(top, dtp, opt_t, lr=spec.lr,
                                        grad_clip=0.0)
        return new_b, new_t, new_ob, new_ot, loss

    return jax.jit(step)


def spec_compressor(spec: SplitSpec) -> C.Compressor:
    """SplitSpec -> codec object — the tabular-config twin of
    `protocol.make_cut_compressor`, shared with `repro.fedtrain`."""
    m = spec.method
    if m in (None, "none"):
        return C.Compressor()
    if m == "topk":
        return C.TopK(k=spec.k)
    if m == "randtopk":
        return C.RandTopK(k=spec.k, alpha=spec.alpha)
    if m == "randtopk_mask":
        return C.RandTopKMask(k=spec.k, alpha=spec.alpha)
    if m == "size_reduction":
        return C.SizeReduction(k=spec.k)
    if m == "quant":
        return C.Quantization(bits=spec.quant_bits)
    if m == "randtopk_quant":
        return C.RandTopKQuant(k=spec.k, alpha=spec.alpha,
                               bits=spec.quant_bits)
    if m == "l1":
        return C.L1Reg(lam=spec.l1_lam)
    raise ValueError(m)


def measured_step_bytes(spec: SplitSpec, o_b, *, key=None) -> int:
    """Byte-exact fwd+bwd wire payload bytes for one batch step, measured by
    actually encoding the cut activation and the backward payload its kind
    dictates (`core.wire.payload_nbytes` on both) — the frame-level
    cross-check of the formula-based `wire_bytes`.

    Agrees with the Table-2 formulas within 5%: the only systematic gaps are
    the per-instance 8 B quantization range header (which the quant row
    omits by design) and whole-byte rounding of bit-packed sections. L1 is
    the exception — its Table-2 row models a sparse encoding of the nnz
    support, while the training-time transport is the dense activation, so
    the two accountings answer different questions and are both reported.
    """
    comp = spec_compressor(spec)
    p = protocol.client_encode(comp, o_b, key=key, training=True)
    g = np.zeros(np.asarray(o_b).shape[:-1] + (spec.cut_dim,), np.float32)
    gp = protocol.server_grad_encode(p, g)
    return wire.payload_nbytes(p) + wire.payload_nbytes(gp)


def wire_bytes(spec: SplitSpec, batch: int, *, training: bool,
               measured_nnz: float = None) -> float:
    d = spec.cut_dim
    if spec.method == "none":
        return wire.bytes_per_step("identity", d, batch, training=training)
    if spec.method == "l1":
        k = measured_nnz if measured_nnz is not None else d
        return wire.bytes_per_step("l1", d, batch, k=k, training=training)
    return wire.bytes_per_step(spec.method, d, batch, k=spec.k,
                               bits=spec.quant_bits, training=training)


from functools import partial


@partial(jax.jit, static_argnums=(4, 5))
def _accuracy(bottom, top, x, y, mask_fn_id: int, k: int):
    o = bottom_fn(bottom, x)
    if mask_fn_id == 1:
        o = o * selection.topk_mask(o, k).astype(o.dtype)
    elif mask_fn_id == 2:
        o = o * (jnp.arange(o.shape[-1]) < k).astype(o.dtype)
    logits = o @ top["w"] + top["b"]
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


def evaluate(bottom, top, spec: SplitSpec, x, y, *, quant=True) -> float:
    """Inference-time accuracy with the method's deterministic behavior."""
    if spec.method in ("topk", "randtopk", "randtopk_quant"):
        if spec.method == "randtopk_quant":
            from repro.core.compressors import RandTopKQuant
            comp = RandTopKQuant(k=spec.k, alpha=spec.alpha,
                                 bits=spec.quant_bits)
            o = bottom_fn(bottom, x)
            o, _ = comp.forward(o, training=False)
            logits = o @ top["w"] + top["b"]
            return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(
                jnp.float32)))
        return float(_accuracy(bottom, top, x, y, 1, spec.k))
    if spec.method == "size_reduction":
        return float(_accuracy(bottom, top, x, y, 2, spec.k))
    if spec.method == "quant":
        o = bottom_fn(bottom, x)
        comp = C.Quantization(bits=spec.quant_bits)
        o = comp.decode(comp.encode(o), dtype=o.dtype)
        logits = o @ top["w"] + top["b"]
        return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(
            jnp.float32)))
    return float(_accuracy(bottom, top, x, y, 0, 0))


def train(spec: SplitSpec, dataset, *, epochs: int = 15, batch: int = 128,
          seed: int = 0, record_every: int = 0) -> Dict:
    """Full two-party training run. Returns accuracy + comm accounting +
    optional convergence trace."""
    key = jax.random.key(seed)
    bottom, top = init_parties(key, spec)
    opt_b, opt_t = adamw_init(bottom), adamw_init(top)
    step = make_train_step(spec)
    rng = np.random.RandomState(seed)
    trace = []
    total_bytes = 0.0
    measured_bytes = 0.0
    step_nbytes = None
    it = 0
    for ep in range(epochs):
        for xb, yb in dataset.batches(batch, rng=rng):
            key, sub = jax.random.split(key)
            bottom, top, opt_b, opt_t, loss = step(
                bottom, top, opt_b, opt_t, jnp.asarray(xb), jnp.asarray(yb),
                sub)
            if step_nbytes is None:
                # per-step wire size is shape-static for every method
                # (l1's training transport is dense): measure once
                o_probe = bottom_fn(bottom, jnp.asarray(xb))
                step_nbytes = measured_step_bytes(spec, o_probe, key=sub)
            measured_bytes += step_nbytes
            if spec.method == "l1":
                o = bottom_fn(bottom, jnp.asarray(xb))
                nnz = float(jnp.mean(jnp.sum(jnp.abs(o) > 1e-4, -1)))
                total_bytes += wire_bytes(spec, batch, training=True,
                                          measured_nnz=nnz)
            else:
                total_bytes += wire_bytes(spec, batch, training=True)
            it += 1
            if record_every and it % record_every == 0:
                acc = evaluate(bottom, top, spec,
                               jnp.asarray(dataset.x_test),
                               jnp.asarray(dataset.y_test))
                trace.append((it, total_bytes, float(loss), acc))
    test_acc = evaluate(bottom, top, spec, jnp.asarray(dataset.x_test),
                        jnp.asarray(dataset.y_test))
    train_acc = evaluate(bottom, top, spec, jnp.asarray(dataset.x_train),
                         jnp.asarray(dataset.y_train))
    # measured compressed size at inference (relative, %)
    if spec.method == "l1":
        o = bottom_fn(bottom, jnp.asarray(dataset.x_test))
        nnz = float(jnp.mean(jnp.sum(jnp.abs(o) > 1e-4, -1)))
        rel = wire.table2_row("l1", spec.cut_dim, k=nnz)["fwd"]
    elif spec.method == "none":
        rel = 1.0
    else:
        rel = wire.table2_row(spec.method, spec.cut_dim, k=spec.k,
                              bits=spec.quant_bits)["fwd"]
    # formula-vs-measured cross-check (the PR-2 byte-accounting rule): the
    # compressor's own fwd/bwd accounting — which, unlike the quant Table-2
    # row, includes the 8 B range header any real encoder ships — must match
    # the measured frame bytes within 5%. L1 is exempt: its row models the
    # nnz sparse encoding, not the dense training transport
    # (see measured_step_bytes).
    if spec.method != "l1" and it > 0:
        comp = spec_compressor(spec)
        analytic = (comp.fwd_bits(spec.cut_dim)
                    + comp.bwd_bits(spec.cut_dim)) / 8 * batch * it
        rel_err = abs(measured_bytes - analytic) / analytic
        assert rel_err < 0.05, (
            f"{spec.method}: measured train bytes {measured_bytes:.0f} vs "
            f"analytic {analytic:.0f} ({100 * rel_err:.1f}% apart)")
        if spec.method != "quant":  # quant's Table-2 row omits the header
            rel_err = abs(measured_bytes - total_bytes) / total_bytes
            assert rel_err < 0.05, (
                f"{spec.method}: measured train bytes {measured_bytes:.0f} "
                f"vs Table-2 {total_bytes:.0f} ({100 * rel_err:.1f}% apart)")
    return {
        "method": spec.method, "k": spec.k, "alpha": spec.alpha,
        "test_acc": test_acc, "train_acc": train_acc,
        "gen_gap": train_acc - test_acc,
        "compressed_size_pct": 100.0 * rel,
        "train_bytes": total_bytes,
        "train_bytes_measured": measured_bytes, "trace": trace,
        "bottom": bottom, "top": top,
    }
