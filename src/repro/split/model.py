"""SplitModel: backbone forward with the cut-layer compression boundary.

The boundary is the packed-payload codec of `split.protocol.cut_boundary`:
the bottom model's activation is `encode`d to its wire form (values /
codes / indices / headers), ppermuted across the pod axis leaf-by-leaf, and
`decode`d before the top model — so the tensor bytes crossing the pod
boundary are exactly the Table-2 compressed sizes in both directions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ArchConfig, Runtime
from repro.split import protocol


def forward(params, cfg: ArchConfig, rt: Runtime, batch, *, key=None):
    """Split-aware forward: bottom layers -> encode/transfer/decode -> top
    layers.

    Returns (logits, aux) where aux folds the MoE balance loss and the L1
    cut-activation penalty.
    """
    if cfg.split is None or cfg.split.cut_layer <= 0:
        return transformer.forward(params, cfg, rt, batch, key=key)

    cut = cfg.split.cut_layer
    assert 0 < cut < cfg.n_layers, f"cut_layer {cut} out of range"
    extras = transformer.make_extras(params, cfg, rt, batch)
    x = transformer.embed(params, cfg, rt, batch["tokens"])
    x, aux1 = transformer.apply_layers(params, cfg, rt, x, extras, 0, cut)
    x, pen = protocol.cut_boundary(x, cfg, rt, key)
    x, aux2 = transformer.apply_layers(params, cfg, rt, x, extras, cut,
                                       cfg.n_layers)
    logits = transformer.lm_head(params, cfg, rt, x)
    return logits, aux1 + aux2 + pen


def decode_step(params, cfg: ArchConfig, rt: Runtime, token, cache):
    """Split-aware decode: the forward cut payload crosses the pod boundary
    every generated token (inference-phase communication — the paper's main
    target). Inference uses deterministic TopK (RandTopk is training-only)."""
    if cfg.split is None or cfg.split.cut_layer <= 0:
        return transformer.decode_step(params, cfg, rt, token, cache)

    import dataclasses as _dc

    cut = cfg.split.cut_layer
    x = transformer.embed(params, cfg, rt, token)
    x, nc1 = transformer.decode_layers(params, cfg, rt, x, cache, 0, cut)
    rt_inf = _dc.replace(rt, training=False)
    x, _ = protocol.cut_boundary(x, cfg, rt_inf, None)
    x, nc2 = transformer.decode_layers(params, cfg, rt, x, cache, cut,
                                       cfg.n_layers)
    logits = transformer.lm_head(params, cfg, rt, x)
    new_cache = dict(cache)
    for k in nc1:
        if k in nc2:
            new_cache[k] = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], axis=0), nc1[k], nc2[k])
        else:
            new_cache[k] = nc1[k]
    for k in nc2:
        if k not in nc1:
            new_cache[k] = nc2[k]
    new_cache["pos"] = cache["pos"] + 1
    return logits, new_cache
