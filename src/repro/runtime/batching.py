"""Max-batch/max-wait batching queue — the server's admission policy.

The serving loop amortizes one vmapped top-model step over every request
that arrives within a small window: a flush is triggered by whichever comes
first of (a) `max_batch` pending items, or (b) `max_wait` seconds elapsing
after the first pending item of the batch arrived. This is the standard
continuous-batching admission policy; the tradeoff knob is latency
(`max_wait`) against step efficiency (`max_batch` fill).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, List, Optional


class BatchingQueue:
    """Thread-safe queue with a max-batch/max-wait flush policy.

    Producers call `put`; the single consumer calls `get_batch`, which
    returns between 0 and `max_batch` items:

      * empty queue: block up to `idle_timeout` (default `max_wait`) for a
        first item; return `[]` if none arrives (the caller's idle tick).
      * >= 1 item pending: wait at most `max_wait` from the first pending
        item for the batch to fill, then flush whatever is there (the
        ragged final batch of a draining session mix is returned short).
      * `max_batch` items pending: flush immediately.

    `close()` wakes any waiter; once closed and drained, `get_batch`
    returns `[]` forever and `drained` is True.
    """

    def __init__(self, max_batch: int = 8, max_wait: float = 0.01):
        assert max_batch >= 1 and max_wait >= 0
        self.max_batch = max_batch
        self.max_wait = max_wait
        self._items: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._closed = False

    def put(self, item: Any) -> None:
        with self._cv:
            if self._closed:
                raise RuntimeError("put() on closed BatchingQueue")
            self._items.append((time.monotonic(), item))
            # wake the consumer only when its behavior can change: the
            # first pending item (starts the max_wait deadline) and the
            # fill-completing item (flush now). Intermediate puts would
            # each bounce the single consumer awake just to recompute an
            # unchanged deadline — measurable thrash when producers and
            # the serve loop time-slice one core.
            n = len(self._items)
            if n == 1 or n >= self.max_batch:
                self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    @property
    def drained(self) -> bool:
        with self._cv:
            return self._closed and not self._items

    def __len__(self) -> int:
        with self._cv:
            return len(self._items)

    def get_batch(self, idle_timeout: Optional[float] = None) -> List[Any]:
        idle = self.max_wait if idle_timeout is None else idle_timeout
        with self._cv:
            deadline = time.monotonic() + idle
            while not self._items and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return []
                self._cv.wait(remaining)
            if not self._items:
                return []                       # closed and drained
            # flush max_wait after the FIRST pending item arrived
            deadline = self._items[0][0] + self.max_wait
            while len(self._items) < self.max_batch and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
            n = min(self.max_batch, len(self._items))
            return [self._items.popleft()[1] for _ in range(n)]
