"""Max-batch/max-wait batching queue — the server's admission policy.

The serving loop amortizes one vmapped top-model step over every request
that arrives within a small window: a flush is triggered by whichever comes
first of (a) `max_batch` pending items, or (b) `max_wait` seconds elapsing
after the first pending item of the batch arrived. This is the standard
continuous-batching admission policy; the tradeoff knob is latency
(`max_wait`) against step efficiency (`max_batch` fill).

Two serving-scale extensions (docs/serving-slo.md):

  * admission control — `max_depth` bounds the pending backlog; a `put`
    that would exceed it raises `QueueFull` so the caller can reject the
    request instead of letting queueing delay grow without bound (an
    open-loop arrival process at rate > service capacity otherwise builds
    an unbounded queue and every session's latency diverges);
  * time injection — all deadline arithmetic goes through a
    `testing.clock.Clock`, so the identical flush policy runs under real
    threads (`SYSTEM_CLOCK`, the default — behavior unchanged) or under a
    `VirtualClock` event loop (`runtime.loadgen`), where `next_flush_at`
    tells the loop exactly when the policy wants its next flush.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, List, Optional

from repro.testing.clock import Clock, SYSTEM_CLOCK


class QueueFull(RuntimeError):
    """Admission-control rejection: the queue is at `max_depth`."""


class BatchingQueue:
    """Thread-safe queue with a max-batch/max-wait flush policy.

    Producers call `put`; the single consumer calls `get_batch`, which
    returns between 0 and `max_batch` items:

      * empty queue: block up to `idle_timeout` (default `max_wait`) for a
        first item; return `[]` if none arrives (the caller's idle tick).
      * >= 1 item pending: wait at most `max_wait` from the first pending
        item for the batch to fill, then flush whatever is there (the
        ragged final batch of a draining session mix is returned short).
      * `max_batch` items pending: flush immediately.

    `max_depth` (optional) bounds the backlog: `put` raises `QueueFull`
    instead of exceeding it. `close()` wakes any waiter; once closed and
    drained, `get_batch` returns `[]` forever and `drained` is True.
    """

    def __init__(self, max_batch: int = 8, max_wait: float = 0.01,
                 max_depth: Optional[int] = None,
                 clock: Clock = SYSTEM_CLOCK):
        assert max_batch >= 1 and max_wait >= 0
        assert max_depth is None or max_depth >= max_batch
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.max_depth = max_depth
        self.clock = clock
        self._items: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._closed = False

    def put(self, item: Any) -> None:
        with self._cv:
            if self._closed:
                raise RuntimeError("put() on closed BatchingQueue")
            if (self.max_depth is not None
                    and len(self._items) >= self.max_depth):
                raise QueueFull(
                    f"BatchingQueue at max_depth={self.max_depth}")
            self._items.append((self.clock.monotonic(), item))
            # wake the consumer only when its behavior can change: the
            # first pending item (starts the max_wait deadline) and the
            # fill-completing item (flush now). Intermediate puts would
            # each bounce the single consumer awake just to recompute an
            # unchanged deadline — measurable thrash when producers and
            # the serve loop time-slice one core.
            n = len(self._items)
            if n == 1 or n >= self.max_batch:
                self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    @property
    def drained(self) -> bool:
        with self._cv:
            return self._closed and not self._items

    def __len__(self) -> int:
        with self._cv:
            return len(self._items)

    def next_flush_at(self) -> Optional[float]:
        """When the flush policy next wants `get_batch` called: None if
        nothing is pending, "now" if a full batch is already waiting, else
        the first pending item's max_wait deadline. A virtual-clock event
        loop schedules its flush event here and `get_batch(idle_timeout=0)`
        then returns the batch without ever waiting."""
        with self._cv:
            if not self._items:
                return None
            if len(self._items) >= self.max_batch:
                return self.clock.monotonic()
            return self._items[0][0] + self.max_wait

    def get_batch(self, idle_timeout: Optional[float] = None) -> List[Any]:
        idle = self.max_wait if idle_timeout is None else idle_timeout
        with self._cv:
            deadline = self.clock.monotonic() + idle
            while not self._items and not self._closed:
                remaining = deadline - self.clock.monotonic()
                if remaining <= 0:
                    return []
                self.clock.cv_wait(self._cv, remaining)
            if not self._items:
                return []                       # closed and drained
            # flush max_wait after the FIRST pending item arrived
            deadline = self._items[0][0] + self.max_wait
            while len(self._items) < self.max_batch and not self._closed:
                remaining = deadline - self.clock.monotonic()
                if remaining <= 0:
                    break
                self.clock.cv_wait(self._cv, remaining)
            n = min(self.max_batch, len(self._items))
            return [self._items.popleft()[1] for _ in range(n)]
