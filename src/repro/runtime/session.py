"""Per-session state: KV-cache slot + byte accounting from real frames.

`SessionStats` is the measured counterpart of the Table-2 formulas: every
counter is incremented from the `len()` of bytes that actually crossed the
transport, split into payload bytes (the codec's bitstream — what the paper's
compressed sizes describe) and framing bytes (length prefix + headers, a
fixed per-frame cost the analytic rows do not model). Benchmarks compare
`payload_bytes_up / frames_up` against `core.wire` analytic predictions.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class SessionStats:
    """Byte/token accounting for one client session (both parties keep one
    and tests assert they agree)."""

    frames_up: int = 0          # payload frames sent client -> server
    payload_bytes_up: int = 0   # codec bitstream bytes only
    header_bytes_up: int = 0    # framing overhead (length prefix + headers)
    frames_down: int = 0        # token/grad frames server -> client
    bytes_down: int = 0         # total down-direction frame bytes
    payload_bytes_down: int = 0  # grad-frame codec bitstream bytes (training)
    header_bytes_down: int = 0   # grad-frame framing bytes (training)
    tokens_out: int = 0         # tokens the client kept (generated, not prompt)
    # fault counters — all zero on a clean wire; under injected chaos they
    # are the measured recovery record (engine.run_* aggregate them)
    faults_detected: int = 0    # typed WireErrors caught on this connection
    duplicates: int = 0         # replayed frames deduplicated by seq
    replays: int = 0            # retransmissions sent after timeout/error
    reconnects: int = 0         # fresh connections opened to resume

    @property
    def bytes_up(self) -> int:
        return self.payload_bytes_up + self.header_bytes_up

    @property
    def payload_bytes_per_frame(self) -> float:
        return self.payload_bytes_up / max(1, self.frames_up)

    def count_up(self, header_nbytes: int, payload_nbytes: int) -> None:
        self.frames_up += 1
        self.header_bytes_up += header_nbytes
        self.payload_bytes_up += payload_nbytes

    def count_down(self, nbytes: int) -> None:
        self.frames_down += 1
        self.bytes_down += nbytes

    def count_down_frame(self, header_nbytes: int,
                         payload_nbytes: int) -> None:
        """Down-direction frame with the payload/framing split — the
        training grad frames, whose payload bytes the Table-2 bwd column
        models (serving token replies keep the aggregate `count_down`)."""
        self.frames_down += 1
        self.header_bytes_down += header_nbytes
        self.payload_bytes_down += payload_nbytes
        self.bytes_down += header_nbytes + payload_nbytes

    def as_dict(self) -> dict:
        return dict(frames_up=self.frames_up,
                    payload_bytes_up=self.payload_bytes_up,
                    header_bytes_up=self.header_bytes_up,
                    frames_down=self.frames_down,
                    bytes_down=self.bytes_down,
                    payload_bytes_down=self.payload_bytes_down,
                    header_bytes_down=self.header_bytes_down,
                    tokens_out=self.tokens_out,
                    faults_detected=self.faults_detected,
                    duplicates=self.duplicates,
                    replays=self.replays,
                    reconnects=self.reconnects)


@dataclasses.dataclass
class Session:
    """Server-side view of one client: its arena slot + accounting.

    `slot` indexes the server's device-resident `runtime.arena.SlotArena`:
    the session's KV cache and position live in row `slot` of the arena's
    stacked arrays for the session's whole life (assigned at admission,
    surviving reconnects, reset only when the slot is reclaimed after
    close). -1 means no device state — training sessions, or a slot already
    reclaimed. The pre-arena per-session host `cache` pytree is gone: the
    serve loop never holds a per-session cache on host.
    """

    id: int
    slot: int = -1                      # arena row; -1 = none/reclaimed
    cache: Any = None                   # legacy/off-arena state (fedtrain)
    endpoint: Any = None                # server->client reply half (latest
    #                                     connection — updated on reconnect)
    stats: SessionStats = dataclasses.field(default_factory=SessionStats)
    closed: bool = False
    # slot-lifecycle state (docs/sharding.md): `pending` counts frames
    # enqueued but not yet processed — a session is only LRU-evictable at
    # pending == 0, so an in-flight frame can never lose its device row;
    # `last_active` is the serve-clock time of admission / last processed
    # frame (the LRU key); `host_state` holds the evicted KV row on host
    # (None while resident; the server's _EVICTING sentinel between the
    # eviction decision and the serve loop's fetch)
    pending: int = 0
    last_active: float = 0.0
    host_state: Any = None
    # stop-and-wait ARQ state: the highest seq processed and its cached
    # reply bytes, so a replayed frame is re-acked instead of re-processed
    # (re-processing would double-advance the KV cache / top optimizer)
    last_seq: int = -1
    last_reply: Any = None
    last_reply_header: int = 0          # framing bytes of last_reply
