"""Streaming multi-client serving runtime over the packed-payload wire.

Layering (bottom up): `core.wire` frames carry `core.payload.Payload`
bitstreams over `transport` byte channels; `client` runs the bottom model
and the encode half, `server` batches decodes into the device-resident
session-slot `arena` and runs one donated masked top step over it
(`batching` queue, `session` accounting, `steps` jit-able halves);
`engine.run_streaming` wires N clients to one server and reports measured
bytes per session. The hot-path design lives in docs/performance.md.

Production-traffic layer: `loadgen.run_loadgen` drives hundreds of
open-loop sessions over the same stack under a deterministic virtual
clock, `metrics` holds the streaming quantile estimators its SLO report
uses, and `qos.QoSController` adapts each session's (k, bits) under
congestion — see docs/serving-slo.md.
"""
from repro.runtime import steps
from repro.runtime.arena import SlotArena
from repro.runtime.batching import BatchingQueue, QueueFull
from repro.runtime.client import StreamingClient
from repro.runtime.engine import run_streaming
from repro.runtime.loadgen import (ArrivalSpec, FleetSpec, LoadGenConfig,
                                   ServiceModel, SLOSpec, run_loadgen)
from repro.runtime.metrics import LatencyStats, P2Quantile
from repro.runtime.qos import QoSController, QoSSpec
from repro.runtime.server import StreamingServer
from repro.runtime.session import Session, SessionStats
from repro.runtime.transport import Endpoint, channel_pair

__all__ = ["ArrivalSpec", "BatchingQueue", "Endpoint", "FleetSpec",
           "LatencyStats", "LoadGenConfig", "P2Quantile", "QoSController",
           "QoSSpec", "QueueFull", "SLOSpec", "ServiceModel", "Session",
           "SessionStats", "SlotArena", "StreamingClient", "StreamingServer",
           "channel_pair", "run_loadgen", "run_streaming", "steps"]
