"""Streaming multi-client serving runtime over the packed-payload wire.

Layering (bottom up): `core.wire` frames carry `core.payload.Payload`
bitstreams over `transport` byte channels; `client` runs the bottom model
and the encode half, `server` batches decodes into the device-resident
session-slot `arena` and runs one donated masked top step over it
(`batching` queue, `session` accounting, `steps` jit-able halves);
`engine.run_streaming` wires N clients to one server and reports measured
bytes per session. The hot-path design lives in docs/performance.md.
"""
from repro.runtime import steps
from repro.runtime.arena import SlotArena
from repro.runtime.batching import BatchingQueue
from repro.runtime.client import StreamingClient
from repro.runtime.engine import run_streaming
from repro.runtime.server import StreamingServer
from repro.runtime.session import Session, SessionStats
from repro.runtime.transport import Endpoint, channel_pair

__all__ = ["BatchingQueue", "SlotArena", "StreamingClient", "StreamingServer",
           "Session", "SessionStats", "Endpoint", "channel_pair",
           "run_streaming", "steps"]
