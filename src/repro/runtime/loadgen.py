"""Open-loop load generator + SLO harness over the real serving stack.

The bench clients in `engine.run_streaming` are a closed loop: N always-on
sessions, each sending its next request the instant the last reply lands.
Production traffic is open-loop — arrivals do not slow down because the
server is slow — which is exactly the regime where queueing delay diverges
and an SLO means something. This module simulates that regime at scale
against the *real* stack: every request is a real `core.wire` frame (CRC,
subheaders, byte accounting) crossing a real `transport` channel into the
real `StreamingServer` (arena slots, per-(meta, bucket) staging, fused
decode+step, ARQ dedup), with real jitted bottom/top model steps producing
real tokens. Only *time* is simulated.

Co-simulation design: one `testing.clock.VirtualClock` plus a single-
threaded event loop (a heap of (time, seq, fn)) replaces every thread in
the threaded engine:

  * reader threads  -> `server.pump` events, fired when a frame's
    transmission delay (client bandwidth cap) elapses;
  * the serve loop  -> flush events scheduled exactly at
    `BatchingQueue.next_flush_at`, serialized by a modeled service time
    (`ServiceModel`: per-flush overhead + per-row + per-wire-byte — the
    per-byte term is what makes shedding bytes relieve congestion, the
    empirical shape of the serving path measured in docs/performance.md);
  * client threads  -> per-session send/reply/retry events driving the
    same `ArqClientMixin` machinery (`_accept_reply`/`_retransmit`/
    `_reconnect`) the blocking client runs, so chaos from
    `testing.faults.FaultInjector` is recovered by the same code paths.

Everything — arrivals (Poisson or 2-state MMPP bursts), session shapes,
compressor fleet assignment, fault draws, retry timing — is a
deterministic function of the seed: two runs produce bit-identical arrival
traces, (k, bits) trajectories, and SLO reports (`tests/test_loadgen.py`
fuzzes this, clean and under chaos).

Closing the loop, each session may carry a `runtime.qos.QoSController`
that observes queue depth and token latency per reply and walks the
session's compressor down a (k, bits) ladder under congestion — the
adaptive fleet the bench gate (`benchmarks/loadgen.py`) pits against a
static one under a 2x overload burst. Config surface and report fields are
documented in docs/serving-slo.md.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import random
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import compressors, wire
from repro.kernels.encode import ops as _enc_ops
from repro.models import transformer
from repro.models.config import ArchConfig, Runtime
from repro.obs.export import write_trace
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import (EVT_ADMISSION_REJECT, NULL_TRACER, SERVE_TID,
                             SPAN_CLIENT_ENCODE, SPAN_WIRE_SEND, Tracer,
                             session_tid)
from repro.runtime import engine as _engine
from repro.runtime import steps
from repro.runtime.arq import ArqClientMixin
from repro.runtime.metrics import LatencyStats
from repro.runtime.qos import QoSController, QoSSpec
from repro.runtime.qos import compressor_spec as qos_compressor_spec
from repro.runtime.server import StreamingServer
from repro.runtime.session import SessionStats
from repro.runtime.transport import channel_pair
from repro.testing.clock import VirtualClock

_EPS = 1e-9

# trace track for the modeled service time (`ServiceModel.flush_s`): its
# spans cover [flush, server_free_at] and may abut the next flush exactly,
# so they get their own track rather than riding the serve loop's
_SERVICE_TID = 2_000_000


# -- config surface ----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Open-loop session arrival process.

    `poisson`: exponential inter-arrivals at `rate` sessions/s.
    `mmpp`: 2-state Markov-modulated Poisson — calm periods at `rate`
    alternate with bursts at `burst_rate` (default 2x), with exponential
    dwell times `mean_calm_s` / `mean_burst_s`. The seeded state path is
    part of the report, so a bench can gate on behavior *during* bursts.
    """

    process: str = "poisson"            # "poisson" | "mmpp"
    rate: float = 20.0                  # sessions/s (calm state)
    burst_rate: float = 0.0             # sessions/s in bursts (0 -> 2*rate)
    mean_calm_s: float = 4.0
    mean_burst_s: float = 2.0

    def __post_init__(self):
        assert self.process in ("poisson", "mmpp")
        assert self.rate > 0


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Heterogeneous client population: compressor mix, session shapes,
    think times, and the client-side uplink/downlink bandwidth cap."""

    compressors: Tuple[str, ...] = ("randtopk:k=16",)
    weights: Optional[Tuple[float, ...]] = None     # sampling weights
    prompt_len: Tuple[int, int] = (2, 4)            # inclusive range
    gen: Tuple[int, int] = (4, 8)                   # inclusive range
    think_s: float = 0.0        # mean exponential think time between steps
    bandwidth_Bps: float = 0.0  # per-client link bytes/s (0 = infinite)


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Virtual-time cost of one server flush: overhead + per-row compute +
    per-wire-byte host staging/decode. The per-byte term carries the
    operational claim under test — compressed frames are cheaper to serve,
    so tightening (k, bits) genuinely raises capacity (the measured serve
    path is host-byte-bound at smoke scale, docs/performance.md)."""

    flush_overhead_s: float = 1e-3
    per_row_s: float = 2e-4
    per_byte_s: float = 2e-5

    def flush_s(self, rows: int, wire_bytes: int) -> float:
        return (self.flush_overhead_s + self.per_row_s * rows
                + self.per_byte_s * wire_bytes)


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Declared service-level objectives the report is graded against."""

    p99_ms: float = 250.0               # token-latency p99 ceiling
    p50_ms: float = 0.0                 # optional p50 ceiling (0 = off)
    max_reject_frac: float = 0.0        # admission rejections / arrivals
    max_queue_depth: int = 0            # optional depth ceiling (0 = off)


@dataclasses.dataclass(frozen=True)
class LoadGenConfig:
    """One traffic scenario; everything downstream derives from `seed`."""

    seed: int = 0
    duration_s: float = 20.0            # arrivals stop here; drain continues
    arrivals: ArrivalSpec = ArrivalSpec()
    fleet: FleetSpec = FleetSpec()
    service: ServiceModel = ServiceModel()
    slo: SLOSpec = SLOSpec()
    qos: Optional[QoSSpec] = None       # None -> static fleet
    capacity: int = 32                  # arena slots = concurrent sessions
    max_batch: int = 8
    max_wait: float = 0.005
    admission_depth: int = 64           # reject arrivals above this backlog
    retry_timeout: Optional[float] = 0.5
    max_retries: int = 64
    max_sessions: int = 0               # hard cap on arrivals (0 = none)
    device_encode: bool = True          # device-packed wire frames (the
    #   `steps.make_bottom_step_device` path; frames are byte-identical to
    #   the host codec, so seeded reports do not depend on this flag)
    max_exact_latency_samples: int = 0  # >0: `LatencyStats` drops its
    #   exact-sample list once this many samples arrive and reports the
    #   streaming P² estimates only (runtime/metrics.py) — the opt-in for
    #   long runs where keeping every sample is unaffordable
    snapshot_every_s: float = 0.0       # >0: periodic registry snapshots
    #   every N virtual seconds, reported as `metrics_timeline`


# -- arrival process ---------------------------------------------------------

class _Arrivals:
    """Seeded arrival-time generator; `state_path` records MMPP flips."""

    def __init__(self, spec: ArrivalSpec, seed: int):
        self.spec = spec
        self._rng = random.Random(seed)
        self._burst = False
        self._switch_at = (self._rng.expovariate(1.0 / spec.mean_calm_s)
                           if spec.process == "mmpp" else float("inf"))
        self.state_path: List[Tuple[float, str]] = [(0.0, "calm")]

    def next_after(self, t: float) -> float:
        s = self.spec
        if s.process == "poisson":
            return t + self._rng.expovariate(s.rate)
        while True:
            rate = (s.burst_rate or 2 * s.rate) if self._burst else s.rate
            gap = self._rng.expovariate(rate)
            if t + gap < self._switch_at:
                return t + gap
            t = self._switch_at
            self._burst = not self._burst
            self.state_path.append((t, "burst" if self._burst else "calm"))
            mean = s.mean_burst_s if self._burst else s.mean_calm_s
            self._switch_at = t + self._rng.expovariate(1.0 / mean)


# -- per-session client state ------------------------------------------------

class _InFlight:
    """The one outstanding stop-and-wait request of a session."""

    __slots__ = ("step", "frame_bytes", "header_nbytes", "t_send",
                 "retries", "attempt")

    def __init__(self, step: int, frame_bytes: bytes, header_nbytes: int,
                 t_send: float):
        self.step = step
        self.frame_bytes = frame_bytes
        self.header_nbytes = header_nbytes
        self.t_send = t_send
        self.retries = 0        # replays spent (timeout- or error-triggered)
        self.attempt = 0        # bumped per (re)transmission: stale-timer guard


class _Conn:
    """One client<->server channel instance (reconnects make new ones)."""

    __slots__ = ("sep", "sid_seen", "retired")

    def __init__(self, sep):
        self.sep = sep          # server endpoint, pumped by the event loop
        self.sid_seen = None    # per-connection fault-attribution state
        self.retired = False


class _LoadSession(ArqClientMixin):
    """Event-driven feature owner: the `StreamingClient` request cycle with
    the blocking reply wait replaced by harness events. Reuses the ARQ
    mixin's reconnect/retransmit/reply-classification verbatim."""

    _reply_kind = wire.FRAME_TOKENS

    def __init__(self, sid: int, cache, prompt: np.ndarray, gen: int,
                 comp_spec: str, qos: Optional[QoSController],
                 think_rng: random.Random, think_s: float,
                 bandwidth_Bps: float, reconnect: Callable, clock):
        self.id = sid
        self.cache = cache
        self.prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.gen = gen
        self.comp_spec = comp_spec          # static fleet assignment
        self.qos = qos                      # adaptive override (may be None)
        self.think_rng = think_rng
        self.think_s = think_s
        self.bandwidth_Bps = bandwidth_Bps
        self.reconnect = reconnect          # () -> fresh client endpoint
        self.clock = clock
        self.endpoint = None                # set by the first reconnect()
        self.conn: Optional[_Conn] = None   # server half, set alongside
        self.stats = SessionStats()
        self.step = 0
        self.n_steps = len(self.prompt) + gen - 1
        self.inflight: Optional[_InFlight] = None
        self.finished = False
        self.failed: Optional[BaseException] = None
        self.slot_released = False
        self.generated: List[int] = []
        self.latencies: List[float] = []
        self.kb_trace: List[Tuple[int, int]] = []   # (k, bits) per step
        self.t_arrive = clock.monotonic()
        self.t_done = float("nan")

    # bound by the harness at admit (`bind_instruments`); None before that
    _m_frames_down = None
    _m_bytes_down = None

    def bind_instruments(self, registry) -> None:
        self._m_frames_down = registry.counter("frames_total",
                                               party="client",
                                               direction="down")
        self._m_bytes_down = registry.counter("wire_bytes_total",
                                              party="client",
                                              direction="down")

    def _count_reply(self, reply: wire.Frame) -> None:
        self.stats.count_down(reply.nbytes)
        if self._m_frames_down is not None:
            self._m_frames_down.inc()
            self._m_bytes_down.inc(reply.nbytes)

    def spec(self) -> str:
        return (self.qos.compressor_spec() if self.qos is not None
                else self.comp_spec)

    def tx_s(self, nbytes: int) -> float:
        """Link transmission delay under the client's bandwidth cap."""
        if self.bandwidth_Bps <= 0:
            return 0.0
        return nbytes / self.bandwidth_Bps

    def think(self) -> float:
        if self.think_s <= 0:
            return 0.0
        return self.think_rng.expovariate(1.0 / self.think_s)

    def next_token(self) -> np.ndarray:
        """The token the NEXT request carries (prompt prefill, then the
        last generated token) — same discipline as `StreamingClient`."""
        if self.step < len(self.prompt):
            return np.asarray([[self.prompt[self.step]]], np.int32)
        return np.asarray([[self.generated[-1]]], np.int32)


# -- the harness -------------------------------------------------------------

class _Harness:
    """Single-threaded virtual-time co-simulation of one traffic scenario."""

    def __init__(self, cfg: ArchConfig, lg: LoadGenConfig, params,
                 wrap_endpoint=None, trace: bool = False):
        self.cfg = cfg
        self.lg = lg
        self.wrap_endpoint = wrap_endpoint
        self.clock = VirtualClock()
        self.heap: List[Tuple[float, int, Callable]] = []
        self._seq = 0                   # heap tie-break: push order
        # per-run observability: a private registry (so two scenarios never
        # share counters) and, when tracing, a tracer on the VIRTUAL clock —
        # every stamp is simulated time, so the exported Chrome-trace JSON
        # is a deterministic function of the seed
        self.registry = MetricsRegistry()
        self.tracer = Tracer(clock=self.clock) if trace else NULL_TRACER

        rt = Runtime(mesh=None, training=False)
        rt_top = Runtime(mesh=None, training=False,
                         kv_cache_bits=cfg.kv_cache_bits or rt.kv_cache_bits)
        cut = (cfg.split.cut_layer if cfg.split and cfg.split.cut_layer > 0
               else max(1, cfg.n_layers // 2))
        self.rt, self.cut = rt, cut
        self.params = (transformer.init_model(jax.random.key(lg.seed), cfg)
                       if params is None else params)
        self.max_len = lg.fleet.prompt_len[1] + lg.fleet.gen[1]
        self._make_cache = lambda: transformer.init_cache(
            self.params, cfg, rt, 1, self.max_len)
        make_top_cache = lambda: transformer.init_cache(
            self.params, cfg, rt_top, 1, self.max_len)
        self.server = StreamingServer(
            self.params, None, make_top_cache, max_batch=lg.max_batch,
            max_wait=lg.max_wait, dtype=cfg.adtype(), capacity=lg.capacity,
            x_shape=(1, 1, cfg.d_model), clock=self.clock,
            jit_steps=_engine._serving_steps(cfg, rt_top, cut, cfg.dtype,
                                             None),
            tracer=self.tracer, registry=self.registry)
        self._bottom_cache: Dict[str, Tuple] = {}   # spec -> (comp, jit fn)

        # independent seeded streams so adding draws to one cannot shift
        # another (the reseed discipline of testing.faults)
        self.arrivals = _Arrivals(lg.arrivals, lg.seed * 7919 + 1)
        self._fleet_rng = random.Random(lg.seed * 7919 + 2)

        self.sessions: Dict[int, _LoadSession] = {}
        self.slots_in_use = 0
        self.server_free_at = 0.0
        self._flush_armed: Optional[float] = None
        self._next_sid = 0

        # metrics
        self.latency = LatencyStats(
            max_exact_samples=lg.max_exact_latency_samples or None)
        self.arrive_trace: List[float] = []
        self.rejects: List[Tuple[float, str]] = []
        self.depth_at_flush: List[int] = []
        self.completed = 0
        self.failed: List[int] = []
        self.t_end = 0.0
        self.metrics_timeline: List[dict] = []
        # pre-bound client-side instruments (the server pre-binds its own)
        reg = self.registry
        self._m_cl_frames_up = reg.counter("frames_total", party="client",
                                           direction="up")
        self._m_cl_payload_up = reg.counter("payload_bytes_total",
                                            party="client", direction="up")
        self._m_cl_framing_up = reg.counter("framing_bytes_total",
                                            party="client", direction="up")
        self._m_cl_tokens = reg.counter("tokens_total", party="client")
        self._m_cl_latency = reg.histogram("token_latency_ms")
        self._m_reject = {
            reason: reg.counter("admission_rejects_total", reason=reason)
            for reason in ("capacity", "queue")}

    # -- event loop machinery ------------------------------------------------

    def _push(self, t: float, fn: Callable) -> None:
        self._seq += 1
        heapq.heappush(self.heap, (t, self._seq, fn))

    def run(self) -> dict:
        self._warm()
        t0 = time.perf_counter()
        if self.lg.snapshot_every_s > 0:
            # bounded, pre-scheduled registry snapshots over the arrival
            # window (the end-of-run snapshot in the report covers drain)
            t = self.lg.snapshot_every_s
            while t <= self.lg.duration_s + _EPS:
                self._push(t, self._snapshot_event)
                t += self.lg.snapshot_every_s
        first = self.arrivals.next_after(0.0)
        if first <= self.lg.duration_s:
            self._push(first, self._arrival_event)
        while self.heap:
            t, _, fn = heapq.heappop(self.heap)
            self.clock.advance_to(t)
            self.t_end = max(self.t_end, self.clock.monotonic())
            fn()
        return self._report(time.perf_counter() - t0)

    def _snapshot_event(self) -> None:
        self.metrics_timeline.append(
            {"t": round(self.clock.monotonic(), 9),
             "metrics": self.registry.snapshot()})

    def _warm(self) -> None:
        """Compile every bottom/decode/step program the scenario can reach
        (fleet specs + the whole QoS ladder) before the virtual clock's
        first event — virtual time never contains compile time."""
        specs = list(self.lg.fleet.compressors)
        if self.lg.qos is not None:
            specs += [qos_compressor_spec(k, b)
                      for k, b in self.lg.qos.ladder()]
        tok0 = np.zeros((1, 1), np.int32)
        examples = []
        for spec in dict.fromkeys(specs):
            comp, fn = self._bottom(spec)
            out, _ = fn(self.params, self._make_cache(), tok0)
            payload = out[0] if self.lg.device_encode else out
            examples.append(jax.tree.map(np.asarray, payload))
        self.server.warm(examples)

    def _bottom(self, spec: str):
        """(compressor, jitted bottom step) for one spec string, cached —
        the ladder is bounded, so so is the jit cache."""
        hit = self._bottom_cache.get(spec)
        if hit is None:
            comp = compressors.make_compressor(spec)
            make = (steps.make_bottom_step_device if self.lg.device_encode
                    else steps.make_bottom_step)
            fn = jax.jit(make(self.cfg, self.rt, self.cut, comp))
            hit = self._bottom_cache[spec] = (comp, fn)
        return hit

    # -- arrivals & admission ------------------------------------------------

    def _arrival_event(self) -> None:
        now = self.clock.monotonic()
        self.arrive_trace.append(round(now, 9))
        lg = self.lg
        nxt = self.arrivals.next_after(now)
        capped = (lg.max_sessions
                  and len(self.arrive_trace) >= lg.max_sessions)
        if nxt <= lg.duration_s and not capped:
            self._push(nxt, self._arrival_event)
        # admission control: bounded concurrency (arena slots) and bounded
        # backlog — an open-loop overload otherwise grows the queue (and
        # every session's latency) without limit
        if self.slots_in_use >= lg.capacity:
            self._reject(now, "capacity")
            return
        if len(self.server.queue) >= lg.admission_depth:
            self._reject(now, "queue")
            return
        self._admit(now)

    def _reject(self, now: float, reason: str) -> None:
        self.rejects.append((round(now, 9), reason))
        self._m_reject[reason].inc()
        self.tracer.instant(EVT_ADMISSION_REJECT, tid=SERVE_TID,
                            reason=reason, slots=self.slots_in_use,
                            depth=len(self.server.queue))

    def _admit(self, now: float) -> None:
        lg, rng = self.lg, self._fleet_rng
        sid = self._next_sid
        self._next_sid += 1
        fleet = lg.fleet
        spec = rng.choices(list(fleet.compressors),
                           weights=fleet.weights)[0]
        plen = rng.randint(*fleet.prompt_len)
        gen = rng.randint(*fleet.gen)
        prompt = [rng.randrange(self.cfg.vocab) for _ in range(plen)]
        qos = (QoSController(lg.qos, tracer=self.tracer,
                             registry=self.registry, sid=sid)
               if lg.qos is not None else None)
        ls = _LoadSession(
            sid, self._make_cache(), np.asarray(prompt, np.int32), gen,
            spec, qos, random.Random(lg.seed * 7919 + 100 + sid),
            fleet.think_s, fleet.bandwidth_Bps,
            reconnect=lambda ls_sid=sid: self._connect(ls_sid), clock=self.clock)
        # route the session's ARQ mixin events (replays, reconnects,
        # duplicates, accept spans) into this run's tracer + registry
        ls.tracer = self.tracer
        ls.registry = self.registry
        ls.bind_instruments(self.registry)
        self.sessions[sid] = ls
        self.slots_in_use += 1
        ls.endpoint = self._connect(sid)
        self._push(now + ls.think(), lambda: self._send_event(ls))

    def _connect(self, sid: int):
        """Fresh channel onto session `sid` — initial and reconnect path.
        The server half becomes the session's pumped `_Conn`; the client
        half is optionally wrapped (fault injection), mirroring
        `engine.run_streaming._connect`."""
        cep, sep = channel_pair()
        ls = self.sessions[sid]
        old = ls.conn
        ls.conn = _Conn(sep)
        if old is not None and not old.retired:
            # the mixin's abandon notice is already in the old pipe; pump
            # it so the server retires that connection like a reader would
            self._push(self.clock.monotonic() + _EPS,
                       lambda: self._rx_event(ls, old))
        return (self.wrap_endpoint(sid, cep) if self.wrap_endpoint
                else cep)

    # -- client send / retry / reply ----------------------------------------

    def _send_event(self, ls: _LoadSession) -> None:
        if ls.finished:
            return
        now = self.clock.monotonic()
        comp, bottom = self._bottom(ls.spec())
        k, bits = getattr(comp, "k", self.cfg.d_model), getattr(comp, "bits",
                                                                0)
        ls.kb_trace.append((int(k), int(bits)))
        with self.tracer.span(SPAN_CLIENT_ENCODE, tid=session_tid(ls.id),
                              step=ls.step):
            # instantaneous in virtual time (compute is pre-warmed and
            # virtual-free): the span records ordering, not duration
            out, ls.cache = bottom(self.params, ls.cache, ls.next_token())
            if self.lg.device_encode:
                payload, sections = out
                body = _enc_ops.sections_to_bytes(
                    payload.meta, payload.batch_shape, sections)
                frame_bytes = wire.encode_payload_frame_from_bytes(
                    ls.id, ls.step, payload.meta, payload.batch_shape, body)
            else:
                payload = jax.tree.map(np.asarray, out)
                frame_bytes = wire.encode_payload_frame(ls.id, ls.step,
                                                        payload)
        hb = wire.payload_frame_header_nbytes(payload)
        ls.stats.count_up(header_nbytes=hb,
                          payload_nbytes=len(frame_bytes) - hb)
        self._m_cl_frames_up.inc()
        self._m_cl_payload_up.inc(len(frame_bytes) - hb)
        self._m_cl_framing_up.inc(hb)
        ls.endpoint.send(frame_bytes)
        ls.inflight = _InFlight(ls.step, frame_bytes, hb, t_send=now)
        conn = ls.conn
        tx = ls.tx_s(len(frame_bytes))
        if self.tracer.enabled:
            # the modeled uplink occupancy under the client's bandwidth cap
            self.tracer.complete(SPAN_WIRE_SEND, now, now + tx,
                                 tid=session_tid(ls.id), step=ls.step,
                                 nbytes=len(frame_bytes))
        self._push(now + tx, lambda: self._rx_event(ls, conn))
        self._arm_retry(ls)

    def _arm_retry(self, ls: _LoadSession) -> None:
        if self.lg.retry_timeout is None or ls.inflight is None:
            return
        inf = ls.inflight
        step, attempt = inf.step, inf.attempt
        self._push(self.clock.monotonic() + self.lg.retry_timeout,
                   lambda: self._retry_event(ls, step, attempt))

    def _retry_event(self, ls: _LoadSession, step: int, attempt: int) -> None:
        inf = ls.inflight
        if (ls.finished or inf is None or inf.step != step
                or inf.attempt != attempt):
            return                      # stale timer: the step moved on
        if self._drain_replies(ls):
            return                      # the reply was already in the pipe
        inf = ls.inflight
        if inf is None or inf.attempt != attempt:
            return                      # drain reconnected + replayed
        # genuine timeout — mirror `_await_reply`: spend a retry, maybe
        # reconnect to escape a stalled reader, retransmit
        inf.retries += 1
        if inf.retries > self.lg.max_retries:
            self._fail(ls, TimeoutError(
                f"session {ls.id}: no reply to frame {step} after "
                f"{inf.retries - 1} retransmissions"))
            return
        ls.stats.replays += 1
        if inf.retries % 8 == 0:
            ls._reconnect()             # fresh FrameReaders on both ends
        self._replay(ls)

    def _replay(self, ls: _LoadSession) -> None:
        inf = ls.inflight
        inf.attempt += 1
        ls._retransmit(inf.frame_bytes, inf.header_nbytes)
        conn = ls.conn
        self._push(self.clock.monotonic() + ls.tx_s(len(inf.frame_bytes)),
                   lambda: self._rx_event(ls, conn))
        self._arm_retry(ls)

    def _drain_replies(self, ls: _LoadSession) -> bool:
        """Drain the session's downlink; True iff the in-flight step
        completed. Runs the same classification/recovery the blocking
        `_await_reply` loop does, minus the waiting."""
        while ls.inflight is not None:
            step = ls.inflight.step
            try:
                reply = ls.endpoint.recv_frame(timeout=0.0)
            except wire.WireError:
                ls.stats.faults_detected += 1
                inf = ls.inflight
                inf.retries += 1
                if inf.retries > self.lg.max_retries:
                    self._fail(ls, TimeoutError(
                        f"session {ls.id}: retries exhausted recovering a "
                        f"corrupt downlink"))
                    return False
                ls.stats.replays += 1
                ls._reconnect()
                self._replay(ls)
                return False
            if reply is None:
                return False
            if reply.kind == wire.FRAME_ERROR:
                # peer rejected a frame and retired the connection
                ls.stats.count_down(reply.nbytes)
                inf = ls.inflight
                inf.retries += 1
                if inf.retries > self.lg.max_retries:
                    self._fail(ls, TimeoutError(
                        f"session {ls.id}: retries exhausted after peer "
                        f"rejections"))
                    return False
                ls.stats.replays += 1
                ls._reconnect()
                self._replay(ls)
                return False
            got = ls._accept_reply(reply, step)
            if got is not None:
                self._complete_step(ls, got)
                return True
        return False

    def _reply_event(self, ls: _LoadSession, depth_seen: int) -> None:
        """The reply's transmission delay elapsed: drain and, on step
        completion, feed the QoS controller its congestion view."""
        if ls.finished or ls.inflight is None:
            return
        before = ls.step
        if self._drain_replies(ls) and ls.qos is not None:
            ls.qos.observe(depth_seen, ls.latencies[before])

    def _complete_step(self, ls: _LoadSession, reply: wire.Frame) -> None:
        now = self.clock.monotonic()
        ls.latencies.append(now - ls.inflight.t_send)
        self.latency.add(ls.latencies[-1])
        self._m_cl_latency.observe(ls.latencies[-1] * 1e3)
        ls.inflight = None
        nxt = int(reply.tokens[0])
        if ls.step + 1 >= len(ls.prompt):
            ls.generated.append(nxt)
            ls.stats.tokens_out += 1
            self._m_cl_tokens.inc()
        ls.step += 1
        if ls.step < ls.n_steps:
            self._push(now + ls.think(), lambda: self._send_event(ls))
        else:
            self._finish(ls)

    def _finish(self, ls: _LoadSession) -> None:
        ls.finished = True
        ls.t_done = self.clock.monotonic()
        self.completed += 1
        ls.endpoint.send(wire.encode_close_frame(ls.id))
        conn = ls.conn
        close_nbytes = len(wire.encode_close_frame(ls.id))
        self._push(self.clock.monotonic() + ls.tx_s(close_nbytes),
                   lambda: self._rx_event(ls, conn, expect_close=True))

    def _fail(self, ls: _LoadSession, exc: BaseException) -> None:
        ls.finished = True
        ls.failed = exc
        ls.t_done = self.clock.monotonic()
        self.failed.append(ls.id)
        self._release_slot(ls, force=True)

    # -- server side ---------------------------------------------------------

    def _rx_event(self, ls: _LoadSession, conn: _Conn,
                  expect_close: bool = False) -> None:
        """A frame's uplink transmission finished: pump the connection (the
        reader-thread moment) and re-arm the flush timer."""
        if not conn.retired:
            status, conn.sid_seen = self.server.pump(conn.sep, conn.sid_seen)
            if status != "open":
                conn.retired = True
            if status == "closed":
                self._release_slot(ls)
        if expect_close and not ls.slot_released:
            # the CLOSE frame was lost to chaos (dropped/held/corrupted):
            # force the server-side close — the deterministic counterpart
            # of the threaded engine's shutdown() backstop
            sess = self.server.sessions.get(ls.id)
            if sess is not None:
                sess.closed = True
            self._release_slot(ls)
        self._arm_flush()

    def _release_slot(self, ls: _LoadSession, force: bool = False) -> None:
        if ls.slot_released:
            return
        ls.slot_released = True
        self.slots_in_use -= 1
        if force:
            sess = self.server.sessions.get(ls.id)
            if sess is not None:
                sess.closed = True

    def _arm_flush(self) -> None:
        due = self.server.queue.next_flush_at()
        if due is None:
            return
        due = max(due, self.server_free_at)
        if self._flush_armed is not None and self._flush_armed <= due + _EPS:
            return                      # an event at/before `due` is armed
        self._flush_armed = due
        self._push(due, self._flush_event)

    def _flush_event(self) -> None:
        self._flush_armed = None
        due = self.server.queue.next_flush_at()
        if due is None:
            return
        due = max(due, self.server_free_at)
        now = self.clock.monotonic()
        if due > now + _EPS:
            self._arm_flush()           # not actually due yet: re-arm
            return
        self._do_flush(now)
        self._arm_flush()               # backlog may already be flushable

    def _do_flush(self, now: float) -> None:
        q = self.server.queue
        depth = len(q)
        self.depth_at_flush.append(depth)
        batch = q.get_batch(idle_timeout=0.0)
        if not batch:
            return
        wire_bytes = sum(f.header_nbytes + f.payload_nbytes
                         for _, f in batch)
        self.server._process(batch)
        self.server_free_at = now + self.lg.service.flush_s(
            len(batch), wire_bytes)
        if self.tracer.enabled:
            # the ServiceModel's virtual occupancy of the server — the
            # span whose back-to-back packing is visible congestion
            self.tracer.name_track(_SERVICE_TID, "service model")
            self.tracer.complete("service.flush", now, self.server_free_at,
                                 cat="service", tid=_SERVICE_TID,
                                 rows=len(batch), wire_bytes=wire_bytes)
        for sess, frame in batch:
            ls = self.sessions.get(sess.id)
            if ls is None or ls.finished:
                continue
            reply_nbytes = (len(sess.last_reply)
                            if sess.last_reply is not None else 0)
            self._push(self.server_free_at + ls.tx_s(reply_nbytes),
                       functools.partial(self._reply_event, ls, depth))

    # -- report --------------------------------------------------------------

    def _report(self, wall_s_real: float) -> dict:
        lg = self.lg
        arrived = len(self.arrive_trace)
        admitted = len(self.sessions)
        reject_frac = len(self.rejects) / max(arrived, 1)
        tokens_out = sum(ls.stats.tokens_out for ls in self.sessions.values())
        makespan = max(self.t_end, _EPS)
        depth = np.asarray(self.depth_at_flush or [0])
        lat = self.latency.report()
        level_hist: Dict[int, int] = {}
        switches = 0
        for ls in self.sessions.values():
            if ls.qos is not None:
                switches += ls.qos.switches
                for kb in ls.kb_trace:
                    idx = ls.qos.levels.index(kb)
                    level_hist[idx] = level_hist.get(idx, 0) + 1
        slo = evaluate_slo(lg.slo, lat, reject_frac, int(depth.max()))
        report = {
            "seed": lg.seed,
            "virtual_duration_s": round(makespan, 6),
            "wall_s_real": wall_s_real,    # excluded from determinism checks
            "arrivals": {
                "process": lg.arrivals.process,
                "rate": lg.arrivals.rate,
                "burst_rate": (lg.arrivals.burst_rate
                               or 2 * lg.arrivals.rate),
                "state_path": [(round(t, 9), s)
                               for t, s in self.arrivals.state_path],
            },
            "sessions": {"arrived": arrived, "admitted": admitted,
                         "rejected": len(self.rejects),
                         "completed": self.completed,
                         "failed": len(self.failed)},
            "reject_frac": round(reject_frac, 6),
            "tokens_out": tokens_out,
            "goodput_tok_per_s": round(tokens_out / makespan, 4),
            "latency_ms": {k: (v if isinstance(v, bool) else round(v, 4))
                           for k, v in lat.items()},
            "queue_depth": {"max": int(depth.max()),
                            "mean": round(float(depth.mean()), 4)},
            "flushes": len(self.server.batch_sizes),
            "mean_batch_fill": round(float(np.mean(
                self.server.batch_sizes or [0])), 4),
            "bytes_up_per_token": round(
                sum(ls.stats.payload_bytes_up
                    for ls in self.sessions.values())
                / max(tokens_out, 1), 3),
            "qos": {"enabled": lg.qos is not None,
                    "ladder": (list(map(list, lg.qos.ladder()))
                               if lg.qos else []),
                    "level_hist": {str(k): v for k, v
                                   in sorted(level_hist.items())},
                    "switches": switches},
            "fault_counters": _engine.fault_summary(
                self.server, list(self.sessions.values())),
            "metrics": self.registry.snapshot(),
            "metrics_timeline": self.metrics_timeline,
            "trace_events": len(self.tracer) if self.tracer.enabled else 0,
            "slo": slo,
            "cv_waits": self.clock.waits,   # 0 == no real sleeps ever
            "trace": {
                "arrivals": list(self.arrive_trace),
                "rejects": [list(r) for r in self.rejects],
                "k_bits": {str(sid): [list(kb) for kb in ls.kb_trace]
                           for sid, ls in sorted(self.sessions.items())},
            },
        }
        return report


def evaluate_slo(slo: SLOSpec, latency_ms: dict, reject_frac: float,
                 max_depth: int) -> dict:
    """Grade one run's aggregates against the declared SLOs."""
    checks = {"p99": bool(latency_ms["p99_ms"] <= slo.p99_ms
                          or latency_ms["n"] == 0),
              "rejects": bool(reject_frac <= slo.max_reject_frac)}
    if slo.p50_ms:
        checks["p50"] = bool(latency_ms["p50_ms"] <= slo.p50_ms)
    if slo.max_queue_depth:
        checks["queue_depth"] = bool(max_depth <= slo.max_queue_depth)
    return {"targets": dataclasses.asdict(slo),
            "checks": checks, "ok": all(checks.values())}


def run_loadgen(cfg: ArchConfig, lg: LoadGenConfig, *, params=None,
                wrap_endpoint=None, trace_path=None) -> dict:
    """Run one traffic scenario; returns the deterministic SLO report
    (`wall_s_real` is the only nondeterministic field). `wrap_endpoint` is
    the same fault-injection hook `engine.run_streaming` takes.

    `trace_path` (optional) enables lifecycle tracing on the virtual clock
    and writes the run's Chrome-trace JSON there — byte-identical across
    same-seed runs (docs/observability.md)."""
    harness = _Harness(cfg, lg, params, wrap_endpoint,
                       trace=trace_path is not None)
    report = harness.run()
    errs = [(sid, harness.sessions[sid].failed) for sid in harness.failed]
    report["failures"] = [[sid, str(e)] for sid, e in errs]
    if trace_path is not None:
        write_trace(harness.tracer, trace_path)
    return report
