"""Congestion-adaptive (k, bits) QoS control for the serving wire.

The serving-side sibling of `fedtrain.schedule.KScheduler`: where the
training scheduler tightens compression as the *loss* plateaus, this
controller tightens it as the *server* congests — the paper's accuracy-
per-byte argument applied dynamically. Randomized top-k keeps the best
fidelity at any byte budget, so when queue depth or deadline slack says
bytes are scarce, the right move is to shed bytes by walking down a
(k, bits) ladder within declared floors, not to reject sessions or blow
the latency SLO (after Oh et al. 2023, adaptive feature-wise compression,
PAPERS.md).

Mechanics (per session, observed once per token reply):

  * the ladder is built once from `QoSSpec`: (k, bits) at the top, k
    halving toward `k_floor`, then a final rung at `bits_floor` when value
    quantization has room to shrink. A bounded ladder keeps the client's
    per-compressor jit cache small — the same reason `KScheduler` caps
    its anneal at 8 stages;
  * tighten one rung immediately when congestion is *acute*: observed
    queue depth at/above `high_depth`, or reply latency past
    `deadline_s`. Both signals are things a real client can see (depth is
    piggybacked here by the harness; latency it measures itself);
  * tighten also when pressure is *chronic*: an `EmaPlateau` (the exact
    state machine `KScheduler` uses, `fedtrain.schedule`) watches the
    smoothed queue depth and fires when it stops improving while sitting
    above `low_depth` — catching sustained saturation that never crosses
    the acute thresholds;
  * relax one rung only after `patience` consecutive healthy
    observations (depth at/below `low_depth` AND latency under half the
    deadline) — tighten-fast/relax-slow hysteresis so one calm flush in
    the middle of a burst cannot bounce the fleet back up the ladder;
  * `cooldown` observations must pass between any two moves, bounding
    the rung-change (and therefore client recompile) rate.

State (`state()`/`load_state()`) round-trips through `checkpoint.store`
npz files exactly like the training scheduler's, so a serving session can
resume mid-burst at its adapted rung.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.fedtrain.schedule import EmaPlateau
from repro.obs.registry import DEFAULT_REGISTRY
from repro.obs.trace import EVT_QOS_TRANSITION, NULL_TRACER, session_tid


@dataclasses.dataclass(frozen=True)
class QoSSpec:
    """Declared QoS envelope: the compression a session starts at, the
    floors it may be tightened to, and the congestion thresholds."""

    k: int                      # top-of-ladder support (the fleet's spec)
    d: int                      # cut width (frames are self-describing,
    #                             but the ladder must respect k <= d)
    bits: int = 0               # top-of-ladder value-quantization (0 = f32)
    k_floor: int = 4            # never tighten support below this
    bits_floor: int = 0         # extra rung at this bit width (0 = none)
    high_depth: int = 16        # acute congestion: queue depth at/above
    low_depth: int = 2          # healthy: queue depth at/below
    deadline_s: float = 0.25    # acute congestion: token latency beyond
    patience: int = 8           # healthy observations before relaxing
    cooldown: int = 2           # min observations between rung moves
    ema: float = 0.7            # chronic-pressure EMA smoothing
    min_rel_improve: float = 0.05
    sustain: int = 12           # chronic-pressure plateau patience

    def __post_init__(self):
        assert 0 < self.k_floor <= self.k <= self.d
        assert self.bits_floor == 0 or 0 < self.bits_floor <= self.bits
        assert 0 <= self.low_depth < self.high_depth
        assert self.deadline_s > 0 and self.cooldown >= 0

    def ladder(self) -> List[Tuple[int, int]]:
        """(k, bits) rungs, least to most compressed. Bounded: O(log2
        k/k_floor) + 1, so the per-spec jitted bottom steps stay few."""
        rungs = [(self.k, self.bits)]
        k = self.k
        while k > self.k_floor:
            k = max(self.k_floor, k // 2)
            rungs.append((k, self.bits))
        if self.bits_floor and self.bits_floor < self.bits:
            rungs.append((self.k_floor, self.bits_floor))
        return rungs


def compressor_spec(k: int, bits: int) -> str:
    """`core.compressors.make_compressor` spec string for one rung."""
    if bits:
        return f"randtopk_quant:k={k},bits={bits}"
    return f"randtopk:k={k}"


class QoSController:
    """Per-session (k, bits) ladder position, driven by congestion.

    Every rung move emits a `qos.transition` instant on the session's
    trace track (when a tracer is attached) and bumps
    `qos_transitions_total{direction=tighten|relax}` in the registry, so a
    run trace *explains* each move: the instant's args carry the rung
    endpoints, the depth/latency observation that forced it, and whether
    the trigger was acute or chronic.
    """

    def __init__(self, spec: QoSSpec, *, tracer=NULL_TRACER,
                 registry=DEFAULT_REGISTRY, sid: Optional[int] = None):
        self.spec = spec
        self.levels = spec.ladder()
        self.level = 0              # index into `levels` (0 = declared top)
        self.healthy = 0            # consecutive healthy observations
        self.cool = 0               # observations since the last move
        self.switches = 0           # total rung moves (bench/report)
        self._pressure = EmaPlateau(spec.ema, spec.min_rel_improve,
                                    spec.sustain)
        self.tracer = tracer
        self.registry = registry
        self.sid = sid              # trace track / labels (None = unbound)

    def _record_move(self, frm: int, direction: str, *, queue_depth: int,
                     latency_s: float, reason: str) -> None:
        self.registry.counter("qos_transitions_total",
                              direction=direction).inc()
        if self.tracer.enabled:
            k, bits = self.levels[self.level]
            self.tracer.instant(
                EVT_QOS_TRANSITION,
                tid=session_tid(self.sid) if self.sid is not None else None,
                sid=self.sid, frm=frm, to=self.level, k=k, bits=bits,
                direction=direction, reason=reason,
                queue_depth=queue_depth, latency_ms=latency_s * 1e3)

    def k_bits(self) -> Tuple[int, int]:
        return self.levels[self.level]

    def compressor_spec(self) -> str:
        return compressor_spec(*self.k_bits())

    def observe(self, queue_depth: int, latency_s: float) -> None:
        """Feed back one token reply's view of the server: the queue depth
        its flush saw and the request->token round-trip it measured."""
        s = self.spec
        self.cool += 1
        acute = queue_depth >= s.high_depth or latency_s > s.deadline_s
        # chronic: the smoothed depth has stopped improving above low_depth
        chronic = (self._pressure.observe(float(queue_depth))
                   and self._pressure.value > s.low_depth)
        if acute or chronic:
            self.healthy = 0
            if self.cool >= s.cooldown and self.level + 1 < len(self.levels):
                self.level += 1
                self.switches += 1
                self.cool = 0
                self._record_move(self.level - 1, "tighten",
                                  queue_depth=queue_depth,
                                  latency_s=latency_s,
                                  reason="acute" if acute else "chronic")
            return
        if queue_depth <= s.low_depth and latency_s <= s.deadline_s / 2:
            self.healthy += 1
            if (self.healthy >= s.patience and self.cool >= s.cooldown
                    and self.level > 0):
                self.level -= 1
                self.switches += 1
                self.healthy = 0
                self.cool = 0
                self._record_move(self.level + 1, "relax",
                                  queue_depth=queue_depth,
                                  latency_s=latency_s, reason="healthy")
        else:
            self.healthy = 0

    # -- checkpoint state ----------------------------------------------------

    def state(self) -> dict:
        """Numpy-scalar dict, `checkpoint.store.save`-compatible (the same
        convention as `KScheduler.state`)."""
        return {"level": np.int32(self.level),
                "healthy": np.int32(self.healthy),
                "cool": np.int32(self.cool),
                "switches": np.int32(self.switches),
                **{f"pressure_{k}": v
                   for k, v in self._pressure.state().items()}}

    def load_state(self, st: dict) -> None:
        self.level = min(int(st["level"]), len(self.levels) - 1)
        self.healthy = int(st["healthy"])
        self.cool = int(st["cool"])
        self.switches = int(st["switches"])
        self._pressure.load_state(
            {k[len("pressure_"):]: v for k, v in st.items()
             if k.startswith("pressure_")})
