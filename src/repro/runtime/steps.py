"""jit-able client/server decode steps for the streaming runtime.

The split model's decode caches are stacked per layer (axis 0) and the cut
partitions every cache entry into a bottom prefix and a top suffix along
that axis (the same invariant `split.model.decode_step`'s merge relies on),
so each party updates only its own slice of a full-shaped cache:

  * client (feature owner): embed -> layers [0, cut) -> `Compressor.encode`;
    writes the prefix slice.
  * server (label owner): dense cut view -> layers [cut, L) -> lm head ->
    greedy token; writes the suffix slice. The server step is vmapped over a
    leading session axis so one compiled step serves a whole batch of
    sessions, each row with its own cache and position.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import compressors
from repro.models import transformer
from repro.models.config import ArchConfig, Runtime


def _merge_range(cache, partial, *, prefix: bool):
    """Write a contiguous layer-range partial cache back into the full one.

    `partial` covers the first (prefix=True) or last (prefix=False) entries
    of each cache key along the stacked layer axis; untouched keys (e.g.
    frozen cross-attention KV) pass through. Advances `pos`.
    """
    new = dict(cache)
    for key, val in partial.items():
        def m(o, p):
            if prefix:
                return jnp.concatenate([p, o[p.shape[0]:]], axis=0)
            return jnp.concatenate([o[: o.shape[0] - p.shape[0]], p], axis=0)
        new[key] = jax.tree.map(m, cache[key], val)
    new["pos"] = cache["pos"] + 1
    return new


def make_bottom_step(cfg: ArchConfig, rt: Runtime, cut: int,
                     comp: compressors.Compressor) -> Callable:
    """(params, cache, token (1,1) i32) -> (Payload, new cache). jit-able;
    encode is deterministic (inference-mode compression, RandTopk -> TopK)."""

    def bottom_step(params, cache, token):
        x = transformer.embed(params, cfg, rt, token)
        x, partial = transformer.decode_layers(params, cfg, rt, x, cache,
                                               0, cut)
        payload = comp.encode(x, training=False)
        return payload, _merge_range(cache, partial, prefix=True)

    return bottom_step


def make_bottom_step_device(cfg: ArchConfig, rt: Runtime, cut: int,
                            comp: compressors.Compressor) -> Callable:
    """Device-encode variant of `make_bottom_step`: the wire bitstream is
    packed on device inside the same jit program
    (`split.protocol.client_encode_device`), so the client's only host
    crossing per step is the final packed buffer(s).

    (params, cache, token (1,1) i32) -> ((Payload, sections), new cache).
    The Payload keeps device leaves (shape/meta for the frame subheader);
    `sections` are the packed u32 wire buffers the host truncates with
    `kernels.encode.ops.sections_to_bytes` and frames with
    `wire.encode_payload_frame_from_bytes` — byte-identical to the host
    codec on `make_bottom_step`'s payload.
    """
    from repro.split import protocol

    def bottom_step(params, cache, token):
        x = transformer.embed(params, cfg, rt, token)
        x, partial = transformer.decode_layers(params, cfg, rt, x, cache,
                                               0, cut)
        payload, sections = protocol.client_encode_device(comp, x,
                                                          training=False)
        return (payload, sections), _merge_range(cache, partial, prefix=True)

    return bottom_step


def make_top_step(cfg: ArchConfig, rt: Runtime, cut: int) -> Callable:
    """Vmapped server step: (params, x (S,1,1,d), caches stacked over S) ->
    (tokens (S,1) i32, new caches). One compile serves every batch; padded
    rows (batch fill) are computed and discarded.

    This is the pre-arena flush-shaped step, kept as the reference
    implementation the arena parity tests pin against (`make_arena_top_step`
    is the serving hot path)."""

    def one_session(params, x, cache):
        x, partial = transformer.decode_layers(params, cfg, rt, x, cache,
                                               cut, cfg.n_layers)
        logits = transformer.lm_head(params, cfg, rt, x)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return tok, _merge_range(cache, partial, prefix=False)

    return jax.vmap(one_session, in_axes=(None, 0, 0))


def make_arena_top_step(cfg: ArchConfig, rt: Runtime, cut: int,
                        mesh=None) -> Callable:
    """Whole-arena server step with an active-slot mask.

    (params, xbuf (C+1, 1, 1, d), cache arena stacked over C, active (C,)
    bool) -> (tokens (C, 1) i32, new arena). Row i of the arena is session
    slot i; `xbuf`'s trailing scratch row (the decode-group pad target) is
    sliced off before the step. Inactive slots compute and discard — their
    new cache leaves are `where(active, new, old)`, so position/KV never
    advance for a slot that received no frame this flush, and the output
    arena aliases the donated input in place under
    `jax.jit(..., donate_argnums=(2,))` (see `runtime.server`).

    Per-row numerics are identical to `make_top_step` (same vmapped body),
    so arena-served tokens are bit-identical to the flush-stacked path.

    With `mesh` (a `jax.sharding.Mesh`), the step runs under `shard_map`
    with arena rows sharded over every mesh axis and the lm head
    vocab-parallel over 'model' — served tokens stay bit-identical to the
    mesh-less path at any mesh shape (docs/sharding.md gives the
    exactness argument). `mesh=None` is exactly the pre-mesh single-device
    program.
    """

    def one_session(params, x, cache, active):
        x, partial = transformer.decode_layers(params, cfg, rt, x, cache,
                                               cut, cfg.n_layers)
        logits = transformer.lm_head(params, cfg, rt, x)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        new = _merge_range(cache, partial, prefix=False)
        new = jax.tree.map(lambda n, o: jnp.where(active, n, o), new, cache)
        return tok, new

    vstep = jax.vmap(one_session, in_axes=(None, 0, 0, 0))

    if mesh is None:
        def arena_step(params, xbuf, cache, active):
            return vstep(params, xbuf[: active.shape[0]], cache, active)

        return arena_step
    return _make_sharded_arena_step(cfg, rt, cut, mesh)


def _make_sharded_arena_step(cfg: ArchConfig, rt: Runtime, cut: int,
                             mesh) -> Callable:
    """The `shard_map` variant of the arena step (docs/sharding.md).

    Decomposition, chosen so every piece preserves bit-exact tokens:

      * arena rows (slots) shard over ALL mesh axes flattened in mesh
        order — 'pod' x 'data' x 'model' — so session capacity scales
        with every device. Row sharding is batch decomposition: each
        device runs the same per-row program `make_arena_top_step` vmaps,
        no contraction is split, numerics are untouched.
      * the lm head is tensor-parallel over 'model': each rank first
        all-gathers its row block along 'model' (`tp.gather_seq_local`'s
        collective, norm applied BEFORE the gather in Megatron-SP order),
        then multiplies by its vocab shard of `unembed` — an output-dim
        split, NOT a contraction split, so each logit column is
        bit-identical to the replicated matmul — and the greedy token
        comes out of `tp.vocab_parallel_argmax` (exact first-occurrence
        argmax from two scalar-per-row collectives).
      * with a 'pod' axis, the cut activation crosses the pod ring
        (`protocol.pod_ring_perm`) before the top half runs and the token
        rows return on the inverse ring — the serving-side instance of
        the `split.protocol` ppermute cut boundary. Host-side, `xbuf` and
        token rows for slot s live at `SlotArena.wire_row(s)` (the
        ingestion pod's block); cache rows stay slot-aligned.

    The reduce-scatter output projection (`tp.out_proj_rs`) stays OFF this
    path by design: it splits the ff contraction, which reorders f32
    summation and breaks the bit-exact serving contract (see
    docs/sharding.md); it serves the training/prefill pipeline.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.models import common, tp

    axes = tuple(mesh.axis_names)
    sizes = dict(mesh.shape)
    n_model = sizes.get("model", 1)
    n_pod = sizes.get("pod", 1)
    n_rows_shards = 1
    for a in axes:
        n_rows_shards *= sizes[a]
    if cfg.padded_vocab % max(n_model, 1):
        raise ValueError(
            f"padded vocab {cfg.padded_vocab} not divisible by model axis "
            f"{n_model}")

    def one_session_hidden(params, x, cache, active):
        """Per-row top-layer pass, token head split out (it needs the
        cross-rank collectives). Cache update identical to `one_session`."""
        x, partial = transformer.decode_layers(params, cfg, rt, x, cache,
                                               cut, cfg.n_layers)
        new = _merge_range(cache, partial, prefix=False)
        new = jax.tree.map(lambda n, o: jnp.where(active, n, o), new, cache)
        return x, new

    vhidden = jax.vmap(one_session_hidden, in_axes=(None, 0, 0, 0))

    def body(params, x, cache, active):
        if n_pod > 1:
            # cut-boundary crossing: the ingestion pod hands its row block
            # to the pod holding those slots' top-model state
            from repro.split import protocol
            x = jax.lax.ppermute(x, "pod", protocol.pod_ring_perm(n_pod))
        h, new_cache = vhidden(params, x, cache, active)
        h = common.apply_norm(h, params["final_norm"], cfg.norm)
        if n_model > 1:
            # reassemble the (pod, data) row block from the model ranks —
            # the Megatron-SP gather (norm first, gather in activation
            # dtype), rows standing in for the sequence axis
            h = tp.gather_seq_local(h.reshape(1, h.shape[0], -1)
                                    ).reshape(-1, *h.shape[1:])
        logits = h @ params["unembed"].astype(h.dtype)   # local vocab shard
        tok = tp.vocab_parallel_argmax(logits[:, :, -1, :], "model")
        if n_pod > 1:
            from repro.split import protocol
            tok = jax.lax.ppermute(
                tok, "pod", protocol.pod_ring_perm(n_pod, inverse=True))
        return tok, new_cache

    rows = axes if len(axes) > 1 else axes[0]

    def row_spec(a):
        return P(rows, *([None] * (a.ndim - 1)))

    # tokens replicate over 'model' (every rank holds its gathered row
    # block's tokens) and shard over the remaining row axes
    tok_axes = tuple(a for a in axes if a != "model")
    tok_spec = P(tok_axes if len(tok_axes) != 1 else tok_axes[0], None) \
        if tok_axes else P(None, None)

    def arena_step(params, xbuf, cache, active):
        if active.shape[0] % n_rows_shards:
            raise ValueError(
                f"arena capacity {active.shape[0]} not divisible by the "
                f"{n_rows_shards}-way row sharding (SlotArena pads for "
                f"this)")
        pspec = jax.tree.map(lambda _: P(), params)
        pspec["unembed"] = P(None, "model")
        cspec = jax.tree.map(row_spec, cache)
        x = xbuf[: active.shape[0]]
        return shard_map(
            body, mesh=mesh,
            in_specs=(pspec, row_spec(x), cspec, row_spec(active)),
            out_specs=(tok_spec, cspec),
            check_vma=False)(params, x, cache, active)

    return arena_step


def make_fused_decode_step(top_step: Callable, *, dtype,
                           backend=None) -> Callable:
    """Fuse the decode->step seam into ONE dispatch.

    (params, xbuf, payload, slots, cache, active) -> (tokens, xbuf, cache):
    scatter-decode the stacked flush payload into `xbuf[slots]`
    (`split.protocol.decode_to_slots_in_jit` — the same trace-time body as
    the standalone slot decode, Pallas or XLA per `backend`), then run the
    arena `top_step` on the updated buffer, all inside one jit program. The
    serving loop's single-meta flushes (every pure-compressor mix) pay one
    dispatch per flush instead of decode + step; jit caches one program per
    (payload meta, flush-rows bucket).

    `xbuf` (arg 1) and `cache` (arg 4) must be DONATED by the jitting
    caller (`runtime.server`): both alias in place on TPU, and the rebound
    outputs carry the arena forward exactly as the two-call path did.
    Numerics are unchanged — decode and step keep their per-row dataflow;
    tokens stay bit-identical to the separate decode + step dispatches
    (pinned for every payload kind by tests/test_arena.py).
    """
    from repro.split import protocol

    dtype_name = jnp.dtype(dtype).name

    def fused_step(params, xbuf, payload, slots, cache, active):
        xbuf = protocol.decode_to_slots_in_jit(
            xbuf, payload, slots, dtype=dtype_name, backend=backend)
        tokens, cache = top_step(params, xbuf, cache, active)
        return tokens, xbuf, cache

    return fused_step
