"""Serving metrics — streaming quantiles and latency aggregation.

An SLO harness watching millions of token round trips cannot keep every
sample to sort at the end; `P2Quantile` is the classic P² algorithm (Jain &
Chlamtac, CACM 1985): five markers track (min, q/2, q, (1+q)/2, max)
rank positions and are nudged by parabolic (fallback linear) interpolation
as each observation arrives — O(1) memory and time per sample, no buckets
to pre-size. `LatencyStats` runs both the exact (sorted-at-the-end) and the
streaming estimators side by side, so the harness reports exact percentiles
while the bench proves the streaming estimate tracks them within tolerance
(`tests/test_loadgen.py` pins the parity on adversarial distributions).
When sample counts make the exact list unaffordable, construct with
`keep_samples=False` (streaming-only from the start) or set
`max_exact_samples=N` to demote automatically once N samples have been
seen — the loadgen harness opts into the latter above its configured
threshold. In streaming-only mode `report()` fills the `pXX_ms` keys from
the P² markers, so consumers (`evaluate_slo`, the bench emitters) read the
same schema either way.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


class P2Quantile:
    """Streaming estimate of the `q`-quantile via the P² algorithm.

    Exact (interpolated, numpy `linear` method) below 5 observations;
    afterwards the five-marker invariant holds h[0] <= .. <= h[4] with
    h[0]/h[4] the running min/max, so the estimate is always inside the
    observed range.
    """

    def __init__(self, q: float):
        assert 0.0 < q < 1.0
        self.q = q
        self.count = 0
        self._h: List[float] = []           # marker heights
        self._n = [0.0, 1.0, 2.0, 3.0, 4.0]     # marker positions (0-based)
        self._want = [0.0, 2 * q, 4 * q, 2 + 2 * q, 4.0]  # desired positions
        self._dwant = [0.0, q / 2, q, (1 + q) / 2, 1.0]   # per-sample drift

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        if self.count <= 5:
            self._h.append(x)
            if self.count == 5:
                self._h.sort()
            return
        h, n = self._h, self._n
        # locate the cell k with h[k] <= x < h[k+1], extending the extremes
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = max(h[4], x)
            k = 3
        else:
            k = max(i for i in range(4) if h[i] <= x)
        for i in range(k + 1, 5):
            n[i] += 1
        for i in range(5):
            self._want[i] += self._dwant[i]
        # nudge the three interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._want[i] - n[i]
            if ((d >= 1 and n[i + 1] - n[i] > 1)
                    or (d <= -1 and n[i - 1] - n[i] < -1)):
                d = 1.0 if d > 0 else -1.0
                cand = self._parabolic(i, d)
                if not (h[i - 1] < cand < h[i + 1]):
                    cand = self._linear(i, d)
                h[i] = cand
                n[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, n = self._h, self._n
        return h[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        h, n = self._h, self._n
        j = i + int(d)
        return h[i] + d * (h[j] - h[i]) / (n[j] - n[i])

    def value(self) -> float:
        if self.count == 0:
            return float("nan")
        if self.count < 5:
            return float(np.quantile(np.asarray(self._h, float), self.q))
        return self._h[2]


class LatencyStats:
    """Exact + streaming latency percentiles over one traffic run.

    `keep_samples=False` drops the unbounded exact-sample list up front;
    `max_exact_samples=N` keeps exact reporting until N samples have
    arrived, then discards the list and continues streaming-only. Count,
    mean, and max stay exact in every mode (O(1) accumulators).
    """

    QS = (0.50, 0.95, 0.99)

    def __init__(self, keep_samples: bool = True,
                 max_exact_samples: Optional[int] = None):
        self.samples: List[float] = []
        self.keep_samples = bool(keep_samples)
        self.max_exact_samples = max_exact_samples
        self._n = 0
        self._sum = 0.0
        self._max = float("-inf")
        self._p2: Dict[float, P2Quantile] = {q: P2Quantile(q)
                                             for q in self.QS}

    def add(self, seconds: float) -> None:
        seconds = float(seconds)
        self._n += 1
        self._sum += seconds
        if seconds > self._max:
            self._max = seconds
        if self.keep_samples:
            self.samples.append(seconds)
            if (self.max_exact_samples is not None
                    and self._n >= self.max_exact_samples):
                # past the affordability threshold: go streaming-only
                self.keep_samples = False
                self.samples = []
        for est in self._p2.values():
            est.add(seconds)

    def __len__(self) -> int:
        return self._n

    @property
    def streaming_only(self) -> bool:
        """True once the exact-sample list has been dropped."""
        return not self.keep_samples

    def exact(self, q: float) -> float:
        """Exact quantile; falls back to the P² estimate once the sample
        list has been dropped (streaming-only mode)."""
        if self.streaming_only:
            return self.streaming(q)
        if not self.samples:
            return float("nan")
        return float(np.quantile(np.asarray(self.samples), q))

    def streaming(self, q: float) -> float:
        return self._p2[q].value()

    def report(self) -> dict:
        """Percentiles in milliseconds: `pXX_ms` (exact, or the P² value
        in streaming-only mode) next to the always-streaming `p2_pXX_ms`."""
        out = {"n": self._n,
               "mean_ms": (self._sum / self._n * 1e3
                           if self._n else float("nan")),
               "max_ms": self._max * 1e3 if self._n else float("nan"),
               "streaming_only": self.streaming_only}
        for q in self.QS:
            tag = f"p{int(round(q * 100)):02d}"
            out[f"{tag}_ms"] = self.exact(q) * 1e3
            out[f"p2_{tag}_ms"] = self.streaming(q) * 1e3
        return out


def merged_percentiles(groups: Sequence[Sequence[float]]) -> dict:
    """Exact pooled percentiles across per-session latency lists.

    Both branches return the same `pXX_ms`-style keys as
    `LatencyStats.report()`; an all-empty input yields NaN values, not a
    differently-keyed dict.
    """
    tags = {q: f"p{int(round(q * 100)):02d}_ms" for q in LatencyStats.QS}
    pooled = np.concatenate([np.asarray(g, float) for g in groups if len(g)]
                            or [np.asarray([], float)])
    if pooled.size == 0:
        return {tags[q]: float("nan") for q in LatencyStats.QS}
    return {tags[q]: float(np.quantile(pooled, q)) * 1e3
            for q in LatencyStats.QS}
