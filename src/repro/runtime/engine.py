"""Orchestration: build a server + N streaming clients and run the sessions.

This is the simulation harness `launch/serve.py`, `benchmarks/
serve_throughput.py`, and `examples/streaming_clients.py` drive: everything
crosses real framed byte channels, compression is applied per client (a
mixed compressor population is supported), and the result carries both
parties' byte accounting so callers can cross-check measured wire sizes
against the Table-2 analytics.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressors
from repro.models import transformer
from repro.models.config import ArchConfig, Runtime
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.runtime import steps
from repro.runtime.client import StreamingClient
from repro.runtime.server import StreamingServer, jit_serving_steps
from repro.runtime.transport import channel_pair
from repro.split import protocol


#: cross-run cache of jitted serving step pairs — an explicit dict, not an
#: `functools.lru_cache`: the cached jit wrappers pin compiled executables
#: AND their device buffers (per-device under a sharded arena), and an
#: unbounded-lifetime decorator cache gave no way to release them short of
#: killing the process. `clear_serving_steps()` is the shutdown hook.
_STEP_CACHE: dict = {}


def _serving_steps(cfg: ArchConfig, rt: Runtime, cut: int, dtype_name: str,
                   backend: Optional[str], mesh=None):
    """Cross-run cache of the server's jitted step pair.

    jit compile caches live on the wrapped callable, so handing every
    `run_streaming` call the same pair (keyed by the hashable frozen
    configs + mesh) means a benchmark sweep compiles each (meta, bucket)
    program once per process instead of once per run — the repeated-run
    gate used to re-pay the whole warm loop every repetition. Arena shapes
    (capacity) may differ between runs; the jit object retraces per shape
    and keeps both programs."""
    key = (cfg, rt, cut, dtype_name, backend, mesh)
    pair = _STEP_CACHE.get(key)
    if pair is None:
        top = steps.make_arena_top_step(cfg, rt, cut, mesh=mesh)
        pair = _STEP_CACHE[key] = jit_serving_steps(
            top, dtype=jnp.dtype(dtype_name), backend=backend)
    return pair


def clear_serving_steps() -> int:
    """Engine shutdown: drop every cached serving-step pair and the
    compiled executables + device buffers they pin (`jit.clear_cache()`).
    Returns the number of entries released. Long-lived hosts (benchmark
    sweeps over many meshes, embedding servers) call this between
    configurations; within one configuration, keeping the cache warm is
    the whole point of `_serving_steps`."""
    n = len(_STEP_CACHE)
    for top, fused in _STEP_CACHE.values():
        top.clear_cache()
        fused.clear_cache()
    _STEP_CACHE.clear()
    return n


def _client_compressors(cfg: ArchConfig, n_clients: int,
                        mix: Optional[Sequence] = None) -> List:
    """Per-client compressor objects: an explicit mix (spec strings or
    Compressor objects, assigned round-robin) or the config's compressor."""
    if mix is None:
        base = (protocol.make_cut_compressor(cfg.split) if cfg.split
                else compressors.Compressor())
        return [base] * n_clients
    objs = [compressors.make_compressor(m) if isinstance(m, str) else m
            for m in mix]
    return [objs[i % len(objs)] for i in range(n_clients)]


def run_streaming(cfg: ArchConfig, *, n_clients: int = 8, prompt_len: int = 4,
                  gen: int = 8, max_batch: Optional[int] = None,
                  max_wait: float = 0.01, compressor_mix=None, seed: int = 0,
                  params=None, wrap_endpoint=None,
                  retry_timeout: Optional[float] = None,
                  max_retries: int = 16, tracer=None, mesh=None,
                  capacity: Optional[int] = None,
                  release_steps: bool = False,
                  device_encode: bool = True) -> dict:
    """Serve `n_clients` concurrent sessions of `prompt_len + gen` tokens.

    Returns a dict with the generated tokens `(n_clients, gen)`, per-session
    client/server stats dicts, the per-client compressor names, the server's
    batch-fill history, wall-clock throughput, the aggregated
    `fault_counters` (all zero on a clean wire), and a `metrics` snapshot
    of the run's private `MetricsRegistry` (docs/observability.md).

    `wrap_endpoint(cid, endpoint) -> endpoint` intercepts every client-side
    connection — initial and reconnect — which is how
    `repro.testing.faults.FaultInjector` runs the whole stack under seeded
    chaos. `retry_timeout` enables stop-and-wait retransmission (needed for
    drop faults); None keeps the clean-wire single-wait behavior.

    `tracer` (an `obs.trace.Tracer`, default off) records the frame
    lifecycle of every session; `launch/serve.py --trace` exports it as
    Perfetto-loadable Chrome-trace JSON.

    `mesh` (a `jax.sharding.Mesh`) shards the server's arena and runs the
    top step under `shard_map` (docs/sharding.md); tokens are bit-identical
    to `mesh=None` at any shape. `capacity` caps concurrently-RESIDENT
    sessions (default: `n_clients`, so eviction never triggers); setting it
    below `n_clients` exercises the LRU evict-to-host / re-admission path.
    `release_steps` drops the cross-run step cache on exit
    (`clear_serving_steps`) — for sweeps that never revisit a
    configuration.

    `device_encode` (default on) gives every client the
    `steps.make_bottom_step_device` bottom step: the wire bitstream is
    packed on device and the host's per-step encode work is pull +
    truncate + CRC. Frames are byte-identical either way; the result's
    `client_encode_s` / `client_encode_steps` aggregate the per-client
    host pack time (the bench's `encode` µs/token stage), so
    `device_encode=False` is the host-pack baseline the serve bench gates
    against.
    """
    rt = Runtime(mesh=None, training=False)
    # the label owner may serve from a quantized KV arena (int8 codes +
    # f32 scale rows, `ArchConfig.kv_cache_bits`); feature owners always
    # keep their bottom-model caches at the Runtime default (f32)
    rt_top = Runtime(mesh=None, training=False,
                     kv_cache_bits=cfg.kv_cache_bits or rt.kv_cache_bits)
    cut = (cfg.split.cut_layer if cfg.split and cfg.split.cut_layer > 0
           else max(1, cfg.n_layers // 2))
    assert 0 < cut < cfg.n_layers
    if params is None:
        params = transformer.init_model(jax.random.key(seed), cfg)
    max_batch = max_batch or min(8, n_clients)
    max_len = prompt_len + gen
    comps = _client_compressors(cfg, n_clients, compressor_mix)

    # one jitted bottom step per distinct compressor (frozen -> hashable)
    make_bottom = (steps.make_bottom_step_device if device_encode
                   else steps.make_bottom_step)
    bottom_steps = {c: jax.jit(make_bottom(cfg, rt, cut, c))
                    for c in dict.fromkeys(comps)}
    make_cache = lambda: transformer.init_cache(params, cfg, rt, 1, max_len)
    make_top_cache = lambda: transformer.init_cache(params, cfg, rt_top, 1,
                                                    max_len)
    # every session owns a device-resident arena slot for its whole life,
    # so capacity = the expected concurrent session count; the jitted step
    # pair is shared across runs (see _serving_steps)
    tracer = tracer if tracer is not None else NULL_TRACER
    registry = MetricsRegistry()        # per-run, isolated
    server = StreamingServer(params, None, make_top_cache,
                             max_batch=max_batch,
                             max_wait=max_wait, dtype=cfg.adtype(),
                             capacity=capacity or n_clients,
                             x_shape=(1, 1, cfg.d_model),
                             jit_steps=_serving_steps(
                                 cfg, rt_top, cut, cfg.dtype, None, mesh),
                             mesh=mesh,
                             tracer=tracer, registry=registry)
    server.expected_sessions = n_clients

    prompts = np.asarray(jax.random.randint(
        jax.random.key(seed + 1), (n_clients, prompt_len), 0, cfg.vocab))

    def _connect(cid: int):
        """One client connection: fresh channel pair, server reader attached,
        client half optionally wrapped (fault injection). Also the reconnect
        path — a resuming client calls this for a clean channel onto its
        surviving server-side session."""
        cep, sep = channel_pair()
        server.attach(sep)
        return wrap_endpoint(cid, cep) if wrap_endpoint else cep

    clients: List[StreamingClient] = []
    for cid in range(n_clients):
        clients.append(StreamingClient(
            cid, params, make_cache(), bottom_steps[comps[cid]],
            _connect(cid), prompts[cid], gen,
            retry_timeout=retry_timeout, max_retries=max_retries,
            reconnect=lambda cid=cid: _connect(cid),
            tracer=tracer, registry=registry, device_encode=device_encode))

    # warm every hot-loop jit BEFORE spawning threads (one compile, not a
    # storm — and the serving clock never pays compile time): bottom steps,
    # then the server's per-meta slot decodes + the donated arena step
    tok0 = np.zeros((1, 1), np.int32)
    dummy = {c: step(params, make_cache(), tok0)
             for c, step in bottom_steps.items()}
    examples = [out[0] if device_encode else out for out, _ in dummy.values()]
    server.warm([jax.tree.map(np.asarray, p) for p in examples])

    t0 = time.perf_counter()
    serve_thread = threading.Thread(target=server.serve_loop, daemon=True)
    serve_thread.start()
    threads = [threading.Thread(target=c.run, daemon=True) for c in clients]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    # guaranteed stop even if a CLOSE frame was lost to injected faults
    server.shutdown()
    serve_thread.join(timeout=60)
    wall = time.perf_counter() - t0

    if server.errors:
        raise RuntimeError(
            f"server reader threads failed: {server.errors}") \
            from server.errors[0]
    errs = [(c.id, c.error) for c in clients if c.error is not None]
    if errs:
        raise RuntimeError(f"client sessions failed: {errs}") from errs[0][1]

    tokens = np.asarray([c.generated for c in clients], np.int32)
    if release_steps:
        clear_serving_steps()
    result = {
        "tokens": tokens,
        "client_stats": [c.stats.as_dict() for c in clients],
        "server_stats": [server.sessions[c.id].stats.as_dict()
                         for c in clients],
        "compressors": [c.name for c in comps],
        "compressor_objs": comps,
        "batch_sizes": server.batch_sizes,
        "fault_counters": fault_summary(server, clients),
        "metrics": registry.snapshot(),
        # serve-loop wall seconds by stage (host staging [+ mixed-meta
        # decode dispatch] / fused-or-plain step incl. token readback /
        # reply framing+send), the token count those flushes served (for
        # per-token stage costs), host staging-vs-wire byte totals, and
        # per-client request->token round-trip latencies
        "stage_s": dict(server.stage_s),
        "stage_tokens": server.stage_tokens,
        "host_bytes": dict(server.host_bytes),
        "flushes": len(server.batch_sizes),
        "client_latencies": [list(c.latencies) for c in clients],
        # host-side frame-pack CPU seconds summed over clients (+ the
        # frame count) — the client `encode` stage of
        # gate_stage_us_per_token (thread CPU time: see runtime.client)
        "client_encode_s": sum(c.encode_s for c in clients),
        "client_encode_steps": sum(c.encode_steps for c in clients),
        "device_encode": device_encode,
        "wall_s": wall,
        "tokens_per_s": tokens.size / max(wall, 1e-9),
        "n_clients": n_clients,
        "max_batch": max_batch,
        "cut_layer": cut,
    }
    return result


def fault_summary(server, clients) -> dict:
    """Aggregate recovery counters across both parties — reported by
    `run_streaming`/`run_fedtrain` alongside the byte accounting. All zero
    on a clean wire; under injected chaos, the measured recovery record."""
    out = {"server_faults_detected": server.faults_detected,
           "client_faults_detected": 0, "duplicates": 0, "replays": 0,
           "reconnects": 0}
    for c in clients:
        out["client_faults_detected"] += c.stats.faults_detected
        out["replays"] += c.stats.replays
        out["reconnects"] += c.stats.reconnects
        out["duplicates"] += c.stats.duplicates
    for sess in server.sessions.values():
        out["duplicates"] += sess.stats.duplicates
    return out
