"""Device-resident session-slot arena — the serving runtime's hot state.

Every admitted session owns one *slot*: a fixed row of pre-allocated batched
KV-cache/position arrays (`cache`, every leaf stacked over a leading
capacity axis) and of the cut-activation staging buffer (`xbuf`). The slot
is assigned at admission and never moves while the session is resident, so
the serve loop's per-flush work is: scatter-decode the flush's payloads into
`xbuf[slots]` on device, run ONE jitted top step over the whole arena with
an active-slot mask, read the token rows back. Nothing per-session is
stacked, unstacked, or pulled to host — the O(sessions x cache bytes) of
per-flush `jnp.stack`/`a[i]` memcpy the pre-arena server paid per token is
gone, and with buffer donation the step updates the arena in place.

With a device `mesh`, the arena rows shard over every mesh axis (slot ->
shard mapping and the full layout story in docs/sharding.md): capacity is
padded up to a multiple of the device count so each shard holds the same
row count, `cache` leaves carry a `NamedSharding` over the flattened mesh
axes, and `xbuf` is allocated replicated (it is the small per-flush staging
buffer; the KV arena is the HBM term that must scale). `mesh=None` is
bit-identical to the pre-mesh single-device arena.

Aliasing/donation invariants (also in docs/performance.md):

  * `cache` and `xbuf` handles are CONSUMED by the donated jits
    (`steps.make_arena_top_step`, `protocol.server_decode_to_slots`); the
    owner must always rebind the returned arrays and never keep a stale
    reference across a flush.
  * `xbuf` has `capacity + 1` rows: row `capacity` is the scratch row that
    group padding scatters into (a cached zero row, NEVER an alias of a
    live session's data), keeping one compile per payload meta regardless
    of flush fill.
  * inactive slots pass through the top step unchanged (the mask selects
    the old leaf), so stale `xbuf` rows from earlier flushes are never
    observable.

Slot lifecycle is owned by the server (admission, closed-slot reclaim, LRU
eviction of idle sessions to host, re-admission restore — see
`runtime.server`); every arena mutation (`reset_slot`, `restore_slot`,
`fetch_slot`) must only run from the thread that owns the donated step, so
row writes are serialized with the step, never raced against it from a
reader thread. The arena itself holds only the device state.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp

# donation is a no-op on the CPU backend (jax warns once per compile);
# the arena is designed for TPU where it aliases in place
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_slot(cache, row, slot):
    """Write one batch-1 cache pytree into one arena row (donated). Serves
    both the fresh-template reset and the eviction-restore write — same
    program, different `row` operand."""
    return jax.tree.map(lambda a, t: a.at[slot].set(t), cache, row)


class SlotArena:
    """Pre-allocated per-session serving state, resident on device.

    `make_cache() -> batch-1 cache pytree` defines one slot's state;
    `x_shape`/`x_dtype` the per-slot cut-activation row. Slot id assignment
    lives with the owning server (it is session bookkeeping); the arena
    holds the device arrays and the row-write primitives, which must only
    run from the thread that owns the donated step (see module docstring).

    `capacity` is the padded row count (requested capacity rounded up to a
    multiple of the mesh device count); the server admits at most
    `requested_capacity` sessions and the pad rows stay permanently
    inactive under the step's mask.
    """

    def __init__(self, make_cache, capacity: int, x_shape, x_dtype,
                 mesh=None):
        assert capacity >= 1
        self.mesh = mesh
        n_dev = int(mesh.devices.size) if mesh is not None else 1
        self._n_pod = (dict(mesh.shape).get("pod", 1)
                       if mesh is not None else 1)
        self.requested_capacity = capacity
        self.capacity = -(-capacity // n_dev) * n_dev
        self._template = make_cache()
        stacked = jax.tree.map(lambda a: jnp.stack([a] * self.capacity),
                               self._template)
        # +1: the scratch row that padded decode groups scatter into
        xbuf = jnp.zeros((self.capacity + 1,) + tuple(x_shape), x_dtype)
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            axes = tuple(mesh.axis_names)
            rows = axes if len(axes) > 1 else axes[0]
            self.cache = jax.tree.map(
                lambda a: jax.device_put(
                    a, NamedSharding(mesh,
                                     P(rows, *([None] * (a.ndim - 1))))),
                stacked)
            # replicated: its +1 scratch row defeats row sharding, and the
            # step's shard_map reshards the `capacity` live rows anyway
            self.xbuf = jax.device_put(xbuf, NamedSharding(mesh, P()))
        else:
            self.cache = stacked
            self.xbuf = xbuf

    def wire_row(self, slot: int) -> int:
        """The `xbuf`/token row for a slot: identity without a pod axis;
        with one, the slot's ingestion-pod block — the sharded step's
        ppermute pair carries the activation row to the slot's (ring-next)
        label pod and the token row back (docs/sharding.md)."""
        if self._n_pod <= 1 or slot >= self.capacity:
            return slot
        block = self.capacity // self._n_pod
        pod, off = divmod(slot, block)
        return ((pod - 1) % self._n_pod) * block + off

    def reset_slot(self, slot: int) -> None:
        """Restore one row to the fresh-session template (slot reuse after
        a session closed). Must only run from the thread that owns the
        donated step — it consumes and rebinds `cache`."""
        self.cache = _write_slot(self.cache, self._template,
                                 jnp.asarray(slot, jnp.int32))

    def fetch_slot(self, slot: int) -> Any:
        """Host copy of one slot's cache row — the eviction path (the
        session's device state moves to `Session.host_state`). Same
        serialization rule as `reset_slot`: serve-loop thread only, the
        read must not race a donated step consuming `cache`."""
        return jax.tree.map(lambda a: jax.device_get(a[slot]), self.cache)

    def restore_slot(self, slot: int, state: Any) -> None:
        """Write an evicted session's host state back into a (possibly
        different) arena row — the re-admission path. Shares `_write_slot`
        with `reset_slot`, so no extra program compiles."""
        row = jax.tree.map(jnp.asarray, state)
        self.cache = _write_slot(self.cache, row,
                                 jnp.asarray(slot, jnp.int32))

    def slot_cache(self, slot: int) -> Any:
        """Host copy of one slot's cache row (tests/debug only — the serve
        path never unstacks a slot)."""
        return jax.tree.map(lambda a: jax.device_get(a[slot]), self.cache)
