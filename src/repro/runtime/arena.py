"""Device-resident session-slot arena — the serving runtime's hot state.

Every admitted session owns one *slot*: a fixed row of pre-allocated batched
KV-cache/position arrays (`cache`, every leaf stacked over a leading
capacity axis) and of the cut-activation staging buffer (`xbuf`). The slot
is assigned at admission and never moves, so the serve loop's per-flush work
is: scatter-decode the flush's payloads into `xbuf[slots]` on device, run
ONE jitted top step over the whole arena with an active-slot mask, read the
token rows back. Nothing per-session is stacked, unstacked, or pulled to
host — the O(sessions x cache bytes) of per-flush `jnp.stack`/`a[i]` memcpy
the pre-arena server paid per token is gone, and with buffer donation the
step updates the arena in place.

Aliasing/donation invariants (also in docs/performance.md):

  * `cache` and `xbuf` handles are CONSUMED by the donated jits
    (`steps.make_arena_top_step`, `protocol.server_decode_to_slots`); the
    owner must always rebind the returned arrays and never keep a stale
    reference across a flush.
  * `xbuf` has `capacity + 1` rows: row `capacity` is the scratch row that
    group padding scatters into (a cached zero row, NEVER an alias of a
    live session's data), keeping one compile per payload meta regardless
    of flush fill.
  * inactive slots pass through the top step unchanged (the mask selects
    the old leaf), so stale `xbuf` rows from earlier flushes are never
    observable.

Slot lifecycle is owned by the server (admission assigns the next free
slot id; when none is free the slot of a *closed* session is reclaimed and
a `reset_slot` — cache rows back to the fresh-session template — is queued
for the serve loop to apply before the next flush touches the arena), so
resets are serialized with the donated step, never raced against it from a
reader thread. The arena itself holds only the device state.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp

# donation is a no-op on the CPU backend (jax warns once per compile);
# the arena is designed for TPU where it aliases in place
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset_slot(cache, template, slot):
    """Write the fresh-session template back into one arena row (donated)."""
    return jax.tree.map(lambda a, t: a.at[slot].set(t), cache, template)


class SlotArena:
    """Pre-allocated per-session serving state, resident on device.

    `make_cache() -> batch-1 cache pytree` defines one slot's state;
    `x_shape`/`x_dtype` the per-slot cut-activation row. Slot id assignment
    lives with the owning server (it is session bookkeeping); the arena
    holds the device arrays and the reset primitive, and `reset_slot` must
    only run from the thread that owns the donated step (see module
    docstring).
    """

    def __init__(self, make_cache, capacity: int, x_shape, x_dtype):
        assert capacity >= 1
        self.capacity = capacity
        self._template = make_cache()
        self.cache = jax.tree.map(lambda a: jnp.stack([a] * capacity),
                                  self._template)
        # +1: the scratch row that padded decode groups scatter into
        self.xbuf = jnp.zeros((capacity + 1,) + tuple(x_shape), x_dtype)

    def reset_slot(self, slot: int) -> None:
        """Restore one row to the fresh-session template (slot reuse after
        a session closed). Must only run from the thread that owns the
        donated step — it consumes and rebinds `cache`."""
        self.cache = _reset_slot(self.cache, self._template,
                                 jnp.asarray(slot, jnp.int32))

    def slot_cache(self, slot: int) -> Any:
        """Host copy of one slot's cache row (tests/debug only — the serve
        path never unstacks a slot)."""
        return jax.tree.map(lambda a: jax.device_get(a[slot]), self.cache)
