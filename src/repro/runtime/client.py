"""Streaming client — the feature owner of one serving session.

Runs the bottom model against its own KV cache, compresses each cut
activation and pulls the payload to host (the `split.protocol.client_encode`
half, fused into the jitted bottom step), frames it as `core.wire` bytes,
and blocks on the server's token reply before advancing — the classic
split-inference loop, one round trip per token. Prompt tokens are prefilled
through the same path (the server's top model must see them to build its
KV), with the replies discarded until the prompt is exhausted.

With `device_encode=True` the bottom step is the
`steps.make_bottom_step_device` variant: the wire bitstream is packed on
device (`kernels.encode`), and the host work per step shrinks to pulling
the packed u32 sections, truncating them to exact byte length, and
wrapping subheader + CRC (`wire.encode_payload_frame_from_bytes`). Either
way the per-step host pack time is accumulated in `encode_s` (the bench's
client `encode` µs/token stage) and covered by the `client.encode` trace
span, which now encloses frame assembly as well as the model step.

Recovery is the stop-and-wait ARQ loop of `runtime.arq.ArqClientMixin`:
requests carry the step as their sequence number, token replies echo it,
and the client retransmits on timeout, drops stale duplicates, and
reconnects + replays through the engine-provided `reconnect` callable when
a connection dies. With a clean wire and `retry_timeout=None` the path is
byte-identical to the pre-ARQ loop.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import wire
from repro.kernels.encode import ops as enc_ops
from repro.obs.trace import (NULL_TRACER, SPAN_CLIENT_ENCODE, SPAN_WIRE_SEND,
                             session_tid)
from repro.runtime.arq import ArqClientMixin
from repro.runtime.session import SessionStats
from repro.testing.clock import Clock, SYSTEM_CLOCK


class StreamingClient(ArqClientMixin):
    """One simulated feature owner driving a session to completion."""

    _reply_kind = wire.FRAME_TOKENS

    def __init__(self, session_id: int, params, cache, bottom_step,
                 endpoint, prompt: np.ndarray, gen: int,
                 reply_timeout: float = 60.0,
                 retry_timeout: Optional[float] = None,
                 max_retries: int = 16,
                 reconnect: Optional[Callable] = None,
                 clock: Clock = SYSTEM_CLOCK,
                 tracer=NULL_TRACER, registry=None,
                 device_encode: bool = False):
        self.id = session_id
        self.clock = clock
        self.tracer = tracer
        if registry is not None:        # else: the mixin's process default
            self.registry = registry
        self.params = params
        self.cache = cache
        self.bottom_step = bottom_step          # jitted shared per compressor
        self.endpoint = endpoint
        self.prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.gen = gen
        self.reply_timeout = reply_timeout
        self.retry_timeout = retry_timeout      # None -> never retransmit
        self.max_retries = max_retries
        self.reconnect = reconnect              # () -> fresh endpoint
        self.device_encode = device_encode      # bottom step packs the wire
        self.encode_s = 0.0   # host pack CPU seconds (thread_time), summed
        self.encode_steps = 0       # frames packed (encode_s's denominator)
        self.stats = SessionStats()
        self.generated: list = []
        self.latencies: list = []       # per-step send->reply seconds
        self.error: Optional[BaseException] = None
        # pre-bound hot-path instruments (one registry lookup per metric,
        # not per token)
        reg = self.registry
        self._m_frames_up = reg.counter("frames_total", party="client",
                                        direction="up")
        self._m_payload_up = reg.counter("payload_bytes_total",
                                         party="client", direction="up")
        self._m_framing_up = reg.counter("framing_bytes_total",
                                         party="client", direction="up")
        self._m_tokens = reg.counter("tokens_total", party="client")
        self._m_latency = reg.histogram("token_latency_ms")
        self._m_frames_down = reg.counter("frames_total", party="client",
                                          direction="down")
        self._m_bytes_down = reg.counter("wire_bytes_total", party="client",
                                         direction="down")

    def _count_reply(self, reply: wire.Frame) -> None:
        self.stats.count_down(reply.nbytes)
        self._m_frames_down.inc()
        self._m_bytes_down.inc(reply.nbytes)

    def run(self) -> None:
        """Thread target; on any failure records the exception and closes."""
        try:
            self._run()
        except BaseException as e:              # surfaced by the engine
            self.error = e
        finally:
            self.endpoint.send(wire.encode_close_frame(self.id))

    def _run(self) -> None:
        token = np.asarray([[self.prompt[0]]], np.int32)
        n_steps = len(self.prompt) + self.gen - 1
        tid = session_tid(self.id)
        trace = self.tracer.enabled
        if trace:
            self.tracer.name_track(tid, f"session {self.id}")
        for step in range(n_steps):
            with self.tracer.span(SPAN_CLIENT_ENCODE, tid=tid, step=step):
                out, self.cache = self.bottom_step(self.params,
                                                   self.cache, token)
                # sync the device step first so `encode_s` isolates the
                # HOST pack work — the stage the device wire path shrinks.
                # Thread CPU time, not wall: under N client threads the
                # GIL adds ~100us of scheduler wait to any wall-clocked
                # region, swamping the pack cost being measured.
                out = jax.block_until_ready(out)
                t_pack = time.thread_time()
                if self.device_encode:
                    payload, sections = out
                    body = enc_ops.sections_to_bytes(
                        payload.meta, payload.batch_shape, sections)
                    frame_bytes = wire.encode_payload_frame_from_bytes(
                        self.id, step, payload.meta, payload.batch_shape,
                        body)
                else:
                    payload = jax.tree.map(np.asarray, out)  # device -> host
                    frame_bytes = wire.encode_payload_frame(self.id, step,
                                                            payload)
                self.encode_s += time.thread_time() - t_pack
                self.encode_steps += 1
            t_send = self.clock.monotonic()
            self.endpoint.send(frame_bytes)
            if trace:
                self.tracer.complete(SPAN_WIRE_SEND, t_send,
                                     self.clock.monotonic(), tid=tid,
                                     step=step, nbytes=len(frame_bytes))
            hb = wire.payload_frame_header_nbytes(payload)
            self.stats.count_up(header_nbytes=hb,
                                payload_nbytes=len(frame_bytes) - hb)
            self._m_frames_up.inc()
            self._m_payload_up.inc(len(frame_bytes) - hb)
            self._m_framing_up.inc(hb)

            reply = self._await_reply(step, frame_bytes, hb)
            latency = self.clock.monotonic() - t_send
            self.latencies.append(latency)
            self._m_latency.observe(latency * 1e3)
            nxt = int(reply.tokens[0])
            if step + 1 < len(self.prompt):
                token = np.asarray([[self.prompt[step + 1]]], np.int32)
            else:
                self.generated.append(nxt)
                self.stats.tokens_out += 1
                self._m_tokens.inc()
                token = np.asarray([[nxt]], np.int32)
