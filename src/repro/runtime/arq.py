"""Stop-and-wait ARQ recovery — the client half, shared by both runtimes.

`ArqClientMixin` holds the one copy of the retry/reconnect/dedup loop that
`runtime.client.StreamingClient` (awaiting token frames) and
`fedtrain.client.TrainingClient` (awaiting grad frames) both run:

  * every request carries its step as the sequence number; the reply echoes
    it, so stale duplicate re-acks (seq < step) are counted and dropped;
  * no reply within `retry_timeout` -> retransmit the same frame (the
    server dedups by seq and re-acks from its reply cache), counting the
    resent bytes — a retransmission is a real frame crossing the queue;
  * an `error` frame or a corrupt downstream (`wire.WireError`) -> the
    connection is dead; reconnect through the engine-provided callable onto
    the same server-side session and replay the in-flight step. Error-frame
    replays spend the same `max_retries` budget as timeouts, so a
    deterministically-rejecting peer cannot spin the loop forever;
  * every 8th timeout also reconnects: a corrupted length prefix stalls a
    reader waiting for bytes that never come, and only a fresh connection
    (with fresh `FrameReader`s on both ends) can unstick it.

Subclasses provide `id`, `endpoint`, `stats`, `reconnect`, `reply_timeout`,
`retry_timeout`, `max_retries`, plus the two points that differ: the
expected reply kind (`_reply_kind`) and how a received reply is counted
(`_count_reply` — token replies count aggregate bytes, grad replies keep
the payload/framing split that Table-2 bwd accounting needs).

With a clean wire and `retry_timeout=None` the loop is one blocking wait —
byte-identical to the pre-ARQ behavior.
"""
from __future__ import annotations

from repro.core import wire
from repro.obs.registry import DEFAULT_REGISTRY
from repro.obs.trace import (EVT_ARQ_RECONNECT, EVT_ARQ_RETRANSMIT,
                             NULL_TRACER, SPAN_ARQ_ACCEPT, session_tid)
from repro.testing.clock import SYSTEM_CLOCK


class ArqClientMixin:
    """Retry / reconnect / dedup recovery loop for a lock-step client.

    `clock` is the injectable time source behind every latency stamp the
    subclass takes; the blocking `_await_reply` path itself waits on the
    transport (SYSTEM_CLOCK mode), while the event-driven loadgen harness
    replaces the wait with scheduled retry events on a `VirtualClock` and
    reuses `_accept_reply` / `_retransmit` / `_reconnect` unchanged.

    Observability (docs/observability.md): retransmits and reconnects emit
    `arq.*` instants on the session's trace track and bump the
    `arq_replays_total` / `arq_reconnects_total` registry counters; an
    accepted reply closes the lifecycle with a `client.arq_accept` span.
    Both hooks are class-attribute defaults (`NULL_TRACER`, the process
    registry) so subclasses and harnesses override per run.
    """

    _reply_kind: int                    # wire.FRAME_TOKENS / FRAME_GRAD
    clock = SYSTEM_CLOCK
    tracer = NULL_TRACER
    registry = DEFAULT_REGISTRY

    def _count_reply(self, reply: wire.Frame) -> None:
        raise NotImplementedError

    def _reconnect(self) -> None:
        if self.reconnect is None:
            raise RuntimeError(f"session {self.id}: connection failed and "
                               f"no reconnect path is configured")
        # best-effort abandon notice so the old connection's server reader
        # exits instead of polling an orphaned channel forever
        try:
            self.endpoint.send(wire.encode_error_frame(
                self.id, 0, wire.ERR_PROTOCOL, "peer reconnecting"))
        except Exception:
            pass
        self.endpoint = self.reconnect()
        self.stats.reconnects += 1
        self.registry.counter("arq_reconnects_total", party="client").inc()
        self.tracer.instant(EVT_ARQ_RECONNECT, tid=session_tid(self.id),
                            sid=self.id)

    def _retransmit(self, frame_bytes: bytes, header_nbytes: int) -> None:
        self.stats.count_up(header_nbytes,
                            len(frame_bytes) - header_nbytes)
        self.registry.counter("arq_replays_total", party="client").inc()
        self.tracer.instant(EVT_ARQ_RETRANSMIT, tid=session_tid(self.id),
                            sid=self.id, nbytes=len(frame_bytes))
        self.endpoint.send(frame_bytes)

    def _accept_reply(self, reply: wire.Frame, step: int, t_recv=None):
        """Classify one received reply for in-flight `step`: returns the
        frame when it acks `step`, None for a counted stale duplicate
        (seq < step — a server re-ack of a replayed frame), and raises
        `wire.WireError` on a protocol violation (wrong kind, wrong
        session, or a seq from the future the stop-and-wait discipline
        can never produce). `t_recv` (clock seconds, optional) is when the
        reply came off the wire — the start of the traced accept span."""
        if reply.kind == self._reply_kind and reply.session == self.id:
            self._count_reply(reply)
            if reply.seq == step:
                if self.tracer.enabled:
                    now = self.clock.monotonic()
                    self.tracer.complete(
                        SPAN_ARQ_ACCEPT, now if t_recv is None else t_recv,
                        now, tid=session_tid(self.id), sid=self.id,
                        step=step)
                return reply
            if reply.seq < step:
                self.stats.duplicates += 1      # stale re-ack, drop
                self.registry.counter("duplicates_total",
                                      party="client").inc()
                return None
        raise wire.WireError(
            f"session {self.id}: unexpected reply kind={reply.kind} "
            f"seq={reply.seq} while awaiting step {step}")

    def _await_reply(self, step: int, frame_bytes: bytes,
                     header_nbytes: int) -> wire.Frame:
        """Block for the reply echoing `step`; raises TimeoutError once
        `max_retries` replays (timeout- or error-triggered) are spent."""
        timeout = (self.reply_timeout if self.retry_timeout is None
                   else self.retry_timeout)
        retries = 0
        while True:
            try:
                reply = self.endpoint.recv_frame(timeout=timeout)
            except wire.WireError:
                # corrupt downstream: this connection's frame boundaries
                # are gone — resume the session over a fresh one
                self.stats.faults_detected += 1
                self.registry.counter("faults_detected_total",
                                      party="client").inc()
                self._reconnect()
                reply = None
            t_recv = (self.clock.monotonic()
                      if self.tracer.enabled and reply is not None else None)
            if reply is None or reply.kind == wire.FRAME_ERROR:
                if self.retry_timeout is None or retries >= self.max_retries:
                    raise TimeoutError(
                        f"session {self.id}: no reply to frame {step} "
                        f"after {retries} retransmissions")
                retries += 1
                self.stats.replays += 1
                if reply is not None:
                    # peer rejected a frame and retired the connection
                    self.stats.count_down(reply.nbytes)
                    self._reconnect()
                elif self.reconnect is not None and retries % 8 == 0:
                    self._reconnect()   # escape a stalled reader
                self._retransmit(frame_bytes, header_nbytes)
                continue
            got = self._accept_reply(reply, step, t_recv)
            if got is not None:
                return got
