"""In-process byte transport — a simulated duplex socket carrying frames.

Both directions move *bytes*, not arrays: the sender serializes a frame with
`core.wire` and the receiver reassembles it through a `wire.FrameReader`, so
every measured size in the runtime is the length of a real byte string that
crossed a queue. Swapping this for a TCP socket changes only this module —
client, server, and accounting already speak length-prefixed frames and
tolerate arbitrary chunk boundaries.
"""
from __future__ import annotations

import queue
from typing import Optional

from repro.core import wire


class _BytePipe:
    """One direction: an unbounded thread-safe stream of byte chunks."""

    def __init__(self):
        self._q: queue.Queue = queue.Queue()

    def send(self, data: bytes) -> int:
        self._q.put(bytes(data))
        return len(data)

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None


class Endpoint:
    """One party's view of a duplex channel: send bytes, receive frames."""

    def __init__(self, out_pipe: _BytePipe, in_pipe: _BytePipe):
        self._out = out_pipe
        self._in = in_pipe
        self._reader = wire.FrameReader()
        self._pending: list = []

    def send(self, frame_bytes: bytes) -> int:
        return self._out.send(frame_bytes)

    def recv_chunk(self, timeout: Optional[float] = None) -> Optional[bytes]:
        """Next raw byte chunk off the wire, or None on timeout. The
        override point for byte-level interception (testing.faults)."""
        return self._in.recv(timeout=timeout)

    def recv_frame(self, timeout: Optional[float] = None):
        """Next complete frame, or None on timeout. Reassembles chunks.

        Raises `wire.WireError` if the stream is corrupt; frame boundaries
        after that are untrustworthy, so the caller must discard this
        endpoint (and may resume its sessions over a fresh one).
        """
        while not self._pending:
            chunk = self.recv_chunk(timeout=timeout)
            if chunk is None:
                return None
            self._reader.feed(chunk)
            for frame in self._reader.frames():
                self._pending.append(frame)
        return self._pending.pop(0)


def channel_pair():
    """(client_endpoint, server_endpoint) over two in-memory byte pipes."""
    up, down = _BytePipe(), _BytePipe()
    return Endpoint(up, down), Endpoint(down, up)
