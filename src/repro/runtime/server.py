"""Streaming server — the label owner serving N concurrent sessions.

One reader thread per connection parses `core.wire` frames off the byte
transport and feeds a `BatchingQueue`; the single serve loop flushes the
queue under the max-batch/max-wait policy and drives the device-resident
session-slot arena (`runtime.arena.SlotArena`):

  * each session is pinned to one arena slot at admission — its KV cache
    and position are rows of pre-allocated batched device arrays for the
    session's whole life (reconnects keep the slot; a closed session's slot
    is reset and reused);
  * each flush, payloads are grouped by meta and scatter-decoded ON DEVICE
    straight into the arena's cut-activation buffer rows
    (`protocol.server_decode_to_slots`, padded to `max_batch` onto a cached
    zero scratch row so each meta compiles once) — the host touches only
    the compressed wire leaves, never a dense activation;
  * one donated jitted top step runs over the WHOLE arena with an
    active-slot mask — zero per-flush cache stack/unstack, inactive slots
    pass through unchanged — and only the token rows come back to host.

Token replies stream back as frames; per-session byte accounting is taken
from the real frame sizes at receipt. The hot-path design and its
donation/aliasing invariants are documented in docs/performance.md.

Fault tolerance: a malformed frame (typed `wire.WireError` — CRC failure,
bad counts, truncation) no longer kills a reader thread silently. The reader
replies with an `error` frame naming the defect and retires the connection;
the *session* survives, and the client reconnects over a fresh channel and
replays from its last unacknowledged sequence number. Stop-and-wait dedup in
the serve loop (`Session.last_seq` / `last_reply`) re-acks replayed frames
without re-running the top-model step, so a KV cache never double-advances.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core.payload import Payload
from repro.runtime.arena import SlotArena
from repro.runtime.batching import BatchingQueue
from repro.runtime.session import Session
from repro.split import protocol


class FrameServerBase:
    """Connection plumbing shared by the serving and training servers:
    one reader thread per attached channel, typed rejection of malformed
    frames with an `error` frame + connection retire (never a dead
    thread), a session registry that survives reconnects, and the
    queue-close lifecycle.

    Subclasses call `_init_connections` from __init__, implement
    `_new_session(sid, endpoint)`, and set `direction` (the label protocol
    violations are reported under).
    """

    direction = "serving"

    def _init_connections(self, queue: BatchingQueue) -> None:
        self.queue = queue
        self.sessions: Dict[int, Session] = {}
        self._lock = threading.Lock()
        self._readers: List[threading.Thread] = []
        self._open_readers = 0
        self.errors: List[BaseException] = []   # reader-thread failures
        self.faults_detected = 0    # malformed frames rejected (connections
        #                             retired with an error frame, not dead)
        self.expected_sessions: int = 0     # set by the engine; the serve
        #   loop must not stop before this many sessions exist AND closed
        #   (a corrupt first frame can retire a connection before its
        #   session was ever created — the reconnect needs a live queue)

    def _new_session(self, sid: int, endpoint) -> Session:
        raise NotImplementedError

    def attach(self, endpoint) -> threading.Thread:
        """Register a client channel and start its frame-reader thread.

        Called once per client at startup and again for each reconnect — a
        resuming client gets a fresh connection onto its existing session.
        """
        with self._lock:
            self._open_readers += 1
        t = threading.Thread(target=self._read_loop, args=(endpoint,),
                             daemon=True)
        self._readers.append(t)
        t.start()
        return t

    def shutdown(self) -> None:
        """Close the admission queue; the serve loop drains, then exits.
        The engine calls this after every client thread has finished — the
        guaranteed stop even if a session's CLOSE frame was lost in chaos."""
        self.queue.close()

    def _reject(self, endpoint, sid_seen, exc: wire.WireError) -> None:
        """Name the defect in an error frame and retire the connection,
        keeping the session (the client reconnects and replays). A fault
        before any valid frame has no session to charge."""
        with self._lock:
            self.faults_detected += 1
            sess = (self.sessions.get(sid_seen)
                    if sid_seen is not None else None)
            if sess is not None:
                sess.stats.faults_detected += 1
        endpoint.send(wire.encode_error_frame(
            sid_seen if sid_seen is not None else 0, 0,
            wire.error_code(exc), str(exc)))

    def _read_loop(self, endpoint) -> None:
        sid_seen = None             # session observed on THIS connection
        try:
            while True:
                try:
                    frame = endpoint.recv_frame(timeout=0.1)
                except wire.WireError as e:
                    self._reject(endpoint, sid_seen, e)
                    return
                if frame is None:
                    continue
                if frame.kind == wire.FRAME_CLOSE:
                    with self._lock:
                        if frame.session in self.sessions:
                            self.sessions[frame.session].closed = True
                    return
                if frame.kind == wire.FRAME_ERROR:
                    return              # peer abandoned this connection
                if frame.kind != wire.FRAME_PAYLOAD:
                    raise wire.WireError(
                        f"unexpected frame kind {frame.kind} on the "
                        f"{self.direction} up direction")
                sid_seen = frame.session
                sess = self._session_for(frame.session, endpoint)
                sess.stats.count_up(frame.header_nbytes, frame.payload_nbytes)
                try:
                    self.queue.put((sess, frame))
                except RuntimeError:
                    return              # server shut down under us
        except wire.WireError as e:     # protocol violation from a valid frame
            self._reject(endpoint, sid_seen, e)
        except BaseException as e:      # surfaced by the engine
            with self._lock:
                self.errors.append(e)
        finally:
            with self._lock:
                self._open_readers -= 1
                # natural completion: every connection retired AND every
                # expected session exists and closed. A reader retired by a
                # fault (possibly before its session was even created)
                # holds the queue open for the reconnect; the engine's
                # shutdown() after the client joins is the backstop.
                done = (self._open_readers == 0
                        and len(self.sessions) >= self.expected_sessions
                        and all(s.closed for s in self.sessions.values()))
            if done:
                self.queue.close()          # serve loop drains, then exits

    def _session_for(self, sid: int, endpoint) -> Session:
        with self._lock:
            sess = self.sessions.get(sid)
            if sess is None:
                sess = self._new_session(sid, endpoint)
                self.sessions[sid] = sess
            else:
                sess.endpoint = endpoint    # replies follow the latest conn
            return sess


class StreamingServer(FrameServerBase):
    """Top-model serving engine over framed byte channels.

    `top_step` must be an arena-shaped step (`steps.make_arena_top_step`):
    it is jitted here with the arena cache DONATED, so every flush updates
    the slot arrays in place. `capacity` bounds concurrently-open sessions
    (a closed session's slot is reclaimed for the next admission); the
    engine sets it to the expected client count.
    """

    def __init__(self, params, top_step: Callable, make_cache: Callable,
                 *, max_batch: int = 8, max_wait: float = 0.01,
                 dtype=jnp.float32, capacity: Optional[int] = None,
                 x_shape=None, backend: Optional[str] = None):
        self.params = params
        self.top_step = jax.jit(top_step, donate_argnums=(2,))
        self.dtype = dtype
        self.backend = backend              # sparse-decode backend dispatch
        self.batch_sizes: List[int] = []    # flush fill history
        self.stage_s = {"decode": 0.0, "step": 0.0, "reply": 0.0}
        self._init_connections(BatchingQueue(max_batch, max_wait))
        self.arena: Optional[SlotArena] = None
        self._make_cache = make_cache
        self._capacity = capacity or max_batch
        if x_shape is not None:             # else: built lazily from the
            self.arena = SlotArena(make_cache, self._capacity, x_shape,
                                   dtype)    # first payload's meta.d
        self._free_slots: List[int] = list(range(self._capacity))
        self._pending_resets: List[int] = []    # applied by the serve loop
        self._pad_rows: Dict = {}           # cached zero pad rows, per shape

    def _ensure_arena(self, d: int) -> None:
        if self.arena is None:
            self.arena = SlotArena(self._make_cache, self._capacity,
                                   (1, 1, d), self.dtype)

    def _new_session(self, sid: int, endpoint) -> Session:
        # called under self._lock (from _session_for)
        if self._free_slots:
            slot = self._free_slots.pop(0)
        else:
            # reclaim the slot of a closed session; the reset is applied by
            # the serve loop (never raced against the donated step)
            slot = None
            for sess in self.sessions.values():
                if sess.closed and sess.slot >= 0:
                    slot, sess.slot = sess.slot, -1
                    self._pending_resets.append(slot)
                    break
            if slot is None:
                raise RuntimeError(
                    f"session {sid}: arena full ({self._capacity} slots, "
                    f"none closed) — raise `capacity` to the expected "
                    f"session count")
        return Session(id=sid, slot=slot, endpoint=endpoint)

    # -- serving -------------------------------------------------------------

    def serve_loop(self) -> None:
        """Flush/process until every connection has closed and drained."""
        while True:
            batch = self.queue.get_batch(idle_timeout=0.05)
            if batch:
                self._process(batch)
            elif self.queue.drained:
                return

    def warm(self, example_payloads) -> None:
        """Compile every hot-loop jit before the serving clock starts.

        For each example payload (one per distinct client compressor,
        encoded from a probe activation) runs the padded group decode
        aimed entirely at the scratch row, then one all-inactive arena
        step — shapes match the serve path exactly, no session state is
        perturbed, and the first real flush pays zero compile time.
        """
        for p in example_payloads:
            self._ensure_arena(p.meta.d)
            group = [p] * self.queue.max_batch
            slots = np.full(len(group), self.arena.capacity, np.int64)
            self._decode_group(p.meta, group, slots)
        active = jnp.zeros((self.arena.capacity,), bool)
        tokens, self.arena.cache = self.top_step(
            self.params, self.arena.xbuf, self.arena.cache, active)
        jax.block_until_ready(tokens)

    def _dedup(self, items) -> List:
        """Stop-and-wait ARQ filter: the client never has two frames in
        flight, so any seq above the last processed one is fresh progress
        and anything at or below it is a replay. A replay of the last
        processed seq is re-acked from the cached reply bytes (the step
        must NOT re-run — it would advance the KV cache again); anything
        older is dropped. Both cases count as duplicates.
        """
        fresh = []
        for sess, frame in items:
            if frame.seq > sess.last_seq:
                fresh.append((sess, frame))
                continue
            sess.stats.duplicates += 1
            if frame.seq == sess.last_seq and sess.last_reply is not None:
                sess.endpoint.send(sess.last_reply)
                sess.stats.count_down(len(sess.last_reply))
        return fresh

    def _pad_row(self, like: np.ndarray) -> np.ndarray:
        """Cached zero pad row for ragged decode groups. Pad rows scatter
        into the arena's scratch slot and are NEVER an alias of a live
        session's arrays (the pre-arena loop duplicated items[0]'s cache
        reference into pad slots — a stale-aliasing footgun this template
        removes)."""
        key = (like.shape, like.dtype.str)
        row = self._pad_rows.get(key)
        if row is None:
            row = self._pad_rows[key] = np.zeros(like.shape, like.dtype)
        return row

    def _decode_group(self, meta, group, slots: np.ndarray) -> None:
        """Scatter-decode one meta-group of payloads into the arena rows
        `slots`, on device. The group is padded to `max_batch` (zero rows
        aimed at the scratch slot) so each payload meta compiles exactly
        once; the host only stacks the compressed wire leaves — the dense
        view never exists host-side. `xbuf` is donated and rebound."""
        pad = self.queue.max_batch - len(group)
        leaves = {}
        for name, _ in group[0].wire_leaves():
            rows = [np.asarray(getattr(p, name)) for p in group]
            if pad:
                rows.extend([self._pad_row(rows[0])] * pad)
            leaves[name] = np.stack(rows)
        if pad:
            slots = np.concatenate(
                [slots, np.full(pad, self.arena.capacity, np.int64)])
        stacked = Payload(meta=meta, **leaves)
        self.arena.xbuf = protocol.server_decode_to_slots(
            self.arena.xbuf, stacked, slots, dtype=self.dtype,
            backend=self.backend)

    def _process(self, items) -> None:
        items = self._dedup(items)
        with self._lock:
            resets, self._pending_resets = self._pending_resets, []
            # a reclaimed slot means the session closed; any straggler
            # frame has no device state left and is dropped. The slot is
            # SNAPSHOTTED under the same lock: a reader thread admitting a
            # new session may reclaim a closed session's slot at any
            # moment, and a slot flipping to -1 between the filter and the
            # mask build would corrupt another live slot's row.
            items = [(s, f, s.slot) for s, f in items if s.slot >= 0]
        if items:
            self._ensure_arena(items[0][1].payload.meta.d)
        if self.arena is not None:
            for slot in resets:             # serialized with the step here
                self.arena.reset_slot(slot)
        if not items:
            return
        self.batch_sizes.append(len(items))
        t0 = time.perf_counter()
        by_meta: Dict = {}
        for i, (_, frame, _slot) in enumerate(items):
            by_meta.setdefault(frame.payload.meta, []).append(i)
        for meta, idxs in by_meta.items():
            self._decode_group(
                meta, [items[i][1].payload for i in idxs],
                np.fromiter((items[i][2] for i in idxs), np.int64,
                            len(idxs)))
        active = np.zeros(self.arena.capacity, bool)
        for _, _, slot in items:
            active[slot] = True
        t1 = time.perf_counter()
        # ONE donated step over the whole arena: no cache stack/unstack,
        # only the (capacity, 1) token rows come back to host
        tokens, self.arena.cache = self.top_step(
            self.params, self.arena.xbuf, self.arena.cache,
            jnp.asarray(active))
        tokens = np.asarray(tokens)
        t2 = time.perf_counter()
        for sess, frame, slot in items:
            reply = wire.encode_token_frame(sess.id, frame.seq,
                                            tokens[slot])
            sess.last_seq, sess.last_reply = frame.seq, reply
            sess.endpoint.send(reply)
            sess.stats.count_down(len(reply))
        t3 = time.perf_counter()
        self.stage_s["decode"] += t1 - t0
        self.stage_s["step"] += t2 - t1
        self.stage_s["reply"] += t3 - t2
