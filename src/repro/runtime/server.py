"""Streaming server — the label owner serving N concurrent sessions.

One reader thread per connection parses `core.wire` frames off the byte
transport and feeds a `BatchingQueue`; the single serve loop flushes the
queue under the max-batch/max-wait policy and drives the device-resident
session-slot arena (`runtime.arena.SlotArena`):

  * each session is pinned to one arena slot at admission — its KV cache
    and position are rows of pre-allocated batched device arrays for the
    session's whole life (reconnects keep the slot; a closed session's slot
    is reset and reused);
  * each flush, payloads are staged into cached per-(meta, bucket) host
    buffers — padded to the nearest power-of-two flush bucket, NOT to
    `max_batch`, so a ragged flush stages < 2x its wire bytes instead of
    the old `max_batch/fill` amplification — and the host touches only
    the compressed wire leaves, never a dense activation;
  * a single-meta flush (every pure-compressor population) runs ONE fused
    decode+step dispatch (`steps.make_fused_decode_step`): the payload
    scatter-decodes into `xbuf[slots]` and the donated whole-arena top
    step runs in the same jit program, with only the token rows coming
    back to host. Mixed-meta flushes fall back to per-meta device decodes
    (`protocol.server_decode_to_slots`) followed by the donated arena
    step — two dispatches, same numerics.

Token replies stream back as frames; per-session byte accounting is taken
from the real frame sizes at receipt. The hot-path design and its
donation/aliasing invariants are documented in docs/performance.md.

Fault tolerance: a malformed frame (typed `wire.WireError` — CRC failure,
bad counts, truncation) no longer kills a reader thread silently. The reader
replies with an `error` frame naming the defect and retires the connection;
the *session* survives, and the client reconnects over a fresh channel and
replays from its last unacknowledged sequence number. Stop-and-wait dedup in
the serve loop (`Session.last_seq` / `last_reply`) re-acks replayed frames
without re-running the top-model step, so a KV cache never double-advances.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core.payload import Payload
from repro.obs.registry import DEFAULT_REGISTRY, MetricsRegistry
from repro.obs.trace import (EVT_SLOT_ADMIT, EVT_SLOT_EVICT, NULL_TRACER,
                             SERVE_TID, SPAN_DECODE, SPAN_QUEUE_WAIT,
                             SPAN_REPLY, SPAN_STEP, session_tid)
from repro.runtime import steps
from repro.runtime.arena import SlotArena
from repro.runtime.batching import BatchingQueue
from repro.runtime.session import Session
from repro.split import protocol
from repro.testing.clock import Clock, SYSTEM_CLOCK

#: `Session.host_state` between the LRU-eviction decision (reader thread,
#: under the server lock) and the serve loop's fetch of the row to host —
#: marks "evicted, state still on device". A frame arriving in that window
#: re-admits the session; FIFO ordering of the arena-op queue guarantees
#: the fetch runs before the restore, so the restore always writes real
#: host state.
_EVICTING = object()


def jit_serving_steps(top_step: Callable, *, dtype,
                      backend: Optional[str] = None):
    """The server's jitted step pair: (donated plain arena step, donated
    fused decode+step). Split out so `runtime.engine` can cache the pair
    across `run_streaming` calls — jit compile caches live on the wrapped
    callable, and rebuilding the pair per run re-pays every per-(meta,
    bucket) compile the warm loop just amortized."""
    top = jax.jit(top_step, donate_argnums=(2,))
    fused = jax.jit(
        steps.make_fused_decode_step(top_step, dtype=dtype, backend=backend),
        donate_argnums=(1, 4))
    return top, fused


class FrameServerBase:
    """Connection plumbing shared by the serving and training servers:
    one reader thread per attached channel, typed rejection of malformed
    frames with an `error` frame + connection retire (never a dead
    thread), a session registry that survives reconnects, and the
    queue-close lifecycle.

    Subclasses call `_init_connections` from __init__, implement
    `_new_session(sid, endpoint)`, and set `direction` (the label protocol
    violations are reported under).
    """

    direction = "serving"

    def _init_connections(self, queue: BatchingQueue,
                          tracer=NULL_TRACER,
                          registry: Optional[MetricsRegistry] = None) -> None:
        self.queue = queue
        self.sessions: Dict[int, Session] = {}
        self._lock = threading.Lock()
        # admissions blocked on a full arena wait here; notified on session
        # close and after every flush's pending-frame drain (both can make
        # a slot reclaimable/evictable)
        self._slot_cv = threading.Condition(self._lock)
        self._readers: List[threading.Thread] = []
        self._open_readers = 0
        self.errors: List[BaseException] = []   # reader-thread failures
        self.faults_detected = 0    # malformed frames rejected (connections
        #                             retired with an error frame, not dead)
        self.expected_sessions: int = 0     # set by the engine; the serve
        #   loop must not stop before this many sessions exist AND closed
        #   (a corrupt first frame can retire a connection before its
        #   session was ever created — the reconnect needs a live queue)
        self.tracer = tracer
        self.registry = registry if registry is not None else DEFAULT_REGISTRY
        # pre-bound per-frame instruments: the reader/serve hot paths pay a
        # lock + add, never a registry dict lookup
        reg = self.registry
        self._m_frames_up = reg.counter("frames_total", party="server",
                                        direction="up")
        self._m_payload_up = reg.counter("payload_bytes_total",
                                         party="server", direction="up")
        self._m_framing_up = reg.counter("framing_bytes_total",
                                         party="server", direction="up")
        self._m_frames_down = reg.counter("frames_total", party="server",
                                          direction="down")
        self._m_bytes_down = reg.counter("wire_bytes_total", party="server",
                                         direction="down")
        self._m_faults = reg.counter("faults_detected_total", party="server")
        self._m_dups = reg.counter("duplicates_total", party="server")
        self._m_fill = reg.histogram("flush_fill")
        self._m_qwait = reg.histogram("queue_wait_ms")
        self._m_depth = reg.gauge("queue_depth")
        # (sid, seq) -> enqueue clock time; popped at flush into the
        # `server.queue_wait` span / `queue_wait_ms` histogram
        self._enq_ts: Dict = {}

    def _new_session(self, sid: int, endpoint) -> Session:
        raise NotImplementedError

    def _before_enqueue(self, sess: Session) -> None:
        """Hook run after a payload frame is accepted, before it enters the
        batching queue. The serving subclass pins the session's device
        residency and bumps its in-flight frame count here; the training
        server needs neither."""

    def _count_frame_up(self, sess: Session, frame) -> None:
        """Byte accounting for one accepted uplink frame: the session's
        legacy `SessionStats` plus the registry's labeled counters."""
        sess.stats.count_up(frame.header_nbytes, frame.payload_nbytes)
        self._m_frames_up.inc()
        self._m_payload_up.inc(frame.payload_nbytes)
        self._m_framing_up.inc(frame.header_nbytes)

    def _note_enqueue(self, sess: Session, frame) -> None:
        """Stamp a successfully-enqueued frame; `_process` pops the stamp
        into the `server.queue_wait` span and `queue_wait_ms` histogram.
        (dict set/pop are GIL-atomic — reader threads write, serve loop
        pops)."""
        self._enq_ts[(sess.id, frame.seq)] = self.queue.clock.monotonic()

    def _count_frame_down(self, sess: Session, nbytes: int) -> None:
        sess.stats.count_down(nbytes)
        self._m_frames_down.inc()
        self._m_bytes_down.inc(nbytes)

    def attach(self, endpoint) -> threading.Thread:
        """Register a client channel and start its frame-reader thread.

        Called once per client at startup and again for each reconnect — a
        resuming client gets a fresh connection onto its existing session.
        """
        with self._lock:
            self._open_readers += 1
        t = threading.Thread(target=self._read_loop, args=(endpoint,),
                             daemon=True)
        self._readers.append(t)
        t.start()
        return t

    def shutdown(self) -> None:
        """Close the admission queue; the serve loop drains, then exits.
        The engine calls this after every client thread has finished — the
        guaranteed stop even if a session's CLOSE frame was lost in chaos."""
        self.queue.close()

    def _reject(self, endpoint, sid_seen, exc: wire.WireError) -> None:
        """Name the defect in an error frame and retire the connection,
        keeping the session (the client reconnects and replays). A fault
        before any valid frame has no session to charge."""
        with self._lock:
            self.faults_detected += 1
            sess = (self.sessions.get(sid_seen)
                    if sid_seen is not None else None)
            if sess is not None:
                sess.stats.faults_detected += 1
        self._m_faults.inc()
        endpoint.send(wire.encode_error_frame(
            sid_seen if sid_seen is not None else 0, 0,
            wire.error_code(exc), str(exc)))

    def _read_loop(self, endpoint) -> None:
        sid_seen = None             # session observed on THIS connection
        try:
            while True:
                try:
                    frame = endpoint.recv_frame(timeout=0.1)
                except wire.WireError as e:
                    self._reject(endpoint, sid_seen, e)
                    return
                if frame is None:
                    continue
                if frame.kind == wire.FRAME_CLOSE:
                    with self._lock:
                        if frame.session in self.sessions:
                            self.sessions[frame.session].closed = True
                        self._slot_cv.notify_all()
                    return
                if frame.kind == wire.FRAME_ERROR:
                    return              # peer abandoned this connection
                if frame.kind != wire.FRAME_PAYLOAD:
                    raise wire.WireError(
                        f"unexpected frame kind {frame.kind} on the "
                        f"{self.direction} up direction")
                sid_seen = frame.session
                sess = self._session_for(frame.session, endpoint)
                self._count_frame_up(sess, frame)
                self._before_enqueue(sess)
                try:
                    self.queue.put((sess, frame))
                except RuntimeError:
                    return              # server shut down under us
                self._note_enqueue(sess, frame)
        except wire.WireError as e:     # protocol violation from a valid frame
            self._reject(endpoint, sid_seen, e)
        except BaseException as e:      # surfaced by the engine
            with self._lock:
                self.errors.append(e)
        finally:
            with self._lock:
                self._open_readers -= 1
                # natural completion: every connection retired AND every
                # expected session exists and closed. A reader retired by a
                # fault (possibly before its session was even created)
                # holds the queue open for the reconnect; the engine's
                # shutdown() after the client joins is the backstop.
                done = (self._open_readers == 0
                        and len(self.sessions) >= self.expected_sessions
                        and all(s.closed for s in self.sessions.values()))
            if done:
                self.queue.close()          # serve loop drains, then exits

    def pump(self, endpoint, sid_seen: Optional[int] = None):
        """Single-threaded counterpart of `_read_loop`: drain every frame
        currently available on `endpoint` without blocking, enqueueing
        payload frames exactly as the reader thread would.

        Returns `(status, sid_seen)` — the caller (a virtual-clock event
        loop, `runtime.loadgen`) owns the connection lifecycle the reader
        thread normally owns: `status` is `"open"` (keep pumping this
        connection later), `"retired"` (a malformed frame was rejected
        with an error frame, or the peer abandoned the connection — stop
        pumping it; the session survives for a reconnect), or `"closed"`
        (the session's CLOSE frame arrived). `sid_seen` must be passed
        back on the next pump of the same connection so a fault is
        charged to the right session, mirroring `_read_loop`'s per-
        connection state.
        """
        while True:
            try:
                frame = endpoint.recv_frame(timeout=0.0)
            except wire.WireError as e:
                self._reject(endpoint, sid_seen, e)
                return "retired", sid_seen
            if frame is None:
                return "open", sid_seen
            if frame.kind == wire.FRAME_CLOSE:
                with self._lock:
                    if frame.session in self.sessions:
                        self.sessions[frame.session].closed = True
                    self._slot_cv.notify_all()
                return "closed", sid_seen
            if frame.kind == wire.FRAME_ERROR:
                return "retired", sid_seen      # peer abandoned this conn
            if frame.kind != wire.FRAME_PAYLOAD:
                e = wire.WireError(
                    f"unexpected frame kind {frame.kind} on the "
                    f"{self.direction} up direction")
                self._reject(endpoint, sid_seen, e)
                return "retired", sid_seen
            sid_seen = frame.session
            sess = self._session_for(frame.session, endpoint)
            self._count_frame_up(sess, frame)
            self._before_enqueue(sess)
            self.queue.put((sess, frame))       # QueueFull surfaces to caller
            self._note_enqueue(sess, frame)

    def _session_for(self, sid: int, endpoint) -> Session:
        with self._lock:
            sess = self.sessions.get(sid)
            if sess is None:
                sess = self._new_session(sid, endpoint)
                self.sessions[sid] = sess
            else:
                sess.endpoint = endpoint    # replies follow the latest conn
            return sess


class StreamingServer(FrameServerBase):
    """Top-model serving engine over framed byte channels.

    `top_step` must be an arena-shaped step (`steps.make_arena_top_step`,
    built with the same `mesh` passed here): it is jitted with the arena
    cache DONATED, so every flush updates the slot arrays in place.
    `capacity` bounds concurrently-RESIDENT sessions; admission beyond it
    reclaims a closed session's slot, then (with `evict_idle`) LRU-evicts
    an idle session's row to host — the evicted session re-admits
    transparently on its next frame — and only blocks/raises
    (`admit_timeout`) when every slot holds an in-flight session. The
    engine sets `capacity` to the expected concurrent client count, at
    which point neither eviction nor blocking ever triggers.
    """

    def __init__(self, params, top_step: Optional[Callable],
                 make_cache: Callable,
                 *, max_batch: int = 8, max_wait: float = 0.01,
                 dtype=jnp.float32, capacity: Optional[int] = None,
                 x_shape=None, backend: Optional[str] = None,
                 jit_steps=None, clock: Clock = SYSTEM_CLOCK,
                 mesh=None, evict_idle: bool = True,
                 admit_timeout: float = 5.0,
                 tracer=NULL_TRACER,
                 registry: Optional[MetricsRegistry] = None):
        self.params = params
        self.clock = clock
        # `jit_steps` (a `jit_serving_steps` pair) lets the engine share
        # compiled programs across runs; direct construction from a bare
        # arena step keeps working and jits here.
        if jit_steps is None:
            jit_steps = jit_serving_steps(top_step, dtype=dtype,
                                          backend=backend)
        self.top_step, self._fused_step = jit_steps
        self.dtype = dtype
        self.backend = backend              # sparse-decode backend dispatch
        self.batch_sizes: List[int] = []    # flush fill history
        self.stage_s = {"decode": 0.0, "step": 0.0, "reply": 0.0}
        self.stage_tokens = 0               # tokens served by those flushes
        #   (normalizes stage_s to per-token stage costs in the bench)
        self._init_connections(BatchingQueue(max_batch, max_wait,
                                             clock=clock),
                               tracer=tracer, registry=registry)
        if tracer.enabled:
            tracer.name_track(SERVE_TID, "serve loop")
        self.arena: Optional[SlotArena] = None
        self._make_cache = make_cache
        self._capacity = capacity or max_batch
        self._mesh = mesh
        self.evict_idle = evict_idle
        self.admit_timeout = admit_timeout
        if x_shape is not None:             # else: built lazily from the
            self.arena = SlotArena(make_cache, self._capacity, x_shape,
                                   dtype, mesh=mesh)  # first payload's meta.d
        # FIFO free deque: O(1) admission (the old list.pop(0) was
        # O(capacity)) and freed slots cycle to the BACK, so slot reuse
        # walks every row instead of hammering the coldest id — a
        # reuse-after-close bug now surfaces within `capacity` admissions
        self._free_slots: Deque[int] = collections.deque(
            range(self._capacity))
        # ordered arena mutations ("reset" | "fetch" | "restore"), applied
        # by the serve loop before the next flush touches the arena — every
        # row write is serialized with the donated step, and FIFO order
        # guarantees an eviction's fetch lands before any re-admission's
        # restore of the same session
        self._arena_ops: List[Tuple] = []
        # flush-size buckets: powers of two up to max_batch (plus max_batch
        # itself when it is not one) — each (meta, bucket) decode/fused
        # program compiles once, and ragged fills pad < 2x
        self._buckets = sorted(
            {1 << i for i in range(max_batch.bit_length())
             if (1 << i) <= max_batch} | {max_batch})
        self._staging: Dict = {}            # (meta, bucket, leaf) -> np buf
        self.host_bytes = {"staged": 0, "wire": 0}

    def _ensure_arena(self, d: int) -> None:
        if self.arena is None:
            self.arena = SlotArena(self._make_cache, self._capacity,
                                   (1, 1, d), self.dtype, mesh=self._mesh)

    # -- slot lifecycle (admission / reclaim / evict / re-admit) -------------

    def _push_free(self, slot: int) -> None:
        """Freed slots go to the BACK of the deque (cycling; see __init__)."""
        self._free_slots.append(slot)

    def compact_free_slots(self) -> None:
        """Free-list compaction: restore ascending issue order. The serve
        loop runs this whenever the arena goes fully idle, so a long-lived
        server's slot ids don't drift into a permanently shuffled order
        (admission bursts then fill rows — and mesh row shards — from the
        bottom up instead of in historical close order)."""
        with self._lock:
            self._free_slots = collections.deque(sorted(self._free_slots))

    def _assign_slot_locked(self, sid: int) -> int:
        """Take a free slot, else reclaim a closed session's, else
        LRU-evict an idle session's row to host, else block on the slot
        condvar until `admit_timeout` (through `self.clock`, so a
        VirtualClock run degrades to an immediate arena-full error instead
        of deadlocking a single-threaded pump). Called under `self._lock`;
        the wait releases it."""
        deadline = None
        while True:
            if self._free_slots:
                return self._free_slots.popleft()
            for sess in self.sessions.values():
                # reclaim a closed session's slot; the template reset is
                # applied by the serve loop (never raced with the step)
                if sess.closed and sess.slot >= 0:
                    slot, sess.slot = sess.slot, -1
                    self._arena_ops.append(("reset", None, slot))
                    self.registry.counter("slot_reclaims_total").inc()
                    self.tracer.instant(EVT_SLOT_EVICT, tid=SERVE_TID,
                                        sid=sess.id, slot=slot)
                    return slot
            if self.evict_idle:
                cand = None
                for sess in self.sessions.values():
                    # evictable = resident, idle, and fully materialized:
                    # `host_state is not None` means a fetch or restore for
                    # this session is still queued/in flight — re-evicting
                    # now would stamp the sentinel over real saved state
                    # and lose the row (the serve loop clears host_state
                    # when the restore lands)
                    if (sess.slot >= 0 and not sess.closed
                            and sess.pending == 0
                            and sess.host_state is None
                            and sess.id != sid
                            and (cand is None
                                 or sess.last_active < cand.last_active)):
                        cand = sess
                if cand is not None:
                    # LRU eviction: the row moves to host (serve loop runs
                    # the fetch before anything overwrites the row), and
                    # the session re-admits on its next frame
                    slot, cand.slot = cand.slot, -1
                    cand.host_state = _EVICTING
                    self._arena_ops.append(("fetch", cand, slot))
                    self._arena_ops.append(("reset", None, slot))
                    self.registry.counter("slot_evictions_total").inc()
                    self.tracer.instant(EVT_SLOT_EVICT, tid=SERVE_TID,
                                        sid=cand.id, slot=slot)
                    return slot
            now = self.clock.monotonic()
            if deadline is None:
                deadline = now + self.admit_timeout
            if now >= deadline:
                raise RuntimeError(
                    f"session {sid}: arena full ({self._capacity} slots, "
                    f"none closed or idle within {self.admit_timeout:.1f}s)"
                    f" — raise `capacity` toward the expected concurrent "
                    f"session count")
            self.clock.cv_wait(self._slot_cv, deadline - now)

    def _ensure_resident(self, sess: Session) -> None:
        """Re-admit an evicted session (under `self._lock`): assign a row
        (possibly evicting another idle session) and queue the restore —
        FIFO-after its own eviction's fetch, so the serve loop always
        writes back real host state. The restored row carries the exact
        pre-eviction KV/position, and the untouched `last_seq`/`last_reply`
        ARQ state keeps dedup working across the gap: a retransmit of the
        last pre-eviction frame is re-acked from the cached reply, never
        re-stepped — an evicted-then-readmitted cache cannot double-advance.
        """
        if sess.slot >= 0 or sess.closed or sess.host_state is None:
            return
        slot = self._assign_slot_locked(sess.id)
        sess.slot = slot
        self._arena_ops.append(("restore", sess, slot))
        self.registry.counter("slot_readmissions_total").inc()
        self.tracer.instant(EVT_SLOT_ADMIT, tid=SERVE_TID, sid=sess.id,
                            slot=slot)

    def _before_enqueue(self, sess: Session) -> None:
        """Serving-side enqueue hook: pin residency for the frame about to
        enter the queue and count it in flight — `pending > 0` makes the
        session ineligible for eviction until the flush that serves the
        frame drains it."""
        with self._lock:
            self._ensure_resident(sess)
            sess.pending += 1
            sess.last_active = self.clock.monotonic()

    def _new_session(self, sid: int, endpoint) -> Session:
        # called under self._lock (from _session_for)
        slot = self._assign_slot_locked(sid)
        self.registry.counter("slot_admits_total").inc()
        self.tracer.instant(EVT_SLOT_ADMIT, tid=SERVE_TID, sid=sid,
                            slot=slot)
        if self.tracer.enabled:
            self.tracer.name_track(session_tid(sid), f"session {sid}")
        return Session(id=sid, slot=slot, endpoint=endpoint,
                       last_active=self.clock.monotonic())

    def _apply_arena_ops(self, ops) -> None:
        """Run queued row mutations (eviction fetches, template resets,
        re-admission restores) on the serve-loop thread, in FIFO order,
        before the flush's step touches the arena. With no arena yet (no
        payload has sized it), no row was ever written: a fetch degrades
        to a fresh template and reset/restore are no-ops."""
        for kind, sess, slot in ops:
            if self.arena is None:
                if kind == "fetch":
                    sess.host_state = self._make_cache()
                elif kind == "restore":
                    sess.host_state = None
                continue
            if kind == "fetch":
                sess.host_state = self.arena.fetch_slot(slot)
            elif kind == "restore":
                state = sess.host_state
                assert state is not None and state is not _EVICTING, \
                    "restore ordered before its eviction's fetch"
                self.arena.restore_slot(slot, state)
                sess.host_state = None
            else:
                self.arena.reset_slot(slot)

    # -- serving -------------------------------------------------------------

    def serve_loop(self) -> None:
        """Flush/process until every connection has closed and drained."""
        while True:
            batch = self.queue.get_batch(idle_timeout=0.05)
            if batch:
                self._process(batch)
            elif self.queue.drained:
                return

    def warm(self, example_payloads) -> None:
        """Compile every hot-loop jit before the serving clock starts.

        For each example payload (one per distinct client compressor,
        encoded from a probe activation) and each flush-size bucket, runs
        the bucketed group decode aimed entirely at the scratch row AND
        the fused decode+step (all-inactive, so no session state is
        perturbed), then one plain arena step for the mixed-meta path —
        shapes match both serve paths exactly, and the first real flush of
        any fill pays zero compile time.
        """
        for p in example_payloads:
            self._ensure_arena(p.meta.d)
            inactive = jnp.zeros((self.arena.capacity,), bool)
            for size in self._buckets:
                slots = np.full(size, self.arena.capacity, np.int64)
                stacked, slots = self._stack_group(p.meta, [p] * size,
                                                   slots, size)
                self.arena.xbuf = protocol.server_decode_to_slots(
                    self.arena.xbuf, stacked, slots, dtype=self.dtype,
                    backend=self.backend)
                _, self.arena.xbuf, self.arena.cache = self._fused_step(
                    self.params, self.arena.xbuf, stacked, slots,
                    self.arena.cache, inactive)
        if self.arena is None:
            return
        tokens, self.arena.cache = self.top_step(
            self.params, self.arena.xbuf, self.arena.cache,
            jnp.zeros((self.arena.capacity,), bool))
        jax.block_until_ready(tokens)
        self.host_bytes = {"staged": 0, "wire": 0}   # warm traffic is free

    def _dedup(self, items) -> List:
        """Stop-and-wait ARQ filter: the client never has two frames in
        flight, so any seq above the last processed one is fresh progress
        and anything at or below it is a replay. A replay of the last
        processed seq is re-acked from the cached reply bytes (the step
        must NOT re-run — it would advance the KV cache again); anything
        older is dropped. Both cases count as duplicates.
        """
        fresh = []
        for sess, frame in items:
            if frame.seq > sess.last_seq:
                fresh.append((sess, frame))
                continue
            sess.stats.duplicates += 1
            self._m_dups.inc()
            if frame.seq == sess.last_seq and sess.last_reply is not None:
                sess.endpoint.send(sess.last_reply)
                self._count_frame_down(sess, len(sess.last_reply))
        return fresh

    def _bucket(self, n: int) -> int:
        """Smallest flush-size bucket holding `n` rows."""
        return next(b for b in self._buckets if b >= n)

    def _stack_group(self, meta, group, slots: np.ndarray, size: int):
        """Stack one meta-group's wire leaves into the cached
        (meta, bucket) staging buffers, zero-padding to `size` rows aimed
        at the arena's scratch slot. Returns (stacked Payload, (size,)
        slot vector). Pad rows are zeros, never an alias of a live
        session's arrays (the pre-arena loop duplicated items[0]'s cache
        reference into pad slots — a stale-aliasing footgun this template
        removes). Buffer reuse across flushes is safe: every flush forces
        its token rows to host before returning, which drains the device
        work that read the previous staging contents, and jax copies host
        operands at dispatch."""
        n = len(group)
        leaves = {}
        for name, first in group[0].wire_leaves():
            row0 = np.asarray(first)
            key = (meta, size, name)
            buf = self._staging.get(key)
            if buf is None or buf.shape[1:] != row0.shape:
                buf = self._staging[key] = np.zeros((size,) + row0.shape,
                                                    row0.dtype)
            buf[0] = row0
            for i in range(1, n):
                buf[i] = getattr(group[i], name)
            if n < size:
                buf[n:] = 0
            leaves[name] = buf
            self.host_bytes["staged"] += buf.nbytes
            self.host_bytes["wire"] += n * row0.nbytes
        if n < size:
            padded = np.full(size, self.arena.capacity, np.int64)
            padded[:n] = slots
            slots = padded
        return Payload(meta=meta, **leaves), slots

    def _decode_group(self, meta, group, slots: np.ndarray) -> None:
        """Scatter-decode one meta-group of payloads into the arena rows
        `slots`, on device — the mixed-meta flush path (single-meta
        flushes take the fused step in `_process`). The group is padded to
        its flush bucket, so each (meta, bucket) decode compiles once and
        the dense view never exists host-side. `xbuf` is donated and
        rebound."""
        stacked, slots = self._stack_group(meta, group, slots,
                                           self._bucket(len(group)))
        self.arena.xbuf = protocol.server_decode_to_slots(
            self.arena.xbuf, stacked, slots, dtype=self.dtype,
            backend=self.backend)

    def _process(self, items) -> None:
        # queue-wait accounting for every frame this flush picked up
        # (including replays the dedup below drops — they waited too)
        t_flush = self.clock.monotonic()
        trace = self.tracer.enabled
        for sess, frame in items:
            t_enq = self._enq_ts.pop((sess.id, frame.seq), None)
            if t_enq is None:
                continue
            self._m_qwait.observe((t_flush - t_enq) * 1e3)
            if trace:
                self.tracer.complete(SPAN_QUEUE_WAIT, t_enq, t_flush,
                                     tid=session_tid(sess.id), sid=sess.id,
                                     seq=frame.seq)
        self._m_depth.set(len(self.queue))
        all_items = items
        items = self._dedup(items)
        with self._lock:
            # drain the in-flight count for EVERY frame this flush picked
            # up (dedup-dropped replays included — they were enqueued too)
            # and stamp activity for the LRU eviction order
            for sess, _frame in all_items:
                sess.pending -= 1
                sess.last_active = t_flush
            # eager slot release: a closed session's row returns to the
            # free deque now, not at the next full-arena admission scan
            for sess in self.sessions.values():
                if sess.closed and sess.slot >= 0:
                    slot, sess.slot = sess.slot, -1
                    self._arena_ops.append(("reset", None, slot))
                    self._push_free(slot)
                    self.registry.counter("slot_reclaims_total").inc()
                    self.tracer.instant(EVT_SLOT_EVICT, tid=SERVE_TID,
                                        sid=sess.id, slot=slot)
            if len(self._free_slots) == self._capacity:
                # fully idle: compact the free list back to issue order
                self._free_slots = collections.deque(
                    sorted(self._free_slots))
            ops, self._arena_ops = self._arena_ops, []
            self._slot_cv.notify_all()
            # a reclaimed slot means the session closed; any straggler
            # frame has no device state left and is dropped. The slot is
            # SNAPSHOTTED under the same lock: a reader thread admitting a
            # new session may reclaim a closed session's slot at any
            # moment, and a slot flipping to -1 between the filter and the
            # mask build would corrupt another live slot's row.
            items = [(s, f, s.slot) for s, f in items if s.slot >= 0]
        if items:
            self._ensure_arena(items[0][1].payload.meta.d)
        self._apply_arena_ops(ops)      # serialized with the step here
        if not items:
            return
        self.batch_sizes.append(len(items))
        self._m_fill.observe(len(items))
        if trace:
            ts0 = self.clock.monotonic()
        t0 = time.perf_counter()
        by_meta: Dict = {}
        for i, (_, frame, _slot) in enumerate(items):
            by_meta.setdefault(frame.payload.meta, []).append(i)
        active = np.zeros(self.arena.capacity, bool)
        for _, _, slot in items:
            active[slot] = True
        if len(by_meta) == 1:
            # single-meta flush: ONE fused dispatch — decode lands in
            # xbuf[slots] and the donated whole-arena step runs in the
            # same program; only the (capacity, 1) token rows come back
            [(meta, idxs)] = by_meta.items()
            stacked, slots = self._stack_group(
                meta, [items[i][1].payload for i in idxs],
                np.fromiter((self.arena.wire_row(items[i][2])
                             for i in idxs), np.int64, len(idxs)),
                self._bucket(len(idxs)))
            if trace:
                ts1 = self.clock.monotonic()
            t1 = time.perf_counter()
            tokens, self.arena.xbuf, self.arena.cache = self._fused_step(
                self.params, self.arena.xbuf, stacked, slots,
                self.arena.cache, jnp.asarray(active))
        else:
            # mixed-meta flush: per-meta device decodes, then the donated
            # step over the whole arena — no cache stack/unstack either way
            for meta, idxs in by_meta.items():
                self._decode_group(
                    meta, [items[i][1].payload for i in idxs],
                    np.fromiter((self.arena.wire_row(items[i][2])
                                 for i in idxs), np.int64, len(idxs)))
            if trace:
                ts1 = self.clock.monotonic()
            t1 = time.perf_counter()
            tokens, self.arena.cache = self.top_step(
                self.params, self.arena.xbuf, self.arena.cache,
                jnp.asarray(active))
        tokens = np.asarray(tokens)
        if trace:
            ts2 = self.clock.monotonic()
        t2 = time.perf_counter()
        for sess, frame, slot in items:
            # with a pod axis, the token row returned on the inverse ring
            # to the slot's ingestion block (SlotArena.wire_row; identity
            # otherwise)
            reply = wire.encode_token_frame(sess.id, frame.seq,
                                            tokens[self.arena.wire_row(slot)])
            sess.last_seq, sess.last_reply = frame.seq, reply
            sess.endpoint.send(reply)
            self._count_frame_down(sess, len(reply))
        t3 = time.perf_counter()
        self.stage_s["decode"] += t1 - t0
        self.stage_s["step"] += t2 - t1
        self.stage_s["reply"] += t3 - t2
        self.stage_tokens += len(items)
        if trace:
            ts3 = self.clock.monotonic()
            n = len(items)
            self.tracer.complete(SPAN_DECODE, ts0, ts1, tid=SERVE_TID, n=n)
            self.tracer.complete(SPAN_STEP, ts1, ts2, tid=SERVE_TID, n=n)
            self.tracer.complete(SPAN_REPLY, ts2, ts3, tid=SERVE_TID, n=n)
