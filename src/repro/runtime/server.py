"""Streaming server — the label owner serving N concurrent sessions.

One reader thread per connection parses `core.wire` frames off the byte
transport and feeds a `BatchingQueue`; the single serve loop flushes the
queue under the max-batch/max-wait policy, decodes each payload *batch* once
(grouped by payload meta, so a mixed dense/randtopk client population still
gets batched decodes), and runs one vmapped top-model step over the whole
flush — every session row against its own KV cache and position. Token
replies stream back as frames; per-session byte accounting is taken from the
real frame sizes at receipt.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core.payload import Payload
from repro.runtime.batching import BatchingQueue
from repro.runtime.session import Session
from repro.split import protocol


class StreamingServer:
    """Top-model serving engine over framed byte channels."""

    def __init__(self, params, top_step: Callable, make_cache: Callable,
                 *, max_batch: int = 8, max_wait: float = 0.01,
                 dtype=jnp.float32):
        self.params = params
        self.top_step = jax.jit(top_step)
        self.make_cache = make_cache        # () -> fresh batch-1 cache pytree
        self.dtype = dtype
        self.queue = BatchingQueue(max_batch, max_wait)
        self.sessions: Dict[int, Session] = {}
        self.batch_sizes: List[int] = []    # flush fill history
        self._lock = threading.Lock()
        self._readers: List[threading.Thread] = []
        self._open_readers = 0
        self.errors: List[BaseException] = []   # reader-thread failures

    # -- connection handling -------------------------------------------------

    def attach(self, endpoint) -> threading.Thread:
        """Register a client channel and start its frame-reader thread."""
        with self._lock:
            self._open_readers += 1
        t = threading.Thread(target=self._read_loop, args=(endpoint,),
                             daemon=True)
        self._readers.append(t)
        t.start()
        return t

    def _read_loop(self, endpoint) -> None:
        try:
            while True:
                frame = endpoint.recv_frame(timeout=0.1)
                if frame is None:
                    continue
                if frame.kind == wire.FRAME_CLOSE:
                    with self._lock:
                        if frame.session in self.sessions:
                            self.sessions[frame.session].closed = True
                    return
                assert frame.kind == wire.FRAME_PAYLOAD, frame.kind
                sess = self._session_for(frame.session, endpoint)
                sess.stats.count_up(frame.header_nbytes, frame.payload_nbytes)
                self.queue.put((sess, frame))
        except BaseException as e:      # surfaced by engine.run_streaming
            with self._lock:
                self.errors.append(e)
        finally:
            with self._lock:
                self._open_readers -= 1
                last = self._open_readers == 0
            if last:
                self.queue.close()          # serve loop drains, then exits

    def _session_for(self, sid: int, endpoint) -> Session:
        with self._lock:
            sess = self.sessions.get(sid)
            if sess is None:
                sess = Session(id=sid, cache=self.make_cache(),
                               endpoint=endpoint)
                self.sessions[sid] = sess
            return sess

    # -- serving -------------------------------------------------------------

    def serve_loop(self) -> None:
        """Flush/process until every connection has closed and drained."""
        while True:
            batch = self.queue.get_batch(idle_timeout=0.05)
            if batch:
                self._process(batch)
            elif self.queue.drained:
                return

    def _process(self, items) -> None:
        self.batch_sizes.append(len(items))
        xs: List = [None] * len(items)
        by_meta: Dict = {}
        for i, (_, frame) in enumerate(items):
            by_meta.setdefault(frame.payload.meta, []).append(i)
        # decode each payload batch ONCE: stack wire leaves across sessions
        for meta, idxs in by_meta.items():
            leaves = {
                name: np.stack(
                    [getattr(items[i][1].payload, name) for i in idxs])
                for name, _ in items[idxs[0]][1].payload.wire_leaves()}
            stacked = Payload(meta=meta, **leaves)
            dense = np.asarray(protocol.server_decode(stacked,
                                                      dtype=self.dtype))
            for row, i in enumerate(idxs):
                xs[i] = dense[row]
        # pad the flush to max_batch so the vmapped step compiles once
        pad = self.queue.max_batch - len(items)
        caches = [sess.cache for sess, _ in items] + \
                 [items[0][0].cache] * pad
        xs = xs + [xs[0]] * pad
        cache_stack = jax.tree.map(lambda *a: jnp.stack(a), *caches)
        tokens, new_caches = self.top_step(self.params, jnp.asarray(
            np.stack(xs)), cache_stack)
        tokens = np.asarray(tokens)
        for i, (sess, _) in enumerate(items):
            sess.cache = jax.tree.map(lambda a, i=i: a[i], new_caches)
            reply = wire.encode_token_frame(sess.id, sess.seq, tokens[i])
            sess.seq += 1
            sess.endpoint.send(reply)
            sess.stats.count_down(len(reply))
