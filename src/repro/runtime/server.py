"""Streaming server — the label owner serving N concurrent sessions.

One reader thread per connection parses `core.wire` frames off the byte
transport and feeds a `BatchingQueue`; the single serve loop flushes the
queue under the max-batch/max-wait policy, decodes each payload *batch* once
(grouped by payload meta, so a mixed dense/randtopk client population still
gets batched decodes), and runs one vmapped top-model step over the whole
flush — every session row against its own KV cache and position. Token
replies stream back as frames; per-session byte accounting is taken from the
real frame sizes at receipt.

Fault tolerance: a malformed frame (typed `wire.WireError` — CRC failure,
bad counts, truncation) no longer kills a reader thread silently. The reader
replies with an `error` frame naming the defect and retires the connection;
the *session* survives, and the client reconnects over a fresh channel and
replays from its last unacknowledged sequence number. Stop-and-wait dedup in
the serve loop (`Session.last_seq` / `last_reply`) re-acks replayed frames
without re-running the top-model step, so a KV cache never double-advances.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core.payload import Payload
from repro.runtime.batching import BatchingQueue
from repro.runtime.session import Session
from repro.split import protocol


class FrameServerBase:
    """Connection plumbing shared by the serving and training servers:
    one reader thread per attached channel, typed rejection of malformed
    frames with an `error` frame + connection retire (never a dead
    thread), a session registry that survives reconnects, and the
    queue-close lifecycle.

    Subclasses call `_init_connections` from __init__, implement
    `_new_session(sid, endpoint)`, and set `direction` (the label protocol
    violations are reported under).
    """

    direction = "serving"

    def _init_connections(self, queue: BatchingQueue) -> None:
        self.queue = queue
        self.sessions: Dict[int, Session] = {}
        self._lock = threading.Lock()
        self._readers: List[threading.Thread] = []
        self._open_readers = 0
        self.errors: List[BaseException] = []   # reader-thread failures
        self.faults_detected = 0    # malformed frames rejected (connections
        #                             retired with an error frame, not dead)
        self.expected_sessions: int = 0     # set by the engine; the serve
        #   loop must not stop before this many sessions exist AND closed
        #   (a corrupt first frame can retire a connection before its
        #   session was ever created — the reconnect needs a live queue)

    def _new_session(self, sid: int, endpoint) -> Session:
        raise NotImplementedError

    def attach(self, endpoint) -> threading.Thread:
        """Register a client channel and start its frame-reader thread.

        Called once per client at startup and again for each reconnect — a
        resuming client gets a fresh connection onto its existing session.
        """
        with self._lock:
            self._open_readers += 1
        t = threading.Thread(target=self._read_loop, args=(endpoint,),
                             daemon=True)
        self._readers.append(t)
        t.start()
        return t

    def shutdown(self) -> None:
        """Close the admission queue; the serve loop drains, then exits.
        The engine calls this after every client thread has finished — the
        guaranteed stop even if a session's CLOSE frame was lost in chaos."""
        self.queue.close()

    def _reject(self, endpoint, sid_seen, exc: wire.WireError) -> None:
        """Name the defect in an error frame and retire the connection,
        keeping the session (the client reconnects and replays). A fault
        before any valid frame has no session to charge."""
        with self._lock:
            self.faults_detected += 1
            sess = (self.sessions.get(sid_seen)
                    if sid_seen is not None else None)
            if sess is not None:
                sess.stats.faults_detected += 1
        endpoint.send(wire.encode_error_frame(
            sid_seen if sid_seen is not None else 0, 0,
            wire.error_code(exc), str(exc)))

    def _read_loop(self, endpoint) -> None:
        sid_seen = None             # session observed on THIS connection
        try:
            while True:
                try:
                    frame = endpoint.recv_frame(timeout=0.1)
                except wire.WireError as e:
                    self._reject(endpoint, sid_seen, e)
                    return
                if frame is None:
                    continue
                if frame.kind == wire.FRAME_CLOSE:
                    with self._lock:
                        if frame.session in self.sessions:
                            self.sessions[frame.session].closed = True
                    return
                if frame.kind == wire.FRAME_ERROR:
                    return              # peer abandoned this connection
                if frame.kind != wire.FRAME_PAYLOAD:
                    raise wire.WireError(
                        f"unexpected frame kind {frame.kind} on the "
                        f"{self.direction} up direction")
                sid_seen = frame.session
                sess = self._session_for(frame.session, endpoint)
                sess.stats.count_up(frame.header_nbytes, frame.payload_nbytes)
                try:
                    self.queue.put((sess, frame))
                except RuntimeError:
                    return              # server shut down under us
        except wire.WireError as e:     # protocol violation from a valid frame
            self._reject(endpoint, sid_seen, e)
        except BaseException as e:      # surfaced by the engine
            with self._lock:
                self.errors.append(e)
        finally:
            with self._lock:
                self._open_readers -= 1
                # natural completion: every connection retired AND every
                # expected session exists and closed. A reader retired by a
                # fault (possibly before its session was even created)
                # holds the queue open for the reconnect; the engine's
                # shutdown() after the client joins is the backstop.
                done = (self._open_readers == 0
                        and len(self.sessions) >= self.expected_sessions
                        and all(s.closed for s in self.sessions.values()))
            if done:
                self.queue.close()          # serve loop drains, then exits

    def _session_for(self, sid: int, endpoint) -> Session:
        with self._lock:
            sess = self.sessions.get(sid)
            if sess is None:
                sess = self._new_session(sid, endpoint)
                self.sessions[sid] = sess
            else:
                sess.endpoint = endpoint    # replies follow the latest conn
            return sess


class StreamingServer(FrameServerBase):
    """Top-model serving engine over framed byte channels."""

    def __init__(self, params, top_step: Callable, make_cache: Callable,
                 *, max_batch: int = 8, max_wait: float = 0.01,
                 dtype=jnp.float32):
        self.params = params
        self.top_step = jax.jit(top_step)
        self.make_cache = make_cache        # () -> fresh batch-1 cache pytree
        self.dtype = dtype
        self.batch_sizes: List[int] = []    # flush fill history
        self._init_connections(BatchingQueue(max_batch, max_wait))

    def _new_session(self, sid: int, endpoint) -> Session:
        return Session(id=sid, cache=self.make_cache(), endpoint=endpoint)

    # -- serving -------------------------------------------------------------

    def serve_loop(self) -> None:
        """Flush/process until every connection has closed and drained."""
        while True:
            batch = self.queue.get_batch(idle_timeout=0.05)
            if batch:
                self._process(batch)
            elif self.queue.drained:
                return

    def _dedup(self, items) -> List:
        """Stop-and-wait ARQ filter: the client never has two frames in
        flight, so any seq above the last processed one is fresh progress
        and anything at or below it is a replay. A replay of the last
        processed seq is re-acked from the cached reply bytes (the step
        must NOT re-run — it would advance the KV cache again); anything
        older is dropped. Both cases count as duplicates.
        """
        fresh = []
        for sess, frame in items:
            if frame.seq > sess.last_seq:
                fresh.append((sess, frame))
                continue
            sess.stats.duplicates += 1
            if frame.seq == sess.last_seq and sess.last_reply is not None:
                sess.endpoint.send(sess.last_reply)
                sess.stats.count_down(len(sess.last_reply))
        return fresh

    def _process(self, items) -> None:
        items = self._dedup(items)
        if not items:
            return
        self.batch_sizes.append(len(items))
        xs: List = [None] * len(items)
        by_meta: Dict = {}
        for i, (_, frame) in enumerate(items):
            by_meta.setdefault(frame.payload.meta, []).append(i)
        # decode each payload batch ONCE: stack wire leaves across sessions
        for meta, idxs in by_meta.items():
            leaves = {
                name: np.stack(
                    [getattr(items[i][1].payload, name) for i in idxs])
                for name, _ in items[idxs[0]][1].payload.wire_leaves()}
            stacked = Payload(meta=meta, **leaves)
            dense = np.asarray(protocol.server_decode(stacked,
                                                      dtype=self.dtype))
            for row, i in enumerate(idxs):
                xs[i] = dense[row]
        # pad the flush to max_batch so the vmapped step compiles once
        pad = self.queue.max_batch - len(items)
        caches = [sess.cache for sess, _ in items] + \
                 [items[0][0].cache] * pad
        xs = xs + [xs[0]] * pad
        cache_stack = jax.tree.map(lambda *a: jnp.stack(a), *caches)
        tokens, new_caches = self.top_step(self.params, jnp.asarray(
            np.stack(xs)), cache_stack)
        tokens = np.asarray(tokens)
        for i, (sess, frame) in enumerate(items):
            sess.cache = jax.tree.map(lambda a, i=i: a[i], new_caches)
            reply = wire.encode_token_frame(sess.id, frame.seq, tokens[i])
            sess.last_seq, sess.last_reply = frame.seq, reply
            sess.endpoint.send(reply)
            sess.stats.count_down(len(reply))
