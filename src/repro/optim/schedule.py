"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr, total_steps, min_frac=0.1):
    def lr(step):
        t = jnp.minimum(step / max(1, total_steps), 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 *
                          (1 + jnp.cos(jnp.pi * t)))
    return lr


def linear_warmup_cosine(base_lr, warmup, total_steps, min_frac=0.05):
    cos = cosine_schedule(base_lr, max(1, total_steps - warmup), min_frac)

    def lr(step):
        warm = base_lr * step / max(1, warmup)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return lr
