"""AdamW + SGD in plain JAX (f32 moments over any-dtype params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0, grad_clip=1.0):
    step = opt_state["step"] + 1
    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.zeros((), jnp.float32)

    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                opt_state["mu"], grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                opt_state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, gnorm


def sgd_init(params):
    return {"mom": jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32)}


def sgd_update(params, grads, opt_state, *, lr, momentum=0.9):
    mom = jax.tree_util.tree_map(
        lambda m, g: momentum * m + g.astype(jnp.float32),
        opt_state["mom"], grads)
    new_params = jax.tree_util.tree_map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, mom)
    return new_params, {"mom": mom, "step": opt_state["step"] + 1}
