"""Feature-owner training client — the paper's bottom-model party, live.

One `TrainingClient` owns a shard of the training features, its bottom
model, and its optimizer. Each step it runs the bottom forward, compresses
the cut activation through `split.protocol.client_encode` (the same half
the serving runtime uses), frames it as `core.wire` bytes, and — on sync
steps — blocks for the server's `grad` frame, decodes the compressed cut
gradient back onto the forward support (`protocol.client_grad_decode`), and
pulls it through the bottom VJP. The wire is byte-literal in both
directions: every counter in `self.stats` is the length of a real framed
byte string. The grad route is keyed on the *forward* payload's kind and
indices leaf, so every wire kind — including `mask`, whose indices leaf
is the packed support bitmask the decode re-expands with — works without
per-kind client code (tests/test_fedtrain.py pins randtopk_mask ==
randtopk step for step).

Policies plug in at two points:

  * `KScheduler` (schedule.py) picks the per-sync-step (k, bits); the
    resulting compressor object keys a small jit cache, and the server needs
    no notice because frames are self-describing.
  * `AsyncPolicy` (async_policy.py) decides which steps sync at all; local
    steps train against the cached stale gradient and never touch the wire.

Recovery is the stop-and-wait ARQ loop of `runtime.arq.ArqClientMixin`
(shared with the serving client): each sync step's frame carries the step
as sequence number, the grad reply echoes it, and the client retransmits on
timeout, drops stale duplicate replies, and reconnects + replays on an
`error` frame or a corrupt downstream. The server dedups by seq, so a
replayed step never double-steps the top optimizer.

Optional error feedback keeps a per-client mean-residual vector `e in R^d`
(the batch mean of what compression dropped), added to the next batch's
activations pre-encode — the weakest-state SL analogue of EF memory; the
honest caveats live in docs/beyond-paper.md. All trainer state (params,
optimizer moments, PRNG key, EF residual, stale gradient, schedule state,
byte counters) round-trips through `state()`/`load_state` for
`checkpoint.store`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressors as C, wire
from repro.fedtrain.async_policy import AsyncPolicy
from repro.fedtrain.schedule import KScheduler
from repro.obs.trace import NULL_TRACER, SPAN_CLIENT_ENCODE, session_tid
from repro.optim import adamw_init, adamw_update
from repro.runtime.arq import ArqClientMixin
from repro.runtime.session import SessionStats
from repro.split import protocol, tabular


class TrainingClient(ArqClientMixin):
    """One feature owner driving its training shard over the wire."""

    _reply_kind = wire.FRAME_GRAD

    def __init__(self, cid: int, spec: tabular.SplitSpec, x_shard: np.ndarray,
                 batch_ids: List[np.ndarray], endpoint, *, seed: int,
                 scheduler: Optional[KScheduler] = None,
                 policy: Optional[AsyncPolicy] = None, ef: bool = False,
                 barrier=None, ckpt_every: int = 0,
                 reply_timeout: float = 120.0,
                 retry_timeout: Optional[float] = None,
                 max_retries: int = 16, reconnect=None,
                 tracer=NULL_TRACER, registry=None):
        self.id = cid
        self.tracer = tracer
        if registry is not None:        # else: the mixin's process default
            self.registry = registry
        self.spec = spec
        self.x = np.asarray(x_shard, np.float32)
        self.batch_ids = batch_ids          # one index array per local step
        self.endpoint = endpoint
        self.scheduler = scheduler
        self.policy = policy or AsyncPolicy()
        self.ef = ef
        self.barrier = barrier
        self.ckpt_every = ckpt_every
        self.reply_timeout = reply_timeout
        self.retry_timeout = retry_timeout  # None -> never retransmit
        self.max_retries = max_retries
        self.reconnect = reconnect          # () -> fresh endpoint

        self.start_step = 0
        self.end_step = len(batch_ids)
        self.stats = SessionStats()
        self.losses: list = []              # (step, loss) at sync steps
        self.k_trace: list = []             # (step, k, bits) at sync steps
        self.sync_count = 0                 # schedule clock (survives resume)
        self.analytic_up = 0.0              # compressor-accounting bytes
        self.analytic_down = 0.0
        self.error: Optional[BaseException] = None

        # same chain as split.tabular.train: init consumes key(seed), the
        # per-step subkeys split off the same root (N=1 parity is exact)
        key = jax.random.key(seed)
        self.bottom, _ = tabular.init_parties(key, spec)
        self.opt = adamw_init(self.bottom)
        self._key = key

        batch = len(batch_ids[0]) if batch_ids else 0
        self._stale = np.zeros((batch, spec.cut_dim), np.float32)
        self._has_stale = False
        self._ef_resid = np.zeros((spec.cut_dim,), np.float32)
        self._encode_cache: dict = {}
        self._update = jax.jit(self._make_update())
        # pre-bound hot-path instruments (one registry lookup per metric)
        reg = self.registry
        self._m_frames_up = reg.counter("frames_total", party="client",
                                        direction="up")
        self._m_payload_up = reg.counter("payload_bytes_total",
                                         party="client", direction="up")
        self._m_framing_up = reg.counter("framing_bytes_total",
                                         party="client", direction="up")
        self._m_frames_down = reg.counter("frames_total", party="client",
                                          direction="down")
        self._m_bytes_down = reg.counter("wire_bytes_total", party="client",
                                         direction="down")

    # -- jitted halves -------------------------------------------------------

    def _encode_fn(self, comp: C.Compressor):
        """Jitted bottom forward + encode half, one cache entry per
        compressor object (distinct (k, bits) -> distinct entry)."""
        fn = self._encode_cache.get(comp)
        if fn is None:
            ef = self.ef

            def encode(bottom, x, key, resid):
                o = tabular.bottom_fn(bottom, x)
                if ef:
                    o = o + resid[None, :]
                p = comp.encode(o, key=key, training=True)
                if ef:
                    dec = comp.decode(p, shape=o.shape, dtype=o.dtype)
                    resid = jnp.mean(o - dec, axis=0)
                return p, resid

            fn = self._encode_cache[comp] = jax.jit(encode)
        return fn

    def _make_update(self):
        spec = self.spec

        def update(bottom, opt, x, g_cut):
            o, vjp = jax.vjp(lambda bp: tabular.bottom_fn(bp, x), bottom)
            g = g_cut
            if spec.method == "l1":
                g = g + spec.l1_lam * jnp.sign(o) / x.shape[0]
            (db,) = vjp(g)
            new_b, new_opt, _ = adamw_update(bottom, db, opt, lr=spec.lr,
                                             grad_clip=0.0)
            return new_b, new_opt

        return update

    def _compressor(self, k: int, bits: int) -> C.Compressor:
        """(k, bits) from the schedule -> codec object. k >= d means the
        dense warmup phase (identity transfer); otherwise delegate to the
        shared SplitSpec dispatch with the scheduled (k, bits) swapped in."""
        spec = self.spec
        if spec.method in (None, "none") or (k >= spec.cut_dim
                                             and bits == 0):
            return C.Compressor()
        return tabular.spec_compressor(dataclasses.replace(
            spec, k=k, quant_bits=bits or spec.quant_bits))

    # -- the loop ------------------------------------------------------------

    def run(self) -> None:
        """Thread target; failures are recorded and surfaced by the engine."""
        try:
            self._run()
        except BaseException as e:
            self.error = e
            if self.barrier is not None:
                self.barrier.abort()    # don't deadlock healthy clients
        finally:
            self.endpoint.send(wire.encode_close_frame(self.id))

    def _count_reply(self, reply: wire.Frame) -> None:
        # grad replies keep the payload/framing split: their payload bytes
        # ARE the Table-2 bwd column
        self.stats.count_down_frame(reply.header_nbytes,
                                    reply.payload_nbytes)
        self._m_frames_down.inc()
        self._m_bytes_down.inc(reply.nbytes)

    def _sync_step(self, step: int, xb, sub) -> np.ndarray:
        spec = self.spec
        d = spec.cut_dim
        if self.scheduler is not None:
            k, bits = self.scheduler.k_bits(self.sync_count)
        else:
            k, bits = spec.k, spec.quant_bits
        self.sync_count += 1
        comp = self._compressor(min(k, d), bits)
        with self.tracer.span(SPAN_CLIENT_ENCODE, tid=session_tid(self.id),
                              step=step):
            p, resid = self._encode_fn(comp)(self.bottom, xb, sub,
                                             jnp.asarray(self._ef_resid))
            p = jax.tree.map(np.asarray, p)
        self._ef_resid = np.asarray(resid)

        fb = wire.encode_payload_frame(self.id, step, p)
        self.endpoint.send(fb)
        hb = wire.payload_frame_header_nbytes(p)
        self.stats.count_up(hb, len(fb) - hb)
        self._m_frames_up.inc()
        self._m_payload_up.inc(len(fb) - hb)
        self._m_framing_up.inc(hb)
        # L1's training transport is dense; its fwd_bits models the
        # worst-case nnz encoding, so account what actually crossed
        fwd_bits = (d * C.FLOAT_BITS if isinstance(comp, C.L1Reg)
                    else comp.fwd_bits(d))
        self.analytic_up += fwd_bits / 8 * xb.shape[0]

        reply = self._await_reply(step, fb, hb)
        self.analytic_down += comp.bwd_bits(d) / 8 * xb.shape[0]

        g_cut = np.asarray(protocol.client_grad_decode(
            reply.payload, fwd_kind=p.meta.kind, indices=p.indices, d=d))
        if self.scheduler is not None:
            self.scheduler.observe(reply.loss)
        self.losses.append((step, reply.loss))
        self.k_trace.append((step, min(k, d), bits))
        return g_cut

    def _run(self) -> None:
        for step in range(self.start_step, self.end_step):
            xb = jnp.asarray(self.x[self.batch_ids[step]])
            self._key, sub = jax.random.split(self._key)
            if self.policy.is_sync(step):
                g_cut = self._sync_step(step, xb, sub)
                self._stale, self._has_stale = g_cut, True
            else:
                assert self._has_stale, "local step before any sync"
                g_cut = self._stale     # stale cut gradient (Chen et al.)
            self.bottom, self.opt = self._update(self.bottom, self.opt, xb,
                                                 jnp.asarray(g_cut))
            if (self.barrier is not None and self.ckpt_every
                    and (step + 1) % self.ckpt_every == 0):
                self.barrier.wait()     # engine snapshots all parties here

    # -- checkpoint state ----------------------------------------------------

    def state(self) -> dict:
        s = self.stats
        return {
            "bottom": self.bottom, "opt": self.opt,
            "key": jax.random.key_data(self._key),
            "ef": self._ef_resid,
            "stale": self._stale,
            "has_stale": np.int32(self._has_stale),
            "sched": (self.scheduler.state() if self.scheduler else {}),
            # i32/f32: checkpoints restore through jnp, which truncates
            # 64-bit under the default x64-disabled config
            "counters": np.asarray(
                [s.frames_up, s.payload_bytes_up, s.header_bytes_up,
                 s.frames_down, s.bytes_down, s.payload_bytes_down,
                 s.header_bytes_down, self.sync_count], np.int32),
            "analytic": np.asarray([self.analytic_up, self.analytic_down],
                                   np.float32),
        }

    def load_state(self, st: dict) -> None:
        self.bottom = st["bottom"]
        self.opt = st["opt"]
        self._key = jax.random.wrap_key_data(jnp.asarray(st["key"]))
        self._ef_resid = np.asarray(st["ef"])
        self._stale = np.asarray(st["stale"])
        self._has_stale = bool(st["has_stale"])
        if self.scheduler is not None and st["sched"]:
            self.scheduler.load_state(st["sched"])
        c = np.asarray(st["counters"])
        (self.stats.frames_up, self.stats.payload_bytes_up,
         self.stats.header_bytes_up, self.stats.frames_down,
         self.stats.bytes_down, self.stats.payload_bytes_down,
         self.stats.header_bytes_down, self.sync_count) = (
            int(v) for v in c)
        self.analytic_up, self.analytic_down = (
            float(v) for v in np.asarray(st["analytic"]))
