"""Asynchronous local-update training policy for the feature owner.

The configurable analogue of *Communication and Computation Reduction for
Split Learning using Asynchronous Training* (Chen et al., 2021,
arXiv:2107.09786): instead of crossing the wire every step, a client only
*syncs* — sends the compressed cut activation up and blocks for the grad
frame — every `local_steps` steps, and trains its bottom model against the
**stale** cut gradient in between.

Staleness semantics (normative; docs/protocol.md "Training over the wire"):

  * A sync step caches the dense cut gradient decoded from the grad frame
    (scattered onto the forward support for sparse kinds).
  * Each of the following `local_steps - 1` *local* steps recomputes the
    bottom forward/VJP on its own fresh batch and pulls the cached gradient
    back through it. The stale gradient is per-sample, so pairing it with a
    different batch is an approximation — exactly the trade Chen et al.
    accept — bounded by `local_steps - 1` steps of staleness.
  * The label owner never sees local-step batches: the top model neither
    runs nor updates on them, so BOTH directions' wire traffic and the
    server's compute shrink by ~`local_steps`.

`warmup_sync` forces fully-synchronous training for the first N steps, when
the loss landscape moves too fast for stale gradients to point anywhere
useful.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AsyncPolicy:
    """When does a client step cross the wire? `local_steps=1` == fully
    synchronous split learning (the paper's setting)."""

    local_steps: int = 1
    warmup_sync: int = 0

    def __post_init__(self):
        assert self.local_steps >= 1 and self.warmup_sync >= 0

    def is_sync(self, step: int) -> bool:
        if step < self.warmup_sync:
            return True
        return (step - self.warmup_sync) % self.local_steps == 0
