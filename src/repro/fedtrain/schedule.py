"""Adaptive per-step (k, bits) scheduling for the training wire.

Folds the adaptive feature-wise compression idea of *Communication-Efficient
Split Learning via Adaptive Feature-Wise Compression* (Oh et al., 2023,
arXiv:2307.10805) into the fedtrain runtime as a client-side policy: the
compression intensity of the cut-layer payload is not a fixed hyperparameter
but a function of training progress — dense while representations are still
moving (warmup), sparser as they settle (anneal), and sparser still when the
loss plateaus (the activations carry less new information per step).

Because every wire frame is self-describing (`core.wire` subheaders carry
kind / d / k / bits), the label owner needs **no knowledge of the
schedule** — a per-step k change shows up on the server purely as a
different frame subheader, and the byte accounting measures whatever was
actually sent. The schedule is therefore a pure client-side object whose
state (current k, loss EMA, plateau counters) checkpoints alongside the
client's optimizer state.

Phases of `KScheduler` (each optional):

  1. warmup  — the first `warmup_steps` sync steps send the dense payload
               (k = d, no value quantization): early gradients touch every
               feature, and dense transfer keeps them exact.
  2. anneal  — k moves from `k0` (default d) to the target `k` over
               `anneal_steps`, quantized to at most 8 stages so the client's
               per-compressor jit cache stays small.
  3. adaptive — after the anneal, a loss-EMA plateau detector multiplies k
               by `drop` (floor `k_min`) whenever `patience` sync steps pass
               without a relative EMA improvement of `min_rel_improve`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: distinct anneal stages (bounds per-client recompiles during the anneal)
ANNEAL_STAGES = 8


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """Static schedule configuration; `KScheduler` carries the state."""

    k: int                      # target support after warmup + anneal
    d: int                      # cut width (dense warmup sends k = d)
    bits: int = 0               # value-quantization bits past warmup (0=off)
    warmup_steps: int = 0       # sync steps of dense (k = d) transfer
    anneal_steps: int = 0       # sync steps of k0 -> k anneal after warmup
    k0: int = 0                 # anneal start support (0 -> d)
    k_min: int = 0              # plateau-adaptation floor (0 = no adaptation)
    drop: float = 0.5           # multiplicative k drop on a loss plateau
    patience: int = 25          # sync steps without improvement before a drop
    min_rel_improve: float = 1e-3
    ema: float = 0.9            # loss EMA smoothing

    def __post_init__(self):
        assert 0 < self.k <= self.d
        assert 0 <= self.k_min <= self.k
        assert 0.0 < self.drop < 1.0


class EmaPlateau:
    """EMA-smoothed plateau detector — the one copy of the "has this
    signal stopped improving?" state machine shared by `KScheduler`
    (training loss) and `runtime.qos.QoSController` (queue pressure).

    `observe(x)` folds `x` into an EMA and returns True when `patience`
    consecutive observations have passed without the EMA improving
    (dropping) by a relative `min_rel_improve` over the best seen —
    resetting the baseline to the current EMA so consecutive plateaus can
    fire again. `smooth(x)` updates the EMA without plateau tracking (the
    detector's counters stay frozen, exactly the pre-refactor behavior of
    a scheduler at its floor).
    """

    def __init__(self, ema: float, min_rel_improve: float, patience: int):
        self.ema = ema
        self.min_rel_improve = min_rel_improve
        self.patience = patience
        self.value = float("nan")
        self.best = float("inf")
        self.since = 0

    def smooth(self, x: float) -> float:
        self.value = (x if np.isnan(self.value)
                      else self.ema * self.value + (1 - self.ema) * x)
        return self.value

    def observe(self, x: float) -> bool:
        self.smooth(x)
        if self.value < self.best * (1 - self.min_rel_improve):
            self.best = self.value
            self.since = 0
            return False
        self.since += 1
        if self.since >= self.patience:
            self.since = 0
            self.best = self.value
            return True
        return False

    # checkpointable state (numpy scalars, `checkpoint.store`-compatible)

    def state(self) -> dict:
        return {"ema": np.float32(self.value),
                "best": np.float32(self.best),
                "since": np.int32(self.since)}

    def load_state(self, st: dict) -> None:
        self.value = float(st["ema"])
        self.best = float(st["best"])
        self.since = int(st["since"])


class KScheduler:
    """Stateful (k, bits) schedule — one per `TrainingClient`."""

    def __init__(self, spec: ScheduleSpec):
        self.spec = spec
        self.cur_k = spec.k         # plateau-adapted target
        self._plateau = EmaPlateau(spec.ema, spec.min_rel_improve,
                                   spec.patience)

    @property
    def ema_loss(self) -> float:
        return self._plateau.value

    def k_bits(self, step: int) -> tuple:
        """(k, bits) to encode sync step `step` with. k == d means dense."""
        s = self.spec
        if step < s.warmup_steps:
            return s.d, 0
        t = step - s.warmup_steps
        if t < s.anneal_steps:
            k0 = s.k0 or s.d
            stages = min(ANNEAL_STAGES, s.anneal_steps)
            stage = min(stages - 1, t * stages // s.anneal_steps)
            frac = (stage + 1) / stages
            k = int(round(k0 + (self.cur_k - k0) * frac))
            return max(self.cur_k, k), s.bits
        return self.cur_k, s.bits

    def observe(self, loss: float) -> None:
        """Feed back one sync step's loss (from the grad frame)."""
        s = self.spec
        if not s.k_min or s.k_min >= self.cur_k:
            self._plateau.smooth(loss)      # EMA tracks, counters frozen
            return
        if self._plateau.observe(loss):
            self.cur_k = max(s.k_min, int(self.cur_k * s.drop))

    # -- checkpoint state ----------------------------------------------------

    def state(self) -> dict:
        return {"cur_k": np.int32(self.cur_k), **self._plateau.state()}

    def load_state(self, st: dict) -> None:
        self.cur_k = int(st["cur_k"])
        self._plateau.load_state(st)
