"""Orchestration: N training clients + one label-owner server, over frames.

`run_fedtrain` is the training twin of `runtime.engine.run_streaming`: it
shards the dataset's features over N `TrainingClient`s (the label shard
stays with the `TrainingServer`), wires every party over in-process byte
channels, and runs split training with every cut activation and cut
gradient crossing as real `core.wire` frames — so the result's byte
accounting is measured, in both directions, and cross-checkable against the
compressors' Table-2 analytics.

Batch alignment: each client's batch-index stream is a deterministic
function of (seed + client id), generated up front; the server's
`labels_for(session, seq)` indexes the label shard through the same stream —
the simulation stand-in for the out-of-band sample-ID alignment of real
vertical deployments. With `n_clients=1` the stream, the parameter inits,
and the per-step PRNG chain reproduce `split.tabular.train` exactly, which
is what `tests/test_fedtrain.py` pins.

Checkpointing: with `ckpt_dir`/`ckpt_every`, all clients rendezvous on a
barrier every `ckpt_every` local steps; the barrier action (running while
every client is paused and the server queue is drained — sync steps are
blocking, so no frame is in flight) snapshots every party's trainer state
into one `checkpoint.store` file. A later call with the same config
auto-resumes from the latest step, restoring params, optimizer moments,
PRNG chains, EF residuals, stale gradients, schedule state, and byte
counters. `stop_after_steps` emulates a mid-run kill for the resume tests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.fedtrain.async_policy import AsyncPolicy
from repro.fedtrain.client import TrainingClient
from repro.fedtrain.schedule import KScheduler, ScheduleSpec
from repro.fedtrain.server import TrainingServer
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.optim import adamw_init
from repro.runtime import engine as runtime_engine
from repro.runtime.session import SessionStats
from repro.runtime.transport import channel_pair
from repro.split import tabular


def _batch_stream(n: int, batch: int, epochs: int, seed: int) -> List:
    """Deterministic per-client batch-index stream — replicates
    `data.synthetic.ManyClassDataset.batches` so n_clients=1 sees exactly
    the batches `split.tabular.train` would."""
    rng = np.random.RandomState(seed)
    ids = []
    for _ in range(epochs):
        idx = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            ids.append(idx[i: i + batch])
    return ids


def run_fedtrain(spec: tabular.SplitSpec, dataset, *, n_clients: int = 1,
                 epochs: int = 2, batch: int = 64, seed: int = 0,
                 schedule: Optional[ScheduleSpec] = None,
                 policy: Optional[AsyncPolicy] = None, ef: bool = False,
                 max_batch: Optional[int] = None, max_wait: float = 0.005,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 stop_after_steps: Optional[int] = None,
                 reply_timeout: float = 120.0, wrap_endpoint=None,
                 retry_timeout: Optional[float] = None,
                 max_retries: int = 16, tracer=None) -> dict:
    """Train `spec` over the wire; returns losses, accuracy, measured and
    analytic byte accounting for both directions, aggregated
    `fault_counters`, and the final params.

    `wrap_endpoint(cid, endpoint) -> endpoint` intercepts every client-side
    connection (initial + reconnect) — the hook
    `repro.testing.faults.FaultInjector` uses to run training under seeded
    chaos; `retry_timeout` enables stop-and-wait retransmission. `tracer`
    (an `obs.trace.Tracer`, default off) records encode/queue-wait spans;
    the result's `metrics` key is the run's private `MetricsRegistry`
    snapshot (docs/observability.md)."""
    # -- parties -------------------------------------------------------------
    tracer = tracer if tracer is not None else NULL_TRACER
    registry = MetricsRegistry()        # per-run, isolated
    _, top = tabular.init_parties(jax.random.key(seed), spec)
    server = TrainingServer(spec, top, adamw_init(top),
                            max_batch=max_batch or max(1, n_clients),
                            max_wait=max_wait,
                            tracer=tracer, registry=registry)
    server.expected_sessions = n_clients

    shards_x = [dataset.x_train[c::n_clients] for c in range(n_clients)]
    shards_y = [dataset.y_train[c::n_clients] for c in range(n_clients)]
    streams = [_batch_stream(len(shards_x[c]), batch, epochs, seed + c)
               for c in range(n_clients)]
    n_steps = min(len(s) for s in streams)
    assert n_steps > 0, "shard smaller than one batch"
    streams = [s[:n_steps] for s in streams]    # barrier-aligned step counts
    server.labels_for = lambda sid, seq: shards_y[sid][streams[sid][seq]]

    barrier = None
    ckpt_steps: List[int] = []
    if ckpt_dir and ckpt_every:
        clients_box: List[TrainingClient] = []   # filled below

        def _save_action():
            step = ckpt_steps.pop(0)
            tree = {"clients": {str(c.id): c.state() for c in clients_box},
                    "server": server.state()}
            store.save(ckpt_dir, step, tree)

        barrier = threading.Barrier(n_clients, action=_save_action)

    def _connect(cid: int):
        """One client connection (also the reconnect path): fresh channel
        pair, server reader attached, client half optionally wrapped."""
        cep, sep = channel_pair()
        server.attach(sep)
        return wrap_endpoint(cid, cep) if wrap_endpoint else cep

    clients: List[TrainingClient] = []
    for cid in range(n_clients):
        clients.append(TrainingClient(
            cid, spec, shards_x[cid], streams[cid], _connect(cid),
            seed=seed + cid,
            scheduler=KScheduler(schedule) if schedule else None,
            policy=policy, ef=ef, barrier=barrier, ckpt_every=ckpt_every,
            reply_timeout=reply_timeout, retry_timeout=retry_timeout,
            max_retries=max_retries,
            reconnect=lambda cid=cid: _connect(cid),
            tracer=tracer, registry=registry))
    if barrier is not None:
        clients_box.extend(clients)

    # -- resume --------------------------------------------------------------
    start_step = 0
    if ckpt_dir:
        last = store.latest_step(ckpt_dir)
        if last >= 0:
            like = {"clients": {str(c.id): c.state() for c in clients},
                    "server": server.state()}
            restored = store.restore(ckpt_dir, last, like)
            for c in clients:
                c.load_state(restored["clients"][str(c.id)])
            server.load_state(restored["server"])
            start_step = last

    end_step = min(n_steps, stop_after_steps or n_steps)
    for c in clients:
        c.start_step, c.end_step = start_step, end_step
    if barrier is not None:
        ckpt_steps.extend(m for m in range(start_step + 1, end_step + 1)
                          if m % ckpt_every == 0)

    # -- run -----------------------------------------------------------------
    t0 = time.perf_counter()
    train_thread = threading.Thread(target=server.train_loop, daemon=True)
    train_thread.start()
    threads = [threading.Thread(target=c.run, daemon=True) for c in clients]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    # guaranteed stop even if a CLOSE frame was lost to injected faults
    server.shutdown()
    train_thread.join(timeout=120)
    wall = time.perf_counter() - t0

    if server.errors:
        raise RuntimeError(f"server reader threads failed: {server.errors}") \
            from server.errors[0]
    errs = [(c.id, c.error) for c in clients if c.error is not None]
    if errs:
        raise RuntimeError(f"training clients failed: {errs}") from errs[0][1]

    # -- evaluate + account --------------------------------------------------
    accs = []
    for c in clients:
        spec_eval = spec
        if c.scheduler is not None:
            spec_eval = dataclasses.replace(spec, k=c.scheduler.cur_k)
        accs.append(tabular.evaluate(c.bottom, server.top, spec_eval,
                                     jax.numpy.asarray(dataset.x_test),
                                     jax.numpy.asarray(dataset.y_test)))

    cstats = [c.stats.as_dict() for c in clients]
    # a fully-resumed run (start == end, e.g. rerun after completion) sends
    # only CLOSE frames, so the server may hold no session for a client
    sstats = [(server.sessions[c.id].stats.as_dict()
               if c.id in server.sessions else SessionStats().as_dict())
              for c in clients]
    return {
        "losses": [c.losses for c in clients],
        "k_trace": [c.k_trace for c in clients],
        "client_stats": cstats,
        "server_stats": sstats,
        "test_acc": accs,
        "mean_test_acc": float(np.mean(accs)),
        "payload_bytes_up": sum(s["payload_bytes_up"] for s in cstats),
        "payload_bytes_down": sum(s["payload_bytes_down"] for s in cstats),
        "header_bytes": sum(s["header_bytes_up"] + s["header_bytes_down"]
                            for s in cstats),
        "analytic_bytes_up": sum(c.analytic_up for c in clients),
        "analytic_bytes_down": sum(c.analytic_down for c in clients),
        "fault_counters": runtime_engine.fault_summary(server, clients),
        "metrics": registry.snapshot(),
        "final_k": [c.scheduler.cur_k if c.scheduler else spec.k
                    for c in clients],
        "steps": end_step,
        "n_clients": n_clients,
        "bottoms": [c.bottom for c in clients],
        "top": server.top,
        "wall_s": wall,
    }
