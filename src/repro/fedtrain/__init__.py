"""Federated split-training runtime — the training direction over the wire.

The serving runtime (`repro.runtime`) moves compressed activations up and
tokens down; this package closes the paper's actual loop: activations up,
compressed cut **gradients** down (`core.wire` `grad` frames), with the
party boundary realized as an explicit `jax.vjp` on each side. Layering:
`client` runs bottom models + the `split.protocol` encode half and applies
returned gradients; `server` batches via `runtime.batching`, runs top model
+ loss, and streams grad frames back; `schedule` adapts per-step (k, bits)
to training progress (Oh et al. 2023); `async_policy` trades staleness for
communication (Chen et al. 2021); `engine.run_fedtrain` orchestrates,
checkpoints every party through `checkpoint.store`, and accounts both
directions' bytes from real frames.
"""
from repro.fedtrain.async_policy import AsyncPolicy
from repro.fedtrain.client import TrainingClient
from repro.fedtrain.engine import run_fedtrain
from repro.fedtrain.schedule import KScheduler, ScheduleSpec
from repro.fedtrain.server import TrainingServer

__all__ = ["AsyncPolicy", "KScheduler", "ScheduleSpec", "TrainingClient",
           "TrainingServer", "run_fedtrain"]
