"""Label-owner training server — the top model + loss across the wire.

One reader thread per client connection parses `core.wire` frames into a
`runtime.batching.BatchingQueue` (the same admission policy the serving
runtime uses); the single train loop flushes the queue and, for each
received activation frame in arrival order, decodes the self-described
payload to the dense cut view (`protocol.server_decode`), runs the top
model + loss with an explicit `jax.vjp` — the party boundary is literal,
no autodiff shortcut through the wire — updates the top optimizer, and
streams the compressed cut gradient back as a `grad` frame
(`protocol.server_grad_encode` + `wire.encode_grad_frame`, which also
carries the scalar step loss the client's schedule feeds on).

Top-model updates are applied sequentially in flush arrival order: with one
client this is exactly the paper's alternating two-party loop (and
bit-for-bit reproducible); with N clients the flush amortizes queue/host
overhead while updates interleave by arrival. Labels never cross the wire —
the engine hands the server a `labels_for(session, seq)` view of the
label-owner's shard, aligned with the clients' deterministic batch streams
(the stand-in for the sample-ID alignment real VFL deployments do out of
band).
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.optim import adamw_update
from repro.runtime.batching import BatchingQueue
from repro.runtime.session import Session
from repro.split import protocol, tabular


class TrainingServer:
    """Top-model training engine over framed byte channels."""

    def __init__(self, spec: tabular.SplitSpec, top, opt, *,
                 max_batch: int = 4, max_wait: float = 0.005):
        self.spec = spec
        self.top = top
        self.opt = opt
        self.queue = BatchingQueue(max_batch, max_wait)
        self.sessions: Dict[int, Session] = {}
        self.batch_sizes: List[int] = []
        self.step_count = 0
        self.labels_for: Callable = None    # set by the engine
        self.errors: List[BaseException] = []
        self._lock = threading.Lock()
        self._open_readers = 0
        self._step = jax.jit(self._make_step())

    def _make_step(self):
        spec = self.spec

        def step(top, opt, view, y):
            (loss, _), vjp = jax.vjp(
                lambda tp, o: tabular.top_fn(tp, o, y), top, view)
            dtp, dview = vjp((jnp.ones(()),
                              jnp.zeros((view.shape[0], spec.n_classes))))
            new_t, new_ot, _ = adamw_update(top, dtp, opt, lr=spec.lr,
                                            grad_clip=0.0)
            return new_t, new_ot, loss, dview

        return step

    # -- connection handling (same shape as runtime.server) ------------------

    def attach(self, endpoint) -> threading.Thread:
        with self._lock:
            self._open_readers += 1
        t = threading.Thread(target=self._read_loop, args=(endpoint,),
                             daemon=True)
        t.start()
        return t

    def _read_loop(self, endpoint) -> None:
        try:
            while True:
                frame = endpoint.recv_frame(timeout=0.1)
                if frame is None:
                    continue
                if frame.kind == wire.FRAME_CLOSE:
                    with self._lock:
                        if frame.session in self.sessions:
                            self.sessions[frame.session].closed = True
                    return
                assert frame.kind == wire.FRAME_PAYLOAD, frame.kind
                sess = self._session_for(frame.session, endpoint)
                sess.stats.count_up(frame.header_nbytes, frame.payload_nbytes)
                self.queue.put((sess, frame))
        except BaseException as e:      # surfaced by engine.run_fedtrain
            with self._lock:
                self.errors.append(e)
        finally:
            with self._lock:
                self._open_readers -= 1
                last = self._open_readers == 0
            if last:
                self.queue.close()      # train loop drains, then exits

    def _session_for(self, sid: int, endpoint) -> Session:
        with self._lock:
            sess = self.sessions.get(sid)
            if sess is None:
                sess = Session(id=sid, cache=None, endpoint=endpoint)
                self.sessions[sid] = sess
            return sess

    # -- training ------------------------------------------------------------

    def train_loop(self) -> None:
        """Flush/process until every client connection closed and drained."""
        while True:
            batch = self.queue.get_batch(idle_timeout=0.05)
            if batch:
                self._process(batch)
            elif self.queue.drained:
                return

    def _process(self, items) -> None:
        self.batch_sizes.append(len(items))
        for sess, frame in items:
            view = jnp.asarray(protocol.server_decode(frame.payload))
            y = jnp.asarray(self.labels_for(sess.id, frame.seq))
            self.top, self.opt, loss, dview = self._step(
                self.top, self.opt, view, y)
            gp = protocol.server_grad_encode(frame.payload,
                                             np.asarray(dview))
            gf = wire.encode_grad_frame(sess.id, frame.seq, gp, float(loss))
            sess.endpoint.send(gf)
            sess.stats.count_down_frame(wire.grad_frame_header_nbytes(gp),
                                        len(gf)
                                        - wire.grad_frame_header_nbytes(gp))
            self.step_count += 1

    # -- checkpoint state ----------------------------------------------------

    def state(self) -> dict:
        return {"top": self.top, "opt": self.opt}

    def load_state(self, st: dict) -> None:
        self.top = st["top"]
        self.opt = st["opt"]
