"""Label-owner training server — the top model + loss across the wire.

One reader thread per client connection parses `core.wire` frames into a
`runtime.batching.BatchingQueue` (the same admission policy the serving
runtime uses); the single train loop flushes the queue and, for each
received activation frame in arrival order, decodes the self-described
payload to the dense cut view ON DEVICE (`protocol.server_decode_device`:
only the compressed wire leaves cross host->device, the scatter/dequant
runs under jit), runs the top
model + loss with an explicit `jax.vjp` — the party boundary is literal,
no autodiff shortcut through the wire — updates the top optimizer, and
streams the compressed cut gradient back as a `grad` frame
(`protocol.server_grad_encode` + `wire.encode_grad_frame`, which also
carries the scalar step loss the client's schedule feeds on).

Top-model updates are applied sequentially in flush arrival order: with one
client this is exactly the paper's alternating two-party loop (and
bit-for-bit reproducible); with N clients the flush amortizes queue/host
overhead while updates interleave by arrival. Labels never cross the wire —
the engine hands the server a `labels_for(session, seq)` view of the
label-owner's shard, aligned with the clients' deterministic batch streams
(the stand-in for the sample-ID alignment real VFL deployments do out of
band).

Fault tolerance mirrors `runtime.server`: malformed frames are rejected with
a typed `error` frame and a connection retire (never a dead thread), the
session survives for the client's reconnect, and stop-and-wait dedup by
sequence number re-acks replayed steps from the cached grad frame — the top
optimizer never double-steps, which is what keeps the faulted loss
trajectory bit-identical to the clean one.
"""
from __future__ import annotations

from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.obs.trace import NULL_TRACER, SPAN_QUEUE_WAIT, session_tid
from repro.optim import adamw_update
from repro.runtime.batching import BatchingQueue
from repro.runtime.server import FrameServerBase
from repro.runtime.session import Session
from repro.split import protocol, tabular


class TrainingServer(FrameServerBase):
    """Top-model training engine over framed byte channels."""

    direction = "training"

    def __init__(self, spec: tabular.SplitSpec, top, opt, *,
                 max_batch: int = 4, max_wait: float = 0.005,
                 tracer=NULL_TRACER, registry=None):
        self.spec = spec
        self.top = top
        self.opt = opt
        self.batch_sizes: List[int] = []
        self.step_count = 0
        self.labels_for: Callable = None    # set by the engine
        self._init_connections(BatchingQueue(max_batch, max_wait),
                               tracer=tracer, registry=registry)
        self._step = jax.jit(self._make_step())

    def _new_session(self, sid: int, endpoint) -> Session:
        return Session(id=sid, cache=None, endpoint=endpoint)

    def _make_step(self):
        spec = self.spec

        def step(top, opt, view, y):
            (loss, _), vjp = jax.vjp(
                lambda tp, o: tabular.top_fn(tp, o, y), top, view)
            dtp, dview = vjp((jnp.ones(()),
                              jnp.zeros((view.shape[0], spec.n_classes))))
            new_t, new_ot, _ = adamw_update(top, dtp, opt, lr=spec.lr,
                                            grad_clip=0.0)
            return new_t, new_ot, loss, dview

        return step

    # -- training ------------------------------------------------------------
    # (connection handling — attach/readers/rejection/sessions — is
    # inherited from runtime.server.FrameServerBase)

    def train_loop(self) -> None:
        """Flush/process until every client connection closed and drained."""
        while True:
            batch = self.queue.get_batch(idle_timeout=0.05)
            if batch:
                self._process(batch)
            elif self.queue.drained:
                return

    def _process(self, items) -> None:
        kept = 0
        # pop every enqueue stamp (leaks otherwise) into the queue-wait
        # histogram/span — the training twin of StreamingServer._process
        t_flush = self.queue.clock.monotonic()
        trace = self.tracer.enabled
        for sess, frame in items:
            t_enq = self._enq_ts.pop((sess.id, frame.seq), None)
            if t_enq is None:
                continue
            self._m_qwait.observe((t_flush - t_enq) * 1e3)
            if trace:
                self.tracer.complete(SPAN_QUEUE_WAIT, t_enq, t_flush,
                                     tid=session_tid(sess.id), sid=sess.id,
                                     seq=frame.seq)
        self._m_depth.set(len(self.queue))
        for sess, frame in items:
            # stop-and-wait dedup: the client never has two frames in
            # flight, so any seq above the last processed one is fresh
            # progress (async local steps and checkpoint resume both skip
            # seqs); anything at or below it is a replay and must NOT
            # re-run the top update (the optimizer would double-step) —
            # re-ack the latest from cache instead.
            if frame.seq <= sess.last_seq:
                sess.stats.duplicates += 1
                self._m_dups.inc()
                if (frame.seq == sess.last_seq
                        and sess.last_reply is not None):
                    sess.endpoint.send(sess.last_reply)
                    sess.stats.count_down_frame(
                        sess.last_reply_header,
                        len(sess.last_reply) - sess.last_reply_header)
                    self._m_frames_down.inc()
                    self._m_bytes_down.inc(len(sess.last_reply))
                continue
            kept += 1
            # device-side decode: the dense cut view never exists on host
            view = protocol.server_decode_device(frame.payload)
            y = jnp.asarray(self.labels_for(sess.id, frame.seq))
            self.top, self.opt, loss, dview = self._step(
                self.top, self.opt, view, y)
            gp = protocol.server_grad_encode(frame.payload,
                                             np.asarray(dview))
            gf = wire.encode_grad_frame(sess.id, frame.seq, gp, float(loss))
            sess.last_seq, sess.last_reply = frame.seq, gf
            sess.last_reply_header = wire.grad_frame_header_nbytes(gp)
            sess.endpoint.send(gf)
            sess.stats.count_down_frame(sess.last_reply_header,
                                        len(gf) - sess.last_reply_header)
            self._m_frames_down.inc()
            self._m_bytes_down.inc(len(gf))
            self.step_count += 1
        if kept:
            self.batch_sizes.append(kept)
            self._m_fill.observe(kept)

    # -- checkpoint state ----------------------------------------------------

    def state(self) -> dict:
        return {"top": self.top, "opt": self.opt}

    def load_state(self, st: dict) -> None:
        self.top = st["top"]
        self.opt = st["opt"]
