"""Serving driver — a thin CLI over the streaming runtime.

Spins up N simulated clients (feature owners, `--clients`; `--batch` is an
alias), each holding the bottom model and compressing its cut activations,
against one batching server holding the top model (`repro.runtime`). Every
cut payload crosses an in-process byte channel as `core.wire` frames, so the
reported bytes/client/token are measured frame sizes, cross-checked here
against the Table-2 analytic prediction.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --clients 8 --prompt-len 16 --gen 32 --split randtopk --k 16
"""
from __future__ import annotations

import argparse

import numpy as np

import repro.configs as configs
from repro.models.config import SplitConfig
from repro.runtime import engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--clients", "--batch", dest="clients", type=int,
                    default=4, help="concurrent client sessions")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--split", default=None)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="server flush size (default min(8, clients))")
    ap.add_argument("--max-wait", type=float, default=0.01,
                    help="server batching window in seconds")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    if args.split:
        cfg = cfg.with_(split=SplitConfig(
            cut_layer=max(1, cfg.n_layers // 2), compressor=args.split,
            k=args.k))

    res = engine.run_streaming(
        cfg, n_clients=args.clients, prompt_len=args.prompt_len,
        gen=args.gen, max_batch=args.max_batch, max_wait=args.max_wait)

    out = res["tokens"]
    fills = res["batch_sizes"]
    print(f"served {args.clients} sessions x {args.gen} tokens in "
          f"{res['wall_s']:.2f}s ({res['tokens_per_s']:.1f} tok/s, "
          f"mean batch fill {np.mean(fills):.1f}/{res['max_batch']})")

    # measured vs analytic wire bytes, per client per token
    per_client = [s["payload_bytes_up"] / s["frames_up"]
                  for s in res["client_stats"]]
    header = [s["header_bytes_up"] / s["frames_up"]
              for s in res["client_stats"]]
    comp = res["compressor_objs"][0]
    analytic = comp.fwd_bits(cfg.d_model) / 8  # models quant headers too
    print(f"cut-layer wire: {np.mean(per_client):.1f} B/client/token "
          f"measured payload (+{np.mean(header):.1f} B framing) vs "
          f"{analytic:.1f} B analytic ({comp.name}) vs "
          f"{cfg.d_model * 4} B uncompressed")
    print("sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
