"""Serving driver: batched greedy decoding against a KV cache, with the
split-learning cut compression applied to every generated token's forward
payload (the paper's inference-communication target).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 16 --gen 32 --split randtopk --k 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.launch.steps import make_serve_step
from repro.models import transformer
from repro.models.config import Runtime, SplitConfig
from repro.split import protocol


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--split", default=None)
    ap.add_argument("--k", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    if args.split:
        cfg = cfg.with_(split=SplitConfig(
            cut_layer=max(1, cfg.n_layers // 2), compressor=args.split,
            k=args.k))
    rt = Runtime(mesh=None, training=False)
    params = transformer.init_model(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.gen
    cache = transformer.init_cache(params, cfg, rt, args.batch, max_len)
    serve = jax.jit(make_serve_step(cfg, rt), donate_argnums=(1,))

    prompt = jax.random.randint(jax.random.key(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab,
                                dtype=jnp.int32)
    # prefill token-by-token through the decode path (cache warm-up)
    tok = prompt[:, :1]
    for i in range(args.prompt_len):
        nxt, cache = serve(params, cache, prompt[:, i: i + 1])
    generated = [nxt]
    t0 = time.time()
    for _ in range(args.gen - 1):
        nxt, cache = serve(params, cache, generated[-1])
        generated.append(nxt)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    per_tok = 0.0
    if cfg.split:
        per_tok = protocol.wire_bytes_per_step(cfg, args.batch, 1,
                                               training=False)
        measured = protocol.measured_payload_bytes(cfg, args.batch, 1,
                                                   training=False)
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({dt/max(1, args.gen-1)*1e3:.1f} ms/token)")
    if cfg.split:
        print(f"cut-layer wire: {per_tok:.0f} B/token-batch analytic, "
              f"{measured} B measured payload "
              f"({cfg.split.compressor}, k={cfg.split.k}) vs "
              f"{cfg.d_model*4*args.batch:.0f} B uncompressed")
    print("sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
