"""Serving driver — a thin CLI over the streaming runtime.

Spins up N simulated clients (feature owners, `--clients`; `--batch` is an
alias), each holding the bottom model and compressing its cut activations,
against one batching server holding the top model (`repro.runtime`). Every
cut payload crosses an in-process byte channel as `core.wire` frames, so the
reported bytes/client/token are measured frame sizes, cross-checked here
against the Table-2 analytic prediction.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --clients 8 --prompt-len 16 --gen 32 --split randtopk --k 16

`--loadgen` switches the driver to the open-loop production-traffic
harness (`repro.runtime.loadgen`, docs/serving-slo.md): seeded Poisson or
MMPP-burst session arrivals over the same stack under a virtual clock,
graded against a declared SLO, optionally with the congestion-adaptive
(k, bits) QoS controller:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --split randtopk --k 16 --loadgen --arrival mmpp --rate 22 \
        --duration 10 --slo-p99-ms 60 --qos
"""
from __future__ import annotations

import argparse

import numpy as np

import repro.configs as configs
from repro.models.config import SplitConfig
from repro.obs.export import write_trace
from repro.obs.trace import Tracer
from repro.runtime import engine
from repro.runtime.loadgen import (ArrivalSpec, FleetSpec, LoadGenConfig,
                                   SLOSpec, run_loadgen)
from repro.runtime.qos import QoSSpec


def _run_loadgen(cfg, args) -> None:
    qos = None
    if args.qos:
        qos = QoSSpec(k=args.k, d=cfg.d_model, k_floor=args.k_floor,
                      high_depth=6, low_depth=2,
                      deadline_s=args.slo_p99_ms / 1e3 / 2,
                      patience=16, cooldown=1)
    lg = LoadGenConfig(
        seed=args.seed, duration_s=args.duration,
        arrivals=ArrivalSpec(process=args.arrival, rate=args.rate,
                             burst_rate=args.burst_rate),
        fleet=FleetSpec(compressors=(f"{args.split or 'randtopk'}:"
                                     f"k={args.k}",)
                        if args.split != "identity" else ("identity",),
                        prompt_len=(2, max(2, args.prompt_len)),
                        gen=(2, max(2, args.gen)),
                        bandwidth_Bps=args.bandwidth),
        slo=SLOSpec(p99_ms=args.slo_p99_ms,
                    max_reject_frac=args.max_reject_frac),
        qos=qos, capacity=args.capacity,
        max_batch=args.max_batch or 8, max_wait=args.max_wait,
        admission_depth=args.admission_depth)
    rep = run_loadgen(cfg, lg, trace_path=args.trace)
    if args.trace:
        print(f"trace: {rep['trace_events']} events -> {args.trace} "
              f"(load in https://ui.perfetto.dev)")
    lat, s = rep["latency_ms"], rep["sessions"]
    print(f"loadgen: {s['arrived']} arrivals over "
          f"{rep['virtual_duration_s']:.1f}s virtual "
          f"({rep['wall_s_real']:.1f}s real), {s['completed']} completed, "
          f"{s['rejected']} rejected, {s['failed']} failed")
    print(f"goodput {rep['goodput_tok_per_s']:.1f} tok/s; latency p50 "
          f"{lat['p50_ms']:.1f} / p95 {lat['p95_ms']:.1f} / p99 "
          f"{lat['p99_ms']:.1f} ms (streaming P2 p99 "
          f"{lat['p2_p99_ms']:.1f}); queue depth max "
          f"{rep['queue_depth']['max']}")
    if rep["qos"]["enabled"]:
        print(f"qos: ladder {rep['qos']['ladder']}, "
              f"{rep['qos']['switches']} rung switches, "
              f"level hist {rep['qos']['level_hist']}")
    print(f"SLO {'MET' if rep['slo']['ok'] else 'VIOLATED'}: "
          f"{rep['slo']['checks']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--clients", "--batch", dest="clients", type=int,
                    default=4, help="concurrent client sessions")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--split", default=None)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="server flush size (default min(8, clients))")
    ap.add_argument("--max-wait", type=float, default=0.01,
                    help="server batching window in seconds")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record the frame lifecycle and write Chrome-trace"
                         " JSON here (Perfetto-loadable, "
                         "docs/observability.md)")
    lgrp = ap.add_argument_group("loadgen", "open-loop traffic + SLO mode")
    lgrp.add_argument("--loadgen", action="store_true",
                      help="run the open-loop load generator instead of "
                           "the closed-loop client fleet")
    lgrp.add_argument("--arrival", choices=("poisson", "mmpp"),
                      default="poisson")
    lgrp.add_argument("--rate", type=float, default=20.0,
                      help="session arrivals per second (calm state)")
    lgrp.add_argument("--burst-rate", type=float, default=0.0,
                      help="mmpp burst arrival rate (0 = 2x --rate)")
    lgrp.add_argument("--duration", type=float, default=10.0,
                      help="virtual seconds of arrivals")
    lgrp.add_argument("--seed", type=int, default=0)
    lgrp.add_argument("--slo-p99-ms", type=float, default=100.0)
    lgrp.add_argument("--max-reject-frac", type=float, default=0.02)
    lgrp.add_argument("--qos", action="store_true",
                      help="congestion-adaptive (k, bits) ladder")
    lgrp.add_argument("--k-floor", type=int, default=4)
    lgrp.add_argument("--capacity", type=int, default=32,
                      help="arena slots / max concurrent sessions")
    lgrp.add_argument("--admission-depth", type=int, default=48,
                      help="reject arrivals above this queue backlog")
    lgrp.add_argument("--bandwidth", type=float, default=400_000.0,
                      help="per-client link bytes/s (0 = infinite)")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    if args.split:
        cfg = cfg.with_(split=SplitConfig(
            cut_layer=max(1, cfg.n_layers // 2), compressor=args.split,
            k=args.k))

    if args.loadgen:
        return _run_loadgen(cfg, args)

    tracer = Tracer() if args.trace else None
    res = engine.run_streaming(
        cfg, n_clients=args.clients, prompt_len=args.prompt_len,
        gen=args.gen, max_batch=args.max_batch, max_wait=args.max_wait,
        tracer=tracer)
    if tracer is not None:
        n = write_trace(tracer, args.trace)
        print(f"trace: {n} events -> {args.trace} "
              f"(load in https://ui.perfetto.dev)")

    out = res["tokens"]
    fills = res["batch_sizes"]
    print(f"served {args.clients} sessions x {args.gen} tokens in "
          f"{res['wall_s']:.2f}s ({res['tokens_per_s']:.1f} tok/s, "
          f"mean batch fill {np.mean(fills):.1f}/{res['max_batch']})")

    # measured vs analytic wire bytes, per client per token
    per_client = [s["payload_bytes_up"] / s["frames_up"]
                  for s in res["client_stats"]]
    header = [s["header_bytes_up"] / s["frames_up"]
              for s in res["client_stats"]]
    comp = res["compressor_objs"][0]
    analytic = comp.fwd_bits(cfg.d_model) / 8  # models quant headers too
    print(f"cut-layer wire: {np.mean(per_client):.1f} B/client/token "
          f"measured payload (+{np.mean(header):.1f} B framing) vs "
          f"{analytic:.1f} B analytic ({comp.name}) vs "
          f"{cfg.d_model * 4} B uncompressed")
    print("sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
