"""Federated split-training driver — a thin CLI over `repro.fedtrain`.

Spins up N feature-owner training clients against one label-owner server,
every cut activation and cut gradient crossing an in-process byte channel
as `core.wire` frames, and reports the measured dual-direction wire bytes
against the compressors' Table-2 accounting.

    PYTHONPATH=src python -m repro.launch.fedtrain --clients 2 \
        --method randtopk --k 9 --epochs 3 --schedule adaptive

    # async local steps (Chen et al. 2021): sync every --local-steps
    PYTHONPATH=src python -m repro.launch.fedtrain --local-steps 4
"""
from __future__ import annotations

import argparse

from repro.data.synthetic import ManyClassDataset
from repro.fedtrain import AsyncPolicy, ScheduleSpec, run_fedtrain
from repro.split.tabular import SplitSpec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--method", default="randtopk",
                    help="none|topk|randtopk|size_reduction|quant|"
                         "randtopk_quant|l1")
    ap.add_argument("--k", type=int, default=9)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--classes", type=int, default=20)
    ap.add_argument("--train-n", type=int, default=2560)
    ap.add_argument("--cut-dim", type=int, default=64)
    ap.add_argument("--schedule", default="fixed",
                    choices=["fixed", "adaptive"],
                    help="adaptive: warmup-dense -> anneal -> plateau drops")
    ap.add_argument("--warmup", type=int, default=0,
                    help="dense warmup sync steps (adaptive schedule)")
    ap.add_argument("--local-steps", type=int, default=1,
                    help=">1 enables async local steps on a stale gradient")
    ap.add_argument("--ef", action="store_true",
                    help="per-client mean-residual error feedback")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args(argv)

    ds = ManyClassDataset(n_classes=args.classes, in_dim=32,
                          n_train=args.train_n, n_test=1024, noise=0.3,
                          seed=args.seed)
    spec = SplitSpec(in_dim=32, hidden=128, cut_dim=args.cut_dim,
                     n_classes=args.classes, method=args.method, k=args.k,
                     alpha=args.alpha, quant_bits=args.bits, lr=args.lr)
    schedule = None
    if args.schedule == "adaptive":
        schedule = ScheduleSpec(k=args.k, d=args.cut_dim,
                                warmup_steps=args.warmup,
                                anneal_steps=8, k0=min(args.cut_dim,
                                                       2 * args.k),
                                k_min=max(1, args.k // 2))
    policy = (AsyncPolicy(local_steps=args.local_steps, warmup_sync=8)
              if args.local_steps > 1 else None)

    res = run_fedtrain(spec, ds, n_clients=args.clients, epochs=args.epochs,
                       batch=args.batch, seed=args.seed, schedule=schedule,
                       policy=policy, ef=args.ef, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every)

    up, down = res["payload_bytes_up"], res["payload_bytes_down"]
    print(f"trained {args.clients} clients x {res['steps']} steps "
          f"({args.method}, schedule={args.schedule}, "
          f"local_steps={args.local_steps}) in {res['wall_s']:.1f}s")
    for cid, losses in enumerate(res["losses"]):
        if not losses:      # rerun of an already-completed checkpoint dir
            print(f"  client {cid}: nothing left to train")
            continue
        first, last = losses[0][1], losses[-1][1]
        print(f"  client {cid}: loss {first:.3f} -> {last:.3f} "
              f"({len(losses)} sync steps), final_k={res['final_k'][cid]}")
    print(f"wire: {up} B up / {down} B down measured payload "
          f"(+{res['header_bytes']} B framing) vs "
          f"{res['analytic_bytes_up']:.0f} / {res['analytic_bytes_down']:.0f}"
          f" B analytic")
    print(f"test acc {res['mean_test_acc']:.4f}, "
          f"{res['mean_test_acc'] / ((up + down) / 1e6):.3f} acc/MB")
    return res


if __name__ == "__main__":
    main()
