"""Input shapes, abstract (ShapeDtypeStruct) input specs, and sharding trees
for every (architecture x input-shape) dry-run combination.

Nothing here allocates device memory: params/optimizer/cache shapes come from
`jax.eval_shape`, inputs are ShapeDtypeStructs, and shardings are derived from
the logical param/cache spec trees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer
from repro.models.config import ArchConfig, Runtime
from repro.optim import adamw_init


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# Sub-quadratic handling of long_500k (DESIGN.md §Shape skips):
#   ssm/hybrid run natively (recurrent state); attention-bearing archs run
#   the sliding-window variant (window 8192) which we implement first-class.
LONG_CTX_WINDOW = 8192


def adapt_config(cfg: ArchConfig, shape: ShapeSpec) -> ArchConfig:
    """Per-shape architecture adaptation (e.g. sliding window for 500k)."""
    if shape.name == "long_500k" and cfg.family != "ssm":
        return cfg.with_(sliding_window=LONG_CTX_WINDOW)
    return cfg


def dp_only_spec(spec: P) -> P:
    """ZeRO-3 param layout: drop TP ('model' -> None) and widen FSDP
    ('data' -> ('data','model')) so params are fully sharded over the whole
    mesh and SPMD all-gathers them per use."""
    out = []
    for entry in tuple(spec):
        if entry == "model":
            out.append(None)
        elif entry == "data":
            out.append(("data", "model"))
        else:
            out.append(entry)
    return P(*out)


def spec_to_shardings(spec_tree, mesh, *, dp_only=False):
    def conv(s):
        if dp_only:
            s = dp_only_spec(s)
        return NamedSharding(mesh, s)

    return jax.tree_util.tree_map(
        conv, spec_tree, is_leaf=lambda s: isinstance(s, P))


def _sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes that do not divide the argument dimension (pjit arg
    shardings must divide exactly; internal constraints may still repartition
    unevenly). E.g. a 4-way-GQA KV cache on a 16-way model axis, or batch=1
    on the data axis, degrade to replicated on that dim."""
    out = []
    for i, entry in enumerate(tuple(spec)):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(entry if shape[i] % n == 0 else None)
    return P(*out)


def sanitize_shardings(sharding_tree, abstract_tree, mesh):
    """Leaf-wise divisibility repair of NamedSharding trees vs arg shapes."""
    def fix(sh, ab):
        if not isinstance(sh, NamedSharding):
            return sh
        return NamedSharding(mesh, _sanitize_spec(sh.spec, ab.shape, mesh))

    return jax.tree_util.tree_map(fix, sharding_tree, abstract_tree)


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: transformer.init_model(jax.random.key(0), cfg))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, rt: Runtime) -> Dict:
    """ShapeDtypeStructs for the training/prefill batch."""
    B, S = shape.batch, shape.seq
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    out = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), cfg.adtype())
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), cfg.adtype())
    return out


def batch_shardings(cfg: ArchConfig, rt: Runtime) -> Dict:
    bp = rt.pspec("batch", None)
    out = {"tokens": bp, "labels": bp}
    if cfg.family == "vlm":
        out["patches"] = rt.pspec("batch", None, None)
    if cfg.family == "audio":
        out["frames"] = rt.pspec("batch", None, None)
    return spec_to_shardings(out, rt.mesh) if rt.mesh else out


def opt_shardings(param_spec_tree, mesh, *, dp_only=False):
    """AdamW moments share the param specs; step is replicated."""
    return {
        "mu": spec_to_shardings(param_spec_tree, mesh, dp_only=dp_only),
        "nu": spec_to_shardings(param_spec_tree, mesh, dp_only=dp_only),
        "step": NamedSharding(mesh, P()),
    }


def train_specs(cfg: ArchConfig, shape: ShapeSpec, rt: Runtime):
    """(arg_shapes, in_shardings, out_shardings_hint) for train_step."""
    params_abs = abstract_params(cfg)
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    pspec = transformer.param_spec(cfg)
    mesh = rt.mesh
    p_sh = sanitize_shardings(
        spec_to_shardings(pspec, mesh, dp_only=rt.dp_only), params_abs, mesh)
    o_sh = sanitize_shardings(opt_shardings(pspec, mesh, dp_only=rt.dp_only),
                              opt_abs, mesh)
    b_abs = batch_specs(cfg, shape, rt)
    b_sh = sanitize_shardings(batch_shardings(cfg, rt), b_abs, mesh)
    args = (params_abs, opt_abs, b_abs)
    in_sh = (p_sh, o_sh, b_sh)
    return args, in_sh


def decode_specs(cfg: ArchConfig, shape: ShapeSpec, rt: Runtime):
    """(arg_shapes, in_shardings) for serve_step (one token w/ cache)."""
    params_abs = abstract_params(cfg)
    pspec = transformer.param_spec(cfg)
    mesh = rt.mesh
    B = shape.batch
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), cfg.adtype())
    if cfg.family == "audio":
        extras["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), cfg.adtype())
    cache_abs = jax.eval_shape(
        lambda p, e: transformer.init_cache(p, cfg, rt, B, shape.seq, e),
        params_abs, extras)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    c_sh = sanitize_shardings(
        spec_to_shardings(transformer.cache_spec(cfg, rt), mesh), cache_abs,
        mesh)
    p_sh = sanitize_shardings(spec_to_shardings(pspec, mesh), params_abs,
                              mesh)
    t_sh = sanitize_shardings(
        NamedSharding(mesh, rt.pspec("batch", None)), token, mesh)
    args = (params_abs, cache_abs, token)
    in_sh = (p_sh, c_sh, t_sh)
    return args, in_sh
