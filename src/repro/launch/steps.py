"""jit-able train / serve step builders shared by trainer, dry-run, benches."""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ArchConfig, Runtime
from repro.optim import adamw_update
from repro.split import model as split_model

AUX_WEIGHT = 0.01  # MoE balance-loss weight


def loss_fn(params, cfg: ArchConfig, rt: Runtime, batch, key):
    logits, aux = split_model.forward(params, cfg, rt, batch, key=key)
    ce = transformer.cross_entropy(logits, batch["labels"], rt)
    return ce + AUX_WEIGHT * aux, (ce, aux)


def make_train_step(cfg: ArchConfig, rt: Runtime, *, lr=3e-4,
                    weight_decay=0.0, internal_key=False) -> Callable:
    def _step(params, opt_state, batch, key):
        (total, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, rt, batch, key)
        new_params, new_opt, gnorm = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay)
        metrics = {"loss": total, "ce": ce, "aux": aux, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    if not internal_key:
        return _step

    def train_step(params, opt_state, batch):
        # deterministic per-step key; keeps the jit signature key-free
        key = jax.random.fold_in(jax.random.key(0), opt_state["step"])
        return _step(params, opt_state, batch, key)

    return train_step


def make_serve_step(cfg: ArchConfig, rt: Runtime) -> Callable:
    def serve_step(params, cache, token):
        logits, new_cache = split_model.decode_step(params, cfg, rt, token,
                                                    cache)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_token[:, None], new_cache

    return serve_step


def make_eval_step(cfg: ArchConfig, rt: Runtime) -> Callable:
    def eval_step(params, batch):
        logits, _ = split_model.forward(params, cfg, rt, batch, key=None)
        ce = transformer.cross_entropy(logits, batch["labels"], rt)
        acc = jnp.mean(
            (jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return {"ce": ce, "acc": acc}

    return eval_step
