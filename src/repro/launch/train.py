"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 200 --batch 8 --seq 128 --split randtopk --k 16

Runs a real training loop (synthetic pipeline, AdamW, checkpointing every
--ckpt-every steps) on whatever devices exist; with --mesh d,m it builds a
(data, model) mesh over the host devices.
"""
from __future__ import annotations

import argparse

import jax

import repro.configs as configs
from repro.checkpoint import latest_step, restore, save
from repro.data.pipeline import TokenPipeline
from repro.launch.steps import make_eval_step, make_train_step
from repro.models import transformer
from repro.models.common import count_params
from repro.models.config import Runtime, SplitConfig
from repro.optim import adamw_init
from repro.testing.clock import Clock, SYSTEM_CLOCK


def main(argv=None, *, clock: Clock = SYSTEM_CLOCK):
    """CLI entry; `clock` is the injectable time source every elapsed-time
    print reads (`testing.clock`) — wall time by default, a `VirtualClock`
    in tests so logged timings are deterministic instead of machine noise."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--split", default=None)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--cut", type=int, default=0)
    ap.add_argument("--selection-backend", default=None,
                    choices=["auto", "xla", "pallas"],
                    help="top-k selection backend (default: pallas on TPU, "
                         "xla elsewhere)")
    ap.add_argument("--mesh", default=None, help="e.g. 2,4 for (data,model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    if args.split:
        cut = args.cut or max(1, cfg.n_layers // 2)
        if cfg.family == "vlm":
            g = cfg.cross_attn_every
            cut = max(g, cut // g * g)
        cfg = cfg.with_(split=SplitConfig(cut_layer=cut,
                                          compressor=args.split, k=args.k,
                                          alpha=args.alpha,
                                          backend=args.selection_backend))
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh

        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    rt = Runtime(mesh=mesh, training=True)

    params = transformer.init_model(jax.random.key(0), cfg)
    opt = adamw_init(params)
    print(f"arch={cfg.name} params={count_params(params):,} "
          f"devices={jax.device_count()} split={cfg.split}")
    if cfg.split:
        from repro.split import protocol

        analytic = protocol.wire_bytes_per_step(cfg, args.batch, args.seq,
                                                training=True)
        measured = protocol.measured_payload_bytes(cfg, args.batch, args.seq,
                                                   training=False,
                                                   key=jax.random.key(3))
        print(f"cut-layer wire/step: {analytic:.0f} B analytic (fwd+bwd), "
              f"{measured} B measured fwd payload "
              f"(dense fwd would be {args.batch*args.seq*cfg.d_model*4} B)")

    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last >= 0:
            params = restore(args.ckpt_dir, last, params)
            opt = restore(args.ckpt_dir + "/opt", last, opt)
            start = last
            print(f"restored step {last}")

    pipe = TokenPipeline(cfg, args.batch, args.seq, rt=rt)
    step_fn = jax.jit(make_train_step(cfg, rt, lr=args.lr),
                      donate_argnums=(0, 1))
    t0 = clock.monotonic()
    for step in range(start, args.steps):
        batch = pipe.next_batch(step)
        key = jax.random.fold_in(jax.random.key(1), step)
        params, opt, metrics = step_fn(params, opt, batch, key)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"step {step:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                  f"gnorm={m['grad_norm']:.2f} "
                  f"({(clock.monotonic()-t0):.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, params)
            save(args.ckpt_dir + "/opt", step + 1, opt)
    return params


if __name__ == "__main__":
    main()
