"""Production mesh construction. A function — importing this module never
touches jax device state."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """`jax.make_mesh` across jax versions: newer releases want explicit
    Auto axis_types; 0.4.x predates the argument (everything is Auto)."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=axis_types)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    return make_mesh(shape, axes)


def make_serving_mesh(n_devices=None, *, model: int = 1, pod: int = 1):
    """Mesh for the sharded serving arena (docs/sharding.md): axes
    ('data', 'model') — with a leading 'pod' when `pod > 1` — where the
    data extent soaks up every device not claimed by `model`/`pod`. Arena
    slots shard over all axes; the lm head is vocab-parallel over 'model';
    a pod ring carries the cut activation across the pod boundary."""
    n = n_devices if n_devices is not None else len(jax.devices())
    if n % (model * pod):
        raise ValueError(f"{n} devices not divisible by model={model} x "
                         f"pod={pod}")
    data = n // (model * pod)
    if pod > 1:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
