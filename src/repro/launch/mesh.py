"""Production mesh construction. A function — importing this module never
touches jax device state."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """`jax.make_mesh` across jax versions: newer releases want explicit
    Auto axis_types; 0.4.x predates the argument (everything is Auto)."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=axis_types)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    return make_mesh(shape, axes)
