import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           # keep bf16 dots/collectives bf16 (TPU semantics);
                           # the host backend otherwise upcasts to f32
                           "--xla_allow_excess_precision=false")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analyses, and emit roofline rows.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --json out.json
"""
import argparse
import json
import sys
import traceback

import jax

import repro.configs as configs
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_serve_step, make_train_step
from repro.models.config import Runtime, SplitConfig
from repro.roofline import analysis
from repro.testing.clock import Clock, SYSTEM_CLOCK


def _cut_for(cfg):
    if cfg.family == "vlm":
        g = cfg.cross_attn_every
        return max(g, (cfg.n_layers // 2) // g * g)
    return max(1, cfg.n_layers // 2)


def build_config(arch: str, shape_name: str, *, split: str = None, k: int = 64,
                 alpha: float = 0.1, cut: int = -1):
    cfg = configs.get(arch)
    shape = specs_mod.SHAPES[shape_name]
    cfg = specs_mod.adapt_config(cfg, shape)
    if split:
        cut_layer = cut if cut > 0 else _cut_for(cfg)
        cfg = cfg.with_(split=SplitConfig(
            cut_layer=cut_layer, compressor=split, k=k, alpha=alpha))
    return cfg, shape


def lower_one(cfg, shape, mesh, *, runtime_kw=None):
    """Lower + compile one (cfg, shape, mesh). Returns (compiled, rt)."""
    kw = dict(runtime_kw or {})
    kw.setdefault("seq_shard", shape.kind != "decode")
    rt = Runtime(mesh=mesh, training=(shape.kind == "train"), **kw)
    with mesh:
        if shape.kind == "train":
            step = make_train_step(cfg, rt, internal_key=True)
            args, in_sh = specs_mod.train_specs(cfg, shape, rt)
            jitted = jax.jit(step, in_shardings=in_sh,
                             donate_argnums=(0, 1))
        elif shape.kind == "prefill":
            from repro.split import model as split_model

            def prefill(params, batch):
                logits, _ = split_model.forward(params, cfg, rt, batch,
                                                key=None)
                return logits

            p_abs = specs_mod.abstract_params(cfg)
            pspec = __import__("repro.models.transformer",
                               fromlist=["param_spec"]).param_spec(cfg)
            args = (p_abs, specs_mod.batch_specs(cfg, shape, rt))
            in_sh = (specs_mod.spec_to_shardings(pspec, mesh),
                     specs_mod.batch_shardings(cfg, rt))
            jitted = jax.jit(prefill, in_shardings=in_sh)
        else:  # decode
            step = make_serve_step(cfg, rt)
            args, in_sh = specs_mod.decode_specs(cfg, shape, rt)
            jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=(1,))
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled, rt


def run_combo(arch: str, shape_name: str, *, multi_pod=False, split=None,
              k=64, alpha=0.1, verbose=True, runtime_kw=None,
              clock: Clock = SYSTEM_CLOCK):
    """`clock` (`testing.clock`) feeds the compile-time report — injectable
    so tests can pin the printed timing deterministically."""
    cfg, shape = build_config(arch, shape_name, split=split, k=k, alpha=alpha)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = clock.monotonic()
    compiled, rt = lower_one(cfg, shape, mesh, runtime_kw=runtime_kw)
    dt = clock.monotonic() - t0
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    mf = analysis.model_flops(cfg, tokens=tokens,
                              training=(shape.kind == "train"))
    hlo_text = compiled.as_text()
    roof = analysis.from_compiled(
        compiled, arch=arch, shape=shape_name,
        mesh_desc="x".join(map(str, mesh.devices.shape)), chips=chips,
        model_flops=mf, hlo_text=hlo_text,
        bf16_target=(cfg.dtype == "bfloat16"))
    if verbose:
        mem = compiled.memory_analysis()
        print(f"== {arch} x {shape_name} mesh={roof.mesh} "
              f"(compile {dt:.1f}s) ==")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"out={mem.output_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
              f"alias={mem.alias_size_in_bytes/1e9:.2f}GB")
        r = roof.row()
        print(f"  cost_analysis: flops={r['hlo_flops']:.3e} "
              f"model_flops={r['model_flops']:.3e} "
              f"useful={r['useful_ratio']:.2f}")
        print(f"  roofline: compute={r['t_compute_s']*1e3:.2f}ms "
              f"memory={r['t_memory_s']*1e3:.2f}ms "
              f"collective={r['t_collective_s']*1e3:.2f}ms "
              f"-> {r['bottleneck']}-bound")
        print(f"  collectives: " + ", ".join(
            f"{op}={b/1e9:.2f}GB" for op, b in r["coll_detail"].items()))
    return roof


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--split", default=None,
                    help="cut-layer compressor (randtopk/topk/...)")
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in configs.ARCHS:
            for s in specs_mod.SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    rows, failures = [], []
    for arch, shape in combos:
        try:
            roof = run_combo(arch, shape, multi_pod=args.multi_pod,
                             split=args.split, k=args.k, alpha=args.alpha)
            rows.append(roof.row())
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((arch, shape, f"{type(e).__name__}: {e}"))

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "failures": failures}, f, indent=1)
    print(f"\n{len(rows)} OK, {len(failures)} FAILED")
    for a, s, e in failures:
        print(f"  FAIL {a} x {s}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
