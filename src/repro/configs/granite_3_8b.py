"""Granite-3-8B: dense GQA, 40L d=4096 32H kv=8 d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab=49155, rope_theta=1e4,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, param_dtype="float32", dtype="float32",
)
