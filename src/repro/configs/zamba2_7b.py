"""Zamba2-7B: 81 Mamba2 layers (d=3584, state=64) + SHARED attention block
(32H kv=32, d_ff=14336) applied every 6 layers. [arXiv:2411.15242]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_every=6, rope_theta=1e4,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab=512, ssm_state=16, ssm_head_dim=32, attn_every=2,
    param_dtype="float32", dtype="float32",
)
