"""RWKV6-1.6B ("Finch"): attention-free, 24L d=2048 d_ff=7168 vocab=65536,
data-dependent per-channel decay. [arXiv:2404.05892]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab=65536, rwkv=True, rwkv_lora=64,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab=512, rwkv_lora=16, param_dtype="float32", dtype="float32",
)
