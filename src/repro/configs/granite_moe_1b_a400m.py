"""Granite-MoE-1B-A400M: 24L d=1024 16H kv=8, 32 experts top-8, expert
d_ff=512, vocab 49155. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155, n_experts=32, topk_experts=8, rope_theta=1e4,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=128, vocab=512, n_experts=4, topk_experts=2,
    param_dtype="float32", dtype="float32",
)
