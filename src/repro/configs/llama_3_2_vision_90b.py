"""Llama-3.2-Vision-90B backbone: 100L total (80 self + 20 gated cross-attn,
one per 5), d=8192 64H kv=8 d_ff=28672 vocab=128256. Vision encoder STUBBED:
input_specs provides patch embeddings (B, 1601, d).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256, cross_attn_every=5, n_image_tokens=1601,
    rope_theta=5e5, param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, cross_attn_every=2, n_image_tokens=8,
    param_dtype="float32", dtype="float32",
)
