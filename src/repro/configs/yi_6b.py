"""Yi-6B: llama-arch dense GQA, 32L d=4096 32H kv=4 d_ff=11008 vocab=64000.
[arXiv:2403.04652]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab=64000, rope_theta=5e6,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, param_dtype="float32", dtype="float32",
)
