"""Qwen3-MoE-235B-A22B: 94L, d=4096, 64H (GQA kv=4, hd=128), 128 experts
top-8, expert d_ff=1536, vocab 151936, qk-norm. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, n_experts=128, topk_experts=8,
    qk_norm=True, rope_theta=1e6,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=128, vocab=512, n_experts=4, topk_experts=2,
    param_dtype="float32", dtype="float32",
)
