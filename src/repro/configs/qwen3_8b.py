"""Qwen3-8B: dense GQA with qk-norm, 36L d=4096 32H kv=8 d_ff=12288
vocab=151936. [hf:Qwen/Qwen3-8B]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151936, qk_norm=True, rope_theta=1e6,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, param_dtype="float32", dtype="float32",
)
