"""Phi-3-mini-3.8B: dense, 32L d=3072 32H kv=32 (MHA) d_ff=8192 vocab=32064,
RoPE + SwiGLU. [arXiv:2404.14219]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064, rope_theta=1e4,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab=512, param_dtype="float32", dtype="float32",
)
