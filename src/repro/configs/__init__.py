"""Architecture registry: the 10 assigned architectures (+ paper-scale
split-learning configs). Each module exposes FULL (the exact assigned
config) and SMOKE (a reduced same-family variant for CPU tests)."""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen3_moe_235b_a22b",
    "zamba2_7b",
    "granite_3_8b",
    "yi_6b",
    "granite_moe_1b_a400m",
    "rwkv6_1p6b",
    "llama_3_2_vision_90b",
    "qwen3_8b",
    "whisper_tiny",
    "phi3_mini_3p8b",
]

_ALIASES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "zamba2-7b": "zamba2_7b",
    "granite-3-8b": "granite_3_8b",
    "yi-6b": "yi_6b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "qwen3-8b": "qwen3_8b",
    "whisper-tiny": "whisper_tiny",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
}


def get(name: str, *, smoke: bool = False):
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.FULL


def all_archs():
    return [get(a) for a in ARCHS]
