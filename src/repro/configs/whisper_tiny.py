"""Whisper-tiny backbone: enc-dec, 4+4L d=384 6H kv=6 d_ff=1536 vocab=51865.
Mel/conv frontend STUBBED: input_specs provides frame embeddings (B, 1500,
384). LayerNorm per the original. [arXiv:2212.04356]"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51865, encdec=True, n_enc_layers=4, n_frames=1500,
    norm="layer", rope_theta=1e4,
    param_dtype="bfloat16", dtype="bfloat16",
)

SMOKE = FULL.with_(
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
    d_ff=256, vocab=512, n_enc_layers=2, n_frames=16,
    param_dtype="float32", dtype="float32",
)
