"""Seeded fault injection for the framed byte wire — deterministic chaos.

`FaultyEndpoint` wraps a `runtime.transport.Endpoint` and mangles the byte
chunks crossing it in either direction, driven by a seeded `FaultPlan`:

  corrupt     flip one byte of a chunk (must surface as `wire.ChecksumError`
              at the receiver — never as a silently-wrong payload)
  truncate    cut a chunk short (desyncs the stream -> CRC/length failure)
  drop        the chunk never arrives (recovered by ARQ retransmission)
  duplicate   the chunk arrives twice (recovered by seq dedup)
  reorder     the chunk is held back and delivered after its successor (or
              at the next idle recv timeout, so a hold-back with no later
              traffic degrades to a late delivery, never a silent drop —
              except a final send on an endpoint that never receives again,
              which the engines' shutdown() backstop tolerates)
  rechunk     split a chunk at arbitrary boundaries (benign: exercises
              `FrameReader` reassembly, costs nothing to recover)

At most one fault applies per chunk, drawn from a per-connection
`random.Random` seeded by (plan.seed, client id, connection index), so a
chaos run is reproducible chunk-for-chunk. Destructive faults share a
bounded budget (`plan.max_faults`) so every run terminates: once spent, the
wire goes clean and the ARQ layer drains the damage.

`FaultInjector` is the `wrap_endpoint` hook `runtime.engine.run_streaming`
and `fedtrain.engine.run_fedtrain` accept: it wraps every client-side
connection — initial and reconnect — and aggregates the injected-fault
counters that `scripts/chaos_smoke.py` and `tests/test_faults.py` check
against the engines' detected-fault counters.
"""
from __future__ import annotations

import collections
import dataclasses
import random
import threading
from typing import List, Optional

from repro.runtime.transport import Endpoint

#: fault kinds that damage the stream and consume the shared budget
DESTRUCTIVE_FAULTS = ("corrupt", "truncate", "drop", "duplicate", "reorder")
#: all fault kinds, in the order probabilities are drawn
FAULT_KINDS = DESTRUCTIVE_FAULTS + ("rechunk",)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-chunk fault probabilities + the seed that makes them replayable.

    Probabilities are independent per chunk and at most one fault fires per
    chunk (drawn cumulatively in `FAULT_KINDS` order). `max_faults` bounds
    the total destructive faults across every connection of one
    `FaultInjector`, guaranteeing the chaos run terminates.
    """

    seed: int = 0
    corrupt: float = 0.0
    truncate: float = 0.0
    drop: float = 0.0
    duplicate: float = 0.0
    reorder: float = 0.0
    rechunk: float = 0.0
    max_faults: int = 64

    def any_destructive(self) -> bool:
        return any(getattr(self, f) > 0 for f in DESTRUCTIVE_FAULTS)


class _Budget:
    """Thread-safe countdown of destructive faults left to inject."""

    def __init__(self, n: int):
        self._n = n
        self._lock = threading.Lock()

    def take(self) -> bool:
        with self._lock:
            if self._n <= 0:
                return False
            self._n -= 1
            return True


class FaultyEndpoint(Endpoint):
    """An `Endpoint` whose chunks pass through the fault plan.

    The up direction is mangled at `send`, the down direction at
    `recv_chunk` (before the `FrameReader` sees the bytes), so one wrapper
    on the client half subjects both directions of the channel to chaos —
    servers stay untouched. `injected` counts every fault actually applied,
    by kind.
    """

    def __init__(self, inner: Endpoint, plan: FaultPlan,
                 rng: Optional[random.Random] = None,
                 budget: Optional[_Budget] = None):
        super().__init__(inner._out, inner._in)
        self._plan = plan
        self._rng = rng or random.Random(plan.seed)
        self._budget = budget or _Budget(plan.max_faults)
        self.injected: collections.Counter = collections.Counter()
        self._tx_delayed: Optional[bytes] = None    # reorder hold-back slots
        self._rx_delayed: Optional[bytes] = None
        self._rx_stash: collections.deque = collections.deque()

    # -- fault application ---------------------------------------------------

    def _draw_fault(self, chunk: bytes) -> Optional[str]:
        if len(chunk) < 2:
            return None
        r = self._rng.random()
        for name in FAULT_KINDS:
            prob = getattr(self._plan, name)
            if r < prob:
                if name in DESTRUCTIVE_FAULTS and not self._budget.take():
                    return None
                return name
            r -= prob
        return None

    def _mangle(self, chunk: bytes, delayed_attr: str) -> List[bytes]:
        """Apply at most one fault; returns the chunks to deliver now."""
        rng = self._rng
        fault = self._draw_fault(chunk)
        out: List[bytes]
        if fault == "corrupt":
            b = bytearray(chunk)
            b[rng.randrange(len(b))] ^= rng.randint(1, 255)
            out = [bytes(b)]
        elif fault == "truncate":
            out = [chunk[: rng.randrange(1, len(chunk))]]
        elif fault == "drop":
            out = []
        elif fault == "duplicate":
            out = [chunk, chunk]
        elif fault == "reorder":
            if getattr(self, delayed_attr) is None:
                setattr(self, delayed_attr, chunk)
                out = []                # held back until the next chunk
            else:
                fault = None            # one hold-back slot per direction
                out = [chunk]
        elif fault == "rechunk":
            cuts = sorted(rng.randrange(1, len(chunk))
                          for _ in range(rng.randint(1, 3)))
            bounds = [0] + cuts + [len(chunk)]
            out = [chunk[a:b] for a, b in zip(bounds, bounds[1:]) if a < b]
        else:
            out = [chunk]
        if fault is not None:
            self.injected[fault] += 1
        # a held-back chunk is released right after the chunk that overtook it
        if fault != "reorder" and getattr(self, delayed_attr) is not None:
            out = out + [getattr(self, delayed_attr)]
            setattr(self, delayed_attr, None)
        return out

    # -- Endpoint overrides --------------------------------------------------

    def send(self, frame_bytes: bytes) -> int:
        for chunk in self._mangle(bytes(frame_bytes), "_tx_delayed"):
            super().send(chunk)
        return len(frame_bytes)     # sender accounting sees the clean length

    def recv_chunk(self, timeout: Optional[float] = None) -> Optional[bytes]:
        if self._rx_stash:
            return self._rx_stash.popleft()
        chunk = super().recv_chunk(timeout=timeout)
        if chunk is None:
            # idle moment: flush any reorder-held chunk so a hold-back
            # with no successor degrades to a late delivery, not a drop
            if self._rx_delayed is not None:
                chunk, self._rx_delayed = self._rx_delayed, None
                return chunk
            if self._tx_delayed is not None:
                held, self._tx_delayed = self._tx_delayed, None
                Endpoint.send(self, held)
                return b""          # released upstream; keep waiting
            return None
        out = self._mangle(chunk, "_rx_delayed")
        if not out:
            return b""              # dropped: an empty feed, not a timeout
        self._rx_stash.extend(out[1:])
        return out[0]


class FaultInjector:
    """`wrap_endpoint` hook: deterministic chaos across every connection.

    Each wrapped connection draws from its own RNG seeded by
    (plan.seed, cid, per-client connection index) — reconnect replays a
    *different* fault stream, so a corrupted retry cannot loop forever —
    while all connections share one destructive-fault budget.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._budget = _Budget(plan.max_faults)
        self._conn_counts: collections.Counter = collections.Counter()
        self._lock = threading.Lock()
        self.endpoints: List[FaultyEndpoint] = []

    def __call__(self, cid: int, endpoint: Endpoint) -> FaultyEndpoint:
        with self._lock:
            conn = self._conn_counts[cid]
            self._conn_counts[cid] += 1
        rng = random.Random(self.plan.seed * 1_000_003 + cid * 8191 + conn)
        fep = FaultyEndpoint(endpoint, self.plan, rng=rng,
                             budget=self._budget)
        with self._lock:
            self.endpoints.append(fep)
        return fep

    def injected(self) -> collections.Counter:
        """Total faults injected so far, by kind, across all connections."""
        with self._lock:
            total: collections.Counter = collections.Counter()
            for ep in self.endpoints:
                total.update(ep.injected)
            return total

    @property
    def connections(self) -> int:
        with self._lock:
            return sum(self._conn_counts.values())
