"""Test-harness subsystems that run the production stack under adversity.

`faults` drives the streaming/fedtrain runtimes through seeded byte-level
chaos (corrupt/truncate/drop/duplicate/reorder/re-chunk) via the engines'
`wrap_endpoint` hook — the proof harness for the frame layer's CRC +
typed-error + reconnect/replay guarantees. `clock` is the injectable time
source that lets the same timing-dependent runtime code run under real
threads or a deterministic single-threaded simulation
(`runtime.loadgen`).
"""
from repro.testing.clock import (Clock, SYSTEM_CLOCK, SystemClock,
                                 VirtualClock)
from repro.testing.faults import (DESTRUCTIVE_FAULTS, FAULT_KINDS,
                                  FaultInjector, FaultPlan, FaultyEndpoint)

__all__ = ["Clock", "DESTRUCTIVE_FAULTS", "FAULT_KINDS", "FaultInjector",
           "FaultPlan", "FaultyEndpoint", "SYSTEM_CLOCK", "SystemClock",
           "VirtualClock"]
