"""Test-harness subsystems that run the production stack under adversity.

`faults` drives the streaming/fedtrain runtimes through seeded byte-level
chaos (corrupt/truncate/drop/duplicate/reorder/re-chunk) via the engines'
`wrap_endpoint` hook — the proof harness for the frame layer's CRC +
typed-error + reconnect/replay guarantees.
"""
from repro.testing.faults import (DESTRUCTIVE_FAULTS, FAULT_KINDS,
                                  FaultInjector, FaultPlan, FaultyEndpoint)

__all__ = ["DESTRUCTIVE_FAULTS", "FAULT_KINDS", "FaultInjector", "FaultPlan",
           "FaultyEndpoint"]
