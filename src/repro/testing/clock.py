"""Injectable time source — real threads or deterministic simulation.

Every timing-dependent component of the serving runtime (`BatchingQueue`'s
max-wait flush deadline, `ArqClientMixin`'s retry timers, the loadgen's
arrival/transmission/service events) reads time through a `Clock` so the
same code runs in two modes:

  * `SystemClock` (the default, shared `SYSTEM_CLOCK` instance) — wall
    time + real condition-variable waits; the threaded production path is
    byte-identical to the pre-clock code.
  * `VirtualClock` — a simulated monotonic clock advanced explicitly by a
    single-threaded event loop (`runtime.loadgen`). Nothing ever sleeps:
    `sleep`/`cv_wait` advance the clock instead of blocking, so a
    thousand-session, minutes-long traffic trace runs in milliseconds and
    every timing race is a deterministic function of the seed.

The contract that keeps `BatchingQueue` correct under both: `monotonic()`
is non-decreasing, and `cv_wait(cv, timeout)` returns only when either the
condition variable was notified (SystemClock) or `timeout` simulated
seconds elapsed (VirtualClock — there is no other thread to notify, so a
wait can only mean "the deadline passed").
"""
from __future__ import annotations

import threading
import time


class Clock:
    """Time-source interface; see `SystemClock` / `VirtualClock`."""

    def monotonic(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError

    def cv_wait(self, cv: threading.Condition, timeout: float) -> bool:
        """Wait on `cv` (held) for up to `timeout` seconds; returns the
        underlying `Condition.wait` result (False on timeout)."""
        raise NotImplementedError


class SystemClock(Clock):
    """Wall-clock time and real waits — the threaded production mode."""

    def monotonic(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        time.sleep(seconds)

    def cv_wait(self, cv: threading.Condition, timeout: float) -> bool:
        return cv.wait(timeout)


#: process-wide default — component constructors take `clock=SYSTEM_CLOCK`
SYSTEM_CLOCK = SystemClock()


class VirtualClock(Clock):
    """Simulated monotonic clock for single-threaded event-loop tests.

    The owner (an event loop, or a test) advances time explicitly with
    `advance`/`advance_to`; components under test read `monotonic()` and
    their deadline arithmetic behaves exactly as it would under wall time.
    `sleep`/`cv_wait` advance the clock by the full timeout — in a
    single-threaded simulation no other thread can produce work mid-wait,
    so a wait always runs to its deadline. A well-scheduled event loop
    never triggers them (it fires consumers exactly at their deadlines);
    they exist so a component that *does* wait stays terminating instead
    of deadlocking the simulation.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self.waits = 0          # cv_wait calls observed (wake-thrash probe)

    def monotonic(self) -> float:
        return self._now

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)

    def cv_wait(self, cv: threading.Condition, timeout: float) -> bool:
        self.waits += 1
        self.advance(max(0.0, timeout))
        return False            # nothing can notify mid-wait: pure timeout

    # -- simulation control --------------------------------------------------

    def advance(self, seconds: float) -> float:
        assert seconds >= 0, f"time cannot move backwards ({seconds})"
        self._now += seconds
        return self._now

    def advance_to(self, t: float) -> float:
        assert t >= self._now - 1e-9, \
            f"advance_to({t}) behind current time {self._now}"
        self._now = max(self._now, float(t))
        return self._now
