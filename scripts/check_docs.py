#!/usr/bin/env python
"""Fail CI on README/docs links that point at nonexistent files.

Checks every markdown link and image target in README.md and docs/*.md:
relative targets must exist on disk (anchors are stripped; http(s)/mailto
links are skipped). Also verifies that backtick-quoted repo paths of the
form `dir/file.py` mentioned in those documents exist, so the README's
benchmark table cannot rot silently.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
# `benchmarks/foo.py`-style inline path mentions (at least one slash)
PATH_RE = re.compile(r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+)`")


def _rel(md: pathlib.Path) -> str:
    try:
        return str(md.relative_to(ROOT))
    except ValueError:
        return str(md)


def check_file(md: pathlib.Path) -> list:
    errors = []
    text = md.read_text()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{_rel(md)}: broken link -> {target}")
    for target in PATH_RE.findall(text):
        if "*" in target or target.endswith("/"):
            continue
        # repo-relative path mention; ignore dotted module paths w/o suffix
        if "." not in pathlib.Path(target).name:
            continue
        if not (ROOT / target).exists():
            errors.append(f"{_rel(md)}: missing path -> {target}")
    return errors


def main() -> int:
    files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    errors = []
    for md in files:
        if md.exists():
            errors.extend(check_file(md))
        else:
            errors.append(f"missing documentation file: {md}")
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    print(f"check_docs: {len(files)} files, "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
