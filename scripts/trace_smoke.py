"""Trace smoke — CI gate for the observability layer (docs/observability.md).

Runs one short seeded open-loop loadgen scenario (virtual clock, chaos
injected) twice with tracing ON, entirely inside a tempdir (no artifacts
survive, pass or fail), and asserts the telemetry contract:

  1. the exported file is schema-valid Chrome-trace-event JSON
     (`obs.export.validate_chrome_trace`) whose spans form a laminar
     family per track (`check_span_nesting`);
  2. all seven frame-lifecycle spans (`obs.trace.LIFECYCLE_SPANS`) and the
     QoS / ARQ / admission instants are present;
  3. the two same-seed runs wrote byte-identical files — the determinism
     the VirtualClock-driven tracer promises;
  4. if BENCH_serve.json (written by `benchmarks/serve_throughput.py`,
     which ci.sh runs first) carries an `obs` section, its tracing
     overhead gate must have passed.

    PYTHONPATH=src python scripts/trace_smoke.py
"""
from __future__ import annotations

import json
import pathlib
import sys
import tempfile

import jax

import repro.configs as configs
from repro.models import transformer
from repro.models.config import SplitConfig
from repro.obs.export import check_span_nesting, validate_chrome_trace
from repro.obs.trace import (EVT_ADMISSION_REJECT, EVT_ARQ_RETRANSMIT,
                             EVT_QOS_TRANSITION, LIFECYCLE_SPANS)
from repro.runtime.loadgen import (ArrivalSpec, FleetSpec, LoadGenConfig,
                                   ServiceModel, SLOSpec, run_loadgen)
from repro.runtime.qos import QoSSpec
from repro.testing import FaultInjector, FaultPlan

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = ROOT / "BENCH_serve.json"

#: every instant class the scenario below must surface: admission pressure
#: (tight capacity under an MMPP burst), ARQ recovery (injected drops),
#: and QoS rung moves (latency pushed past the controller's deadline)
REQUIRED_INSTANTS = (EVT_ADMISSION_REJECT, EVT_ARQ_RETRANSMIT,
                     EVT_QOS_TRANSITION)


def _scenario() -> LoadGenConfig:
    qos = QoSSpec(k=16, d=64, k_floor=4, high_depth=4, low_depth=1,
                  deadline_s=0.02, patience=4, cooldown=1)
    return LoadGenConfig(
        seed=11, duration_s=2.5,
        arrivals=ArrivalSpec(process="mmpp", rate=14.0, burst_rate=28.0,
                             mean_calm_s=1.0, mean_burst_s=1.0),
        fleet=FleetSpec(compressors=("randtopk:k=16",), prompt_len=(2, 3),
                        gen=(3, 5), bandwidth_Bps=400_000.0),
        service=ServiceModel(flush_overhead_s=2e-3, per_row_s=2e-4,
                             per_byte_s=3e-5),
        slo=SLOSpec(p99_ms=250.0, max_reject_frac=1.0),
        qos=qos, capacity=4, max_batch=4, max_wait=0.004,
        admission_depth=6, retry_timeout=0.05, max_retries=64)


def main() -> int:
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
    params = transformer.init_model(jax.random.key(0), cfg)
    lg = _scenario()
    plan = FaultPlan(seed=11, corrupt=0.04, drop=0.05, duplicate=0.04,
                     reorder=0.03, max_faults=40)
    problems = []

    with tempfile.TemporaryDirectory() as tmp:
        paths = [pathlib.Path(tmp) / f"run{i}.json" for i in (1, 2)]
        for p in paths:
            run_loadgen(cfg, lg, params=params,
                        wrap_endpoint=FaultInjector(plan), trace_path=p)
        blobs = [p.read_bytes() for p in paths]
        if blobs[0] != blobs[1]:
            problems.append("same-seed runs wrote different trace bytes")
        obj = json.loads(blobs[0])
        problems += validate_chrome_trace(obj)
        problems += check_span_nesting(obj["traceEvents"])
        names = {e["name"] for e in obj["traceEvents"]}
        missing = [s for s in LIFECYCLE_SPANS if s not in names]
        if missing:
            problems.append(f"missing lifecycle spans: {missing}")
        missing = [s for s in REQUIRED_INSTANTS if s not in names]
        if missing:
            problems.append(f"missing instant events: {missing}")
        print(f"trace_smoke: {len(obj['traceEvents'])} events, "
              f"{len(names)} distinct names, two runs byte-identical="
              f"{blobs[0] == blobs[1]}")

    if BENCH_PATH.exists():
        try:
            obs = json.loads(BENCH_PATH.read_text()).get("obs")
        except ValueError:
            obs = None
        if obs is not None:
            print(f"trace_smoke: bench overhead ratio "
                  f"{obs['on_off_ratio']} (floor {obs['ratio_floor']})")
            if not obs["ok"]:
                problems.append(
                    f"tracing overhead gate failed in BENCH_serve.json: "
                    f"ratio {obs['on_off_ratio']} < {obs['ratio_floor']}")

    for p in problems:
        print(f"trace_smoke: FAIL: {p}", file=sys.stderr)
    if not problems:
        print("trace_smoke: OK")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
