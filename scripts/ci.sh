#!/usr/bin/env bash
# Tier-1 verification — the command the driver runs after every PR.
#
#   scripts/ci.sh            # full tier-1 suite + docs check + serving smoke
#   scripts/ci.sh -m "not slow"   # quick pass (skip subprocess dry-runs)
set -euo pipefail
cd "$(dirname "$0")/.."

# README/docs links must point at files that exist
python scripts/check_docs.py

# fused decode kernel parity: the Pallas (interpret-mode on CPU) decode
# family must match the two-pass XLA decode bit-for-bit (<= 1 ulp for
# quant kinds) for every payload kind before anything downstream runs on
# top of it — a codegen regression here silently corrupts every served
# activation
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q tests/test_decode_kernels.py

# seeded chaos smoke: streaming + fedtrain under an injected FaultPlan
# (corrupt/truncate/drop/duplicate/reorder) must complete with tokens and
# losses identical to the clean run — CRC catches every corruption, sessions
# reconnect and resume via seq replay
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/chaos_smoke.py

# streaming serving smoke + perf gate: measured bytes must match the
# Table-2 analytics within 5% AND be byte-exactly the codec's own payload
# size, and the randtopk/identity tokens-per-second ratio (median of
# GATE_REPS pure 8-client runs each) must stay above the RATIO_FLOOR
# pinned in the bench — the compressed path must remain the fast path; a
# regression to host-side densification fails here. Also audits the
# compiled decode + fused-step programs against the closed-form roofline
# predictions (exact flops, calibrated byte bands). Writes
# BENCH_serve.json with the ratio, floor, per-stage timings, and
# roofline rows.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/serve_throughput.py --smoke

# observability smoke: a short seeded chaos loadgen run with tracing ON,
# twice, entirely in a tempdir (no artifacts on any path) — the exported
# Chrome-trace JSON must be schema-valid, laminar per track, carry all
# seven lifecycle spans plus the QoS/ARQ/admission instants, and be
# byte-identical across the two same-seed runs; also re-checks the
# tracing-overhead gate the bench above recorded in BENCH_serve.json's
# `obs` section (on/off throughput ratio >= its pinned floor)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/trace_smoke.py

# production-traffic SLO gate: open-loop MMPP arrivals on a virtual clock
# over the real frame/ARQ/arena path — under the seeded 2x overload burst
# the QoS-adaptive (k, bits) fleet must hold the declared p99 token-latency
# SLO with no rejected sessions while the static comparator violates it;
# fully deterministic (exact comparison, no jitter tolerance). Merges a
# `loadgen` section into BENCH_serve.json.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/loadgen.py --smoke

# fedtrain smoke: over-the-wire split training; randtopk bytes must match
# the Table-2 fwd+bwd analytics, adaptive-k and async must hold
# accuracy-per-measured-byte >= fixed-k topk
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/fedtrain_convergence.py --smoke

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
