#!/usr/bin/env bash
# Tier-1 verification — the command the driver runs after every PR.
#
#   scripts/ci.sh            # fast tier, smokes/gates, then the full suite
#   scripts/ci.sh -m "not slow"   # forwards extra args to the FULL pass only
#
# Stages run cheapest-first so a regression fails in minutes, not after the
# 9-minute full suite; each stage prints its wall time.
set -euo pipefail
cd "$(dirname "$0")/.."

STAGE_T0=$SECONDS
stage() {
    local now=$SECONDS
    echo "== ci stage: $1 (previous stage took $((now - STAGE_T0))s) =="
    STAGE_T0=$now
}

# README/docs links must point at files that exist
stage "docs check"
python scripts/check_docs.py

# fast tier: everything not marked `slow` (the slow marks cover the
# subprocess dry-runs, forced-8-device mesh suites, and multi-step
# training loops). Runs first so unit-level breakage surfaces in under
# five minutes; the full pass below still runs every test.
stage "pytest fast tier (-m 'not slow')"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow"

# fused decode kernel parity: the Pallas (interpret-mode on CPU) decode
# family must match the two-pass XLA decode bit-for-bit (<= 1 ulp for
# quant kinds) for every payload kind before anything downstream runs on
# top of it — a codegen regression here silently corrupts every served
# activation
stage "decode kernel parity"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q tests/test_decode_kernels.py

# fused encode kernel parity: the device pack path (Pallas kernels +
# XLA fallback) must produce frames byte-identical to the host codec for
# every payload kind (<= 1 ulp for quant leaves) — the client ships
# whatever this path packs, so a regression here corrupts the wire at
# the source
stage "encode kernel parity"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q tests/test_encode_kernels.py

# seeded chaos smoke: streaming + fedtrain under an injected FaultPlan
# (corrupt/truncate/drop/duplicate/reorder) must complete with tokens and
# losses identical to the clean run — CRC catches every corruption, sessions
# reconnect and resume via seq replay
stage "chaos smoke"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/chaos_smoke.py

# streaming serving smoke + perf gate: measured bytes must match the
# Table-2 analytics within 5% AND be byte-exactly the codec's own payload
# size, and the randtopk/identity tokens-per-second ratio (median of
# GATE_REPS pure 8-client runs each) must stay above the RATIO_FLOOR
# pinned in the bench — the compressed path must remain the fast path; a
# regression to host-side densification fails here. Also audits the
# compiled decode + fused-step programs against the closed-form roofline
# predictions (exact flops, calibrated byte bands), and runs the sharded-
# arena capacity sweep in an 8-forced-device subprocess (slots x devices
# tokens/s, eviction/readmission churn, bit-exact tokens at every point,
# collective-byte audit of the sharded step). Writes BENCH_serve.json
# with the ratio, floor, per-stage timings, roofline rows, and the
# capacity section.
stage "serve throughput bench + gates"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/serve_throughput.py --smoke

# observability smoke: a short seeded chaos loadgen run with tracing ON,
# twice, entirely in a tempdir (no artifacts on any path) — the exported
# Chrome-trace JSON must be schema-valid, laminar per track, carry all
# seven lifecycle spans plus the QoS/ARQ/admission instants, and be
# byte-identical across the two same-seed runs; also re-checks the
# tracing-overhead gate the bench above recorded in BENCH_serve.json's
# `obs` section (on/off throughput ratio >= its pinned floor)
stage "trace smoke"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/trace_smoke.py

# production-traffic SLO gate: open-loop MMPP arrivals on a virtual clock
# over the real frame/ARQ/arena path — under the seeded 2x overload burst
# the QoS-adaptive (k, bits) fleet must hold the declared p99 token-latency
# SLO with no rejected sessions while the static comparator violates it;
# fully deterministic (exact comparison, no jitter tolerance). Merges a
# `loadgen` section into BENCH_serve.json.
stage "loadgen SLO gate"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/loadgen.py --smoke

# fedtrain smoke: over-the-wire split training; randtopk bytes must match
# the Table-2 fwd+bwd analytics, adaptive-k and async must hold
# accuracy-per-measured-byte >= fixed-k topk
stage "fedtrain convergence smoke"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/fedtrain_convergence.py --smoke

stage "pytest full suite"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

stage "done"
