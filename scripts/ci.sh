#!/usr/bin/env bash
# Tier-1 verification — the command the driver runs after every PR.
#
#   scripts/ci.sh            # full tier-1 suite
#   scripts/ci.sh -m "not slow"   # quick pass (skip subprocess dry-runs)
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
