#!/usr/bin/env python
"""Seeded chaos smoke — CI gate for the hardened frame path.

Runs both runtimes twice at equal seeds — once clean, once under a seeded
`FaultPlan` mixing corrupt/truncate/drop/duplicate/reorder/re-chunk faults
injected through `repro.testing.faults.FaultInjector` — and asserts the
acceptance bar of the frame-integrity work:

  * both engines COMPLETE under chaos (no dead reader threads, sessions
    reconnect and resume via sequence-number replay);
  * zero silent decodes: streaming tokens and fedtrain losses/accuracy are
    identical to the clean run;
  * the recovery machinery demonstrably engaged (faults were injected and
    detected, frames were replayed);
  * analytic payload accounting is fault-invariant.

    PYTHONPATH=src python scripts/chaos_smoke.py
"""
from __future__ import annotations

import sys

import numpy as np

import jax
import repro.configs as configs
from repro.data.synthetic import ManyClassDataset
from repro.fedtrain import run_fedtrain
from repro.models import transformer
from repro.models.config import SplitConfig
from repro.runtime import run_streaming
from repro.split.tabular import SplitSpec
from repro.testing import DESTRUCTIVE_FAULTS, FaultInjector, FaultPlan

CHAOS = dict(corrupt=0.06, truncate=0.03, drop=0.05, duplicate=0.05,
             reorder=0.03, rechunk=0.15, max_faults=30)
ARQ = dict(retry_timeout=0.3, max_retries=40)


def _report(emit, tag, injected, fc) -> bool:
    destructive = sum(injected[f] for f in DESTRUCTIVE_FAULTS)
    detected = fc["server_faults_detected"] + fc["client_faults_detected"]
    engaged = fc["replays"] + fc["duplicates"] + fc["reconnects"] + detected
    emit(f"chaos,{tag},injected={destructive},rechunk={injected['rechunk']},"
         f"detected={detected},replays={fc['replays']},"
         f"duplicates={fc['duplicates']},reconnects={fc['reconnects']}")
    ok = destructive > 0 and engaged > 0
    emit(f"chaos_check,{tag},faults_injected_and_recovered,{ok}")
    return ok


def main(emit=print) -> bool:
    ok = True

    # -- streaming under chaos ----------------------------------------------
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = dict(n_clients=4, prompt_len=3, gen=6, max_batch=4, max_wait=0.02,
              compressor_mix=["identity", "randtopk:k=16"], params=params)
    clean = run_streaming(cfg, **kw)
    inj = FaultInjector(FaultPlan(seed=3, **CHAOS))
    chaos = run_streaming(cfg, **kw, wrap_endpoint=inj, **ARQ)
    tokens_ok = bool(np.array_equal(clean["tokens"], chaos["tokens"]))
    emit(f"chaos_check,streaming,tokens_identical_under_faults,{tokens_ok}")
    ok &= tokens_ok
    ok &= _report(emit, "streaming", inj.injected(),
                  chaos["fault_counters"])

    # -- fedtrain under chaos -----------------------------------------------
    ds = ManyClassDataset(n_classes=10, in_dim=16, n_train=512, n_test=256,
                          noise=0.3, seed=0)
    spec = SplitSpec(in_dim=16, hidden=32, cut_dim=32, n_classes=10,
                     method="randtopk", k=3)
    fkw = dict(n_clients=1, epochs=1, batch=64, seed=0)
    fclean = run_fedtrain(spec, ds, **fkw)
    finj = FaultInjector(FaultPlan(seed=7, **CHAOS))
    fchaos = run_fedtrain(spec, ds, **fkw, wrap_endpoint=finj, **ARQ)
    loss_ok = bool(np.array_equal(
        np.asarray([l for _, l in fclean["losses"][0]]),
        np.asarray([l for _, l in fchaos["losses"][0]])))
    acc_ok = fclean["mean_test_acc"] == fchaos["mean_test_acc"]
    analytic_ok = (fclean["analytic_bytes_up"] == fchaos["analytic_bytes_up"]
                   and fclean["analytic_bytes_down"]
                   == fchaos["analytic_bytes_down"])
    emit(f"chaos_check,fedtrain,losses_bitwise_identical_under_faults,"
         f"{loss_ok}")
    emit(f"chaos_check,fedtrain,accuracy_identical,{acc_ok}")
    emit(f"chaos_check,fedtrain,analytic_bytes_fault_invariant,"
         f"{analytic_ok}")
    ok &= loss_ok and acc_ok and analytic_ok
    ok &= _report(emit, "fedtrain", finj.injected(),
                  fchaos["fault_counters"])
    return ok


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
