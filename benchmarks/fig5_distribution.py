"""Paper Figure 5 — distribution of top-k neuron selections at inference.

After training, iterate the train set and count how often each of the d cut
neurons lands in the (deterministic) top-k. The paper's claim: RandTopk
training balances the histogram (no starved neurons, no always-on neurons),
which is the mechanism behind its better use of the C(d,k) feature space.
We report min/max counts and the normalized entropy of the histogram.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import EPOCHS, dataset, spec
from repro.core import selection
from repro.split.tabular import bottom_fn, train


def selection_histogram(bottom, k, x):
    o = bottom_fn(bottom, jnp.asarray(x))
    mask = np.asarray(selection.topk_mask(o, k))
    return mask.sum(axis=0)  # (d,) counts


def norm_entropy(counts):
    p = counts / max(1.0, counts.sum())
    p = p[p > 0]
    return float(-(p * np.log(p)).sum() / np.log(len(counts)))


def main(emit=print):
    ds = dataset()
    stats = {}
    deep = max(EPOCHS, int(EPOCHS * 2))  # histogram read after convergence
    for method, kw in [("topk", dict(k=3)),
                       ("randtopk", dict(k=3, alpha=0.1)),
                       ("randtopk_a3", dict())]:
        if method == "randtopk_a3":
            sp = spec("randtopk", k=3, alpha=0.3)
        else:
            sp = spec(method, **kw)
        r = train(sp, ds, epochs=deep, seed=0)
        counts = selection_histogram(r["bottom"], 3, ds.x_train)
        ent = norm_entropy(counts)
        stats[method] = (counts, ent)
        emit(f"fig5,{method},min={counts.min():.0f},max={counts.max():.0f},"
             f"dead={(counts == 0).sum()},entropy={ent:.4f}")
    # Absolute topk-vs-randtopk balance does NOT reproduce on the synthetic
    # MLP task (EXPERIMENTS.md §Fig5 — the starved-neuron effect needs the
    # convnet feature space of the paper's setup); emitted as metrics, and
    # only the alpha-monotonicity trend (which does reproduce) is asserted.
    emit(f"fig5_info,topk_vs_randtopk_balance_gap,"
         f"{stats['topk'][1] - stats['randtopk'][1]:+.4f}")
    checks = {
        "larger_alpha_more_balanced":
            stats["randtopk_a3"][1] >= stats["randtopk"][1] - 0.01,
    }
    for name, ok in checks.items():
        emit(f"fig5_check,{name},{ok}")
    return stats, checks


if __name__ == "__main__":
    main()
