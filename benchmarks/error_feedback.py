"""Beyond-paper ablation: error feedback (Stich et al., cited by the paper
for HFL gradients) transplanted to split-learning cut activations.

Open question the paper leaves implicit: does EF, the standard fix for
biased gradient compression, transfer to activation compression? Finding
(reported either way): activations are per-sample signals, so classic EF is
ill-posed; per-class residual memory is the closest analogue and we measure
its effect against plain Topk and RandTopk at high compression.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import EPOCHS, dataset, spec
from repro.core.error_feedback import ef_topk_forward
from repro.optim import adamw_init, adamw_update
from repro.split import tabular
from repro.split.tabular import SplitSpec, bottom_fn, top_fn, train


def train_ef(sp: SplitSpec, ds, *, epochs, seed=0):
    key = jax.random.key(seed)
    bottom, top = tabular.init_parties(key, sp)
    opt_b, opt_t = adamw_init(bottom), adamw_init(top)
    err0 = jnp.zeros((sp.n_classes, sp.cut_dim))

    @jax.jit
    def step(bottom, top, opt_b, opt_t, err, x, y):
        o_b, vjp_bottom = jax.vjp(lambda bp: bottom_fn(bp, x), bottom)
        view, mask, new_err = ef_topk_forward(o_b, err, y, sp.k,
                                              sp.n_classes)
        view = jax.lax.stop_gradient(view)
        (loss, _), vjp_top = jax.vjp(lambda tp, o: top_fn(tp, o, y), top,
                                     view)
        dtp, dview = vjp_top((jnp.ones(()),
                              jnp.zeros((x.shape[0], sp.n_classes))))
        (dbp,) = vjp_bottom(dview * mask.astype(dview.dtype))
        bottom, opt_b, _ = adamw_update(bottom, dbp, opt_b, lr=sp.lr,
                                        grad_clip=0.0)
        top, opt_t, _ = adamw_update(top, dtp, opt_t, lr=sp.lr,
                                     grad_clip=0.0)
        return bottom, top, opt_b, opt_t, new_err, loss

    rng = np.random.RandomState(seed)
    err = err0
    for _ in range(epochs):
        for xb, yb in ds.batches(128, rng=rng):
            bottom, top, opt_b, opt_t, err, loss = step(
                bottom, top, opt_b, opt_t, err, jnp.asarray(xb),
                jnp.asarray(yb))
    return tabular.evaluate(bottom, top, sp, jnp.asarray(ds.x_test),
                            jnp.asarray(ds.y_test))


def main(emit=print):
    ds = dataset()
    sp = spec("topk", k=3)
    acc_topk = train(sp, ds, epochs=EPOCHS, seed=0)["test_acc"]
    acc_rand = train(spec("randtopk", k=3, alpha=0.1), ds,
                     epochs=EPOCHS, seed=0)["test_acc"]
    acc_ef = train_ef(sp, ds, epochs=EPOCHS, seed=0)
    emit(f"ef,topk,{acc_topk:.4f}")
    emit(f"ef,randtopk,{acc_rand:.4f}")
    emit(f"ef,topk+class_error_feedback,{acc_ef:.4f}")
    # informational: does EF close any of the randtopk-topk gap?
    emit(f"ef_info,ef_minus_topk,{acc_ef - acc_topk:+.4f}")
    emit(f"ef_info,randtopk_minus_ef,{acc_rand - acc_ef:+.4f}")
    return {"topk": acc_topk, "randtopk": acc_rand, "ef": acc_ef}


if __name__ == "__main__":
    main()
