"""Paper Figures 3/4 — convergence in epochs AND in communication volume.

Claims validated: (a) vanilla converges in fewest epochs but the MOST bytes;
(b) compressed methods dominate on accuracy-per-byte; (c) RandTopk reaches a
better end point than Topk; (d) RandTopk's generalization gap is smaller.
"""
import numpy as np

from benchmarks.common import EPOCHS, dataset, spec
from repro.split.tabular import train


def main(emit=print):
    traces = {}
    results = {}
    for method, kw in [("none", {}), ("topk", dict(k=3)),
                       ("randtopk", dict(k=3, alpha=0.1))]:
        r = train(spec(method, **kw), dataset(), epochs=EPOCHS, seed=0,
                  record_every=50)
        traces[method] = r["trace"]
        results[method] = r
        for it, byts, loss, acc in r["trace"][::4]:
            emit(f"fig4,{method},{it},{byts:.3e},{loss:.4f},{acc:.4f}")
        emit(f"fig4_final,{method},acc={r['test_acc']:.4f},"
             f"gen_gap={r['gen_gap']:.4f},bytes={r['train_bytes']:.3e}")

    # bytes to reach a fixed accuracy threshold
    thresh = 0.15
    byte_to_acc = {}
    for m, tr in traces.items():
        hit = [b for (_, b, _, a) in tr if a >= thresh]
        byte_to_acc[m] = min(hit) if hit else float("inf")
        emit(f"fig4_bytes_to_{int(thresh*100)}pct,{m},{byte_to_acc[m]:.3e}")
    checks = {
        "compressed_beats_vanilla_on_bytes":
            byte_to_acc["randtopk"] < byte_to_acc["none"],
        "randtopk_endpoint>=topk":
            results["randtopk"]["test_acc"] >= results["topk"]["test_acc"]
            - 0.01,
        "randtopk_gap<=topk":
            results["randtopk"]["gen_gap"] <= results["topk"]["gen_gap"]
            + 0.02,
    }
    for name, ok in checks.items():
        emit(f"fig4_check,{name},{ok}")
    return traces, checks


if __name__ == "__main__":
    main()
