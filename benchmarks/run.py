"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all paper benches
    PYTHONPATH=src python -m benchmarks.run --fast     # reduced epochs
    REPRO_BENCH_EPOCHS=40 ... python -m benchmarks.run # deeper runs

Emits `name,metric,value` CSV lines; `*_check` lines assert the paper's
qualitative claims and the driver exits non-zero if any check fails.
"""
import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced epochs/seeds for CI-speed runs")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (table2,table3,fig2,...)")
    args, _ = ap.parse_known_args()
    if args.fast:
        os.environ.setdefault("REPRO_BENCH_EPOCHS", "6")
        os.environ.setdefault("REPRO_BENCH_SEEDS", "1")

    from benchmarks import (alpha_sweep, appendixB_privacy,
                            combined_compression, error_feedback,
                            fedtrain_convergence, fig2_toy,
                            fig4_convergence, fig5_distribution, loadgen,
                            roofline_report, serve_throughput, table2_sizes,
                            table3_accuracy, table7_dbpedia_geometry,
                            wire_packing)

    sections = {
        "table2": table2_sizes.main,
        "fig2": fig2_toy.main,
        "table3": table3_accuracy.main,
        "fig4": fig4_convergence.main,
        "fig5": fig5_distribution.main,
        "alpha": alpha_sweep.main,
        "combined": combined_compression.main,
        "ef": error_feedback.main,
        "table7": table7_dbpedia_geometry.main,
        "privacy": appendixB_privacy.main,
        "roofline": roofline_report.main,
        "wire": wire_packing.main,
        "serve": serve_throughput.main,
        "loadgen": loadgen.main,
        "fedtrain": fedtrain_convergence.main,
    }
    chosen = (args.only.split(",") if args.only else list(sections))

    lines = []

    def emit(msg):
        print(msg, flush=True)
        lines.append(str(msg))

    t0 = time.time()
    for name in chosen:
        emit(f"## section {name}")
        sections[name](emit=emit)
        emit(f"## section {name} done ({time.time()-t0:.0f}s elapsed)")

    failures = [l for l in lines if "_check" in l and l.endswith("False")]
    emit(f"## {len(failures)} failed checks")
    for f in failures:
        emit("FAILED: " + f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
