"""Paper Appendix C — impact of the randomness coefficient alpha.

Claims: every alpha in [0.05, 0.3] beats plain top-k (alpha=0) on the
many-class task; very large alpha degrades toward Dropout-like noise.
"""
import numpy as np

from benchmarks.common import EPOCHS, SEEDS, dataset, spec
from repro.split.tabular import train

ALPHAS = [0.0, 0.05, 0.1, 0.2, 0.3, 0.6]


def main(emit=print):
    accs = {}
    for alpha in ALPHAS:
        runs = [train(spec("randtopk", k=3, alpha=alpha), dataset(),
                      epochs=EPOCHS, seed=s)["test_acc"]
                for s in range(max(1, SEEDS - 1))]
        accs[alpha] = (float(np.mean(runs)), float(np.std(runs)))
        emit(f"alpha_sweep,{alpha},{accs[alpha][0]:.4f},{accs[alpha][1]:.4f}")
    best = max(accs, key=lambda a: accs[a][0])
    checks = {
        "moderate_alpha_beats_topk": any(
            accs[a][0] > accs[0.0][0] for a in (0.05, 0.1, 0.2, 0.3)),
        # the paper reports a task-dependent optimum (0.05 on YooChoose,
        # 0.1-0.3 on CIFAR-100); on the synthetic task the curve is flat
        # between 0.2 and 0.6 — assert the optimum is NOT at alpha=0.
        "best_alpha_nonzero": best > 0.0,
    }
    # on this synthetic task even alpha=0.6 keeps helping (the paper's
    # "too-large alpha hurts" was observed on YooChoose); report, don't gate.
    emit(f"alpha_info,alpha06_minus_best_moderate,"
         f"{accs[0.6][0] - max(accs[a][0] for a in (0.05, 0.1, 0.2, 0.3)):+.4f}")
    for name, ok in checks.items():
        emit(f"alpha_check,{name},{ok}")
    return accs, checks


if __name__ == "__main__":
    main()
