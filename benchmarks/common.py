"""Shared benchmark configuration (paper-scale experiments)."""
import os

from repro.data.synthetic import ManyClassDataset
from repro.split.tabular import SplitSpec

# CIFAR-100-like geometry: d=128 cut, 100 classes; k in {3, 6, 13} gives the
# paper's High/Medium/Low compressed sizes (2.86 / 5.71 / 12.38 %).
EPOCHS = int(os.environ.get("REPRO_BENCH_EPOCHS", "24"))
SEEDS = int(os.environ.get("REPRO_BENCH_SEEDS", "2"))
HIDDEN = 512
LR = 2e-3

_DS = None


def dataset() -> ManyClassDataset:
    global _DS
    if _DS is None:
        _DS = ManyClassDataset(n_classes=100, in_dim=64, n_train=20000,
                               n_test=4000, noise=0.3, seed=0)
    return _DS


def spec(method: str, **kw) -> SplitSpec:
    kw.setdefault("hidden", HIDDEN)
    kw.setdefault("lr", LR)
    return SplitSpec(method=method, **kw)
