"""Paper Table 2 — compressed sizes: analytic formulas vs byte-exact wire
encodings (core/wire.py), plus kernel-vs-oracle timing microbenches."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection, wire
from repro.kernels.randtopk import kernel as tk_kernel


def main(emit=print):
    d, n_inst = 128, 64
    x = np.random.RandomState(0).randn(n_inst, d).astype(np.float32)
    ok_all = True
    for method, kw in [("size_reduction", dict(k=3)), ("topk", dict(k=3)),
                       ("randtopk", dict(k=3)), ("quant", dict(bits=4)),
                       ("identity", {})]:
        row = wire.table2_row(method, d, **kw)
        # byte-exact measurement of the forward payload
        if method in ("topk", "randtopk"):
            k = kw["k"]
            vals, idx = selection.topk_values_indices(jnp.asarray(x), k)
            buf = wire.encode_sparse(np.asarray(vals), np.asarray(idx), d)
            measured = len(buf) / (n_inst * d * 4)
        elif method == "size_reduction":
            measured = kw["k"] * 4 * n_inst / (n_inst * d * 4)
        elif method == "quant":
            bits = kw["bits"]
            codes = np.zeros((n_inst, d))
            buf = wire.encode_quant(codes, np.zeros(n_inst),
                                    np.ones(n_inst), bits)
            measured = len(buf) / (n_inst * d * 4)
        else:
            measured = 1.0
        analytic = row["fwd"]
        if method == "quant":
            # Table 2 writes 2^b/N and ignores the per-instance (lo, step)
            # range header (8 B) that any real encoder ships; the byte-exact
            # measurement includes it.
            analytic += 2 * 32 / (d * 32)
        close = abs(measured - analytic) / max(analytic, 1e-9) < 0.11
        ok_all &= close
        emit(f"table2,{method},fwd_analytic={row['fwd']:.4f},"
             f"fwd_measured={measured:.4f},bwd={row['bwd']:.4f},"
             f"match={close}")
    emit(f"table2_check,analytic_matches_measured,{ok_all}")

    # kernel microbench (interpret mode timing is indicative only)
    xb = jax.random.normal(jax.random.key(0), (256, 1024))
    t0 = time.perf_counter()
    tk_kernel.topk_mask_threshold(xb, 16)[0].block_until_ready()
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(5):
        tk_kernel.topk_mask_threshold(xb, 16)[0].block_until_ready()
    t_steady = (time.perf_counter() - t0) / 5
    emit(f"kernel_bench,topk_bisect_256x1024,us_per_call,"
         f"{t_steady*1e6:.0f},compile_s={t_first:.2f}")
    return ok_all


if __name__ == "__main__":
    main()
