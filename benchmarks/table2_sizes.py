"""Paper Table 2 — compressed sizes: analytic formulas vs byte-exact wire
encodings of the packed payloads (core/wire.encode_payload on
core/compressors.encode output), plus kernel-vs-oracle timing microbenches.

Every method is measured the same way: encode the probe activation to its
`Payload`, serialize it, and compare the socket bytes against the Table-2
analytic row — one codec, one source of truth."""
import time

import jax
import numpy as np

from repro.core import compressors as C, wire
from repro.kernels.randtopk import kernel as tk_kernel


def main(emit=print):
    d, n_inst = 128, 64
    x = jax.numpy.asarray(
        np.random.RandomState(0).randn(n_inst, d).astype(np.float32))
    ok_all = True
    for method, kw in [("size_reduction", dict(k=3)), ("topk", dict(k=3)),
                       ("randtopk", dict(k=3)),
                       ("randtopk_mask", dict(k=3)),
                       ("quant", dict(bits=4)),
                       ("randtopk_quant", dict(k=3, bits=8)),
                       ("identity", {})]:
        row = wire.table2_row(method, d, **kw)
        comp = C.make_compressor(method, **kw)
        # byte-exact measurement of the forward payload via the codec
        payload = jax.tree.map(np.asarray,
                               comp.encode(x, key=jax.random.key(0)))
        measured = wire.payload_nbytes(payload) / (n_inst * d * 4)
        analytic = row["fwd"]
        if method == "quant":
            # Table 2 writes 2^b/N and ignores the per-instance (lo, step)
            # range header (8 B) that any real encoder ships; the byte-exact
            # measurement includes it.
            analytic += 2 * 32 / (d * 32)
        close = abs(measured - analytic) / max(analytic, 1e-9) < 0.11
        ok_all &= close
        emit(f"table2,{method},fwd_analytic={row['fwd']:.4f},"
             f"fwd_measured={measured:.4f},bwd={row['bwd']:.4f},"
             f"match={close}")
        # the codec's own per-instance analytic bits must agree byte-for-byte
        codec_bits = wire.payload_bits_per_instance(payload.meta) * n_inst
        slop = 8 * 2  # two bit-packed streams round up to whole bytes
        codec_ok = abs(wire.payload_nbytes(payload) * 8 - codec_bits) <= slop
        ok_all &= codec_ok
        emit(f"table2,{method},codec_bits_match={codec_ok}")
    emit(f"table2_check,analytic_matches_measured,{ok_all}")

    # kernel microbench (interpret mode timing is indicative only)
    xb = jax.random.normal(jax.random.key(0), (256, 1024))
    t0 = time.perf_counter()
    tk_kernel.topk_mask_threshold(xb, 16)[0].block_until_ready()
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(5):
        tk_kernel.topk_mask_threshold(xb, 16)[0].block_until_ready()
    t_steady = (time.perf_counter() - t0) / 5
    emit(f"kernel_bench,topk_bisect_256x1024,us_per_call,"
         f"{t_steady*1e6:.0f},compile_s={t_first:.2f}")
    return ok_all


if __name__ == "__main__":
    main()
