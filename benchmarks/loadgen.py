"""Production-traffic bench: open-loop load + SLO gate for the QoS ladder.

Drives the virtual-clock load generator (`repro.runtime.loadgen`) over the
real serving stack and gates the PR's operational claim: under a seeded
2x overload burst (2-state MMPP arrivals), a fleet whose sessions adapt
(k, bits) down a randomized-top-k ladder under congestion
(`runtime.qos.QoSController`) holds the declared p99 token-latency SLO
with no admission rejections, while the byte-identical static fleet —
same seed, same arrivals, same server — blows the deadline or rejects
sessions. Shedding *bytes* instead of *sessions* is the serving-side
payoff of the paper's accuracy-per-byte result: randomized top-k degrades
fidelity gracefully as k tightens, so the QoS floor trades a little
fidelity for a lot of latency headroom.

Everything is deterministic (virtual time, seeded arrivals/fleet/faults):
the gate compares exact numbers, not noisy wall-clock medians. The full
(non-smoke) run adds a heterogeneous calm-fleet scenario (mixed
compressors, think times, bandwidth caps) and a longer burst at a second
seed. Results land in the repo-root `BENCH_serve.json` under `loadgen`,
merged into (never clobbering) the serving-throughput section.

    PYTHONPATH=src python benchmarks/loadgen.py --smoke
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax

import repro.configs as configs
from repro.models import transformer
from repro.models.config import SplitConfig
from repro.runtime.loadgen import (ArrivalSpec, FleetSpec, LoadGenConfig,
                                   ServiceModel, SLOSpec, run_loadgen)
from repro.runtime.qos import QoSSpec

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = ROOT / "BENCH_serve.json"

#: declared SLO for the burst gate: p99 token latency and admission
#: rejections. The static fleet's p99 measures ~2x this ceiling under the
#: burst; the adaptive fleet holds ~30% under it — both deterministic.
SLO = SLOSpec(p99_ms=60.0, max_reject_frac=0.02)

#: the mixed calm fleet keeps 10% `identity` sessions, and one dense
#: d_model=256 frame costs ~31ms of modeled service time alone
#: (`ServiceModel.per_byte_s` x ~1KB) — a deliberately looser declared
#: ceiling for a fleet that ships dense frames; the compressed-only burst
#: fleets are graded against the tight `SLO` above
MIXED_SLO = SLOSpec(p99_ms=150.0, max_reject_frac=0.02)

#: 2x overload: calm arrivals at ~0.75 of the static fleet's service
#: capacity, bursts at ~1.5x of it (the service model is host-byte-bound,
#: `ServiceModel.per_byte_s`, so capacity scales with frame size)
ARRIVALS = ArrivalSpec(process="mmpp", rate=22.0, burst_rate=44.0,
                       mean_calm_s=2.0, mean_burst_s=3.0)
SERVICE = ServiceModel(flush_overhead_s=1e-3, per_row_s=1e-4,
                       per_byte_s=3e-5)
FLEET = FleetSpec(compressors=("randtopk:k=16",), prompt_len=(2, 3),
                  gen=(5, 8), bandwidth_Bps=400_000.0)

#: the adaptive fleet's declared envelope: the same randtopk:k=16 spec at
#: the top, tightening by halves to k=4 under congestion
def _qos(d: int) -> QoSSpec:
    return QoSSpec(k=16, d=d, k_floor=4, high_depth=6, low_depth=2,
                   deadline_s=0.04, patience=16, cooldown=1)


def _scenario(seed: int, duration_s: float, qos) -> LoadGenConfig:
    return LoadGenConfig(seed=seed, duration_s=duration_s,
                         arrivals=ARRIVALS, fleet=FLEET, service=SERVICE,
                         slo=SLO, qos=qos, capacity=32, max_batch=8,
                         max_wait=0.004, admission_depth=48)


def _strip(report: dict) -> dict:
    """BENCH-sized copy: drop the per-event traces (tests use those) and
    the one nondeterministic field."""
    out = {k: v for k, v in report.items()
           if k not in ("trace", "wall_s_real", "metrics",
                        "metrics_timeline")}
    out["arrivals"] = {k: v for k, v in report["arrivals"].items()
                       if k != "state_path"}
    return out


def _emit_run(emit, name: str, r: dict) -> None:
    lat = r["latency_ms"]
    emit(f"loadgen,{name},arrived={r['sessions']['arrived']},"
         f"completed={r['sessions']['completed']},"
         f"rejected={r['sessions']['rejected']},"
         f"failed={r['sessions']['failed']}")
    emit(f"loadgen,{name},goodput_tok_per_s={r['goodput_tok_per_s']},"
         f"p50_ms={lat['p50_ms']},p95_ms={lat['p95_ms']},"
         f"p99_ms={lat['p99_ms']},depth_max={r['queue_depth']['max']},"
         f"mean_fill={r['mean_batch_fill']}")
    emit(f"loadgen,{name},p2_p50_ms={lat['p2_p50_ms']},"
         f"p2_p95_ms={lat['p2_p95_ms']},p2_p99_ms={lat['p2_p99_ms']}")
    if r["qos"]["enabled"]:
        emit(f"loadgen,{name},qos_switches={r['qos']['switches']},"
             f"level_hist={'/'.join(f'{k}:{v}' for k, v in r['qos']['level_hist'].items())}")


def main(emit=print, smoke: bool = False) -> bool:
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
    params = transformer.init_model(jax.random.key(0), cfg)
    duration = 10.0 if smoke else 20.0
    qos = _qos(cfg.d_model)

    # -- the gate: 2x overload burst, static vs adaptive, same seed --------
    static = run_loadgen(cfg, _scenario(7, duration, None), params=params)
    adaptive = run_loadgen(cfg, _scenario(7, duration, qos), params=params)
    _emit_run(emit, "static", static)
    _emit_run(emit, "adaptive", adaptive)

    adaptive_ok = (adaptive["slo"]["ok"]
                   and adaptive["sessions"]["failed"] == 0)
    static_violates = not static["slo"]["ok"]
    no_sleeps = (static["cv_waits"] == 0 and adaptive["cv_waits"] == 0)
    # the streaming P² estimate must track the exact p99 it will replace
    # at scale (parity is pinned tighter on adversarial distributions in
    # tests/test_loadgen.py; this checks the live traffic distribution)
    p2_ok = all(
        abs(r["latency_ms"]["p2_p99_ms"] - r["latency_ms"]["p99_ms"])
        <= 0.25 * r["latency_ms"]["p99_ms"]
        for r in (static, adaptive))
    emit(f"loadgen_check,adaptive,holds_p99_slo_under_burst,{adaptive_ok}")
    emit(f"loadgen_check,static,violates_slo_under_burst,{static_violates}")
    emit(f"loadgen_check,harness,virtual_clock_no_real_sleeps,{no_sleeps}")
    emit(f"loadgen_check,quantiles,p2_tracks_exact_p99,{p2_ok}")
    ok = adaptive_ok and static_violates and no_sleeps and p2_ok

    section = {"smoke": bool(smoke), "arch": cfg.name,
               "slo": {"p99_ms": SLO.p99_ms,
                       "max_reject_frac": SLO.max_reject_frac},
               "qos_ladder": [list(r) for r in qos.ladder()],
               "static": _strip(static), "adaptive": _strip(adaptive)}

    if not smoke:
        # heterogeneous calm fleet: mixed compressor population, think
        # times, tighter bandwidth — the report scenario (no gate beyond
        # completing within SLO at calm utilization)
        calm = LoadGenConfig(
            seed=13, duration_s=duration,
            arrivals=ArrivalSpec(process="poisson", rate=10.0),
            fleet=FleetSpec(
                compressors=("randtopk:k=16", "randtopk_quant:k=16,bits=8",
                             "identity"),
                weights=(0.6, 0.3, 0.1), prompt_len=(2, 4), gen=(4, 8),
                think_s=0.02, bandwidth_Bps=200_000.0),
            service=SERVICE, slo=MIXED_SLO, qos=None, capacity=32,
            max_batch=8, max_wait=0.004, admission_depth=48)
        mixed = run_loadgen(cfg, calm, params=params)
        _emit_run(emit, "mixed_fleet", mixed)
        mixed_ok = (mixed["slo"]["ok"] and mixed["sessions"]["failed"] == 0)
        emit(f"loadgen_check,mixed_fleet,calm_within_slo,{mixed_ok}")
        ok &= mixed_ok
        section["mixed_fleet"] = _strip(mixed)

        # second seed for the burst gate: the qualitative outcome must not
        # be a one-seed accident
        static2 = run_loadgen(cfg, _scenario(11, duration, None),
                              params=params)
        adaptive2 = run_loadgen(cfg, _scenario(11, duration, qos),
                                params=params)
        _emit_run(emit, "static_seed11", static2)
        _emit_run(emit, "adaptive_seed11", adaptive2)
        seed2_ok = (adaptive2["slo"]["ok"] and not static2["slo"]["ok"])
        emit(f"loadgen_check,seed11,adaptive_beats_static,{seed2_ok}")
        ok &= seed2_ok

    section["ok"] = bool(ok)
    # merge into the serving bench's JSON without clobbering its gate
    data = {}
    if BENCH_PATH.exists():
        try:
            data = json.loads(BENCH_PATH.read_text())
        except ValueError:
            data = {}
    data["loadgen"] = section
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    emit(f"loadgen,wrote,{BENCH_PATH.name}")
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="burst gate only, 10s virtual duration")
    args = ap.parse_args()
    sys.exit(0 if main(smoke=args.smoke) else 1)
