"""Fedtrain convergence: accuracy per measured wire byte across policies.

Runs the over-the-wire training engine (`repro.fedtrain`) on the tabular
dataset with four policies — fixed-k topk, fixed-k randtopk, adaptive-k
(dense warmup -> anneal -> loss-plateau drops), and async local steps — and
scores each by final accuracy per *measured* up+down payload byte (every
byte counted off a real frame). Claims checked:

  * randtopk's measured up+down bytes match the Table-2 fwd+bwd analytics
    within 5% (the acceptance bar, same rule as the serving bench);
  * adaptive-k and async both finish with accuracy-per-byte >= fixed-k topk
    (they spend strictly fewer bytes for comparable accuracy).

    PYTHONPATH=src python benchmarks/fedtrain_convergence.py --smoke
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.data.synthetic import ManyClassDataset
from repro.fedtrain import AsyncPolicy, ScheduleSpec, run_fedtrain
from repro.split.tabular import SplitSpec

TOL = 0.05  # measured-vs-analytic relative tolerance (acceptance bar)
K = 9       # medium compression (paper's Table-3 middle operating point)


def _setup(smoke: bool):
    if smoke:
        ds = ManyClassDataset(n_classes=20, in_dim=32, n_train=2560,
                              n_test=1024, noise=0.3, seed=0)
        spec = SplitSpec(in_dim=32, hidden=128, cut_dim=64, n_classes=20,
                         method="randtopk", k=K, lr=2e-3)
        epochs = int(os.environ.get("REPRO_BENCH_EPOCHS", "3"))
    else:
        ds = ManyClassDataset(n_classes=100, in_dim=64, n_train=20000,
                              n_test=4000, noise=0.3, seed=0)
        spec = SplitSpec(in_dim=64, hidden=512, cut_dim=128, n_classes=100,
                         method="randtopk", k=K, lr=2e-3)
        epochs = int(os.environ.get("REPRO_BENCH_EPOCHS", "12"))
    return ds, spec, epochs


def main(emit=print, smoke: bool = False) -> bool:
    import dataclasses

    ds, base, epochs = _setup(smoke)
    d = base.cut_dim
    steps_hint = epochs * (ds.n_train // 2 // 128)  # per client, 2 clients
    # schedule phases scale with run length so the dense warmup amortizes
    runs = {
        "topk": dict(spec=dataclasses.replace(base, method="topk")),
        "randtopk": dict(spec=base),
        "adaptive": dict(spec=base, schedule=ScheduleSpec(
            k=K, d=d, warmup_steps=steps_hint // 60,
            anneal_steps=max(4, steps_hint // 10), k0=min(d, K + K // 3),
            # patience capped: late plateau drops pay full-k bytes all run
            # yet evaluate at the dropped k — worst of both trades
            k_min=K // 2, patience=min(10, max(3, steps_hint // 15)),
            drop=0.6, min_rel_improve=5e-3)),
        "async": dict(spec=base, policy=AsyncPolicy(local_steps=2,
                                                    warmup_sync=8)),
    }

    results = {}
    for name, kw in runs.items():
        spec = kw.pop("spec")
        r = run_fedtrain(spec, ds, n_clients=2, epochs=epochs, batch=128,
                         seed=0, **kw)
        payload = r["payload_bytes_up"] + r["payload_bytes_down"]
        acc = r["mean_test_acc"]
        results[name] = dict(acc=acc, bytes=payload,
                             acc_per_mb=acc / (payload / 1e6), res=r)
        emit(f"fedtrain,{name},steps={r['steps']},acc={acc:.4f},"
             f"payload_B={payload},framing_B={r['header_bytes']},"
             f"acc_per_MB={results[name]['acc_per_mb']:.3f},"
             f"final_k={max(r['final_k'])},wall_s={r['wall_s']:.1f}")
        for step, loss in r["losses"][0][:: max(1, r["steps"] // 8)]:
            emit(f"fedtrain_trace,{name},{step},{loss:.4f}")

    # measured == analytic for the fixed-k randtopk run (both directions)
    r = results["randtopk"]["res"]
    ok_bytes = True
    for direction in ("up", "down"):
        m = r[f"payload_bytes_{direction}"]
        a = r[f"analytic_bytes_{direction}"]
        rel = abs(m - a) / a
        ok = rel < TOL
        ok_bytes &= ok
        emit(f"fedtrain,randtopk_bytes_{direction},measured_B={m},"
             f"analytic_B={a:.0f},rel_err={rel:.4f}")
    emit(f"fedtrain_check,randtopk_bytes_within_5pct,{ok_bytes}")

    checks = {"bytes": ok_bytes}
    for name in ("adaptive", "async"):
        ok = results[name]["acc_per_mb"] >= results["topk"]["acc_per_mb"]
        checks[name] = ok
        emit(f"fedtrain_check,{name}_acc_per_byte>=topk,{ok}")
    return all(checks.values())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced dataset/epochs (CI-speed)")
    args = ap.parse_args()
    sys.exit(0 if main(smoke=args.smoke) else 1)
