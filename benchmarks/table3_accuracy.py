"""Paper Table 3 (CIFAR-100 block) — accuracy vs compressed size for all
methods at High/Medium/Low compression, on the synthetic 100-class task.

Validated claims (paper Section 5.2):
  * RandTopk >= Topk at every compression level;
  * Topk and RandTopk >> size reduction at high compression (many classes);
  * quantization only reaches moderate compression (b-bit floor);
  * vanilla (no compression) is the accuracy ceiling.
"""
import numpy as np

from benchmarks.common import EPOCHS, SEEDS, dataset, spec
from repro.split.tabular import train

LEVELS = {"high": 3, "medium": 6, "low": 13}


def run_method(method, seeds=SEEDS, **kw):
    accs, sizes = [], []
    for s in range(seeds):
        r = train(spec(method, **kw), dataset(), epochs=EPOCHS, seed=s)
        accs.append(r["test_acc"])
        sizes.append(r["compressed_size_pct"])
    return float(np.mean(accs)), float(np.std(accs)), float(np.mean(sizes))


def main(emit=print):
    results = {}
    acc, std, size = run_method("none")
    results[("none", "-")] = (acc, std, size)
    emit(f"table3,none,-,{acc:.4f},{std:.4f},{size:.2f}")
    for level, k in LEVELS.items():
        for method in ["randtopk", "topk", "size_reduction"]:
            kw = {"k": k}
            if method == "randtopk":
                kw["alpha"] = 0.1
            acc, std, size = run_method(method, **kw)
            results[(method, level)] = (acc, std, size)
            emit(f"table3,{method},{level},{acc:.4f},{std:.4f},{size:.2f}")
    # quantization: only 4-bit (12.5%) is in the Low band
    acc, std, size = run_method("quant", quant_bits=4)
    results[("quant", "low")] = (acc, std, size)
    emit(f"table3,quant,low,{acc:.4f},{std:.4f},{size:.2f}")
    acc, std, size = run_method("l1", l1_lam=1e-3)
    results[("l1", "-")] = (acc, std, size)
    emit(f"table3,l1,-,{acc:.4f},{std:.4f},{size:.2f}")

    # ---- validated orderings
    checks = {}
    for level in LEVELS:
        checks[f"randtopk>=topk@{level}"] = (
            results[("randtopk", level)][0] >=
            results[("topk", level)][0] - 0.01)
        checks[f"topk>sizered@{level}"] = (
            results[("topk", level)][0] > results[("size_reduction",
                                                   level)][0])
    checks["none_is_ceiling"] = all(
        results[("none", "-")][0] >= v[0] - 0.02 for v in results.values())
    for name, ok in checks.items():
        emit(f"table3_check,{name},{ok}")
    return results, checks


if __name__ == "__main__":
    main()
