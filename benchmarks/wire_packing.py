"""Micro-benchmark: vectorized numpy bit packing vs the per-bit Python loop.

`wire._pack_bits` / `_unpack_bits` used to walk every (value, bit) pair in
Python; both are now the two-aligned-word scheme (pack ORs each of 64
lanes into its at most two aligned uint64 words, unpack assembles each
value from two aligned words of the stream) — no (n, width) bit matrix is
ever materialized in either direction. This bench keeps the historical
per-bit implementations inline as the baseline, verifies byte-identical
streams and value-identical unpacks in both directions (including the
full-uint32 and full-uint64 widths the device mask/pack kernels lean on),
and reports both speedups.

    PYTHONPATH=src python -m benchmarks.wire_packing
"""
import time

import numpy as np

from repro.core import wire


def _pack_bits_loop(vals: np.ndarray, width: int) -> bytes:
    """Historical reference: per-(value, bit) Python loop."""
    vals = vals.astype(np.uint64).ravel()
    nbits = int(vals.size) * width
    out = np.zeros((nbits + 7) // 8, dtype=np.uint8)
    for i, v in enumerate(vals.tolist()):
        base = i * width
        for b in range(width):
            if (v >> b) & 1:
                out[(base + b) >> 3] |= 1 << ((base + b) & 7)
    return out.tobytes()


def _unpack_bits_loop(buf: bytes, width: int, count: int) -> np.ndarray:
    arr = np.frombuffer(buf, dtype=np.uint8)
    out = np.zeros(count, dtype=np.uint64)
    for i in range(count):
        base = i * width
        v = 0
        for b in range(width):
            if arr[(base + b) >> 3] & (1 << ((base + b) & 7)):
                v |= 1 << b
        out[i] = v
    return out


def _time(fn, reps=5):
    fn()  # warm-up
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def main(emit=print):
    rng = np.random.RandomState(0)
    ok_all = True
    for n, width in [(4096, 4), (65536, 7), (65536, 12), (65536, 16),
                     (65536, 32), (16384, 64)]:
        hi = min(2 ** width, 2 ** 63)   # randint bound caps at int64
        vals = rng.randint(0, hi, size=n).astype(np.uint64)
        ref = _pack_bits_loop(vals, width)
        new = wire._pack_bits(vals, width)
        same = ref == new
        back = wire._unpack_bits(new, width, n)
        # unpack must be value-identical to both the pack input and the
        # per-bit reference unpack (byte-identical wire, both directions)
        same &= bool((back == vals).all())
        same &= bool((_unpack_bits_loop(new, width, n) == back).all())
        # ragged tail: a count that does not fill the last byte/word
        for cut in (n - 1, n - 7, 1):
            part = wire._unpack_bits(new, width, cut)
            same &= bool((part == vals[:cut]).all())
        ok_all &= same
        t_loop = _time(lambda: _pack_bits_loop(vals, width), reps=3)
        t_vec = _time(lambda: wire._pack_bits(vals, width))
        t_uloop = _time(lambda: _unpack_bits_loop(new, width, n), reps=3)
        t_uvec = _time(lambda: wire._unpack_bits(new, width, n))
        emit(f"wire_packing,n={n},width={width},loop_ms={t_loop*1e3:.2f},"
             f"vectorized_ms={t_vec*1e3:.3f},"
             f"speedup={t_loop/max(t_vec, 1e-9):.0f}x,match={same}")
        emit(f"wire_unpacking,n={n},width={width},"
             f"loop_ms={t_uloop*1e3:.2f},vectorized_ms={t_uvec*1e3:.3f},"
             f"speedup={t_uloop/max(t_uvec, 1e-9):.0f}x")
    emit(f"wire_packing_check,vectorized_matches_loop,{ok_all}")
    return ok_all


if __name__ == "__main__":
    main()
