"""Roofline summary from the checked-in dry-run JSONs (does not recompile;
run `python -m repro.launch.dryrun --all --json dryrun_singlepod.json` to
regenerate the inputs)."""
import json
import os


def main(emit=print):
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_singlepod.json")
    if not os.path.exists(path):
        emit("roofline_report,skipped,no dryrun_singlepod.json")
        return None
    data = json.load(open(path))
    rows = data["rows"]
    emit(f"roofline_report,rows,{len(rows)}")
    emit(f"roofline_report,failures,{len(data['failures'])}")
    by_bneck = {}
    for r in rows:
        by_bneck.setdefault(r["bottleneck"], []).append(r)
    for b, rs in sorted(by_bneck.items()):
        emit(f"roofline_report,bottleneck_{b},{len(rs)}")
    worst = max(rows, key=lambda r: (max(r["t_compute_s"], r["t_memory_s"],
                                         r["t_collective_s"])
                                     / max(r["t_compute_s"], 1e-9)))
    emit(f"roofline_report,worst_fraction,{worst['arch']}x{worst['shape']}")
    emit(f"roofline_check,all_combinations_lower,{len(data['failures']) == 0}")
    return rows


if __name__ == "__main__":
    main()
