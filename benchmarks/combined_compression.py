"""Beyond-paper: sparsification + quantization combined (the direction the
paper's conclusion names as promising future work).

Claim validated here: at matched-or-smaller compressed size, spending the
byte budget on a LARGER top-k support with low-bit values dominates fp32
values on a small support.
"""
import numpy as np

from benchmarks.common import EPOCHS, dataset, spec
from repro.core import wire
from repro.split.tabular import train

D = 128


def main(emit=print):
    rows = {}
    for name, method, kw in [
        ("randtopk_fp32_k3", "randtopk", dict(k=3, alpha=0.1)),
        ("randtopk_fp32_k6", "randtopk", dict(k=6, alpha=0.1)),
        ("randtopk_q8_k7", "randtopk_quant",
         dict(k=7, alpha=0.1, quant_bits=8)),
        ("randtopk_q4_k12", "randtopk_quant",
         dict(k=12, alpha=0.1, quant_bits=4)),
    ]:
        r = train(spec(method, **kw), dataset(), epochs=EPOCHS, seed=0)
        size = wire.table2_row(method, D, k=kw["k"],
                               bits=kw.get("quant_bits", 0))["fwd"] * 100
        rows[name] = (r["test_acc"], size)
        emit(f"combined,{name},{r['test_acc']:.4f},{size:.2f}")
    checks = {
        # 4-bit k=12 (4.79%) must beat fp32 k=6 (5.71%) — better accuracy at
        # fewer bytes
        "q4_k12_beats_fp32_k6_at_fewer_bytes":
            rows["randtopk_q4_k12"][0] > rows["randtopk_fp32_k6"][0]
            and rows["randtopk_q4_k12"][1] < rows["randtopk_fp32_k6"][1],
        "q8_k7_beats_fp32_k3":
            rows["randtopk_q8_k7"][0] > rows["randtopk_fp32_k3"][0],
    }
    for name, ok in checks.items():
        emit(f"combined_check,{name},{ok}")
    return rows, checks


if __name__ == "__main__":
    main()
