"""Paper Table 7 analogue — a SECOND task geometry (DBPedia: d=600 cut,
219 classes) to confirm the method ordering is not an artifact of the
CIFAR-like geometry. k=2 reproduces the paper's 0.44% "High+" compressed
size; k=9 its 1.97% "Medium"."""
import numpy as np

from benchmarks.common import EPOCHS
from repro.data.synthetic import ManyClassDataset
from repro.split.tabular import SplitSpec, train

_DS = None


def dataset():
    global _DS
    if _DS is None:
        _DS = ManyClassDataset(n_classes=219, in_dim=128, n_train=20000,
                               n_test=4000, noise=0.25, seed=1)
    return _DS


def main(emit=print):
    results = {}
    for name, method, kw in [
        ("none", "none", {}),
        ("randtopk_k2", "randtopk", dict(k=2, alpha=0.1)),
        ("topk_k2", "topk", dict(k=2)),
        ("sizered_k2", "size_reduction", dict(k=2)),
        ("randtopk_k9", "randtopk", dict(k=9, alpha=0.1)),
        ("topk_k9", "topk", dict(k=9)),
        ("sizered_k9", "size_reduction", dict(k=9)),
    ]:
        sp = SplitSpec(method=method, cut_dim=600, n_classes=219,
                       in_dim=128, hidden=512, lr=2e-3, **kw)
        r = train(sp, dataset(), epochs=max(10, EPOCHS // 2), seed=0)
        results[name] = r["test_acc"]
        emit(f"table7,{name},{r['test_acc']:.4f},"
             f"{r['compressed_size_pct']:.2f}")
    checks = {
        "randtopk>=topk@high+": results["randtopk_k2"] >=
            results["topk_k2"] - 0.01,
        "topk>sizered@high+": results["topk_k2"] > results["sizered_k2"],
        "randtopk>=topk@medium": results["randtopk_k9"] >=
            results["topk_k9"] - 0.01,
        "topk>sizered@medium": results["topk_k9"] > results["sizered_k9"],
    }
    for name, ok in checks.items():
        emit(f"table7_check,{name},{ok}")
    return results, checks


if __name__ == "__main__":
    main()
