"""Streaming serving throughput + measured wire bytes (clients x compressor).

Runs the `repro.runtime` engine over a sweep of concurrent-client counts and
cut-layer compressors (including a mixed dense/randtopk population in one
session mix), and reports bytes/client/token from the *measured* payload
frame sizes, cross-checked within 5% against the compressors' own
`fwd_bits` accounting — the same analytics `benchmarks/table2_sizes.py`
validates byte-exactly against the Table-2 rows. The latest run's
trajectory point is written to the repo-root `BENCH_serve.json`
(overwritten each run; history lives in version control).

Timing hygiene: every jit in the hot loop (per-compressor bottom steps, the
server's per-(meta, bucket) slot decodes, the fused decode+step, the donated
arena step) is compiled AND executed once by the engine's warmup before its
clock starts, so `tokens_per_s` never folds compile time into the first row
of a sweep. Each row also carries the serve loop's per-TOKEN stage costs
(host staging / fused-or-plain step / reply, normalized by the tokens the
flushes served), the host staging-vs-wire byte ratio, and the clients'
p50/p95 request->token latency.

Roofline audit: every serving program (per-kind slot decode, per-kind fused
decode+step) is lowered, compiled, and costed with `roofline.hlo
.program_costs`, then compared against the analytic predictions in
`roofline.analysis` (`serving_decode_costs` / `serving_step_costs`). The
predicted-vs-measured flops/bytes rows land in BENCH_serve.json under
`roofline`; tolerances are the calibrated bands documented there and in
docs/performance.md.

Perf gate (run by `scripts/ci.sh --smoke`): the randtopk/identity
tokens-per-second ratio at the largest client count served by both pure
mixes must stay above `RATIO_FLOOR` — the compressed path must remain the
fast path; the ratio, the floor, and each gate run's per-stage
encode/decode/step split are recorded in the JSON. A second,
observability gate runs the same engine with a live `obs.trace.Tracer` +
metrics registry and requires the tracing-on/off throughput ratio to stay
above `OBS_RATIO_FLOOR` (the `obs` section of BENCH_serve.json;
scripts/trace_smoke.py re-checks it). A third, client-encode gate pits
the device wire path (`device_encode=True`: packed sections pulled +
truncated, `kernels.encode`) against the host codec baseline and requires
the per-frame host pack time to drop by `ENCODE_SPEEDUP_FLOOR`; and a
mask-crossover audit asserts the `mask` payload beats u16-index sparse
byte-exactly where Table 2 predicts (k/d > 1/16) and nowhere else.

Each run also appends ONE summary row (gate throughput, encode gate,
bytes/token per compressor) to the repo-root `BENCH_history.jsonl` — the
append-only trend line the overwritten JSON cannot provide.

    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import compressors, wire
from repro.models import transformer
from repro.models.config import Runtime, SplitConfig
from repro.obs.trace import Tracer
from repro.roofline import analysis, hlo as hlo_mod
from repro.runtime import engine, steps
from repro.split import protocol

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = ROOT / "BENCH_serve.json"
#: append-only one-row-per-run history (BENCH_serve.json is overwritten
#: each run): gate throughput + bytes/token, for trend lines across runs
HISTORY_PATH = ROOT / "BENCH_history.jsonl"

TOL = 0.05  # measured-vs-analytic relative tolerance (acceptance bar)

#: perf-smoke floor: randtopk must serve at least this fraction of
#: identity's tokens/s in pure 8-client mixes. The pre-arena host-densify
#: loop sat at ~0.54; the fused decode+step serving path measures
#: 0.85-1.05 (the two mixes pay near-identical client and server work, so
#: only thread-scheduling noise separates them; runs are sub-second and
#: the gate takes the median of GATE_REPS dedicated runs per mix). 0.8
#: keeps the compressed path honest while absorbing CI jitter.
RATIO_FLOOR = 0.8
GATE_REPS = 5
GATE_CLIENTS = 8
#: tokens generated per session in each gate run. Long enough that the
#: flush cadence locks into full 8-row batches for most of the run —
#: short runs (gen<=32) spend a third of their wall in session ramp and
#: under-report steady-state tokens/s by ~15% on a single-core box.
GATE_GEN = 48

#: observability overhead gate: the fully-instrumented hot path (live
#: `obs.trace.Tracer` + per-run registry counters) must keep at least this
#: fraction of un-traced throughput — the measured cost of the telemetry
#: layer (docs/observability.md). Median of OBS_REPS interleaved run pairs.
#: 0.90, not 0.95: the device encode path cut per-token host work ~3x, so
#: the telemetry layer's fixed per-span cost is a larger fraction of a
#: faster loop AND the median-of-5 ratio itself spreads 0.93-1.06 across
#: identical trials on a loaded single-core box — 0.95 sat inside that
#: noise band.
OBS_RATIO_FLOOR = 0.90
OBS_REPS = 5

#: client-encode gate: the device wire path (`device_encode=True`, packed
#: sections pulled + truncated) must cut the host pack time per frame by at
#: least this factor vs the host codec baseline (`device_encode=False`,
#: numpy bit-pack per frame). Microbenchmarked at ~6x on the smoke config;
#: 2x absorbs thread-scheduling noise in the full engine. Median of
#: ENCODE_REPS interleaved run pairs, randtopk mix.
ENCODE_SPEEDUP_FLOOR = 2.0
ENCODE_REPS = 5

#: the serving-kernel roofline audit covers one payload kind per wire
#: format the compressors can emit
AUDIT_SPECS = ("identity", "randtopk:k=16", "quant:bits=4",
               "randtopk_quant:k=16,bits=8", "randtopk_mask:k=16")


def _codec_frame_payload_nbytes(cfg, comp) -> int:
    """Exact payload bytes one serving frame of `comp` carries — the codec's
    own bitstream length for a (1, 1, d) activation, independent of any
    framing (version byte, CRC trailer, subheaders)."""
    p = protocol.client_encode(
        comp, jax.numpy.zeros((1, 1, cfg.d_model), np.float32),
        key=jax.random.key(0), training=False)
    return wire.payload_nbytes(p)


def _mix_rows(cfg, res, emit) -> list:
    """Per-compressor rows of one run: measured vs analytic bytes, plus the
    clients' per-token round-trip latency percentiles."""
    rows = []
    by_comp = {}
    lat_by_comp = {}
    wire_fields = ("frames_up", "payload_bytes_up", "header_bytes_up",
                   "frames_down", "bytes_down")
    for comp, cs, ss, lat in zip(res["compressor_objs"], res["client_stats"],
                                 res["server_stats"],
                                 res["client_latencies"]):
        # both parties count the same bytes off the same frames
        # (tokens_out is client-side only: the server never sees the prompt)
        assert all(cs[f] == ss[f] for f in wire_fields), (cs, ss)
        by_comp.setdefault(comp, []).append(cs)
        lat_by_comp.setdefault(comp, []).extend(lat)
    for comp, stats in sorted(by_comp.items(), key=lambda kv: kv[0].name):
        name = comp.name
        measured = float(np.mean(
            [s["payload_bytes_up"] / s["frames_up"] for s in stats]))
        header = float(np.mean(
            [s["header_bytes_up"] / s["frames_up"] for s in stats]))
        lats = np.asarray(lat_by_comp[comp])
        p50_ms = float(np.percentile(lats, 50) * 1e3)
        p95_ms = float(np.percentile(lats, 95) * 1e3)
        # the compressor's own Table-2 accounting (incl. quant range headers);
        # byte-exact vs table2_row in benchmarks/table2_sizes.py
        analytic = comp.fwd_bits(cfg.d_model) / 8
        rel_err = abs(measured - analytic) / analytic
        ok = rel_err < TOL
        # frame-integrity overhead (version byte + CRC32 trailer) is framing,
        # never payload: measured payload bytes must equal the codec's own
        # bitstream length exactly — byte-identical to the pre-CRC format
        codec_B = _codec_frame_payload_nbytes(cfg, comp)
        payload_exact = all(
            s["payload_bytes_up"] == s["frames_up"] * codec_B
            for s in stats)
        integrity = wire.FRAME_INTEGRITY_NBYTES
        rows.append(dict(compressor=name, n_sessions=len(stats),
                         measured_B_per_token=measured,
                         framing_B_per_token=header,
                         integrity_B_per_frame=integrity,
                         analytic_B_per_token=analytic, rel_err=rel_err,
                         payload_exact=bool(payload_exact),
                         latency_p50_ms=p50_ms, latency_p95_ms=p95_ms,
                         ok=bool(ok and payload_exact)))
        emit(f"serve,{name},sessions={len(stats)},"
             f"measured_B={measured:.1f},analytic_B={analytic:.1f},"
             f"framing_B={header:.1f},rel_err={rel_err:.4f}")
        emit(f"serve,{name},integrity_B_per_frame={integrity}"
             f",framing_B_per_frame={header:.1f}"
             f",payload_B_per_frame={codec_B}")
        emit(f"serve,{name},latency_p50_ms={p50_ms:.2f},"
             f"latency_p95_ms={p95_ms:.2f}")
        emit(f"serve_check,{name},bytes_within_5pct,{ok}")
        emit(f"serve_check,{name},payload_bytes_codec_exact,{payload_exact}")
    return rows


def _roofline_rows(cfg, params, emit) -> list:
    """Predicted-vs-measured (flops, bytes) audit of the serving programs.

    Lowers + compiles the exact jitted pair the engine serves with (shared
    via `engine._serving_steps`, so the audit also pre-populates the
    serving jit cache) plus the client's fused device-encode program
    (`protocol.client_encode_device`), walks the optimized HLO with
    `roofline.hlo.program_costs`, and checks each program against the
    analytic predictions: decode AND encode flops must be exactly zero (no
    dots — the kernels' zero-dot-flops budget), fused-step flops within
    `FUSED_FLOPS_RTOL`, and every byte count within its calibrated band
    above the traffic floor.
    """
    rt = Runtime(mesh=None, training=False)
    cut = cfg.split.cut_layer
    cap, rows, max_len = GATE_CLIENTS, GATE_CLIENTS, 4 + 16
    d = cfg.d_model
    top_jit, fused_jit = engine._serving_steps(cfg, rt, cut, cfg.dtype, None)
    xbuf = jnp.zeros((cap + 1, 1, 1, d), jnp.float32)
    cache = jax.tree.map(
        lambda a: jnp.stack([a] * cap),
        transformer.init_cache(params, cfg, rt, 1, max_len))
    state_nbytes = sum(l.nbytes for l in jax.tree.leaves(cache)) + xbuf.nbytes
    active = jnp.zeros((cap,), bool)
    slots = np.full(rows, cap, np.int64)
    x = jax.random.normal(jax.random.key(1), (rows, 1, 1, d), jnp.float32)
    decode_jit = jax.jit(
        lambda xb, p, sl: protocol.decode_to_slots_in_jit(
            xb, p, sl, dtype=cfg.dtype, backend=None))

    xrows = x.reshape(rows, 1, d)   # the client's per-step activation rows

    out = []
    for spec in AUDIT_SPECS:
        comp = compressors.make_compressor(spec)
        payload = comp.encode(x, training=False)
        kind = payload.meta.kind
        encode_jit = jax.jit(
            lambda xr, comp=comp: protocol.client_encode_device(comp, xr))
        for program, (mf, mb) in (
                ("encode", hlo_mod.program_costs(
                    encode_jit.lower(xrows).compile().as_text())),
                ("decode", hlo_mod.program_costs(
                    decode_jit.lower(xbuf, payload, slots)
                    .compile().as_text())),
                ("fused_step", hlo_mod.program_costs(
                    fused_jit.lower(params, xbuf, payload, slots, cache,
                                    active).compile().as_text()))):
            if program == "encode":
                pf, pb = analysis.serving_encode_costs(rows, d)
                flops_ok = mf == pf        # no dots in an encode, ever
                lo, hi = analysis.ENCODE_BYTES_BAND
            elif program == "decode":
                pf, pb = analysis.serving_decode_costs(rows, d)
                flops_ok = mf == pf        # no dots in a decode, ever
                lo, hi = analysis.DECODE_BYTES_BAND
            else:
                pf, pb = analysis.serving_step_costs(cfg, cut, cap, max_len,
                                                     state_nbytes)
                flops_ok = abs(mf - pf) <= analysis.FUSED_FLOPS_RTOL * pf
                lo, hi = analysis.FUSED_BYTES_BAND
            ratio = mb / pb
            bytes_ok = lo <= ratio <= hi
            out.append(dict(
                program=program, kind=kind, compressor=comp.name,
                predicted_flops=pf, measured_flops=mf,
                predicted_bytes_floor=pb, measured_bytes=mb,
                bytes_ratio=round(ratio, 3),
                bytes_band=[lo, hi],
                ok=bool(flops_ok and bytes_ok)))
            emit(f"roofline,{program},{kind},"
                 f"flops_pred={pf:.4g},flops_meas={mf:.4g},"
                 f"bytes_floor={pb:.4g},bytes_meas={mb:.4g},"
                 f"bytes_ratio={ratio:.2f}")
            emit(f"roofline_check,{program},{kind},"
                 f"predicted_vs_measured,{bool(flops_ok and bytes_ok)}")
    return out


def _mask_crossover_rows(cfg, emit) -> list:
    """The mask payload's byte-crossover claim, asserted against Table 2.

    For every (d, k) with k/d > 1/16 the MEASURED mask payload (k floats +
    one packed d-bit support mask per row) must be byte-exactly smaller
    than the u16-index sparse baseline (4k value + 2k index bytes per
    row); at or below the threshold it must NOT win. Measured bytes must
    also equal the Table-2 forward rate exactly
    (`wire.table2_row("randtopk_mask")` -> 4k + ceil(d/8) bytes/row)."""
    rows = []
    for d in sorted({64, 256, cfg.d_model}):
        ks = sorted({max(1, d // 32), d // 16, d // 16 + 1, d // 8, d // 4})
        for k in ks:
            comp = compressors.make_compressor(f"randtopk_mask:k={k}")
            p = comp.encode(jnp.zeros((1, 1, d), jnp.float32),
                            training=False)
            measured = wire.payload_nbytes(p)
            table2_B = wire.table2_row("randtopk_mask", d, k=k)["fwd"] * d * 4
            u16_B = 4 * k + 2 * k
            wins = measured < u16_B
            expect_win = k / d > 1 / 16
            ok = measured == table2_B and wins == expect_win
            rows.append(dict(d=d, k=k, mask_B=measured,
                             u16_sparse_B=u16_B, table2_B=table2_B,
                             wins=bool(wins), expected_win=bool(expect_win),
                             ok=bool(ok)))
            emit(f"serve,mask_crossover,d={d},k={k},mask_B={measured},"
                 f"u16_sparse_B={u16_B},wins={wins},expected={expect_win}")
    ok_all = all(r["ok"] for r in rows)
    emit(f"serve_check,mask_crossover,table2_exact_and_crossover,{ok_all}")
    return rows


def _capacity_meshes(smoke: bool):
    """(n_devices, mesh-axis overrides) sweep points. Data-only meshes
    scale slots; the model/pod meshes additionally exercise (and audit)
    the sharded step's collectives."""
    devs = (1, 8) if smoke else (1, 2, 4, 8)
    specs = [(n, {}) for n in devs]
    specs += ([(8, {"model": 4})] if smoke
              else [(8, {"model": 4}), (8, {"model": 2, "pod": 2})])
    return specs


def _collective_audit_row(cfg, params, mesh, emit) -> dict:
    """Predicted-vs-parsed collective bytes of the sharded arena step at
    `mesh` — the `serving_step_costs` companion for the collective ring
    term. Intrinsic collectives (the Megatron row gather, the exact-argmax
    pmax/pmin, the pod-ring permutes) must match
    `analysis.serving_collective_costs` byte-exactly; partitioner staging
    on top is bounded by `analysis.serving_collective_slack` per op."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    rt = Runtime(mesh=None, training=False)
    cut = cfg.split.cut_layer
    cap = 8
    cache = jax.tree.map(
        lambda a: jnp.stack([a] * cap),
        transformer.init_cache(params, cfg, rt, 1, 8))
    xbuf = jnp.zeros((cap + 1, 1, 1, cfg.d_model), jnp.float32)
    active = jnp.ones((cap,), bool)
    axes = tuple(mesh.axis_names)
    rows = axes if len(axes) > 1 else axes[0]
    rep = NamedSharding(mesh, P())
    row = lambda a: NamedSharding(                          # noqa: E731
        mesh, P(rows, *([None] * (a.ndim - 1))))
    step = __import__("repro.runtime.steps", fromlist=["steps"]) \
        .make_arena_top_step(cfg, rt, cut, mesh=mesh)
    in_sh = (jax.tree.map(lambda a: rep, params), rep,
             jax.tree.map(row, cache), rep)
    txt = jax.jit(step, in_shardings=in_sh).lower(
        params, xbuf, cache, active).compile().as_text()
    stats = hlo_mod.collective_bytes(txt)
    pred, pred_total = analysis.serving_collective_costs(
        cfg, cap, dict(mesh.shape))
    slack = analysis.serving_collective_slack(cfg, cap, dict(mesh.shape))
    ok = True
    for op in sorted(set(pred) | set(stats.raw_bytes)):
        m = stats.raw_bytes.get(op, 0.0)
        p = pred.get(op, 0.0)
        ok &= p - 1e-9 <= m <= p + slack.get(op, 0.0) + 1e-9
    mesh_desc = "x".join(f"{a}{s}" for a, s in mesh.shape.items())
    emit(f"capacity,collectives,mesh={mesh_desc},"
         f"pred_link_B={pred_total:.0f},"
         f"meas_link_B={stats.total_link_bytes:.0f},ok={ok}")
    return dict(mesh=dict(mesh.shape), predicted_B=pred,
                measured_B=stats.raw_bytes,
                predicted_link_total_B=pred_total,
                measured_link_total_B=stats.total_link_bytes,
                slack_B=slack, ok=bool(ok))


def _counter_total(snap: dict, name: str) -> int:
    return int(sum(r["value"]
                   for r in snap.get(name, {}).get("series", [])))


def capacity_worker(smoke: bool, emit=print) -> dict:
    """Runs in a dedicated 8-forced-device subprocess: the slots x devices
    capacity/utilization sweep plus the sharded-step collective audit.

    Every sweep point must serve tokens BIT-IDENTICAL to the uncontended
    single-device reference (eviction/readmission and row sharding are
    invisible to clients); contended points (2 admitted slots, 8 sessions)
    must actually evict and readmit."""
    from repro.launch.mesh import make_serving_mesh

    assert jax.device_count() == 8, jax.device_count()
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = dict(n_clients=8, prompt_len=2, gen=6, max_batch=4,
              max_wait=0.02, params=params, seed=0)
    ref = engine.run_streaming(cfg, **kw)["tokens"]

    points, ok = [], True
    for n_dev, spec in _capacity_meshes(smoke):
        mesh = make_serving_mesh(n_dev, **spec)
        mesh_desc = "x".join(f"{a}{s}" for a, s in mesh.shape.items())
        for slots in (2, 8):
            gc.collect()
            res = engine.run_streaming(cfg, mesh=mesh, capacity=slots, **kw)
            snap = res["metrics"]
            ev = _counter_total(snap, "slot_evictions_total")
            re_ = _counter_total(snap, "slot_readmissions_total")
            exact = bool(np.array_equal(ref, res["tokens"]))
            churn_ok = ev >= 1 and re_ >= 1 if slots == 2 else True
            ok &= exact and churn_ok
            points.append(dict(
                mesh=mesh_desc, devices=n_dev, slots=slots,
                padded_capacity=slots + (-slots) % n_dev,
                tokens_per_s=round(res["tokens_per_s"], 2),
                mean_batch_fill=round(float(np.mean(res["batch_sizes"])), 3),
                utilization=round(
                    float(np.mean(res["batch_sizes"])) / slots, 3),
                evictions=ev, readmissions=re_,
                tokens_exact=exact))
            emit(f"capacity,run,mesh={mesh_desc},slots={slots},"
                 f"tok_per_s={res['tokens_per_s']:.1f},evictions={ev},"
                 f"readmissions={re_},tokens_exact={exact}")

    audits = [_collective_audit_row(cfg, params, make_serving_mesh(n, **s),
                                    emit)
              for n, s in _capacity_meshes(smoke) if n == 8]
    ok &= all(a["ok"] for a in audits)
    return {"points": points, "collectives": audits, "ok": bool(ok)}


def _capacity_sweep(emit, smoke: bool) -> dict:
    """Spawn `--capacity-worker` under 8 forced host devices (this process
    already initialized single-device jax) and collect its JSON section."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": "src" + (":" + os.environ["PYTHONPATH"]
                                  if os.environ.get("PYTHONPATH") else "")}
    cmd = [sys.executable, str(ROOT / "benchmarks" / "serve_throughput.py"),
           "--capacity-worker"] + (["--smoke"] if smoke else [])
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=1800,
                       env=env, cwd=str(ROOT))
    if r.returncode != 0:
        emit(f"capacity,worker_failed,rc={r.returncode}")
        emit(r.stdout[-2000:] + r.stderr[-2000:])
        return {"points": [], "collectives": [], "ok": False}
    section = None
    for line in r.stdout.splitlines():
        if line.startswith("CAPACITY_JSON "):
            section = json.loads(line[len("CAPACITY_JSON "):])
        elif line.startswith("capacity,"):
            emit(line)
    if section is None:
        emit("capacity,worker_failed,no_json")
        return {"points": [], "collectives": [], "ok": False}
    return section


def main(emit=print, smoke: bool = False) -> bool:
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
    params = transformer.init_model(jax.random.key(0), cfg)
    d = cfg.d_model

    # (n_clients, compressor mix) sweep; the mixed population exercises
    # grouped-by-meta batched decode in one session mix, the pure identity/
    # randtopk pairs feed the perf-gate throughput ratio.
    mixed = ["identity", "randtopk:k=16", "randtopk_mask:k=16"]
    points = ([(8, mixed)] if smoke
              else [(4, ["identity"]), (4, ["randtopk:k=16"]),
                    (8, ["identity"]), (8, ["randtopk:k=16"]),
                    (8, mixed), (16, mixed),
                    (8, ["quant:bits=4"]),
                    (8, ["randtopk_quant:k=16,bits=8"]),
                    (8, ["randtopk_mask:k=16"])])

    # perf gate FIRST, in the cleanest process state: the roofline audit and
    # the sweep below compile extra programs and churn the allocator, which
    # costs the gate runs ~8% tok/s when they go last on a single-core box.
    # The compressed path must stay the fast path; individual sub-second
    # runs are scheduler-noisy, so the gate takes the median of GATE_REPS
    # dedicated longer runs per pure mix.
    # reps are interleaved across the two mixes with a gc.collect() before
    # each run: back-to-back reps of one mix see drifting process state
    # (allocator churn from the previous runs' arenas and sessions), which
    # skewed whichever mix ran second by ~10%
    gate_mixes = (("identity", ["identity"]), ("randtopk", ["randtopk:k=16"]))
    gate_samples = {name: [] for name, _ in gate_mixes}
    gate_stage = {}
    for name, mix in gate_mixes:
        # untimed warmup: pays the jit compiles the sweep used to provide
        engine.run_streaming(cfg, n_clients=GATE_CLIENTS, prompt_len=4,
                             gen=4, max_batch=8, max_wait=0.02,
                             compressor_mix=mix, params=params)
    for _ in range(GATE_REPS):
        for name, mix in gate_mixes:
            gc.collect()
            res = engine.run_streaming(
                cfg, n_clients=GATE_CLIENTS, prompt_len=4, gen=GATE_GEN,
                max_batch=8, max_wait=0.02, compressor_mix=mix,
                params=params)
            gate_samples[name].append(res["tokens_per_s"])
            # per-stage decode/step split of the last gate run, per token
            stok = max(res["stage_tokens"], 1)
            gate_stage[name] = {k: round(v / stok * 1e6, 2)
                                for k, v in res["stage_s"].items()}
            # client-side host pack time per frame (the `client.encode`
            # trace span's host tail), alongside the server stages
            gate_stage[name]["encode"] = round(
                res["client_encode_s"]
                / max(res["client_encode_steps"], 1) * 1e6, 2)
    gate_tps = {name: float(np.median(s)) for name, s in gate_samples.items()}
    ratio = gate_tps["randtopk"] / gate_tps["identity"]
    ratio_ok = ratio >= RATIO_FLOOR
    emit(f"serve,perf_gate,n_clients={GATE_CLIENTS},"
         f"identity_tok_per_s={gate_tps['identity']:.1f},"
         f"randtopk_tok_per_s={gate_tps['randtopk']:.1f},"
         f"randtopk_identity_ratio={ratio:.3f},floor={RATIO_FLOOR}")
    for name, st in gate_stage.items():
        emit(f"serve,perf_gate_stage,{name},"
             f"encode_us_tok={st['encode']},"
             f"decode_us_tok={st['decode']},step_us_tok={st['step']},"
             f"reply_us_tok={st['reply']}")
    emit(f"serve_check,perf_gate,randtopk_vs_identity_ratio,{ratio_ok}")

    # client-encode gate: the device wire path (packed sections pulled +
    # truncated, `steps.make_bottom_step_device`) vs the host codec
    # baseline (full numpy bit-pack per frame), randtopk at GATE_CLIENTS.
    # Reps interleaved with gc fences exactly like the gates around it.
    enc_samples = {"device": [], "host": []}
    engine.run_streaming(cfg, n_clients=GATE_CLIENTS, prompt_len=4, gen=4,
                         max_batch=8, max_wait=0.02,
                         compressor_mix=["randtopk:k=16"], params=params,
                         device_encode=False)   # compile the host variant
    for _ in range(ENCODE_REPS):
        for mode in ("device", "host"):
            gc.collect()
            res = engine.run_streaming(
                cfg, n_clients=GATE_CLIENTS, prompt_len=4, gen=GATE_GEN,
                max_batch=8, max_wait=0.02,
                compressor_mix=["randtopk:k=16"], params=params,
                device_encode=(mode == "device"))
            enc_samples[mode].append(
                res["client_encode_s"]
                / max(res["client_encode_steps"], 1) * 1e6)
    enc_us = {m: float(np.median(s)) for m, s in enc_samples.items()}
    enc_speedup = enc_us["host"] / max(enc_us["device"], 1e-9)
    enc_ok = enc_speedup >= ENCODE_SPEEDUP_FLOOR
    emit(f"serve,encode_gate,n_clients={GATE_CLIENTS},"
         f"device_us_per_token={enc_us['device']:.2f},"
         f"host_us_per_token={enc_us['host']:.2f},"
         f"speedup={enc_speedup:.2f},floor={ENCODE_SPEEDUP_FLOOR}")
    emit(f"serve_check,encode_gate,device_vs_host_pack,{enc_ok}")

    # observability overhead gate: identical randtopk runs with tracing off
    # vs ON (live tracer + registry already wired by the engine), reps
    # interleaved with gc fences exactly like the perf gate so allocator
    # drift never lands on one mode
    obs_samples = {"off": [], "on": []}
    obs_events = 0
    for _ in range(OBS_REPS):
        for mode in ("off", "on"):
            gc.collect()
            tracer = Tracer() if mode == "on" else None
            res = engine.run_streaming(
                cfg, n_clients=GATE_CLIENTS, prompt_len=4, gen=GATE_GEN,
                max_batch=8, max_wait=0.02,
                compressor_mix=["randtopk:k=16"], params=params,
                tracer=tracer)
            obs_samples[mode].append(res["tokens_per_s"])
            if tracer is not None:
                obs_events = len(tracer)
    obs_tps = {m: float(np.median(s)) for m, s in obs_samples.items()}
    obs_ratio = obs_tps["on"] / obs_tps["off"]
    obs_ok = obs_ratio >= OBS_RATIO_FLOOR
    emit(f"serve,obs_gate,n_clients={GATE_CLIENTS},"
         f"off_tok_per_s={obs_tps['off']:.1f},"
         f"on_tok_per_s={obs_tps['on']:.1f},"
         f"trace_events={obs_events},"
         f"on_off_ratio={obs_ratio:.3f},floor={OBS_RATIO_FLOOR}")
    emit(f"serve_check,obs_gate,tracing_overhead_ratio,{obs_ok}")

    roofline_rows = _roofline_rows(cfg, params, emit)
    roofline_ok = all(r["ok"] for r in roofline_rows)
    emit(f"roofline_check,all_programs,predicted_vs_measured,{roofline_ok}")

    mask_rows = _mask_crossover_rows(cfg, emit)
    mask_ok = all(r["ok"] for r in mask_rows)

    all_rows, ok_all = [], True
    for n_clients, mix in points:
        res = engine.run_streaming(
            cfg, n_clients=n_clients, prompt_len=4, gen=8,
            max_batch=min(8, n_clients), max_wait=0.02,
            compressor_mix=mix, params=params)
        stage = res["stage_s"]
        stok = max(res["stage_tokens"], 1)
        stage_us_tok = {k: v / stok * 1e6 for k, v in stage.items()}
        hb = res["host_bytes"]
        staged_ratio = hb["staged"] / max(hb["wire"], 1)
        emit(f"serve,run,clients={n_clients},mix={'+'.join(mix)},"
             f"tok_per_s={res['tokens_per_s']:.1f},"
             f"mean_batch_fill={np.mean(res['batch_sizes']):.2f},"
             f"wall_s={res['wall_s']:.2f},"
             f"decode_us_tok={stage_us_tok['decode']:.1f},"
             f"step_us_tok={stage_us_tok['step']:.1f},"
             f"reply_us_tok={stage_us_tok['reply']:.1f},"
             f"staged_over_wire={staged_ratio:.2f}")
        rows = _mix_rows(cfg, res, emit)
        for r in rows:
            r.update(n_clients=n_clients,
                     tokens_per_s=res["tokens_per_s"],
                     mean_batch_fill=float(np.mean(res["batch_sizes"])),
                     stage_us_per_token={k: round(v, 2)
                                         for k, v in stage_us_tok.items()},
                     host_staged_over_wire=round(staged_ratio, 3))
            ok_all &= r["ok"]
        all_rows.extend(rows)

    # sharded-arena capacity sweep (+ collective audit) in its own
    # 8-device subprocess — this process stays single-device
    capacity = _capacity_sweep(emit, smoke)
    emit(f"capacity_check,sweep,tokens_exact_and_collectives,"
         f"{capacity['ok']}")

    dense_B = d * 4
    emit(f"serve_check,all_compressors,measured_within_5pct,{ok_all}")
    ok_all &= roofline_ok
    ok_all &= ratio_ok
    ok_all &= obs_ok
    ok_all &= enc_ok
    ok_all &= mask_ok
    ok_all &= capacity["ok"]
    point = {"bench": "serve_throughput", "smoke": bool(smoke),
             "arch": cfg.name, "d_model": d,
             "uncompressed_B_per_token": dense_B,
             "gate_tokens_per_s": {k: round(v, 2)
                                   for k, v in gate_tps.items()},
             "randtopk_identity_ratio": round(float(ratio), 4),
             "ratio_n_clients": GATE_CLIENTS, "ratio_floor": RATIO_FLOOR,
             "gate_reps": GATE_REPS,
             "gate_stage_us_per_token": gate_stage,
             "obs": {"tokens_per_s_off": round(obs_tps["off"], 2),
                     "tokens_per_s_on": round(obs_tps["on"], 2),
                     "on_off_ratio": round(float(obs_ratio), 4),
                     "ratio_floor": OBS_RATIO_FLOOR, "reps": OBS_REPS,
                     "trace_events": obs_events, "ok": bool(obs_ok)},
             "encode": {"device_us_per_token": round(enc_us["device"], 2),
                        "host_us_per_token": round(enc_us["host"], 2),
                        "speedup": round(float(enc_speedup), 3),
                        "speedup_floor": ENCODE_SPEEDUP_FLOOR,
                        "reps": ENCODE_REPS, "ok": bool(enc_ok)},
             "mask_crossover": mask_rows,
             "roofline": roofline_rows,
             "capacity": capacity,
             "rows": all_rows, "ok": bool(ok_all)}
    # benchmarks/loadgen.py owns the `loadgen` section of the same file;
    # carry it across this bench's rewrite instead of clobbering it
    if BENCH_PATH.exists():
        try:
            prev = json.loads(BENCH_PATH.read_text())
        except ValueError:
            prev = {}
        if "loadgen" in prev:
            point["loadgen"] = prev["loadgen"]
    BENCH_PATH.write_text(json.dumps(point, indent=2) + "\n")
    emit(f"serve,wrote,{BENCH_PATH.name}")
    # one summary row per run, append-only (the trend line BENCH_serve.json
    # cannot give because it is overwritten): gate throughput, the encode
    # gate, and bytes/token per compressor from this run's sweep rows
    hist = {"t": round(time.time(), 3), "bench": "serve_throughput",
            "smoke": bool(smoke),
            "gate_tokens_per_s": {k: round(v, 2)
                                  for k, v in gate_tps.items()},
            "randtopk_identity_ratio": round(float(ratio), 4),
            "encode_us_per_token": {"device": round(enc_us["device"], 2),
                                    "host": round(enc_us["host"], 2)},
            "bytes_per_token": {r["compressor"]:
                                round(r["measured_B_per_token"], 1)
                                for r in all_rows},
            "ok": bool(ok_all)}
    with HISTORY_PATH.open("a") as f:
        f.write(json.dumps(hist) + "\n")
    emit(f"serve,appended,{HISTORY_PATH.name}")
    return ok_all


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single 8-client dense+randtopk mix point")
    ap.add_argument("--capacity-worker", action="store_true",
                    help=argparse.SUPPRESS)  # internal: 8-device subprocess
    args = ap.parse_args()
    if args.capacity_worker:
        section = capacity_worker(args.smoke)
        print("CAPACITY_JSON " + json.dumps(section))
        sys.exit(0 if section["ok"] else 1)
    sys.exit(0 if main(smoke=args.smoke) else 1)
