"""Paper Appendix B analogue — input-reconstruction (inversion) attack on
the cut-layer activations.

The attacker (the label owner, or an eavesdropper on the wire) trains an
inverter network from observed cut payloads back to the raw inputs, using
its own data. Paper claim: sparsified cut activations (Topk/RandTopk) leak
less than the dense cut — reconstruction error is higher, and RandTopk's is
at least Topk's.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import EPOCHS, dataset, spec
from repro.core import selection
from repro.optim import adamw_init, adamw_update
from repro.split.tabular import bottom_fn, train


def _inverter_init(key, d_in, d_out, hidden=256):
    k1, k2 = jax.random.split(key)
    return {
        "w1": (2.0 / d_in) ** 0.5 * jax.random.normal(k1, (d_in, hidden)),
        "b1": jnp.zeros((hidden,)),
        "w2": (2.0 / hidden) ** 0.5 * jax.random.normal(k2, (hidden, d_out)),
        "b2": jnp.zeros((d_out,)),
    }


def _inverter_fn(p, o):
    h = jax.nn.relu(o @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def attack(bottom, view_fn, ds, *, epochs=8, seed=0):
    """Train the inverter on (view(bottom(x)), x) pairs; report test MSE."""
    key = jax.random.key(seed)
    inv = _inverter_init(key, 128, ds.in_dim)
    opt = adamw_init(inv)

    @jax.jit
    def step(inv, opt, x):
        o = view_fn(bottom_fn(bottom, x))

        def loss(inv):
            return jnp.mean((_inverter_fn(inv, o) - x) ** 2)

        g = jax.grad(loss)(inv)
        inv, opt, _ = adamw_update(inv, g, opt, lr=1e-3, grad_clip=0.0)
        return inv, opt

    rng = np.random.RandomState(seed)
    for _ in range(epochs):
        for xb, _ in ds.batches(128, rng=rng):
            inv, opt = step(inv, opt, jnp.asarray(xb))
    xt = jnp.asarray(ds.x_test)
    o = view_fn(bottom_fn(bottom, xt))
    return float(jnp.mean((_inverter_fn(inv, o) - xt) ** 2))


def main(emit=print):
    ds = dataset()
    ep = max(8, EPOCHS // 2)
    errs = {}
    for method, kw in [("none", {}), ("topk", dict(k=3)),
                       ("randtopk", dict(k=3, alpha=0.1))]:
        r = train(spec(method, **kw), ds, epochs=ep, seed=0)
        if method == "none":
            view = lambda o: o
        else:
            view = lambda o: o * selection.topk_mask(o, 3).astype(o.dtype)
        errs[method] = attack(r["bottom"], view, ds, epochs=max(4, ep // 2))
        emit(f"appendixB,{method},reconstruction_mse,{errs[method]:.4f}")
    checks = {
        "sparsified_leaks_less_than_dense":
            min(errs["topk"], errs["randtopk"]) > errs["none"],
        "randtopk_at_least_topk_privacy":
            errs["randtopk"] >= errs["topk"] * 0.9,
    }
    for name, ok in checks.items():
        emit(f"appendixB_check,{name},{ok}")
    return errs, checks


if __name__ == "__main__":
    main()
