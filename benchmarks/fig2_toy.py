"""Paper Figure 2 — the toy local-minimum example, reproduced exactly.

Concept f(x1,x2)=Sign(x1-x2); split model M_b=(w1 x1, w2 x2),
M_t=Tanh(o1+o2); samples (1,0)->+1 and (0.5,1)->-1; init w1=1, w2=-0.1.

With top-1 sparsification o2 is always masked (|w1 x1| > |w2 x2| for both
samples at init), so w2 never trains and SGD converges to the bad local
minimum. RandTopk occasionally selects o2 (prob alpha), trains w2, and
escapes. We verify both behaviors.
"""
import jax
import jax.numpy as jnp
import numpy as np

X = jnp.array([[1.0, 0.0], [0.5, 1.0]])
Y = jnp.array([1.0, -1.0])


def loss_fn(w, mask):
    o = w * X * mask                        # (2, 2) masked cut activations
    pred = jnp.tanh(o.sum(-1))
    return jnp.mean((pred - Y) ** 2)


def select_mask(w, alpha, key):
    o = w * X
    top = (jnp.abs(o) >= jnp.abs(o).max(-1, keepdims=True)).astype(jnp.float32)
    if alpha == 0.0:
        return top
    flip = jax.random.bernoulli(key, alpha, (X.shape[0], 1))
    return jnp.where(flip, 1.0 - top, top)


def run(alpha: float, steps: int = 4000, lr: float = 0.1, seed: int = 0):
    w = jnp.array([1.0, -0.1])
    key = jax.random.key(seed)
    grad = jax.grad(loss_fn)
    traj = [np.asarray(w)]
    for t in range(steps):
        key, sub = jax.random.split(key)
        mask = select_mask(w, alpha, sub)
        w = w - lr * grad(w, mask)
        if t % 500 == 0:
            traj.append(np.asarray(w))
    final_loss = float(loss_fn(w, jnp.ones_like(X)))
    return np.asarray(w), final_loss, traj


def main(emit=print):
    w_topk, loss_topk, _ = run(alpha=0.0)
    w_rand, loss_rand, _ = run(alpha=0.1)
    emit(f"fig2_toy,topk_final_loss,{loss_topk:.4f},w={w_topk.round(3)}")
    emit(f"fig2_toy,randtopk_final_loss,{loss_rand:.4f},w={w_rand.round(3)}")
    # paper's claim: topk is stuck (w2 untrained, loss high); randtopk escapes
    stuck = abs(w_topk[1] - (-0.1)) < 0.05 and loss_topk > 0.3
    escaped = w_rand[1] < -0.5 and loss_rand < 0.2
    emit(f"fig2_toy,topk_stuck,{stuck}")
    emit(f"fig2_toy,randtopk_escaped,{escaped}")
    return {"topk_loss": loss_topk, "rand_loss": loss_rand,
            "topk_stuck": stuck, "rand_escaped": escaped}


if __name__ == "__main__":
    main()
