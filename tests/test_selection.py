"""Unit + property tests for the paper's selection primitives (Eq. 7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import selection


@given(st.integers(1, 31), st.integers(2, 5), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_topk_mask_selects_exactly_k(k, rows, seed):
    d = 32
    x = jax.random.normal(jax.random.key(seed), (rows, d))
    mask = selection.topk_mask(x, k)
    assert mask.shape == x.shape
    np.testing.assert_array_equal(np.asarray(mask.sum(-1)), k)


@given(st.integers(1, 31), st.floats(0.0, 1.0), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
@pytest.mark.slow
def test_randtopk_mask_selects_exactly_k(k, alpha, seed):
    d = 32
    x = jax.random.normal(jax.random.key(seed), (3, d))
    mask = selection.randtopk_mask(x, k, alpha, jax.random.key(seed + 1))
    np.testing.assert_array_equal(np.asarray(mask.sum(-1)), k)


def test_topk_mask_matches_lax_topk():
    x = jax.random.normal(jax.random.key(0), (64, 128))
    mask = selection.topk_mask(x, 7)
    _, idx = jax.lax.top_k(jnp.abs(x), 7)
    ref = np.zeros(x.shape, bool)
    np.put_along_axis(ref, np.asarray(idx), True, axis=-1)
    np.testing.assert_array_equal(np.asarray(mask), ref)


def test_randtopk_alpha0_equals_topk():
    x = jax.random.normal(jax.random.key(0), (16, 64))
    m0 = selection.randtopk_mask(x, 9, 0.0, jax.random.key(1))
    mt = selection.topk_mask(x, 9)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(mt))


def test_randtopk_alpha_statistics():
    """Non-top-k selection frequency should track alpha (Eq. 7)."""
    d, k, alpha = 64, 8, 0.3
    x = jax.random.normal(jax.random.key(0), (1, d))
    is_top = np.asarray(selection.topk_mask(x, k))[0]
    n_trials = 2000
    keys = jax.random.split(jax.random.key(42), n_trials)
    masks = jax.vmap(lambda kk: selection.randtopk_mask(x, k, alpha, kk))(keys)
    masks = np.asarray(masks)[:, 0, :]
    # expected non-top-k picks per trial = alpha * k
    non_top_picks = masks[:, ~is_top].sum(axis=1)
    assert abs(non_top_picks.mean() - alpha * k) < 0.15, non_top_picks.mean()
    # within the non-top-k pool selection should be ~uniform
    freq = masks[:, ~is_top].mean(axis=0)
    assert freq.std() < 0.05


def test_randtopk_mask_ties():
    x = jnp.ones((2, 16))
    m = selection.randtopk_mask(x, 4, 0.2, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(m.sum(-1)), 4)


def test_k_equals_d():
    x = jax.random.normal(jax.random.key(0), (4, 8))
    assert bool(selection.topk_mask(x, 8).all())
    assert bool(selection.randtopk_mask(x, 8, 0.5, jax.random.key(1)).all())


def test_kth_threshold():
    x = jax.random.normal(jax.random.key(3), (10, 50))
    thr = selection.kth_magnitude_threshold(x, 5)
    mag = np.abs(np.asarray(x))
    ref = np.sort(mag, axis=-1)[:, -5]
    np.testing.assert_allclose(np.asarray(thr), ref, rtol=1e-6)
