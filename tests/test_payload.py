"""Packed-payload codec: encode/decode round-trips for every compressor,
byte-stable wire serialization, measured-vs-analytic size cross-checks, and
the protocol-level guarantee that the pod transfer moves wire dtypes (uint8
codes + f32 headers for quantization — not the dense float tensor)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import compressors as C, wire
from repro.core.payload import Payload, PayloadMeta
from repro.models.config import Runtime, SplitConfig
from repro.split import protocol

ALL_SPECS = [
    ("identity", {}),
    ("size_reduction", dict(k=6)),
    ("topk", dict(k=6)),
    ("randtopk", dict(k=6, alpha=0.2)),
    ("quant", dict(bits=4)),
    ("l1", {}),
    ("randtopk_quant", dict(k=6, alpha=0.1, bits=8)),
]


def _np_payload(p):
    return jax.tree.map(np.asarray, p)


@pytest.mark.parametrize("spec,kw", ALL_SPECS)
@pytest.mark.parametrize("training", [False, True])
def test_decode_encode_equals_forward(spec, kw, training):
    """`decode(encode(x))` must equal `forward(x)` exactly, per compressor."""
    x = jax.random.normal(jax.random.key(0), (4, 64))
    comp = C.make_compressor(spec, **kw)
    key = jax.random.key(1)
    p = comp.encode(x, key=key, training=training)
    y = comp.decode(p, shape=x.shape, dtype=x.dtype)
    yf, _ = comp.forward(x, key=key, training=training)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yf))


@pytest.mark.parametrize("spec,kw", ALL_SPECS)
def test_wire_serialization_byte_stable(spec, kw):
    """serialize -> deserialize -> serialize must be byte-identical, and the
    deserialized payload must decode to the same dense view."""
    x = jax.random.normal(jax.random.key(2), (3, 5, 32))
    comp = C.make_compressor(spec, **kw)
    p = _np_payload(comp.encode(x, key=jax.random.key(3), training=True))
    buf = wire.encode_payload(p)
    p2 = wire.decode_payload(buf, p.meta, p.batch_shape)
    assert wire.encode_payload(p2) == buf
    y = comp.decode(jax.tree.map(jnp.asarray, p), shape=x.shape)
    y2 = comp.decode(jax.tree.map(jnp.asarray, p2), shape=x.shape)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


@pytest.mark.parametrize("spec,kw,method", [
    ("size_reduction", dict(k=6), "size_reduction"),
    ("topk", dict(k=6), "topk"),
    ("randtopk", dict(k=6, alpha=0.2), "randtopk"),
    ("quant", dict(bits=4), "quant"),
    ("randtopk_quant", dict(k=6, alpha=0.1, bits=8), "randtopk_quant"),
    ("identity", {}, "identity"),
])
def test_measured_bytes_match_table2(spec, kw, method):
    """Measured socket bytes of the encoded payload vs the Table-2 analytic
    row and the compressor's own fwd_bits — one source of truth."""
    d, n = 128, 48
    x = jax.random.normal(jax.random.key(4), (n, d))
    comp = C.make_compressor(spec, **kw)
    p = _np_payload(comp.encode(x, key=jax.random.key(5), training=True))
    measured_bits = wire.payload_nbytes(p) * 8
    t2kw = {a: b for a, b in kw.items() if a in ("k", "bits")}
    analytic = wire.table2_row(method, d, **t2kw)["fwd"] * n * d * 32
    if method == "quant":
        analytic += n * 2 * 32  # Table 2 omits the (lo, step) header
    # bit-packed streams round up to whole bytes once per stream
    assert abs(measured_bits - analytic) <= 8 * 2
    # compressor-side accounting agrees with the codec-side accounting
    assert comp.fwd_bits(d) == pytest.approx(
        wire.payload_bits_per_instance(p.meta), rel=1e-6)


def test_payload_wire_dtypes():
    """Every compressor's payload is already in wire dtypes."""
    x = jax.random.normal(jax.random.key(6), (2, 8, 64))
    expect = {
        "identity": dict(values=jnp.float32),
        "size_reduction": dict(values=jnp.float32),
        "topk": dict(values=jnp.float32, indices=jnp.uint16),
        "randtopk": dict(values=jnp.float32, indices=jnp.uint16),
        "quant": dict(values=jnp.uint8, header=jnp.float32),
        "l1": dict(values=jnp.float32),
        "randtopk_quant": dict(values=jnp.uint8, indices=jnp.uint16,
                               header=jnp.float32),
    }
    for spec, kw in ALL_SPECS:
        comp = C.make_compressor(spec, **kw)
        p = comp.encode(x, key=jax.random.key(7), training=True)
        got = {name: a.dtype for name, a in p.wire_leaves()}
        want = {name: jnp.dtype(dt) for name, dt in expect[spec].items()}
        assert got == want, (spec, got)


def test_quant_pod_transfer_moves_codes_not_dense(monkeypatch):
    """Acceptance: the quantization pod transfer moves uint8 codes + f32
    (lo, step) headers — NOT the dense dequantized float tensor."""
    captured = []
    orig = protocol._pod_permute

    def spy(rt, *leaves, **kwargs):
        captured.append(leaves)
        return orig(rt, *leaves, **kwargs)

    monkeypatch.setattr(protocol, "_pod_permute", spy)
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="quant", quant_bits=4))
    rt = Runtime(mesh=None, training=True)
    B, S, d = 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.key(0), (B, S, d))
    y, _ = protocol.cut_boundary(x, cfg, rt, jax.random.key(1))
    assert y.shape == (B, S, d)
    (leaves,) = captured  # one forward transfer
    assert len(leaves) == 2
    codes, header = leaves
    assert codes.dtype == jnp.uint8 and codes.shape == (B, S, d)
    assert header.dtype == jnp.float32 and header.shape == (B, S, 2)
    moved = sum(l.size * l.dtype.itemsize for l in leaves)
    dense = B * S * d * 4
    assert moved < 0.3 * dense, (moved, dense)  # 4-bit codes in u8 + header
    # what crossed is exactly the payload's device representation
    comp = protocol.make_cut_compressor(cfg.split)
    assert moved == comp.encode(x, training=True).device_nbytes()


def test_sparse_pod_transfer_leaf_sizes(monkeypatch):
    """Top-k forward transfer moves k f32 values + k u16 indices per token;
    the backward transfer moves exactly k gradient floats per token."""
    fwd_leaves, bwd_leaves = [], []
    orig = protocol._pod_permute

    def spy(rt, *leaves, inverse=False, **kwargs):
        (bwd_leaves if inverse else fwd_leaves).append(leaves)
        return orig(rt, *leaves, inverse=inverse, **kwargs)

    monkeypatch.setattr(protocol, "_pod_permute", spy)
    k = 8
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="topk", k=k))
    rt = Runtime(mesh=None, training=True)
    B, S, d = 2, 4, cfg.d_model
    x = jax.random.normal(jax.random.key(0), (B, S, d))

    def f(x):
        y, _ = protocol.cut_boundary(x, cfg, rt, jax.random.key(1))
        return jnp.sum(y ** 2)

    g = jax.grad(f)(x)
    (fwd,) = fwd_leaves
    assert {(l.dtype, l.shape) for l in fwd} == {
        (jnp.dtype(jnp.float32), (B, S, k)),
        (jnp.dtype(jnp.uint16), (B, S, k))}
    (bwd,) = bwd_leaves
    assert [(l.dtype, l.shape) for l in bwd] == [
        (jnp.dtype(jnp.float32), (B, S, k))]
    # gradient masked to the forward support
    assert (np.asarray((g != 0).sum(-1)) <= k).all()


def test_protocol_has_no_isinstance_branches():
    """Acceptance: `cut_boundary` is one generic encode/transfer/decode path
    — no per-compressor isinstance dispatch anywhere in the protocol."""
    import inspect

    src = inspect.getsource(protocol)
    assert "isinstance" not in src


@pytest.mark.parametrize("comp", ["randtopk", "topk", "size_reduction",
                                  "quant", "l1", "identity",
                                  "randtopk_quant"])
def test_cut_boundary_matches_compressor_forward(comp):
    """With no mesh the boundary must reproduce the compressor's forward
    view exactly (transfer is the identity)."""
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor=comp, k=16, alpha=0.1,
                          quant_bits=4))
    rt = Runtime(mesh=None, training=False)
    x = jax.random.normal(jax.random.key(0), (2, 8, cfg.d_model))
    y, _ = protocol.cut_boundary(x, cfg, rt, None)
    c = protocol.make_cut_compressor(cfg.split)
    yref, _ = c.forward(x, training=False)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yref))


def test_payload_pytree_roundtrip():
    """Payload is a well-formed pytree: flatten/unflatten preserves leaves
    and static meta; None leaves stay structural."""
    p = Payload(meta=PayloadMeta("sparse", d=32, k=4),
                values=jnp.ones((2, 4)), indices=jnp.zeros((2, 4), jnp.uint16))
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert len(leaves) == 2  # header=None is not a leaf
    p2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert p2.meta == p.meta and p2.header is None
    p3 = jax.tree.map(lambda a: a + 0, p)
    assert p3.meta.kind == "sparse"


def test_payload_meta_validation():
    with pytest.raises(ValueError):
        PayloadMeta("nope", d=8)


def test_quant_ste_gradient_through_boundary():
    """Quantization through the full boundary keeps the STE identity
    gradient (paper: backward is the uncompressed dense gradient)."""
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="quant", quant_bits=4))
    rt = Runtime(mesh=None, training=True)
    x = jax.random.normal(jax.random.key(0), (1, 4, cfg.d_model))
    g = jax.grad(lambda x: jnp.sum(
        protocol.cut_boundary(x, cfg, rt, jax.random.key(1))[0]))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_measured_payload_bytes_helper():
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="topk", k=8))
    measured = protocol.measured_payload_bytes(cfg, 2, 16, training=False)
    analytic = protocol.wire_bytes_per_step(cfg, 2, 16, training=False)
    assert 0 < measured <= analytic * 1.01 + 16
