"""Launcher integration tests: train driver convergence, serve driver,
checkpoint resume, and a small-mesh dry-run (subprocess keeps the main
pytest process single-device)."""
import subprocess
import sys
import textwrap

import pytest


def _run(code: str, device_count=8, timeout=900):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "XLA_FLAGS":
                 f"--xla_force_host_platform_device_count={device_count}",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_train_driver_loss_decreases(tmp_path):
    out = _run(f"""
        from repro.launch.train import main
        import re, io, contextlib
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            main(["--arch", "yi-6b", "--smoke", "--steps", "40",
                  "--batch", "8", "--seq", "64", "--lr", "1e-3",
                  "--split", "randtopk", "--k", "16",
                  "--ckpt-dir", "{tmp_path}/ck", "--ckpt-every", "20"])
        text = buf.getvalue()
        losses = [float(m) for m in
                  __import__("re").findall(r"loss=([0-9.]+)", text)]
        assert losses[-1] < losses[0] - 0.01, losses
        print("LOSSES", losses[0], losses[-1])
    """, device_count=1)
    assert "LOSSES" in out


@pytest.mark.slow
def test_train_driver_restores_checkpoint(tmp_path):
    _run(f"""
        from repro.launch.train import main
        main(["--arch", "yi-6b", "--smoke", "--steps", "10", "--batch", "4",
              "--seq", "32", "--ckpt-dir", "{tmp_path}/ck",
              "--ckpt-every", "10"])
        # resume: start==10 -> zero new steps executed, restore path covered
        import io, contextlib
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            main(["--arch", "yi-6b", "--smoke", "--steps", "12",
                  "--batch", "4", "--seq", "32",
                  "--ckpt-dir", "{tmp_path}/ck", "--ckpt-every", "100"])
        assert "restored step 10" in buf.getvalue()
        print("RESUME OK")
    """, device_count=1)


@pytest.mark.slow
def test_serve_driver(capsys):
    _run("""
        import io, contextlib
        from repro.launch.serve import main
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            out = main(["--arch", "granite-moe-1b-a400m", "--smoke",
                        "--batch", "2", "--prompt-len", "4", "--gen", "6",
                        "--split", "topk", "--k", "8"])
        assert out.shape == (2, 6)
        # measured bytes/client/token come from real frames now
        assert "B/client/token" in buf.getvalue(), buf.getvalue()
        print("SERVE OK")
    """, device_count=1)


def test_drivers_route_elapsed_time_through_clock():
    """Regression for the raw `time.time()` reads the train/dryrun drivers
    used to make: every elapsed-time print must go through the injectable
    `Clock`, so a deterministic fake clock fully determines the logged
    timings (and wall-clock noise can never leak into golden output)."""
    _run(r"""
        import io, contextlib, re
        from repro.testing.clock import Clock

        class TickingClock(Clock):
            # +7.5s per monotonic() read: printed elapsed values become a
            # pure function of how many times the driver consulted the clock
            def __init__(self):
                self.t = 100.0
            def monotonic(self):
                self.t += 7.5
                return self.t
            def sleep(self, seconds):
                pass

        from repro.launch.train import main as train_main
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            train_main(["--arch", "yi-6b", "--smoke", "--steps", "3",
                        "--batch", "2", "--seq", "16", "--log-every", "1"],
                       clock=TickingClock())
        elapsed = re.findall(r"\((\d+\.\d)s\)", buf.getvalue())
        assert elapsed == ["7.5", "15.0", "22.5"], elapsed
        print("TRAIN CLOCK OK")

        # dryrun: stub out the (heavyweight) lower/compile and mesh pieces;
        # the compile-time report must read the injected clock, not time.time
        import repro.launch.dryrun as dryrun

        class FakeMem:
            argument_size_in_bytes = output_size_in_bytes = 0
            temp_size_in_bytes = alias_size_in_bytes = 0

        class FakeCompiled:
            def as_text(self):
                return ""
            def memory_analysis(self):
                return FakeMem()

        class FakeDevices:
            size, shape = 1, (1,)

        class FakeMesh:
            devices = FakeDevices()

        class FakeRoof:
            mesh = "1"
            def row(self):
                return dict(hlo_flops=1.0, model_flops=1.0, useful_ratio=1.0,
                            t_compute_s=0.0, t_memory_s=0.0,
                            t_collective_s=0.0, bottleneck="compute",
                            coll_detail={})

        class FakeAnalysis:
            @staticmethod
            def model_flops(cfg, tokens, training):
                return 1.0
            @staticmethod
            def from_compiled(*a, **k):
                return FakeRoof()

        dryrun.make_production_mesh = lambda **kw: FakeMesh()
        dryrun.lower_one = lambda cfg, shape, mesh, runtime_kw=None: \
            (FakeCompiled(), None)
        dryrun.analysis = FakeAnalysis()
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            dryrun.run_combo("yi-6b", "train_4k", clock=TickingClock())
        assert "(compile 7.5s)" in buf.getvalue(), buf.getvalue()
        print("DRYRUN CLOCK OK")
    """, device_count=1)


@pytest.mark.slow
def test_dryrun_small_mesh_train_and_decode():
    """The dry-run machinery on an 8-device (2,2,2) pod mesh: lower+compile
    must succeed and the roofline terms must be positive/finite."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.launch import specs as S
        from repro.launch.steps import make_serve_step, make_train_step
        from repro.models.config import Runtime, SplitConfig
        from repro.roofline import analysis
        import repro.configs as configs

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = configs.get("qwen3-8b", smoke=True).with_(
            split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
        shape = S.ShapeSpec("t", "train", 64, 8)
        rt = Runtime(mesh=mesh, training=True)
        with mesh:
            args, in_sh = S.train_specs(cfg, shape, rt)
            step = make_train_step(cfg, rt, internal_key=True)
            compiled = jax.jit(step, in_shardings=in_sh,
                               donate_argnums=(0, 1)).lower(*args).compile()
        roof = analysis.from_compiled(compiled, arch="qwen3-8b", shape="t",
                                      mesh_desc="2x2x2", chips=8,
                                      model_flops=1.0, bf16_target=False)
        assert roof.t_compute > 0 and roof.t_memory > 0
        assert roof.coll_bytes > 0  # pod permute + TP collectives present
        # decode path
        shape_d = S.ShapeSpec("d", "decode", 64, 8)
        rt_d = Runtime(mesh=mesh, training=False, seq_shard=False)
        with mesh:
            args, in_sh = S.decode_specs(cfg, shape_d, rt_d)
            sstep = make_serve_step(cfg, rt_d)
            jax.jit(sstep, in_shardings=in_sh,
                    donate_argnums=(1,)).lower(*args).compile()
        print("DRYRUN OK")
    """)
