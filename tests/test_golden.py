"""Golden-bytes regression: the wire format is pinned by committed blobs.

`tests/golden/*.bin` hold one canonical frame per frame kind (and one per
payload kind for payload frames), built from fixed arrays with no RNG.
Each test re-encodes the same inputs and compares byte-for-byte against the
committed blob, then decodes the blob and checks every field — so any
accidental layout drift (field order, width, endianness, CRC coverage) in a
future PR fails loudly against bytes produced by the PR that defined the
format.

Regenerate after an *intentional* format change (bump `wire.WIRE_VERSION`!):

    PYTHONPATH=src python tests/test_golden.py --regen
"""
import pathlib
import sys

import numpy as np
import pytest

from repro.core import wire
from repro.core.payload import Payload, PayloadMeta

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"


def _payloads() -> dict:
    """One fixed payload per kind — deliberately boring, byte-stable."""
    return {
        "dense": Payload(
            meta=PayloadMeta("dense", d=8),
            values=np.arange(16, dtype=np.float32).reshape(2, 8) / 4),
        "slice": Payload(
            meta=PayloadMeta("slice", d=8, k=3),
            values=np.asarray([[1.0, -2.0, 0.5]], np.float32)),
        "sparse": Payload(
            meta=PayloadMeta("sparse", d=16, k=2),
            values=np.asarray([[1.5, -2.0]], np.float32),
            indices=np.asarray([[3, 9]], np.uint16)),
        "quant": Payload(
            meta=PayloadMeta("quant", d=8, bits=4),
            values=np.tile(np.arange(8, dtype=np.uint8), (2, 1)),
            header=np.asarray([[-1.0, 0.125], [0.0, 0.25]], np.float32)),
        "sparse_quant": Payload(
            meta=PayloadMeta("sparse_quant", d=16, k=3, bits=8),
            values=np.asarray([[0, 128, 255]], np.uint8),
            indices=np.asarray([[1, 8, 15]], np.uint16),
            header=np.asarray([[-2.0, 0.015625]], np.float32)),
        # support {1, 33, 38} at d=40: words [bit 1, bits 1|6], and the
        # 2-word row truncates to mask_row_nbytes(40) = 5 wire bytes
        "mask": Payload(
            meta=PayloadMeta("mask", d=40, k=3),
            values=np.asarray([[1.0, -0.5, 2.25]], np.float32),
            indices=np.asarray([[1 << 1, (1 << 1) | (1 << 6)]], np.uint32)),
    }


def build_golden() -> dict:
    """name -> canonical frame bytes, all from fixed inputs."""
    frames = {}
    for kind, p in _payloads().items():
        frames[f"payload_{kind}"] = wire.encode_payload_frame(7, 3, p)
    frames["grad_slice"] = wire.encode_grad_frame(
        7, 3, _payloads()["slice"], loss=2.5)
    frames["grad_dense"] = wire.encode_grad_frame(
        7, 3, _payloads()["dense"], loss=0.25)
    frames["tokens"] = wire.encode_token_frame(7, 4, [42, 7, 123456])
    frames["close"] = wire.encode_close_frame(7, 5)
    frames["error"] = wire.encode_error_frame(
        7, 6, wire.ERR_BAD_COUNT, "sparse payload k=99 out of range for d=16")
    return frames


@pytest.mark.parametrize("name", sorted(build_golden()))
def test_golden_bytes_exact(name):
    golden = (GOLDEN_DIR / f"{name}.bin").read_bytes()
    assert build_golden()[name] == golden, (
        f"{name}: frame bytes drifted from the committed golden blob — if "
        f"the wire format changed intentionally, bump wire.WIRE_VERSION and "
        f"regen (PYTHONPATH=src python tests/test_golden.py --regen)")


@pytest.mark.parametrize("kind", sorted(_payloads()))
def test_golden_payload_decodes_exactly(kind):
    blob = (GOLDEN_DIR / f"payload_{kind}.bin").read_bytes()
    frame, consumed = wire.decode_frame(blob)
    assert consumed == len(blob) == frame.nbytes
    assert (frame.kind, frame.session, frame.seq) == (wire.FRAME_PAYLOAD,
                                                      7, 3)
    p = _payloads()[kind]
    assert frame.payload.meta == p.meta
    assert frame.payload_nbytes == wire.payload_nbytes(p)
    for (na, a), (nb, b) in zip(p.wire_leaves(),
                                frame.payload.wire_leaves()):
        assert na == nb and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_golden_nonpayload_decode_fields():
    g, _ = wire.decode_frame((GOLDEN_DIR / "grad_slice.bin").read_bytes())
    assert g.kind == wire.FRAME_GRAD and g.loss == 2.5
    assert g.payload.meta == _payloads()["slice"].meta
    t, _ = wire.decode_frame((GOLDEN_DIR / "tokens.bin").read_bytes())
    assert t.tokens.tolist() == [42, 7, 123456] and t.seq == 4
    c, _ = wire.decode_frame((GOLDEN_DIR / "close.bin").read_bytes())
    assert c.kind == wire.FRAME_CLOSE and (c.session, c.seq) == (7, 5)
    e, _ = wire.decode_frame((GOLDEN_DIR / "error.bin").read_bytes())
    assert e.error_code == wire.ERR_BAD_COUNT
    assert e.error_msg.startswith("sparse payload k=99")


def test_golden_version_byte_is_pinned():
    """The committed blobs pin WIRE_VERSION itself (2 since the CRC
    trailer joined the layout)."""
    for f in sorted(GOLDEN_DIR.glob("*.bin")):
        assert f.read_bytes()[4] == 2 == wire.WIRE_VERSION, f.name


if __name__ == "__main__":
    if "--regen" not in sys.argv:
        sys.exit("usage: PYTHONPATH=src python tests/test_golden.py --regen")
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name, blob in build_golden().items():
        (GOLDEN_DIR / f"{name}.bin").write_bytes(blob)
        print(f"wrote golden/{name}.bin ({len(blob)} B)")
