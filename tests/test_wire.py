"""Byte-exact wire format round-trips + property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import wire


@given(st.integers(2, 2048), st.integers(1, 16), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_sparse_roundtrip(d, k, seed):
    k = min(k, d)
    rng = np.random.RandomState(seed)
    vals = rng.randn(k).astype(np.float32)
    idx = rng.choice(d, size=k, replace=False)
    buf = wire.encode_sparse(vals, idx, d)
    v2, i2 = wire.decode_sparse(buf, k, d)
    np.testing.assert_array_equal(v2, vals)
    np.testing.assert_array_equal(i2, idx)
    # byte count matches Table 2 within rounding
    expect_bits = k * 32 + k * wire.index_bits(d)
    assert len(buf) == 4 * k + (k * wire.index_bits(d) + 7) // 8
    assert abs(len(buf) * 8 - expect_bits) < 8


def test_sparse_to_dense():
    vals = np.array([[1.0, -2.0]])
    idx = np.array([[3, 0]])
    dense = wire.sparse_to_dense(vals, idx, 5)
    np.testing.assert_array_equal(dense, [[-2.0, 0, 0, 1.0, 0]])


@given(st.integers(2, 64), st.integers(1, 8), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_quant_roundtrip(d, bits, seed):
    rng = np.random.RandomState(seed)
    n = 3
    x = rng.randn(n, d).astype(np.float32)
    lo = x.min(-1)
    step = (x.max(-1) - lo) / 2**bits
    step[step <= 0] = 1.0
    codes = np.clip(np.floor((x - lo[:, None]) / step[:, None]), 0,
                    2**bits - 1)
    buf = wire.encode_quant(codes, lo, step, bits)
    deq = wire.decode_quant(buf, n, d, bits)
    assert np.abs(deq - x).max() <= step.max() * 0.51


def test_bytes_per_step():
    b_train = wire.bytes_per_step("topk", 128, 10, k=4, training=True)
    b_inf = wire.bytes_per_step("topk", 128, 10, k=4, training=False)
    assert b_train > b_inf > 0
    ident = wire.bytes_per_step("identity", 128, 10, training=False)
    assert ident == 128 * 4 * 10
