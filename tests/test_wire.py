"""Byte-exact wire format round-trips + property tests + frame layer."""
import doctest
import pathlib

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
from repro.core import compressors as C, wire

ROOT = pathlib.Path(__file__).resolve().parents[1]


@given(st.integers(2, 2048), st.integers(1, 16), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_sparse_roundtrip(d, k, seed):
    k = min(k, d)
    rng = np.random.RandomState(seed)
    vals = rng.randn(k).astype(np.float32)
    idx = rng.choice(d, size=k, replace=False)
    buf = wire.encode_sparse(vals, idx, d)
    v2, i2 = wire.decode_sparse(buf, k, d)
    np.testing.assert_array_equal(v2, vals)
    np.testing.assert_array_equal(i2, idx)
    # byte count matches Table 2 within rounding
    expect_bits = k * 32 + k * wire.index_bits(d)
    assert len(buf) == 4 * k + (k * wire.index_bits(d) + 7) // 8
    assert abs(len(buf) * 8 - expect_bits) < 8


@given(st.integers(1, 64), st.integers(1, 300), st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_bits_roundtrip_any_width(width, n, seed):
    """Every width the wire can carry [1, 64]: pack -> unpack is the
    identity, the byte count is exactly ceil(n*width/8), and appending a
    value extends the stream without disturbing the existing bytes'
    values (the stream is truly positional, no per-value alignment)."""
    rng = np.random.RandomState(seed)
    hi = min(2 ** width, 2 ** 63)
    vals = rng.randint(0, hi, size=n).astype(np.uint64)
    buf = wire._pack_bits(vals, width)
    assert len(buf) == (n * width + 7) // 8
    np.testing.assert_array_equal(wire._unpack_bits(buf, width, n), vals)
    longer = wire._pack_bits(np.concatenate([vals, vals[:1]]), width)
    np.testing.assert_array_equal(
        wire._unpack_bits(longer, width, n + 1)[:n], vals)
    # a shorter read off the same buffer is a strict prefix
    np.testing.assert_array_equal(
        wire._unpack_bits(buf, width, n // 2), vals[:n // 2])


@given(st.integers(1, 200), st.integers(1, 5), st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_mask_words_bytes_roundtrip(d, n, seed):
    """Packed support bitmask serialization: words -> per-row byte-aligned
    wire bytes -> words is the identity at every d (including d not a
    multiple of 8 or 32), and the byte count is n * ceil(d/8)."""
    rng = np.random.RandomState(seed)
    mask = rng.rand(n, d) < 0.3
    words = np.zeros((n, wire.mask_words(d)), np.uint32)
    for j in range(d):
        words[:, j // 32] |= mask[:, j].astype(np.uint32) << (j % 32)
    buf = wire.mask_words_to_bytes(words, d)
    assert len(buf) == n * wire.mask_row_nbytes(d)
    np.testing.assert_array_equal(wire.mask_bytes_to_words(buf, n, d),
                                  words)


def test_sparse_to_dense():
    vals = np.array([[1.0, -2.0]])
    idx = np.array([[3, 0]])
    dense = wire.sparse_to_dense(vals, idx, 5)
    np.testing.assert_array_equal(dense, [[-2.0, 0, 0, 1.0, 0]])


@given(st.integers(2, 64), st.integers(1, 8), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_quant_roundtrip(d, bits, seed):
    rng = np.random.RandomState(seed)
    n = 3
    x = rng.randn(n, d).astype(np.float32)
    lo = x.min(-1)
    step = (x.max(-1) - lo) / 2**bits
    step[step <= 0] = 1.0
    codes = np.clip(np.floor((x - lo[:, None]) / step[:, None]), 0,
                    2**bits - 1)
    buf = wire.encode_quant(codes, lo, step, bits)
    deq = wire.decode_quant(buf, n, d, bits)
    assert np.abs(deq - x).max() <= step.max() * 0.51


def test_bytes_per_step():
    b_train = wire.bytes_per_step("topk", 128, 10, k=4, training=True)
    b_inf = wire.bytes_per_step("topk", 128, 10, k=4, training=False)
    assert b_train > b_inf > 0
    ident = wire.bytes_per_step("identity", 128, 10, training=False)
    assert ident == 128 * 4 * 10


# ---------------------------------------------------------------------------
# Frame layer (docs/wire-format.md is the normative spec)
# ---------------------------------------------------------------------------

ALL_COMPRESSORS = [("identity", {}), ("size_reduction", dict(k=5)),
                   ("topk", dict(k=5)), ("randtopk", dict(k=5, alpha=0.2)),
                   ("quant", dict(bits=4)),
                   ("randtopk_quant", dict(k=5, bits=8)), ("l1", {}),
                   ("randtopk_mask", dict(k=5, alpha=0.2))]


@pytest.mark.parametrize("name,kw", ALL_COMPRESSORS)
def test_payload_frame_roundtrip_all_kinds(name, kw):
    """header + payload bytes -> decode -> exact array equality, per kind."""
    d = 48
    comp = C.make_compressor(name, **kw)
    x = jax.numpy.asarray(
        np.random.RandomState(7).randn(2, 3, d).astype(np.float32))
    p = jax.tree.map(np.asarray,
                     comp.encode(x, key=jax.random.key(0), training=True))
    buf = wire.encode_payload_frame(session=11, seq=4, p=p)
    frame, consumed = wire.decode_frame(buf)
    assert consumed == len(buf) == frame.nbytes
    assert (frame.kind, frame.session, frame.seq) == (wire.FRAME_PAYLOAD,
                                                      11, 4)
    assert frame.payload.meta == p.meta
    assert frame.payload_nbytes == wire.payload_nbytes(p)
    for (name_a, a), (name_b, b) in zip(p.wire_leaves(),
                                        frame.payload.wire_leaves()):
        assert name_a == name_b
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name,kw", ALL_COMPRESSORS)
def test_grad_frame_roundtrip_all_kinds(name, kw):
    """Backward wire: the grad payload a forward kind dictates frames,
    decodes, and routes back onto the forward support exactly."""
    from repro.split import protocol

    d = 48
    comp = C.make_compressor(name, **kw)
    rng = np.random.RandomState(11)
    x = jax.numpy.asarray(rng.randn(2, d).astype(np.float32))
    p = jax.tree.map(np.asarray,
                     comp.encode(x, key=jax.random.key(0), training=True))
    g = rng.randn(2, d).astype(np.float32)
    gp = protocol.server_grad_encode(p, g)
    buf = wire.encode_grad_frame(session=5, seq=7, p=gp, loss=1.5)
    frame, consumed = wire.decode_frame(buf)
    assert consumed == len(buf) == frame.nbytes
    assert (frame.kind, frame.session, frame.seq) == (wire.FRAME_GRAD, 5, 7)
    assert frame.loss == 1.5
    assert frame.payload.meta == gp.meta
    assert frame.payload_nbytes == wire.payload_nbytes(gp)
    assert frame.header_nbytes == wire.grad_frame_header_nbytes(gp)
    g_cut = np.asarray(protocol.client_grad_decode(
        frame.payload, fwd_kind=p.meta.kind, indices=p.indices, d=d))
    assert g_cut.shape == g.shape
    if p.meta.kind in ("sparse", "sparse_quant"):
        mask = np.zeros_like(g, dtype=bool)
        np.put_along_axis(mask, p.indices.astype(np.int64), True, axis=-1)
        np.testing.assert_array_equal(g_cut, g * mask)
    elif p.meta.kind == "mask":
        from repro.core import selection
        mask = np.asarray(selection.unpack_mask_words(
            jax.numpy.asarray(p.indices), d)).astype(bool)
        np.testing.assert_array_equal(g_cut, g * mask)
    elif p.meta.kind == "slice":
        k = p.meta.k
        np.testing.assert_array_equal(g_cut[..., :k], g[..., :k])
        assert not g_cut[..., k:].any()
    else:
        np.testing.assert_array_equal(g_cut, g)


def test_grad_frame_bwd_bytes_match_table2():
    """Grad payload bytes ARE the Table-2 bwd column, measured: k floats
    for sparse kinds, d floats for dense/quant."""
    from repro.core.payload import Payload, PayloadMeta
    from repro.split import protocol

    d, k, n = 64, 5, 3
    g = np.zeros((n, d), np.float32)
    sparse_fwd = Payload(meta=PayloadMeta("sparse", d=d, k=k),
                         values=np.zeros((n, k), np.float32),
                         indices=np.arange(k, dtype=np.uint16)[None].repeat(
                             n, 0))
    assert wire.payload_nbytes(
        protocol.server_grad_encode(sparse_fwd, g)) == 4 * k * n
    dense_fwd = Payload(meta=PayloadMeta("dense", d=d),
                        values=np.zeros((n, d), np.float32))
    assert wire.payload_nbytes(
        protocol.server_grad_encode(dense_fwd, g)) == 4 * d * n


def test_token_and_close_frames():
    buf = wire.encode_token_frame(3, 9, [42, 7]) + wire.encode_close_frame(3)
    f1, off = wire.decode_frame(buf)
    f2, off2 = wire.decode_frame(buf, off)
    assert off2 == len(buf)
    assert f1.kind == wire.FRAME_TOKENS and f1.tokens.tolist() == [42, 7]
    assert f1.payload_nbytes == 8 and f1.nbytes + f2.nbytes == len(buf)
    assert f2.kind == wire.FRAME_CLOSE and f2.session == 3


def test_frame_reader_arbitrary_chunks():
    """Reassembly must not depend on chunk boundaries (1-byte feeds)."""
    p = C.make_compressor("topk", k=2).encode(
        jax.numpy.asarray(np.random.RandomState(0).randn(1, 8).astype(
            np.float32)))
    stream = (wire.encode_payload_frame(0, 0, jax.tree.map(np.asarray, p))
              + wire.encode_token_frame(0, 1, [5])
              + wire.encode_close_frame(0))
    reader = wire.FrameReader()
    got = []
    for i in range(len(stream)):
        reader.feed(stream[i:i + 1])
        got.extend(reader.frames())
    assert [f.kind for f in got] == [wire.FRAME_PAYLOAD, wire.FRAME_TOKENS,
                                     wire.FRAME_CLOSE]


def test_frame_reader_abandoned_iterator_does_not_replay():
    """Consuming one frame and dropping the iterator must not re-yield it."""
    reader = wire.FrameReader()
    reader.feed(wire.encode_token_frame(0, 0, [1])
                + wire.encode_token_frame(0, 1, [2]))
    first = next(reader.frames())        # iterator abandoned mid-stream
    assert first.seq == 0
    assert [f.seq for f in reader.frames()] == [1]


# ---------------------------------------------------------------------------
# Typed error taxonomy: every malformed-but-CRC-valid frame must raise the
# *specific* WireError naming the bad field, and raw corruption must raise
# ChecksumError — never decode silently, never raise something untyped.
# `_forge` builds frames with arbitrary (inconsistent) contents but a valid
# CRC, so each validator is reached past the checksum gate.
# ---------------------------------------------------------------------------

def _forge(kind, body, session=0, seq=0, version=None):
    buf = bytearray(wire._frame(kind, session, seq, body))
    if version is not None:
        buf[4] = version
        buf[-4:] = wire._CRC.pack(
            __import__("zlib").crc32(bytes(buf[4:-4])))
    return bytes(buf)


def _payload_body(kind_idx=2, d=16, k=2, bits=0, bshape=(1,),
                  payload=b"\x00" * 9):
    sub = wire._PAYLOAD_HEAD.pack(kind_idx, d, k, bits, len(bshape))
    import struct as _s
    return (sub + (_s.pack(f"<{len(bshape)}I", *bshape) if bshape else b"")
            + payload)


def test_corrupt_count_raises_typed_badcount():
    """A token frame whose count field disagrees with the body length must
    raise the typed BadCount (it used to be a generic ValueError)."""
    body = wire._TOKENS_HEAD.pack(200) + np.asarray(
        [1, 2], "<i4").tobytes()
    with pytest.raises(wire.BadCount, match="count"):
        wire.decode_frame(_forge(wire.FRAME_TOKENS, body))


def test_bad_payload_kind_index_raises_unknown_kind():
    with pytest.raises(wire.UnknownKind, match="kind index"):
        wire.decode_frame(_forge(wire.FRAME_PAYLOAD,
                                 _payload_body(kind_idx=250)))


def test_bad_payload_d_raises_badcount():
    for d in (0, 1 << 20):
        with pytest.raises(wire.BadCount, match="d="):
            wire.decode_frame(_forge(wire.FRAME_PAYLOAD,
                                     _payload_body(d=d)))


def test_bad_payload_k_raises_badcount():
    for k in (0, 17):                    # k must be in [1, d] for sparse
        with pytest.raises(wire.BadCount, match="k="):
            wire.decode_frame(_forge(wire.FRAME_PAYLOAD,
                                     _payload_body(d=16, k=k)))


def test_bad_payload_bits_raises_badcount():
    for bits in (0, 9):                  # quant code width is 1..8
        with pytest.raises(wire.BadCount, match="bits="):
            wire.decode_frame(_forge(wire.FRAME_PAYLOAD,
                                     _payload_body(kind_idx=3, bits=bits)))


def test_bad_payload_batch_shape_raises_badcount():
    with pytest.raises(wire.BadCount, match="zero dim"):
        wire.decode_frame(_forge(wire.FRAME_PAYLOAD,
                                 _payload_body(bshape=(0,))))
    with pytest.raises(wire.BadCount, match="rank"):
        wire.decode_frame(_forge(wire.FRAME_PAYLOAD,
                                 _payload_body(bshape=(1,) * 9)))


def test_payload_body_length_mismatch_raises_badcount():
    """Declared (meta, batch shape) must account for the body bytes exactly
    — one byte short or long is BadCount, not a misdecode."""
    for payload in (b"\x00" * 8, b"\x00" * 10):     # sparse d=16,k=2 -> 9 B
        with pytest.raises(wire.BadCount, match="needs 9 B"):
            wire.decode_frame(_forge(wire.FRAME_PAYLOAD,
                                     _payload_body(payload=payload)))


def test_truncated_subheader_raises_truncated_frame():
    with pytest.raises(wire.TruncatedFrame):
        wire.decode_frame(_forge(wire.FRAME_PAYLOAD, b"\x02"))
    with pytest.raises(wire.TruncatedFrame, match="batch shape"):
        wire.decode_frame(_forge(
            wire.FRAME_PAYLOAD,
            wire._PAYLOAD_HEAD.pack(2, 16, 2, 0, 4) + b"\x01"))


def test_grad_frame_missing_loss_raises_truncated_frame():
    body = wire._PAYLOAD_HEAD.pack(1, 16, 2, 0, 0)   # slice, no loss field
    with pytest.raises(wire.TruncatedFrame, match="loss"):
        wire.decode_frame(_forge(wire.FRAME_GRAD, body))


def test_close_frame_with_body_raises_badcount():
    with pytest.raises(wire.BadCount, match="close frame"):
        wire.decode_frame(_forge(wire.FRAME_CLOSE, b"\x00\x01"))


def test_unknown_frame_kind_raises_unknown_kind():
    with pytest.raises(wire.UnknownKind, match="frame kind"):
        wire.decode_frame(_forge(77, b""))


def test_absurd_length_prefix_raises_truncated_frame():
    """A corrupt length prefix must fail fast, not stall the reader
    waiting for bytes that will never come."""
    import struct as _s
    with pytest.raises(wire.TruncatedFrame, match="MAX_FRAME_BODY"):
        wire.decode_frame(_s.pack("<I", wire.MAX_FRAME_BODY + 1) + b"\x00")
    with pytest.raises(wire.TruncatedFrame, match="minimum"):
        wire.decode_frame(_s.pack("<I", 3) + b"\x00" * 3)


def test_flipped_byte_raises_checksum_error():
    buf = bytearray(wire.encode_token_frame(0, 0, [1, 2]))
    buf[wire.FRAME_HEAD_NBYTES] ^= 0x40      # corrupt the count field
    with pytest.raises(wire.ChecksumError):
        wire.decode_frame(bytes(buf))


def test_error_frame_roundtrip():
    buf = wire.encode_error_frame(9, 3, wire.ERR_BAD_COUNT, "k=99 > d=16")
    frame, consumed = wire.decode_frame(buf)
    assert consumed == len(buf) == frame.nbytes == frame.header_nbytes
    assert frame.kind == wire.FRAME_ERROR and frame.session == 9
    assert frame.error_code == wire.ERR_BAD_COUNT
    assert frame.error_msg == "k=99 > d=16"
    assert frame.payload_nbytes == 0
    # code mapping covers the whole taxonomy
    assert wire.error_code(wire.ChecksumError("x")) == wire.ERR_CHECKSUM
    assert wire.error_code(wire.TruncatedFrame("x")) == wire.ERR_TRUNCATED
    assert wire.error_code(wire.UnknownKind("x")) == wire.ERR_UNKNOWN_KIND
    assert wire.error_code(wire.BadCount("x")) == wire.ERR_BAD_COUNT
    assert wire.error_code(wire.VersionMismatch("x")) == wire.ERR_VERSION
    assert wire.error_code(RuntimeError("x")) == wire.ERR_PROTOCOL


def test_wire_errors_are_value_errors():
    """Back-compat: pre-taxonomy callers caught ValueError."""
    for cls in (wire.ChecksumError, wire.TruncatedFrame, wire.UnknownKind,
                wire.BadCount, wire.VersionMismatch):
        assert issubclass(cls, wire.WireError)
        assert issubclass(cls, ValueError)


def test_decode_frame_incomplete_returns_none():
    buf = wire.encode_token_frame(0, 0, [1])
    for cut in (0, 3, len(buf) - 1):
        assert wire.decode_frame(buf[:cut]) is None


def test_frame_rejects_unknown_version():
    with pytest.raises(wire.VersionMismatch, match="version"):
        wire.decode_frame(_forge(wire.FRAME_CLOSE, b"", version=99))


def test_wire_format_doc_examples():
    """docs/wire-format.md's examples are executable and must stay true."""
    failures, n = doctest.testfile(str(ROOT / "docs" / "wire-format.md"),
                                   module_relative=False,
                                   optionflags=doctest.NORMALIZE_WHITESPACE)
    assert n > 0 and failures == 0
