"""Streaming runtime: batching-queue flush policy, transport framing, and
end-to-end multi-client serving (byte accounting + local-decode parity)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import wire
from repro.launch.steps import make_serve_step
from repro.models import transformer
from repro.models.config import Runtime, SplitConfig
from repro.runtime import BatchingQueue, channel_pair, run_streaming


# ---------------------------------------------------------------------------
# BatchingQueue flush policy
# ---------------------------------------------------------------------------

def test_queue_empty_times_out():
    q = BatchingQueue(max_batch=4, max_wait=0.05)
    t0 = time.monotonic()
    assert q.get_batch() == []
    assert time.monotonic() - t0 >= 0.04


def test_queue_flushes_full_batch_immediately():
    q = BatchingQueue(max_batch=3, max_wait=10.0)  # max_wait must NOT bind
    for i in range(5):
        q.put(i)
    t0 = time.monotonic()
    assert q.get_batch() == [0, 1, 2]
    assert time.monotonic() - t0 < 1.0
    assert len(q) == 2


def test_queue_max_wait_flushes_partial_batch():
    q = BatchingQueue(max_batch=8, max_wait=0.05)
    q.put("a")
    q.put("b")
    t0 = time.monotonic()
    assert q.get_batch() == ["a", "b"]   # ragged batch after max_wait
    assert 0.03 <= time.monotonic() - t0 < 1.0


def test_queue_fills_from_concurrent_producer():
    q = BatchingQueue(max_batch=3, max_wait=0.5)
    q.put(0)

    def late_puts():
        time.sleep(0.02)
        q.put(1)
        q.put(2)

    t = threading.Thread(target=late_puts)
    t.start()
    batch = q.get_batch()
    t.join()
    assert batch == [0, 1, 2]            # filled before max_wait expired


def test_queue_close_drains_ragged_final_batch():
    q = BatchingQueue(max_batch=8, max_wait=5.0)
    q.put("last")
    q.close()
    assert q.get_batch() == ["last"]     # close flushes without waiting
    assert q.get_batch() == [] and q.drained
    with pytest.raises(RuntimeError):
        q.put("nope")


def test_queue_concurrent_producers_lose_nothing():
    """N producer threads hammering put() against a draining consumer:
    every item comes out exactly once, in batches never exceeding
    max_batch."""
    n_producers, per_producer = 8, 200
    q = BatchingQueue(max_batch=16, max_wait=0.002)

    def produce(pid):
        for i in range(per_producer):
            q.put((pid, i))

    threads = [threading.Thread(target=produce, args=(pid,))
               for pid in range(n_producers)]
    for t in threads:
        t.start()
    got = []
    deadline = time.monotonic() + 30
    while len(got) < n_producers * per_producer:
        assert time.monotonic() < deadline, f"stalled at {len(got)} items"
        batch = q.get_batch(idle_timeout=0.05)
        assert len(batch) <= q.max_batch
        got.extend(batch)
    for t in threads:
        t.join()
    assert q.get_batch(idle_timeout=0.01) == []
    assert sorted(got) == [(p, i) for p in range(n_producers)
                           for i in range(per_producer)]
    # per-producer order is preserved even though batches interleave
    for pid in range(n_producers):
        seq = [i for p, i in got if p == pid]
        assert seq == sorted(seq)


def test_queue_close_during_fill_wait_flushes_promptly():
    """The close-during-flush race: a consumer blocked in the fill wait
    (partial batch, max_wait not yet elapsed) must be woken by close() and
    return the pending items immediately — not after max_wait, and never
    []."""
    q = BatchingQueue(max_batch=8, max_wait=10.0)   # max_wait must NOT bind
    result = {}

    def consume():
        result["batch"] = q.get_batch(idle_timeout=30.0)
        result["t"] = time.monotonic()

    t = threading.Thread(target=consume)
    t.start()
    q.put("a")
    q.put("b")
    time.sleep(0.15)                    # let the consumer enter the fill wait
    t0 = time.monotonic()
    q.close()
    t.join(timeout=5.0)
    assert not t.is_alive(), "consumer still blocked after close()"
    assert result["batch"] == ["a", "b"]
    assert result["t"] - t0 < 1.0       # woke on close, not on max_wait
    assert q.get_batch() == [] and q.drained


def test_queue_concurrent_producers_racing_close():
    """Producers racing close(): items either land in the queue and drain,
    or the put raises — none vanish silently mid-queue."""
    q = BatchingQueue(max_batch=4, max_wait=0.001)
    accepted, rejected = [], []
    lock = threading.Lock()

    def produce(pid):
        for i in range(100):
            try:
                q.put((pid, i))
                with lock:
                    accepted.append((pid, i))
            except RuntimeError:
                with lock:
                    rejected.append((pid, i))

    threads = [threading.Thread(target=produce, args=(pid,))
               for pid in range(4)]
    for t in threads:
        t.start()
    got = []
    for _ in range(30):                 # drain some while producers run
        got.extend(q.get_batch(idle_timeout=0.01))
    q.close()
    for t in threads:
        t.join()
    while True:
        batch = q.get_batch(idle_timeout=0.01)
        if not batch:
            break
        got.extend(batch)
    assert q.drained
    assert sorted(got) == sorted(accepted)
    assert len(got) + len(rejected) == 400


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------

def test_channel_pair_carries_frames_both_ways():
    cep, sep = channel_pair()
    cep.send(wire.encode_token_frame(1, 0, [7]))
    f = sep.recv_frame(timeout=1.0)
    assert f.tokens.tolist() == [7]
    sep.send(wire.encode_close_frame(1))
    assert cep.recv_frame(timeout=1.0).kind == wire.FRAME_CLOSE
    assert cep.recv_frame(timeout=0.01) is None


# ---------------------------------------------------------------------------
# Out-of-process protocol halves
# ---------------------------------------------------------------------------

def test_protocol_halves_roundtrip_over_wire():
    """client_encode -> frame bytes -> server_decode reproduces the fused
    forward() view exactly, with no compressor object on the server side."""
    from repro.core import compressors as C
    from repro.split import protocol

    comp = C.make_compressor("randtopk_quant", k=4, bits=8)
    x = jnp.asarray(np.random.RandomState(3).randn(2, 1, 32).astype(
        np.float32))
    p = protocol.client_encode(comp, x, key=jax.random.key(0), training=True)
    assert all(isinstance(a, np.ndarray) for _, a in p.wire_leaves())
    frame, _ = wire.decode_frame(wire.encode_payload_frame(0, 0, p))
    y = np.asarray(protocol.server_decode(frame.payload))
    fused, _ = comp.forward(x, key=jax.random.key(0), training=True)
    np.testing.assert_allclose(y, np.asarray(fused), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# End-to-end serving
# ---------------------------------------------------------------------------

def _smoke_cfg(**split_kw):
    split = SplitConfig(cut_layer=1, **split_kw) if split_kw else None
    return configs.get("qwen3-8b", smoke=True).with_(split=split)


def test_streaming_matches_local_decode():
    """Identity compression through the full frame/queue/batch machinery
    must reproduce the plain single-process decode loop token-for-token."""
    cfg = _smoke_cfg()
    params = transformer.init_model(jax.random.key(0), cfg)
    prompt_len, gen = 3, 5
    res = run_streaming(cfg, n_clients=2, prompt_len=prompt_len, gen=gen,
                        max_batch=2, params=params, seed=0)

    rt = Runtime(mesh=None, training=False)
    serve = jax.jit(make_serve_step(cfg, rt))
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (2, prompt_len), 0, cfg.vocab))
    for row in range(2):
        cache = transformer.init_cache(params, cfg, rt, 1, prompt_len + gen)
        tok, out = prompts[row:row + 1, :1], []
        for i in range(prompt_len + gen - 1):
            nxt, cache = serve(params, cache, jnp.asarray(tok))
            if i >= prompt_len - 1:
                out.append(int(nxt[0, 0]))
            tok = (prompts[row:row + 1, i + 1:i + 2]
                   if i + 1 < prompt_len else np.asarray(nxt))
        assert res["tokens"][row].tolist() == out


@pytest.mark.slow
def test_streaming_mixed_compressors_byte_accounting():
    """A dense + randtopk session mix: grouped batched decode, and both
    parties' accounting equals the frame sizes the codec predicts."""
    cfg = _smoke_cfg(compressor="randtopk", k=16)
    prompt_len, gen = 2, 4
    res = run_streaming(cfg, n_clients=4, prompt_len=prompt_len, gen=gen,
                        max_batch=4, max_wait=0.05,
                        compressor_mix=["identity", "randtopk:k=16"])
    assert res["tokens"].shape == (4, gen)
    n_frames = prompt_len + gen - 1
    d = cfg.d_model
    r = wire.index_bits(d)
    expect = {"identity": d * 4, "randtopk": 16 * 4 + (16 * r + 7) // 8}
    for name, cs, ss in zip(res["compressors"], res["client_stats"],
                            res["server_stats"]):
        for f in ("frames_up", "payload_bytes_up", "header_bytes_up",
                  "frames_down", "bytes_down"):
            assert cs[f] == ss[f], (f, cs, ss)
        assert cs["frames_up"] == cs["frames_down"] == n_frames
        assert cs["tokens_out"] == gen
        assert cs["payload_bytes_up"] == n_frames * expect[name]
    # the mix really was batched together at least once
    assert max(res["batch_sizes"]) > 1


def test_streaming_sessions_outnumber_max_batch():
    """More sessions than the flush size -> multiple ragged flushes, every
    session still completes with its own cache intact."""
    cfg = _smoke_cfg(compressor="topk", k=8)
    res = run_streaming(cfg, n_clients=5, prompt_len=2, gen=3, max_batch=2,
                        max_wait=0.01)
    assert res["tokens"].shape == (5, 3)
    assert all(1 <= b <= 2 for b in res["batch_sizes"])
    assert sum(res["batch_sizes"]) == 5 * (2 + 3 - 1)
