"""Federated split-training runtime: parity with the in-process trainer,
measured dual-direction byte accounting, checkpoint/resume, async local
steps, and adaptive-k scheduling."""
import numpy as np
import pytest

from repro.data.synthetic import ManyClassDataset
from repro.fedtrain import AsyncPolicy, KScheduler, ScheduleSpec, run_fedtrain
from repro.fedtrain.schedule import ANNEAL_STAGES
from repro.split.tabular import SplitSpec, train

D = 32


def _dataset():
    return ManyClassDataset(n_classes=10, in_dim=16, n_train=512, n_test=256,
                            noise=0.3, seed=0)


def _spec(method="randtopk", **kw):
    kw.setdefault("k", 3)
    return SplitSpec(in_dim=16, hidden=32, cut_dim=D, n_classes=10,
                     method=method, **kw)


# ---------------------------------------------------------------------------
# Acceptance: over-the-wire training == in-process training, and the wire
# bytes it measures == the Table-2 analytics.
# ---------------------------------------------------------------------------

def test_fedtrain_matches_tabular_loss_trajectory():
    """randtopk over real frames reproduces split.tabular.train's loss
    trajectory at equal seeds (same init, data order, and PRNG chain)."""
    ds = _dataset()
    spec = _spec()
    r_tab = train(spec, ds, epochs=2, batch=64, seed=0, record_every=1)
    tab_losses = np.asarray([t[2] for t in r_tab["trace"]])

    r_fed = run_fedtrain(spec, ds, n_clients=1, epochs=2, batch=64, seed=0)
    fed_losses = np.asarray([l for _, l in r_fed["losses"][0]])

    assert len(tab_losses) == len(fed_losses) == r_fed["steps"]
    np.testing.assert_allclose(fed_losses, tab_losses, rtol=1e-5, atol=1e-6)
    assert abs(r_fed["mean_test_acc"] - r_tab["test_acc"]) < 1e-6


def test_fedtrain_mask_matches_randtopk_trajectory():
    """randtopk_mask == randtopk step for step at equal seeds: the mask
    wire encoding changes the frames (packed support bitmask instead of
    u16 indices), not the selection math or the same-mask backward."""
    ds = _dataset()
    r_idx = run_fedtrain(_spec("randtopk", k=7), ds, n_clients=1, epochs=1,
                         batch=64, seed=0)
    r_msk = run_fedtrain(_spec("randtopk_mask", k=7), ds, n_clients=1,
                         epochs=1, batch=64, seed=0)
    np.testing.assert_allclose(
        np.asarray([l for _, l in r_msk["losses"][0]]),
        np.asarray([l for _, l in r_idx["losses"][0]]), rtol=1e-6)
    # against the wire's r-bit packed indices (r = ceil(log2 d) = 5 at
    # d=32) the bitmask wins iff k*r > d: 7*5 = 35 > 32, so the mask
    # frames must be strictly smaller here
    assert r_msk["payload_bytes_up"] < r_idx["payload_bytes_up"]


@pytest.mark.parametrize("method,kw", [
    ("randtopk", dict(k=3)), ("topk", dict(k=3)),
    ("size_reduction", dict(k=3)), ("quant", dict(quant_bits=4)),
    ("randtopk_quant", dict(k=3, quant_bits=4)), ("none", {}),
    ("randtopk_mask", dict(k=3)),
])
def test_fedtrain_measured_bytes_match_analytics(method, kw):
    """Measured up+down payload bytes agree with the compressor's Table-2
    fwd+bwd accounting within 5% (byte-exact for the sparse kinds)."""
    r = run_fedtrain(_spec(method, **kw), _dataset(), n_clients=1, epochs=1,
                     batch=64, seed=0)
    for direction in ("up", "down"):
        measured = r[f"payload_bytes_{direction}"]
        analytic = r[f"analytic_bytes_{direction}"]
        assert abs(measured - analytic) / analytic < 0.05, (
            direction, measured, analytic)


def test_fedtrain_both_parties_count_the_same_frames():
    r = run_fedtrain(_spec(), _dataset(), n_clients=2, epochs=1, batch=64,
                     seed=0)
    for cs, ss in zip(r["client_stats"], r["server_stats"]):
        for f in ("frames_up", "payload_bytes_up", "header_bytes_up",
                  "frames_down", "payload_bytes_down", "header_bytes_down",
                  "bytes_down"):
            assert cs[f] == ss[f], (f, cs, ss)
        assert cs["frames_up"] == cs["frames_down"] == r["steps"]


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------

def test_checkpoint_resume_loss_parity(tmp_path):
    """Kill run_fedtrain mid-run, restore both parties from the store, and
    the resumed run's losses match the uninterrupted run step for step."""
    ds = _dataset()
    spec = _spec()
    full = run_fedtrain(spec, ds, n_clients=1, epochs=2, batch=64, seed=0)
    full_losses = np.asarray([l for _, l in full["losses"][0]])
    assert len(full_losses) == 16

    ckpt = str(tmp_path / "fed")
    killed = run_fedtrain(spec, ds, n_clients=1, epochs=2, batch=64, seed=0,
                          ckpt_dir=ckpt, ckpt_every=4, stop_after_steps=8)
    assert killed["steps"] == 8
    resumed = run_fedtrain(spec, ds, n_clients=1, epochs=2, batch=64, seed=0,
                           ckpt_dir=ckpt, ckpt_every=4)
    steps, losses = zip(*resumed["losses"][0])
    assert steps == tuple(range(8, 16))     # picked up where it was killed
    np.testing.assert_allclose(np.asarray(losses), full_losses[8:],
                               rtol=1e-6, atol=1e-7)
    # byte counters survived the restore: totals equal the full run's
    assert resumed["payload_bytes_up"] == full["payload_bytes_up"]
    assert resumed["payload_bytes_down"] == full["payload_bytes_down"]
    assert abs(resumed["mean_test_acc"] - full["mean_test_acc"]) < 1e-6


@pytest.mark.slow
def test_checkpoint_resume_multi_client_async(tmp_path):
    """The barrier snapshot is consistent for N clients under an async
    policy (stale gradients and schedule clocks checkpoint too)."""
    ds = _dataset()
    spec = _spec()
    pol = AsyncPolicy(local_steps=2)
    kw = dict(n_clients=2, epochs=2, batch=64, seed=0, policy=pol)
    full = run_fedtrain(spec, ds, **kw)
    ckpt = str(tmp_path / "fed2")
    run_fedtrain(spec, ds, ckpt_dir=ckpt, ckpt_every=4, stop_after_steps=4,
                 **kw)
    resumed = run_fedtrain(spec, ds, ckpt_dir=ckpt, ckpt_every=4, **kw)
    for cid in range(2):
        f = dict(full["losses"][cid])
        r = dict(resumed["losses"][cid])
        assert set(r) == {s for s in f if s >= 4}
        # cross-client top updates interleave by arrival order, so the two
        # runs' states differ by a few reorderings of tiny AdamW steps —
        # the resumed trajectory must track the full run, not equal it
        first = min(r)
        np.testing.assert_allclose(r[first], f[first], rtol=0.02)


# ---------------------------------------------------------------------------
# Async local steps
# ---------------------------------------------------------------------------

def test_async_policy_reduces_both_directions():
    ds = _dataset()
    sync = run_fedtrain(_spec(), ds, n_clients=1, epochs=2, batch=64, seed=0)
    asy = run_fedtrain(_spec(), ds, n_clients=1, epochs=2, batch=64, seed=0,
                       policy=AsyncPolicy(local_steps=4))
    assert asy["steps"] == sync["steps"]
    assert asy["client_stats"][0]["frames_up"] == -(-sync["steps"] // 4)
    assert asy["payload_bytes_up"] * 3 < sync["payload_bytes_up"]
    assert asy["payload_bytes_down"] * 3 < sync["payload_bytes_down"]
    assert np.isfinite(asy["mean_test_acc"])


def test_async_policy_schedule():
    p = AsyncPolicy(local_steps=3, warmup_sync=2)
    assert [p.is_sync(s) for s in range(8)] == [
        True, True, True, False, False, True, False, False]


# ---------------------------------------------------------------------------
# Adaptive-k scheduling
# ---------------------------------------------------------------------------

def test_scheduler_warmup_anneal_plateau():
    sched = KScheduler(ScheduleSpec(k=8, d=64, warmup_steps=3,
                                    anneal_steps=6, k_min=2, drop=0.5,
                                    patience=2, min_rel_improve=0.5))
    ks = [sched.k_bits(s)[0] for s in range(12)]
    assert ks[:3] == [64, 64, 64]               # dense warmup
    assert all(a >= b for a, b in zip(ks[3:], ks[4:]))  # monotone anneal
    assert ks[8] == 8 and ks[-1] == 8           # lands on the target
    assert len(set(ks[3:9])) <= ANNEAL_STAGES
    # a plateau (no 50% improvements) halves k after `patience` observations
    for loss in [1.0, 1.0, 1.0]:
        sched.observe(loss)
    assert sched.cur_k == 4
    for loss in [1.0, 1.0]:
        sched.observe(loss)
    assert sched.cur_k == 2
    sched.observe(1.0)
    sched.observe(1.0)
    assert sched.cur_k == 2                     # floored at k_min


def test_adaptive_schedule_over_the_wire():
    """Per-step k changes need no server config: frames self-describe, and
    the measured per-frame payload bytes shrink as the schedule anneals."""
    ds = _dataset()
    sched = ScheduleSpec(k=6, d=D, warmup_steps=2, anneal_steps=4, k_min=3,
                         patience=3)
    r = run_fedtrain(_spec(k=6), ds, n_clients=1, epochs=2, batch=64, seed=0,
                     schedule=sched)
    ks = [k for _, k, _ in r["k_trace"][0]]
    assert ks[0] == D and ks[1] == D            # dense warmup frames
    assert all(a >= b for a, b in zip(ks, ks[1:]))
    assert ks[-1] <= 6
    # analytics track the per-step schedule, not a fixed k
    assert abs(r["payload_bytes_up"] - r["analytic_bytes_up"]) \
        / r["analytic_bytes_up"] < 0.05
    assert r["final_k"][0] <= 6


def test_error_feedback_state_checkpoints(tmp_path):
    """EF residual memory survives a kill/restore without changing the
    resumed trajectory."""
    ds = _dataset()
    spec = _spec("topk", k=3)
    kw = dict(n_clients=1, epochs=2, batch=64, seed=0, ef=True)
    full = run_fedtrain(spec, ds, **kw)
    ckpt = str(tmp_path / "ef")
    run_fedtrain(spec, ds, ckpt_dir=ckpt, ckpt_every=4, stop_after_steps=8,
                 **kw)
    resumed = run_fedtrain(spec, ds, ckpt_dir=ckpt, ckpt_every=4, **kw)
    f = np.asarray([l for _, l in full["losses"][0]])
    r = np.asarray([l for _, l in resumed["losses"][0]])
    np.testing.assert_allclose(r, f[8:], rtol=1e-6, atol=1e-7)
