"""Compressor semantics: forward views, backward rules, size accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C


def test_factory():
    c = C.make_compressor("randtopk:k=5,alpha=0.2")
    assert isinstance(c, C.RandTopK) and c.k == 5 and c.alpha == 0.2
    assert isinstance(C.make_compressor("quant", bits=2), C.Quantization)
    assert isinstance(C.make_compressor(None), C.Compressor)
    with pytest.raises(ValueError):
        C.make_compressor("nope")


def test_topk_forward_backward_support():
    """Gradient must be masked with the forward support (paper Table 2)."""
    x = jax.random.normal(jax.random.key(0), (4, 32))
    c = C.TopK(k=6)

    def f(x):
        y, _ = c.forward(x)
        return jnp.sum(y ** 2)

    g = jax.grad(f)(x)
    mask = np.asarray(c.forward(x)[0] != 0)
    assert (np.asarray(g)[~mask] == 0).all()
    assert (np.abs(np.asarray(g)[mask]) > 0).all()


def test_randtopk_inference_is_deterministic_topk():
    x = jax.random.normal(jax.random.key(0), (4, 32))
    r = C.RandTopK(k=6, alpha=0.3)
    t = C.TopK(k=6)
    yr, _ = r.forward(x, training=False)
    yt, _ = t.forward(x)
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(yt))


def test_randtopk_training_requires_key():
    x = jnp.ones((2, 8))
    with pytest.raises(ValueError):
        C.RandTopK(k=2).forward(x, training=True)


def test_quantization_error_bound():
    x = jax.random.normal(jax.random.key(1), (8, 64))
    for bits in (2, 4, 8):
        c = C.Quantization(bits=bits)
        y, _ = c.forward(x)
        step = (x.max(-1, keepdims=True) - x.min(-1, keepdims=True)) / 2**bits
        assert float(jnp.abs(y - x).max()) <= float(step.max()) * 0.51


def test_quantization_ste_gradient():
    x = jax.random.normal(jax.random.key(2), (4, 16))
    c = C.Quantization(bits=4)
    g = jax.grad(lambda x: jnp.sum(c.forward(x)[0]))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_l1_penalty_and_inference_mask():
    x = jnp.array([[0.5, 1e-9, -2.0, 0.0]])
    c = C.L1Reg(lam=0.1)
    y_train, _ = c.forward(x, training=True)
    np.testing.assert_array_equal(np.asarray(y_train), np.asarray(x))
    y_inf, aux = c.forward(x, training=False)
    assert np.asarray(y_inf[0, 1]) == 0.0
    assert float(c.loss_penalty(x)) > 0


def test_table2_sizes():
    """Compressed sizes must match the paper's Table 2 formulas."""
    from repro.core import wire

    d, k, bits = 128, 4, 2
    r = wire.index_bits(d)  # 7
    row = wire.table2_row("topk", d, k=k)
    assert row["fwd"] == pytest.approx(k / d * (1 + r / 32))
    assert row["bwd"] == pytest.approx(k / d)
    row = wire.table2_row("size_reduction", d, k=k)
    assert row["fwd"] == row["bwd"] == pytest.approx(k / d)
    row = wire.table2_row("quant", d, bits=bits)
    assert row["fwd"] == pytest.approx(bits / 32)
    assert row["bwd"] == 1.0


def test_compressor_fwd_bits_consistent_with_wire():
    from repro.core import wire

    d = 300
    c = C.TopK(k=11)
    assert c.fwd_bits(d) == 11 * (32 + wire.index_bits(d))
    assert c.bwd_bits(d) == 11 * 32


def test_randtopk_quant_combined():
    """Beyond-paper combined compressor: exact-k support, quantized values,
    STE gradient on the support only."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    x = jax.random.normal(jax.random.key(0), (4, 64))
    c = C.make_compressor("randtopk_quant", k=6, alpha=0.1, bits=4)
    y, aux = c.forward(x, key=jax.random.key(1), training=True)
    assert (np.asarray((y != 0).sum(-1)) <= 6).all()
    # inference deterministic, support = top-k
    y2, _ = c.forward(x, training=False)
    mask = np.asarray(y2 != 0)
    from repro.core import selection
    np.testing.assert_array_equal(mask, np.asarray(selection.topk_mask(x, 6)))
    # quantization error bounded by the selected-value range / 2^bits
    sel = np.where(mask, np.asarray(x), np.nan)
    rng = np.nanmax(sel, -1) - np.nanmin(sel, -1)
    err = np.abs(np.asarray(y2) - np.asarray(x) * mask)[mask.astype(bool)]
    assert err.max() <= (rng.max() / 2**4) * 0.51
    # gradient masked to the support
    g = jax.grad(lambda x: jnp.sum(
        c.forward(x, key=jax.random.key(1), training=True)[0]))(x)
    assert (np.asarray(g)[~np.asarray(
        c.forward(x, key=jax.random.key(1), training=True)[0] != 0)] == 0).all()
    # wire accounting smaller than fp32 topk at same k
    assert c.fwd_bits(64) < C.TopK(k=6).fwd_bits(64)
