"""Optimizer / data pipeline / checkpoint / roofline-parser unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.checkpoint import latest_step, restore, save
from repro.data.pipeline import TokenPipeline, make_lm_batch
from repro.data.synthetic import ManyClassDataset
from repro.optim import adamw_init, adamw_update, linear_warmup_cosine
from repro.roofline import hlo as H


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, gnorm = adamw_update(params, grads, opt, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    assert int(opt["step"]) == 200


def test_adamw_grad_clip():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    _, _, gnorm = adamw_update(params, {"w": 1e6 * jnp.ones((4,))}, opt,
                               lr=0.1, grad_clip=1.0)
    assert float(gnorm) > 1e5  # reported pre-clip norm


def test_schedule():
    lr = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(lr(100)) < 0.1


def test_pipeline_determinism_and_structure():
    cfg = configs.get("yi_6b", smoke=True)
    pipe = TokenPipeline(cfg, batch=4, seq=16, seed=3)
    b1, b2 = pipe.next_batch(7), pipe.next_batch(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = pipe.next_batch(8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))


def test_vlm_audio_batches_have_frontend_stubs():
    vlm = configs.get("llama_3_2_vision_90b", smoke=True)
    b = make_lm_batch(jax.random.key(0), vlm, 2, 8)
    assert b["patches"].shape == (2, vlm.n_image_tokens, vlm.d_model)
    aud = configs.get("whisper_tiny", smoke=True)
    b = make_lm_batch(jax.random.key(0), aud, 2, 8)
    assert b["frames"].shape == (2, aud.n_frames, aud.d_model)


def test_synthetic_dataset_deterministic():
    a = ManyClassDataset(n_classes=10, n_train=100, n_test=50, seed=1)
    b = ManyClassDataset(n_classes=10, n_train=100, n_test=50, seed=1)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    assert set(np.unique(a.y_train)) <= set(range(10))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3)},
            "c": [jnp.ones((4,)), jnp.zeros((), jnp.int32)]}
    d = str(tmp_path / "ckpt")
    save(d, 3, tree)
    save(d, 7, tree)
    assert latest_step(d) == 7
    out = restore(d, 3, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(out["a"]["b"], dtype=np.float32),
                                  np.asarray(tree["a"]["b"],
                                             dtype=np.float32))
    assert out["a"]["b"].dtype == jnp.bfloat16


HLO_SAMPLE = """\
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %ag = f32[8,8]{1,0} all-gather(%x), replica_groups={{0,1}}, dimensions={0}
  %d = f32[8,8]{1,0} dot(%ag, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(12)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %w = (s32[], f32[8,8]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  %ar = f32[8,8]{1,0} all-reduce(%a), to_apply=%add
}
"""


def test_hlo_collective_parser_loop_amplification():
    stats = H.collective_bytes(HLO_SAMPLE)
    # all-gather inside 12-trip loop: 12 * 256B; all-reduce once: 2x ring
    assert stats.per_op_bytes["all-gather"] == pytest.approx(12 * 256)
    assert stats.per_op_bytes["all-reduce"] == pytest.approx(256)
    assert stats.total_link_bytes == pytest.approx(12 * 256 + 2 * 256)


def test_hlo_flop_counter():
    flops, byts = H.program_costs(HLO_SAMPLE)
    # dot 8x8x8 inside 12-trip loop = 12 * 2*8*8*8
    assert flops == pytest.approx(12 * 2 * 8 * 8 * 8)
    assert byts > 0


def test_table2_formula_spotcheck():
    from repro.core import wire
    # paper example: d=128, k=3 -> 2.86% fwd for top-k
    row = wire.table2_row("topk", 128, k=3)
    assert row["fwd"] * 100 == pytest.approx(2.86, abs=0.01)
    row = wire.table2_row("topk", 128, k=6)
    assert row["fwd"] * 100 == pytest.approx(5.71, abs=0.01)


def test_attention_score_bytes_detection():
    hlo = """\
HloModule t, num_partitions=4

%body (p: (s32[], f32[2,4,512,4096])) -> (s32[], f32[2,4,512,4096]) {
  %sc = f32[2,4,512,4096]{3,2,1,0} fusion(%x), kind=kLoop, calls=%fc
  %nb = f32[2,4,512,64]{3,2,1,0} fusion(%y), kind=kLoop, calls=%fd
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[2,4,512,4096]) while(%t), condition=%c, body=%body, backend_config={"known_trip_count":{"n":"3"}}
}
"""
    score = H.attention_score_bytes(hlo, 4096)
    # only the (512, 4096)-trailing tensor counts, x3 trips x2 (rw)
    assert score == pytest.approx(3 * 2 * 2 * 4 * 512 * 4096 * 4)
    assert H.attention_score_bytes(hlo, 9999) == 0.0
