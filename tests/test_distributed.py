"""Distributed-correctness tests: mesh-vs-single-device exactness for every
block family, the MoE reduce-scatter combine, and chunked-vs-sequential WKV6.

These run in a subprocess with 8 forced host devices so the main pytest
process keeps its single-device view (per the dry-run isolation rule).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import rwkv, ssm, transformer
from repro.models.config import Runtime


def _run_subprocess(code: str):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


@pytest.mark.slow
def test_mesh_matches_single_device_all_families():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        import repro.configs as configs
        from repro.models import transformer
        from repro.models.config import Runtime
        from repro.data.pipeline import make_lm_batch

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        for arch in ["yi_6b", "qwen3_moe_235b_a22b", "zamba2_7b",
                     "rwkv6_1p6b", "llama_3_2_vision_90b", "whisper_tiny"]:
            cfg = configs.get(arch, smoke=True)
            params = transformer.init_model(jax.random.key(0), cfg)
            batch = make_lm_batch(jax.random.key(1), cfg, 4, 32)
            rt0 = Runtime(mesh=None, training=True, moe_capacity=8.0)
            l0, _ = transformer.forward(params, cfg, rt0, batch)
            with mesh:
                rt = Runtime(mesh=mesh, training=True, moe_capacity=8.0)
                lm, _ = jax.jit(
                    lambda p, b: transformer.forward(p, cfg, rt, b))(params,
                                                                     batch)
            diff = float(jnp.abs(lm - l0).max())
            assert diff < 2e-4, (arch, diff)
            print(arch, "ok", diff)
    """)
    assert out.count("ok") == 6


def test_rwkv_chunk_matches_scan():
    cfg = configs.get("rwkv6_1p6b", smoke=True)
    p = rwkv.init_rwkv_time(jax.random.key(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
    yc, (Sc, _) = rwkv.rwkv_time_mix(
        p, cfg, Runtime(mesh=None, rwkv_mode="chunk", rwkv_chunk=16), x)
    ys, (Ss, _) = rwkv.rwkv_time_mix(
        p, cfg, Runtime(mesh=None, rwkv_mode="scan", rwkv_chunk=16), x)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys), atol=2e-5)
    np.testing.assert_allclose(np.asarray(Sc), np.asarray(Ss), atol=2e-5)


@pytest.mark.slow
def test_rwkv_decode_matches_full_sequence():
    """Token-by-token decode must agree with the full-sequence evaluation."""
    cfg = configs.get("rwkv6_1p6b", smoke=True)
    rt = Runtime(mesh=None, training=False)
    params = transformer.init_model(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab,
                              dtype=jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    full_logits, _ = transformer.forward(params, cfg, rt, batch)
    cache = transformer.init_cache(params, cfg, rt, 2, 16)
    outs = []
    for i in range(8):
        logits, cache = transformer.decode_step(params, cfg, rt,
                                                toks[:, i: i + 1], cache)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.slow
def test_mamba_decode_matches_full_sequence():
    cfg = configs.get("zamba2_7b", smoke=True)
    rt = Runtime(mesh=None, training=False, ssm_chunk=8)
    params = transformer.init_model(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab,
                              dtype=jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    full_logits, _ = transformer.forward(params, cfg, rt, batch)
    cache = transformer.init_cache(params, cfg, rt, 2, 16)
    outs = []
    for i in range(8):
        logits, cache = transformer.decode_step(params, cfg, rt,
                                                toks[:, i: i + 1], cache)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_attention_decode_matches_full_sequence():
    cfg = configs.get("yi_6b", smoke=True)
    rt = Runtime(mesh=None, training=False)
    params = transformer.init_model(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab,
                              dtype=jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    full_logits, _ = transformer.forward(params, cfg, rt, batch)
    cache = transformer.init_cache(params, cfg, rt, 2, 16)
    outs = []
    for i in range(8):
        logits, cache = transformer.decode_step(params, cfg, rt,
                                                toks[:, i: i + 1], cache)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.slow
def test_sliding_window_masks_old_positions():
    cfg = configs.get("yi_6b", smoke=True).with_(sliding_window=4)
    rt = Runtime(mesh=None, training=False)
    params = transformer.init_model(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, cfg.vocab,
                              dtype=jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    logits, _ = transformer.forward(params, cfg, rt, batch)
    # decode with a window-sized rolling cache reproduces the same logits
    cache = transformer.init_cache(params, cfg, rt, 1, 12)
    assert cache["kv"]["k"].shape[2] == 4  # rolling buffer == window
    outs = []
    for i in range(12):
        lg, cache = transformer.decode_step(params, cfg, rt,
                                            toks[:, i: i + 1], cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.slow
def test_int8_kv_cache_decode_close_to_fp():
    import dataclasses
    cfg = configs.get("yi_6b", smoke=True)
    params = transformer.init_model(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab,
                              dtype=jnp.int32)
    outs = {}
    for bits in (16, 8):
        rt = Runtime(mesh=None, training=False, kv_cache_bits=bits)
        cache = transformer.init_cache(params, cfg, rt, 2, 16)
        if bits == 8:
            assert cache["kv"]["k"].dtype == jnp.int8
            assert "k_scale" in cache["kv"]
        o = []
        for i in range(8):
            lg, cache = transformer.decode_step(params, cfg, rt,
                                                toks[:, i: i + 1], cache)
            o.append(lg)
        outs[bits] = jnp.concatenate(o, 1)
    rel = float(jnp.abs(outs[16] - outs[8]).max() /
                jnp.abs(outs[16]).max())
    assert rel < 0.05, rel


def test_error_feedback_shapes_and_residual():
    from repro.core.error_feedback import ef_topk_forward
    o = jax.random.normal(jax.random.key(0), (6, 32))
    err = jnp.zeros((4, 32))
    labels = jnp.array([0, 1, 2, 3, 0, 1])
    view, mask, new_err = ef_topk_forward(o, err, labels, 4, 4)
    np.testing.assert_array_equal(np.asarray(mask.sum(-1)), 4)
    # residual = dropped mass, stored per class
    assert float(jnp.abs(new_err).sum()) > 0
    # a second step adds the residual back before selection
    view2, _, _ = ef_topk_forward(o, new_err, labels, 4, 4)
    assert not np.allclose(np.asarray(view), np.asarray(view2))
