"""Load-generator, SLO-harness, QoS-ladder, and streaming-quantile tests.

Four satellite suites around `runtime.loadgen` (docs/serving-slo.md):

  * P² streaming-quantile parity against exact numpy quantiles on
    adversarial distributions (bimodal, heavy-tail, constant) — parity is
    asserted in *rank space* (the empirical CDF position of the estimate),
    which is the scale-free way to compare quantile estimators;
  * KScheduler / QoSController edge cases: plateau drops landing inside
    the anneal window (the `max(cur_k, k)` clamp), floor freezing, ladder
    construction, tighten/relax hysteresis, cooldown rate-limiting, and
    state round-trips through `checkpoint.store` npz files;
  * BatchingQueue admission/backpressure under an open-loop producer on a
    `VirtualClock`: bounded depth via `QueueFull`, no lost or duplicated
    items, the PR-6 wake policy intact, and `next_flush_at`-scheduled
    flushes that never leave the event loop waiting (`waits == 0`);
  * determinism fuzz over the full co-simulation: same seed -> the SLO
    report is identical field-for-field (everything but `wall_s_real`),
    clean and under seeded `FaultInjector` chaos, plus the mini version of
    the bench's burst gate (adaptive fleet beats static at equal seed).
"""
import random

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.checkpoint import store
from repro.fedtrain.schedule import EmaPlateau, KScheduler, ScheduleSpec
from repro.models import transformer
from repro.models.config import SplitConfig
from repro.runtime.batching import BatchingQueue, QueueFull
from repro.runtime.loadgen import (ArrivalSpec, FleetSpec, LoadGenConfig,
                                   ServiceModel, SLOSpec, _Arrivals,
                                   evaluate_slo, run_loadgen)
from repro.runtime.metrics import LatencyStats, P2Quantile
from repro.runtime.qos import QoSController, QoSSpec, compressor_spec
from repro.testing import FaultInjector, FaultPlan, VirtualClock

QS = (0.50, 0.95, 0.99)


# -- P2 streaming quantiles vs exact ------------------------------------------

def _rank(samples: np.ndarray, v: float) -> float:
    """Empirical CDF position of `v` within `samples` (rank space)."""
    s = np.sort(samples)
    return float(np.searchsorted(s, v, side="right")) / len(s)


def _bimodal(rng, n):
    xs = np.concatenate([rng.normal(0.0, 1.0, n // 2),
                         rng.normal(8.0, 0.25, n - n // 2)])
    return rng.permutation(xs)


def _heavy_tail(rng, n):
    return rng.pareto(1.5, n) + 1.0      # infinite-variance tail


@pytest.mark.parametrize("dist", [_bimodal, _heavy_tail],
                         ids=["bimodal", "heavy_tail"])
def test_p2_rank_parity_adversarial(dist):
    rng = np.random.default_rng(0)
    xs = dist(rng, 4000)
    for q in QS:
        est = P2Quantile(q)
        for x in xs:
            est.add(x)
        # the estimate must sit at the right *rank* of the empirical
        # distribution — scale-free, so one tolerance fits a clean bimodal
        # and a Pareto tail alike
        assert abs(_rank(xs, est.value()) - q) <= 0.025, \
            f"q={q}: estimate {est.value()} at rank {_rank(xs, est.value())}"


def test_p2_constant_distribution_is_exact():
    for q in QS:
        est = P2Quantile(q)
        for _ in range(1000):
            est.add(7.0)
        assert est.value() == 7.0


def test_p2_exact_below_five_samples():
    est = P2Quantile(0.5)
    for x in (1.0, 9.0, 4.0):
        est.add(x)
    assert est.value() == float(np.quantile([1.0, 9.0, 4.0], 0.5))
    assert np.isnan(P2Quantile(0.5).value())


def test_p2_estimate_stays_inside_observed_range():
    rng = np.random.default_rng(3)
    xs = _heavy_tail(rng, 2000)
    est = P2Quantile(0.99)
    for x in xs:
        est.add(x)
    assert xs.min() <= est.value() <= xs.max()


def test_latency_stats_reports_exact_next_to_streaming():
    rng = np.random.default_rng(1)
    xs = _bimodal(rng, 1500) + 10.0      # strictly positive "seconds"
    stats = LatencyStats()
    for x in xs:
        stats.add(x)
    rep = stats.report()
    assert rep["n"] == len(stats) == len(xs)
    for q in QS:
        tag = f"p{int(round(q * 100)):02d}"
        assert rep[f"{tag}_ms"] == pytest.approx(
            float(np.quantile(xs, q)) * 1e3)
        # streaming tracks exact in rank space on the same data
        assert abs(_rank(xs, rep[f"p2_{tag}_ms"] / 1e3) - q) <= 0.025


# -- QoS ladder / controller edge cases ---------------------------------------

def test_ladder_halves_to_floor_with_bits_rung():
    spec = QoSSpec(k=32, d=64, bits=8, k_floor=4, bits_floor=4,
                   high_depth=4, low_depth=1, deadline_s=0.1)
    assert spec.ladder() == [(32, 8), (16, 8), (8, 8), (4, 8), (4, 4)]
    # no quantization room -> no bits rung; k at floor -> single-k ladder
    assert QoSSpec(k=8, d=64, k_floor=8).ladder() == [(8, 0)]
    assert QoSSpec(k=8, d=64, bits=4, k_floor=4,
                   bits_floor=4).ladder() == [(8, 4), (4, 4)]


def test_ladder_floor_validation():
    with pytest.raises(AssertionError):
        QoSSpec(k=4, d=64, k_floor=8)           # floor above top
    with pytest.raises(AssertionError):
        QoSSpec(k=8, d=4)                       # k above cut width
    with pytest.raises(AssertionError):
        QoSSpec(k=8, d=64, bits=4, bits_floor=8)  # bits floor above top


def test_compressor_spec_strings():
    assert compressor_spec(8, 0) == "randtopk:k=8"
    assert compressor_spec(8, 4) == "randtopk_quant:k=8,bits=4"


def _qspec(**kw):
    base = dict(k=16, d=64, k_floor=4, high_depth=4, low_depth=1,
                deadline_s=0.1, patience=2, cooldown=0, sustain=1000)
    base.update(kw)
    return QoSSpec(**base)


def test_controller_tighten_saturates_at_floor():
    c = QoSController(_qspec())
    for _ in range(10):                 # acute congestion every observation
        c.observe(queue_depth=10, latency_s=0.01)
    assert c.level == len(c.levels) - 1 == 2
    assert c.k_bits() == (4, 0)         # clamped at k_floor, never below
    assert c.switches == 2


def test_controller_relax_saturates_at_declared_top():
    c = QoSController(_qspec())
    for _ in range(4):
        c.observe(10, 0.01)             # drive to the floor
    for _ in range(20):
        c.observe(0, 0.0)               # calm: relax one rung per patience
    assert c.level == 0 and c.k_bits() == (16, 0)
    assert c.switches == 4              # 2 down + 2 back up, then stable


def test_controller_relax_hysteresis_resets_on_pressure():
    c = QoSController(_qspec(patience=3))
    c.observe(10, 0.01)                 # one rung down
    assert c.level == 1
    # two healthy observations, then a mid-pressure one: the healthy
    # streak must restart — one calm flush inside a burst cannot relax
    c.observe(0, 0.0)
    c.observe(0, 0.0)
    c.observe(3, 0.01)                  # neither acute nor healthy
    c.observe(0, 0.0)
    c.observe(0, 0.0)
    assert c.level == 1                 # streak broken: still tightened
    c.observe(0, 0.0)
    assert c.level == 0                 # third consecutive healthy relaxes


def test_controller_cooldown_bounds_switch_rate():
    c = QoSController(_qspec(cooldown=3))
    for _ in range(6):
        c.observe(10, 0.01)
    # 6 acute observations but a move only every `cooldown` of them
    assert c.switches == 2 and c.level == 2


def test_controller_chronic_pressure_tightens_without_acute():
    spec = _qspec(high_depth=50, sustain=3)     # acute thresholds out of reach
    c = QoSController(spec)
    for _ in range(10):
        c.observe(3, 0.01)      # constant mid depth: EMA plateaus above low
    assert c.level >= 1         # chronic detector tightened the rung


def test_controller_state_roundtrip_through_store(tmp_path):
    a = QoSController(_qspec())
    for depth in (10, 10, 0, 10, 3):
        a.observe(depth, 0.01)
    store.save(str(tmp_path), 3, a.state())
    b = QoSController(_qspec())
    b.load_state(store.restore(str(tmp_path), 3, like=b.state()))
    assert (b.level, b.healthy, b.cool, b.switches) == \
        (a.level, a.healthy, a.cool, a.switches)
    for depth in (10, 0, 0, 0, 10):     # identical futures stay identical
        a.observe(depth, 0.01)
        b.observe(depth, 0.01)
        assert b.level == a.level and b.healthy == a.healthy


def test_controller_load_clamps_level_to_ladder():
    long = QoSController(_qspec(k=64))          # 5 rungs: 64..4
    for _ in range(10):
        long.observe(10, 0.01)
    st = long.state()
    short = QoSController(_qspec(k=8))          # 2 rungs: 8, 4
    short.load_state(st)
    assert short.level == len(short.levels) - 1


def _sspec(**kw):
    base = dict(k=16, d=64, warmup_steps=2, anneal_steps=4, k_min=4,
                drop=0.5, patience=2, min_rel_improve=0.05, ema=0.5)
    base.update(kw)
    return ScheduleSpec(**base)


def test_kscheduler_plateau_drop_inside_anneal_window():
    sched = KScheduler(_sspec())
    assert sched.k_bits(0) == (64, 0)           # dense warmup
    pre = [sched.k_bits(s)[0] for s in range(2, 6)]
    assert pre == sorted(pre, reverse=True) and pre[-1] == 16
    # constant loss -> plateau fires after `patience`, halving cur_k while
    # the anneal is conceptually still running
    for _ in range(3):
        sched.observe(1.0)
    assert sched.cur_k == 8
    post = [sched.k_bits(s)[0] for s in range(2, 6)]
    # the anneal now targets the dropped cur_k and the `max(cur_k, k)`
    # clamp keeps every stage at/above it, monotone to the new endpoint
    assert post == sorted(post, reverse=True) and post[-1] == 8
    assert all(k >= sched.cur_k for k in post)


def test_kscheduler_freezes_at_floor():
    sched = KScheduler(_sspec())
    while sched.cur_k > sched.spec.k_min:
        sched.observe(1.0)
    assert sched.cur_k == 4
    frozen = sched.state()["since"]
    for _ in range(10):                 # at the floor: EMA tracks, no drops
        sched.observe(1.0)
    assert sched.cur_k == 4
    assert sched.state()["since"] == frozen


def test_kscheduler_state_roundtrip_through_store(tmp_path):
    a = KScheduler(_sspec())
    for loss in (1.0, 0.9, 0.9, 0.9):
        a.observe(loss)
    store.save(str(tmp_path), 7, {"sched": a.state()})
    b = KScheduler(_sspec())
    b.load_state(store.restore(str(tmp_path), 7,
                               like={"sched": b.state()})["sched"])
    assert b.cur_k == a.cur_k
    assert b.ema_loss == pytest.approx(a.ema_loss)
    for _ in range(6):                  # identical futures stay identical
        a.observe(0.9)
        b.observe(0.9)
        assert b.cur_k == a.cur_k


def test_ema_plateau_smooth_keeps_counters_frozen():
    p = EmaPlateau(0.5, 0.05, 2)
    assert not p.observe(1.0)
    p.smooth(1.0)
    p.smooth(1.0)
    assert p.since == 0 and p.best == 1.0       # smooth() never advances
    assert not p.observe(1.0) and p.observe(1.0)  # observe() still can


# -- BatchingQueue admission / backpressure on a virtual clock ----------------

def test_queue_full_raises_and_preserves_backlog():
    vc = VirtualClock()
    q = BatchingQueue(max_batch=4, max_wait=0.01, max_depth=8, clock=vc)
    for i in range(8):
        q.put(i)
    with pytest.raises(QueueFull):
        q.put(8)
    assert len(q) == 8                  # the rejected put left no residue
    assert q.get_batch(idle_timeout=0.0) == [0, 1, 2, 3]
    q.put(8)                            # headroom is back after the flush


def test_open_loop_overload_bounded_no_loss_no_dup():
    """Open-loop producer at ~3x service capacity: depth stays bounded by
    `max_depth`, rejected puts raise, and every accepted item is drained
    exactly once in order — no loss, no duplication, no real waits."""
    vc = VirtualClock()
    q = BatchingQueue(max_batch=4, max_wait=0.01, max_depth=10, clock=vc)
    rng = random.Random(0)
    accepted, drained = [], []
    rejected = 0
    busy_until = 0.0                    # modeled service time serializes
    max_depth_seen = 0

    def drain_due(limit):
        nonlocal busy_until
        while True:
            due = q.next_flush_at()
            if due is None:
                return
            due = max(due, busy_until)
            if due > limit:
                return
            vc.advance_to(due)
            drained.extend(q.get_batch(idle_timeout=0.0))
            busy_until = due + 0.05     # ~80 items/s vs ~300/s offered

    t = 0.0
    for i in range(400):
        t += rng.expovariate(300.0)
        drain_due(t)
        vc.advance_to(t)
        try:
            q.put(i)
            accepted.append(i)
        except QueueFull:
            rejected += 1
        max_depth_seen = max(max_depth_seen, len(q))
    drain_due(float("inf"))

    assert rejected > 0                 # overload genuinely hit admission
    assert max_depth_seen <= 10         # backlog bounded by max_depth
    assert drained == accepted          # exact, ordered, no loss/no dup
    assert vc.waits == 0                # event loop never had to wait


def test_put_wake_policy_unchanged():
    """PR-6 wake policy: only the deadline-starting (n==1) and the
    fill-completing (n>=max_batch) puts notify the consumer."""
    vc = VirtualClock()
    q = BatchingQueue(max_batch=4, max_wait=0.01, clock=vc)
    wakes = []
    orig = q._cv.notify_all
    q._cv.notify_all = lambda: (wakes.append(len(q._items)), orig())[-1]
    for i in range(6):
        q.put(i)
    assert wakes == [1, 4, 5, 6]        # n==2, n==3 stayed silent
    assert q.get_batch(idle_timeout=0.0) == [0, 1, 2, 3]
    wakes.clear()
    q.put(6)                            # backlog at 3: not a first item...
    assert wakes == []
    q.put(7)                            # ...but this fills the batch
    assert wakes == [4]


def test_next_flush_at_drives_waitless_flushes():
    vc = VirtualClock(start=100.0)
    q = BatchingQueue(max_batch=3, max_wait=0.02, clock=vc)
    assert q.next_flush_at() is None
    q.put("a")
    assert q.next_flush_at() == pytest.approx(100.02)
    vc.advance(0.005)
    q.put("b")                          # deadline pinned to the FIRST item
    assert q.next_flush_at() == pytest.approx(100.02)
    q.put("c")                          # full: flush wants to run now
    assert q.next_flush_at() == vc.monotonic()
    assert q.get_batch(idle_timeout=0.0) == ["a", "b", "c"]
    q.put("d")
    vc.advance_to(q.next_flush_at())
    assert q.get_batch(idle_timeout=0.0) == ["d"]   # ragged partial at due
    assert q.get_batch(idle_timeout=0.0) == []      # idle tick, no wait
    assert vc.waits == 0


# -- full co-simulation: determinism, admission, the burst claim --------------

@pytest.fixture(scope="module")
def smoke():
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


def _mini(seed, qos=None, **kw):
    base = dict(
        seed=seed, duration_s=2.5,
        arrivals=ArrivalSpec(process="mmpp", rate=12.0, burst_rate=24.0,
                             mean_calm_s=1.0, mean_burst_s=1.0),
        fleet=FleetSpec(compressors=("randtopk:k=16",), prompt_len=(2, 3),
                        gen=(3, 5), bandwidth_Bps=400_000.0),
        service=ServiceModel(flush_overhead_s=1e-3, per_row_s=1e-4,
                             per_byte_s=3e-5),
        slo=SLOSpec(p99_ms=60.0, max_reject_frac=0.02),
        qos=qos, capacity=16, max_batch=8, max_wait=0.004,
        admission_depth=24)
    base.update(kw)
    return LoadGenConfig(**base)


def _no_wall(report):
    return {k: v for k, v in report.items() if k != "wall_s_real"}


def test_arrivals_deterministic_and_mmpp_alternates():
    spec = ArrivalSpec(process="mmpp", rate=5.0, burst_rate=50.0,
                       mean_calm_s=0.5, mean_burst_s=0.5)
    a, b = _Arrivals(spec, 42), _Arrivals(spec, 42)
    ta = tb = 0.0
    seq_a, seq_b = [], []
    for _ in range(300):
        ta, tb = a.next_after(ta), b.next_after(tb)
        seq_a.append(ta)
        seq_b.append(tb)
    assert seq_a == seq_b               # bit-identical arrival trace
    states = [s for _, s in a.state_path]
    assert states[0] == "calm" and len(states) > 2
    assert all(x != y for x, y in zip(states, states[1:]))


@pytest.mark.slow
def test_report_deterministic_same_seed(smoke):
    cfg, params = smoke
    r1 = run_loadgen(cfg, _mini(3), params=params)
    r2 = run_loadgen(cfg, _mini(3), params=params)
    assert _no_wall(r1) == _no_wall(r2)
    assert r1["cv_waits"] == 0          # nothing ever really slept
    assert r1["sessions"]["failed"] == 0
    s = r1["sessions"]
    assert s["arrived"] == s["admitted"] + s["rejected"]
    r3 = run_loadgen(cfg, _mini(5), params=params)
    assert r3["trace"]["arrivals"] != r1["trace"]["arrivals"]


def test_report_deterministic_under_chaos(smoke):
    cfg, params = smoke
    plan = FaultPlan(seed=11, corrupt=0.06, drop=0.05, duplicate=0.05,
                     reorder=0.03, rechunk=0.15, max_faults=30)
    runs = []
    for _ in range(2):                  # fresh injector per run, same plan
        runs.append(run_loadgen(
            cfg, _mini(7, retry_timeout=0.1), params=params,
            wrap_endpoint=FaultInjector(plan)))
    r1, r2 = runs
    assert _no_wall(r1) == _no_wall(r2)     # chaos replays chunk-for-chunk
    assert r1["sessions"]["failed"] == 0    # every session recovered
    assert r1["sessions"]["completed"] > 0
    assert r1["cv_waits"] == 0
    fc = r1["fault_counters"]
    assert (fc["server_faults_detected"] + fc["client_faults_detected"]
            + fc["duplicates"] + fc["replays"]) > 0
    assert r1["trace"]["k_bits"] == r2["trace"]["k_bits"]


def test_admission_control_rejects_at_capacity(smoke):
    cfg, params = smoke
    r = run_loadgen(cfg, _mini(1, capacity=2, admission_depth=8),
                    params=params)
    s = r["sessions"]
    assert s["rejected"] > 0
    assert s["arrived"] == s["admitted"] + s["rejected"]
    assert {reason for _, reason in r["trace"]["rejects"]} <= \
        {"capacity", "queue"}
    assert s["failed"] == 0             # rejection is clean, never an error


@pytest.mark.slow
def test_adaptive_fleet_beats_static_under_burst(smoke):
    """Mini version of the bench gate (benchmarks/loadgen.py): same seed,
    same MMPP burst — the QoS ladder must buy real p99 headroom by
    shedding bytes, and its (k, bits) trajectory must be deterministic."""
    cfg, params = smoke
    arr = ArrivalSpec(process="mmpp", rate=22.0, burst_rate=44.0,
                      mean_calm_s=2.0, mean_burst_s=3.0)
    fleet = FleetSpec(compressors=("randtopk:k=16",), prompt_len=(2, 3),
                      gen=(5, 8), bandwidth_Bps=400_000.0)
    qos = QoSSpec(k=16, d=cfg.d_model, k_floor=4, high_depth=6, low_depth=2,
                  deadline_s=0.04, patience=16, cooldown=1)
    kw = dict(arrivals=arr, fleet=fleet, duration_s=6.0, capacity=32,
              admission_depth=48)
    static = run_loadgen(cfg, _mini(7, qos=None, **kw), params=params)
    adaptive = run_loadgen(cfg, _mini(7, qos=qos, **kw), params=params)
    assert static["sessions"]["failed"] == 0
    assert adaptive["sessions"]["failed"] == 0
    assert adaptive["qos"]["switches"] > 0          # the ladder engaged
    assert len(adaptive["qos"]["level_hist"]) > 1   # below the top rung
    assert (adaptive["latency_ms"]["p99_ms"]
            < static["latency_ms"]["p99_ms"])
    assert (adaptive["bytes_up_per_token"]
            < static["bytes_up_per_token"])         # headroom came from bytes


def test_evaluate_slo_optional_gates():
    lat = {"n": 100, "p50_ms": 5.0, "p99_ms": 10.0}
    slo = SLOSpec(p99_ms=20.0, p50_ms=4.0, max_reject_frac=0.1,
                  max_queue_depth=3)
    out = evaluate_slo(slo, lat, reject_frac=0.05, max_depth=4)
    assert out["checks"] == {"p99": True, "rejects": True,
                             "p50": False, "queue_depth": False}
    assert not out["ok"]
    # zero-traffic runs pass the latency gate vacuously
    empty = {"n": 0, "p50_ms": float("nan"), "p99_ms": float("nan")}
    assert evaluate_slo(SLOSpec(), empty, 0.0, 0)["ok"]
