"""Sharded session arena (docs/sharding.md): shard_map arena step vs the
single-device path — bit-exact tokens for every payload kind at several
mesh shapes, mesh (1,1) == mesh None, eviction/readmission under a mesh,
the inactive-slot freeze, and the pod-ring wire_row mapping.

Multi-device cases run in a subprocess with 8 forced host devices so the
main pytest process keeps its single-device view (the same isolation rule
as tests/test_distributed.py)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer
from repro.models.config import SplitConfig
from repro.runtime import run_streaming
from repro.runtime.arena import SlotArena


def _run_subprocess(*parts: str):
    code = "\n".join(textwrap.dedent(p) for p in parts)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


_PRELUDE = """
    import jax, jax.numpy as jnp
    import numpy as np
    import repro.configs as configs
    from repro.models import transformer
    from repro.models.config import Runtime, SplitConfig
    from repro.launch.mesh import make_serving_mesh
    from repro.runtime import run_streaming, steps

    assert len(jax.devices()) == 8
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=8))
    params = transformer.init_model(jax.random.key(0), cfg)
"""


def test_mesh_1x1_matches_unsharded():
    """The degenerate (1,1) mesh runs the full shard_map program on the
    single local device and must leave served tokens bit-identical to
    `mesh=None` — the existing parity/golden suites stay authoritative."""
    from repro.launch.mesh import make_serving_mesh
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=8))
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = dict(n_clients=2, prompt_len=2, gen=4, max_batch=2, params=params,
              seed=0)
    ref = run_streaming(cfg, **kw)
    got = run_streaming(cfg, mesh=make_serving_mesh(1), **kw)
    np.testing.assert_array_equal(ref["tokens"], got["tokens"])


def test_wire_row_is_identity_without_pod_and_a_block_swap_with():
    """Host-side slot -> xbuf/token row mapping: identity without a pod
    axis; with one, slot s in pod p maps to the ring-previous pod's block
    (the sharded step's forward ppermute then lands the activation on the
    slot's own block) — a permutation of the live rows, scratch fixed."""
    make_cache = lambda: {"pos": np.zeros((1,), np.int32)}
    arena = SlotArena(make_cache, 8, (1, 1, 4), np.float32)
    assert [arena.wire_row(s) for s in range(9)] == list(range(9))

    # pod geometry only touches _n_pod/capacity — no devices needed
    arena = SlotArena.__new__(SlotArena)
    arena._n_pod, arena.capacity = 2, 8
    rows = [arena.wire_row(s) for s in range(8)]
    assert rows == [4, 5, 6, 7, 0, 1, 2, 3]        # blocks swapped
    assert sorted(rows) == list(range(8))          # a permutation
    assert arena.wire_row(8) == 8                  # scratch row pinned


@pytest.mark.slow
def test_sharded_step_matches_unsharded_and_freezes_inactive():
    """Direct step drive on 8 forced devices: the shard_map arena step's
    tokens AND every new-cache leaf are bit-identical to the mesh-less
    step, at data-only, data x model, and pod meshes — and inactive rows
    never move."""
    out = _run_subprocess(_PRELUDE, """
        rt = Runtime(mesh=None, training=False)
        cap = 8
        ref_step = jax.jit(steps.make_arena_top_step(cfg, rt, 1))
        cache0 = jax.tree.map(
            lambda a: jnp.stack([a] * cap),
            transformer.init_cache(params, cfg, rt, 1, 8))
        xbuf = jnp.asarray(np.random.RandomState(0).randn(
            cap + 1, 1, 1, cfg.d_model).astype(np.float32))
        active = jnp.asarray([True, False] * (cap // 2))
        ref_tok, ref_cache = ref_step(params, xbuf, cache0, active)
        for spec in [dict(), dict(model=4), dict(model=2, pod=2)]:
            mesh = make_serving_mesh(8, **spec)
            step = jax.jit(
                steps.make_arena_top_step(cfg, rt, 1, mesh=mesh))
            # the serve loop stages slot s's activation at wire_row(s) and
            # reads its token back there (SlotArena.wire_row: the
            # ingestion-pod block; identity without a pod axis) — the
            # direct drive must present the same layout
            n_pod = dict(mesh.shape).get("pod", 1)
            block = cap // n_pod
            perm = np.asarray([((s // block - 1) % n_pod) * block
                               + s % block for s in range(cap)])
            xw = np.asarray(xbuf).copy()
            xw[perm] = np.asarray(xbuf)[:cap]
            tok, new = step(params, jnp.asarray(xw), cache0, active)
            np.testing.assert_array_equal(np.asarray(ref_tok),
                                          np.asarray(tok)[perm])
            for r, n in zip(jax.tree.leaves(ref_cache),
                            jax.tree.leaves(new)):
                np.testing.assert_array_equal(np.asarray(r), np.asarray(n))
            # frozen rows: bit-identical to the pre-step cache
            for o, n in zip(jax.tree.leaves(cache0), jax.tree.leaves(new)):
                np.testing.assert_array_equal(np.asarray(o)[1::2],
                                              np.asarray(n)[1::2])
            print("mesh", dict(mesh.shape), "ok")
    """)
    assert out.count("ok") == 3


@pytest.mark.slow
def test_sharded_serving_bit_exact_all_payload_kinds():
    """End-to-end `run_streaming` on 8 forced devices: served tokens under
    a data-only (8,1) and a tensor-parallel (2,4) mesh are bit-identical
    to the single-device arena, for all five payload kinds."""
    out = _run_subprocess(_PRELUDE, """
        kinds = ["identity", "size_reduction:k=8", "randtopk:k=8",
                 "quant:bits=4", "randtopk_quant:k=8,bits=8"]
        meshes = [make_serving_mesh(8), make_serving_mesh(8, model=4)]
        kw = dict(n_clients=2, prompt_len=2, gen=4, max_batch=2,
                  params=params, seed=0)
        for spec in kinds:
            ref = run_streaming(cfg, compressor_mix=[spec], **kw)["tokens"]
            for mesh in meshes:
                got = run_streaming(cfg, compressor_mix=[spec], mesh=mesh,
                                    **kw)["tokens"]
                np.testing.assert_array_equal(ref, got)
            print(spec, "ok")
    """)
    assert out.count("ok") == 5


@pytest.mark.slow
def test_pod_mesh_serving_bit_exact_and_uses_ring():
    """A pod mesh (2,2,2) routes the cut activation over the pod ring
    (wire_row + the step's ppermute pair) and still serves bit-identical
    tokens; the lowered program actually contains the ring collective."""
    out = _run_subprocess(_PRELUDE, """
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(8, model=2, pod=2)
        kw = dict(n_clients=3, prompt_len=2, gen=4, max_batch=2,
                  params=params, seed=0)
        ref = run_streaming(cfg, **kw)["tokens"]
        got = run_streaming(cfg, mesh=mesh, **kw)["tokens"]
        np.testing.assert_array_equal(ref, got)
        print("pod-serve PASS")

        rt = Runtime(mesh=None, training=False)
        step = steps.make_arena_top_step(cfg, rt, 1, mesh=mesh)
        cap = 8
        cache = jax.tree.map(
            lambda a: jnp.stack([a] * cap),
            transformer.init_cache(params, cfg, rt, 1, 8))
        xbuf = jnp.zeros((cap + 1, 1, 1, cfg.d_model), jnp.float32)
        txt = jax.jit(step).lower(
            params, xbuf, cache, jnp.ones((cap,), bool)).as_text()
        assert ("collective_permute" in txt or "collective-permute" in txt
                or "ppermute" in txt), "pod ring collective missing"
        print("ring-collective PASS")
    """)
    assert out.count("PASS") == 2


@pytest.mark.slow
def test_sharded_eviction_readmission_token_parity():
    """Capacity pressure under a mesh: 6 clients over 2 resident slots
    forces LRU evict-to-host / restore cycles through the sharded arena,
    and every session's tokens stay bit-identical to the uncontended
    single-device run (dedup + FIFO fetch-before-restore: a KV row never
    double-advances across an evict/readmit)."""
    out = _run_subprocess(_PRELUDE, """
        from repro.runtime.server import StreamingServer, _EVICTING
        from repro.runtime import steps
        mesh = make_serving_mesh(8, model=2)
        kw = dict(n_clients=6, prompt_len=2, gen=4, max_batch=2,
                  params=params, seed=0)
        ref = run_streaming(cfg, **kw)["tokens"]
        got = run_streaming(cfg, mesh=mesh, capacity=2, **kw)
        np.testing.assert_array_equal(ref, got["tokens"])
        snap = got["metrics"]
        ev = snap["slot_evictions_total"]["series"][0]["value"]
        assert ev >= 1, f"no evictions under 6 sessions / 2 slots: {ev}"
        print("evict parity ok", ev,
              snap["slot_readmissions_total"]["series"][0]["value"])

        # deterministic fetch/restore round trip through SHARDED rows:
        # evicted state reaches host bit-exact and restores into a
        # different row of the NamedSharding'd arena
        rt = Runtime(mesh=None, training=False)
        make_cache = lambda: transformer.init_cache(params, cfg, rt, 1, 8)
        server = StreamingServer(
            params, steps.make_arena_top_step(cfg, rt, 1, mesh=mesh),
            make_cache, max_batch=2, capacity=2,
            x_shape=(1, 1, cfg.d_model), mesh=mesh)
        assert server.arena.capacity == 8           # padded to the mesh
        s1 = server._session_for(1, endpoint=None)
        s2 = server._session_for(2, endpoint=None)
        s1.last_active, s2.last_active = 1.0, 2.0
        server.arena.cache["pos"] = server.arena.cache["pos"].at[
            s1.slot].set(5)
        s3 = server._session_for(3, endpoint=None)  # evicts LRU s1
        assert s1.slot == -1 and s1.host_state is _EVICTING
        server._process([])                         # fetch -> reset
        assert int(np.asarray(s1.host_state["pos"])) == 5
        s3.closed = True
        with server._lock:
            server._ensure_resident(s1)
        server._process([])                         # restore
        assert s1.host_state is None and s1.slot >= 0
        assert int(np.asarray(
            server.arena.cache["pos"])[s1.slot]) == 5
        print("sharded evict/restore ok")
    """)
    assert out.count("ok") == 2
