"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.randtopk import kernel as tk_kernel, ops as tk_ops, \
    ref as tk_ref
from repro.kernels.quant import kernel as q_kernel, ref as q_ref

SHAPES = [(4, 64), (17, 128), (128, 256), (3, 5, 96), (1, 8192)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_topk_kernel_matches_ref(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    k = min(8, shape[-1] - 1)
    mask, thr = tk_kernel.topk_mask_threshold(x, k)
    ref_mask = tk_ref.topk_mask(x, k)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(ref_mask))
    ref_thr = tk_ref.kth_threshold(x, k)
    np.testing.assert_allclose(np.asarray(thr), np.asarray(ref_thr),
                               atol=1e-4, rtol=1e-4)


@given(st.integers(1, 63), st.integers(1, 7), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
@pytest.mark.slow
def test_topk_kernel_property(k, rows, seed):
    x = jax.random.normal(jax.random.key(seed), (rows, 64))
    mask, _ = tk_kernel.topk_mask_threshold(x, k)
    np.testing.assert_array_equal(np.asarray(mask.sum(-1)), k)
    np.testing.assert_array_equal(
        np.asarray(mask), np.asarray(tk_ref.topk_mask(x, k)))


def test_randtopk_kernel_counts_and_distribution():
    x = jax.random.normal(jax.random.key(0), (8, 64))
    m = tk_ops.randtopk_mask(x, 8, 0.25, jax.random.key(1))
    np.testing.assert_array_equal(np.asarray(m.sum(-1)), 8)
    # alpha=0 must agree with the deterministic kernel mask
    m0 = tk_ops.randtopk_mask(x, 8, 0.0, jax.random.key(2))
    np.testing.assert_array_equal(
        np.asarray(m0), np.asarray(tk_ops.topk_mask(x, 8)))


def test_randtopk_kernel_matches_xla_reference():
    """The in-kernel Eq. (7) selection must reproduce the XLA path draw for
    draw — same key, same Binomial split, same Gumbel race."""
    x = jax.random.normal(jax.random.key(3), (16, 128))
    for alpha in (0.0, 0.3, 1.0):
        for seed in range(3):
            key = jax.random.key(100 + seed)
            mk = tk_ops.randtopk_mask(x, 8, alpha, key)
            mr = tk_ref.randtopk_mask(x, 8, alpha, key)
            np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr),
                                          err_msg=f"alpha={alpha} s={seed}")


def test_randtopk_kernel_alpha_statistics():
    """Non-top-k pick frequency from the fused kernel tracks alpha*k."""
    d, k, alpha = 64, 8, 0.3
    x = jax.random.normal(jax.random.key(0), (1, d))
    is_top = np.asarray(tk_ops.topk_mask(x, k))[0]
    keys = jax.random.split(jax.random.key(7), 300)
    masks = np.stack([np.asarray(tk_ops.randtopk_mask(x, k, alpha, kk))[0]
                      for kk in keys])
    non_top = masks[:, ~is_top].sum(axis=1)
    assert abs(non_top.mean() - alpha * k) < 0.35, non_top.mean()


def test_topk_kernel_ties():
    x = jnp.concatenate([jnp.ones((4, 16)), 2 * jnp.ones((4, 16))], -1)
    mask, _ = tk_kernel.topk_mask_threshold(x, 20)
    np.testing.assert_array_equal(np.asarray(mask.sum(-1)), 20)
    assert bool(mask[:, 16:].all())  # all the 2s selected


@pytest.mark.parametrize("name,x,k", [
    ("ties", jnp.tile(jnp.array([[3.0, 1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 0.5]]),
                      (3, 1)), 4),
    ("all_equal", jnp.full((4, 32), 1.5), 5),
    ("zeros", jnp.zeros((4, 32)), 6),
    ("negatives", -jnp.abs(jax.random.normal(jax.random.key(8), (5, 64))), 7),
    ("mixed_sign_ties", jnp.array([[-2.0, 2.0, -2.0, 1.0, -1.0, 0.0]]), 3),
    ("k_equals_d", jax.random.normal(jax.random.key(9), (3, 16)), 16),
    ("single_spike", jnp.eye(8, 128) * 100.0, 2),
])
def test_topk_kernel_adversarial_parity(name, x, k):
    """Interpret-mode kernel vs selection.topk_mask on adversarial inputs:
    exact ties, all-zero rows, negatives, k = d."""
    from repro.core import selection

    ref = selection.topk_mask(x, k, backend="xla")
    via_dispatch = selection.topk_mask(x, k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(via_dispatch), np.asarray(ref),
                                  err_msg=name)
    if k < x.shape[-1]:
        mask, _ = tk_kernel.topk_mask_threshold(x, k)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(ref),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(mask.sum(-1)), k)


def test_selection_backend_dispatch():
    """backend='pallas' and backend='xla' agree through the public API; the
    env override REPRO_SELECTION_BACKEND is honored."""
    import os

    from repro.core import selection

    x = jax.random.normal(jax.random.key(10), (6, 96))
    np.testing.assert_array_equal(
        np.asarray(selection.topk_mask(x, 9, backend="pallas")),
        np.asarray(selection.topk_mask(x, 9, backend="xla")))
    key = jax.random.key(11)
    np.testing.assert_array_equal(
        np.asarray(selection.randtopk_mask(x, 9, 0.25, key,
                                           backend="pallas")),
        np.asarray(selection.randtopk_mask(x, 9, 0.25, key, backend="xla")))
    with pytest.raises(ValueError):
        selection.topk_mask(x, 9, backend="cuda")
    os.environ["REPRO_SELECTION_BACKEND"] = "xla"
    try:
        assert selection._resolve_backend(None) == "xla"
    finally:
        del os.environ["REPRO_SELECTION_BACKEND"]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_kernel_matches_ref(shape, bits):
    x = jax.random.normal(jax.random.key(1), shape)
    code, deq, lo, step = q_kernel.quantize(x, bits)
    rc, rdeq, rlo, rstep = q_ref.quantize(x, bits)
    np.testing.assert_array_equal(np.asarray(code), np.asarray(rc))
    np.testing.assert_allclose(np.asarray(deq), np.asarray(rdeq), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(rlo).reshape(lo.shape),
                               atol=1e-6)


def test_quant_kernel_constant_rows():
    x = jnp.ones((4, 32))
    code, deq, lo, step = q_kernel.quantize(x, 4)
    assert not bool(jnp.isnan(deq).any())


def test_quant_kernel_bf16():
    x = jax.random.normal(jax.random.key(2), (8, 128), jnp.bfloat16)
    code, deq, _, _ = q_kernel.quantize(x, 8)
    assert deq.dtype == jnp.bfloat16
    rc, rdeq, _, _ = q_ref.quantize(x, 8)
    np.testing.assert_array_equal(np.asarray(code), np.asarray(rc))


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------

from repro.kernels.flashattn import kernel as fa_kernel, ref as fa_ref


@pytest.mark.parametrize("cfg", [
    dict(B=2, S=128, Hq=4, Hkv=2, hd=64, causal=True, window=0),
    dict(B=1, S=256, Hq=8, Hkv=8, hd=32, causal=True, window=0),
    dict(B=2, S=128, Hq=4, Hkv=1, hd=64, causal=False, window=0),
    dict(B=1, S=256, Hq=4, Hkv=2, hd=64, causal=True, window=64),
])
def test_flash_attention_matches_ref(cfg):
    q = jax.random.normal(jax.random.key(0), (cfg["B"], cfg["S"], cfg["Hq"],
                                              cfg["hd"]), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (cfg["B"], cfg["S"], cfg["Hkv"],
                                              cfg["hd"]), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (cfg["B"], cfg["S"], cfg["Hkv"],
                                              cfg["hd"]), jnp.float32)
    o = fa_kernel.flash_attention(q, k, v, causal=cfg["causal"],
                                  window=cfg["window"], bq=64, bk=64)
    r = fa_ref.attention(q, k, v, causal=cfg["causal"], window=cfg["window"])
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.key(0), (1, 128, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (1, 128, 2, 64), jnp.bfloat16)
    o = fa_kernel.flash_attention(q, k, v, bq=64, bk=64)
    r = fa_ref.attention(q, k, v)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o, dtype=np.float32),
                               np.asarray(r, dtype=np.float32), atol=3e-2)


def test_flash_attention_matches_model_sdpa():
    """The kernel must agree with the model's attention (the path it would
    replace on a TPU runtime)."""
    import repro.configs as configs
    from repro.models import attention as A
    from repro.models.config import Runtime

    cfg = configs.get("yi_6b", smoke=True)
    p = A.init_attention(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 128, cfg.d_model))
    rt = Runtime(mesh=None, attn_chunk=64)
    y_model = A.full_attention(p, cfg, rt, x)
    # rebuild q/k/v exactly as the model does, then apply the kernel
    pos = jnp.arange(128)
    q, k, v = A._project_qkv(p, cfg, x, x, pos[None], pos[None])
    o = fa_kernel.flash_attention(q, k, v, bq=64, bk=64)
    y_kernel = o.reshape(2, 128, -1) @ p["wo"]
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=3e-4, rtol=3e-4)
