"""`hypothesis` shim: use the real library when installed, else a tiny
deterministic fallback so the property tests still run (with fixed-seed
sampled examples) instead of erroring at collection.

The fallback implements exactly the subset these tests use:
`given(st.integers(...), st.floats(...))` + `settings(max_examples=,
deadline=)`. Examples are drawn from `random.Random(0)`, so failures are
reproducible run-to-run.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mimic `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            import inspect

            @functools.wraps(fn)
            def run(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(fn, "_max_examples", 20)):
                    fn(*args,
                       *(s.example_from(rng) for s in strategies), **kwargs)

            # strategies fill the trailing parameters; hide them from pytest
            # so it doesn't look for same-named fixtures
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[: -len(strategies)]
            run.__signature__ = sig.replace(parameters=params)
            del run.__wrapped__
            return run

        return deco
