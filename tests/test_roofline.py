"""Roofline HLO walker on pinned fixture programs: dot-flops counting,
while-loop trip amplification (known_trip_count and compare-constant
fallback), the dynamic-update-slice byte convention, collective ring
factors — plus the closed-form serving-kernel cost predictions in
`roofline.analysis`."""
import numpy as np

import repro.configs as configs
from repro.roofline import analysis, hlo


def _mod(body: str) -> str:
    return "HloModule fixture\n\n" + body.strip() + "\n"


# ---------------------------------------------------------------------------
# program_costs: dots and bytes
# ---------------------------------------------------------------------------

DOT_HLO = _mod("""
ENTRY %main.1 (p0: f32[4,8], p1: f32[8,16]) -> f32[4,16] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %p1 = f32[8,16]{1,0} parameter(1)
  ROOT %d.1 = f32[4,16]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
""")


def test_dot_flops_and_bytes():
    flops, byts = hlo.program_costs(DOT_HLO)
    # 2 * out_elems * contracted = 2 * (4*16) * 8
    assert flops == 2 * 4 * 16 * 8
    # parameters are skipped; only the dot output materializes: write+read
    assert byts == 2 * (4 * 16 * 4)


def test_f32_deflate_halves_bytes_not_flops():
    flops, byts = hlo.program_costs(DOT_HLO, f32_deflate=True)
    assert flops == 2 * 4 * 16 * 8
    assert byts == (4 * 16 * 4)          # counted at bf16 width


WHILE_KNOWN_TRIP = _mod("""
%body.1 (bp: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %bp = (s32[], f32[4,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%bp), index=0
  %x = f32[4,8]{1,0} get-tuple-element(%bp), index=1
  %w = f32[8,8]{1,0} constant({...})
  %y = f32[4,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = (s32[], f32[4,8]{1,0}) tuple(%i, %y)
}

%cond.1 (cp: (s32[], f32[4,8])) -> pred[] {
  %cp = (s32[], f32[4,8]{1,0}) parameter(0)
  %it = s32[] get-tuple-element(%cp), index=0
  %lim = s32[] constant(99)
  ROOT %lt = pred[] compare(%it, %lim), direction=LT
}

ENTRY %main.2 (p0: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p0 = (s32[], f32[4,8]{1,0}) parameter(0)
  ROOT %w.1 = (s32[], f32[4,8]{1,0}) while(%p0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"3"}}
}
""")


def test_while_known_trip_count_beats_compare_constant():
    """XLA's known_trip_count annotation (3) must win over the condition's
    compare constant (99)."""
    flops, byts = hlo.program_costs(WHILE_KNOWN_TRIP)
    body_flops = 2 * (4 * 8) * 8
    assert flops == 3 * body_flops
    # body bytes: only the dot output (GTEs/tuple/params/constants skipped)
    assert byts == 3 * 2 * (4 * 8 * 4)


WHILE_COMPARE_FALLBACK = WHILE_KNOWN_TRIP.replace(
    ', backend_config={"known_trip_count":{"n":"3"}}', "").replace(
    "constant(99)", "constant(5)")


def test_while_compare_constant_fallback():
    flops, _ = hlo.program_costs(WHILE_COMPARE_FALLBACK)
    assert flops == 5 * 2 * (4 * 8) * 8


DUS_FUSION = _mod("""
%fused_dus (fb: f32[8,16], fu: f32[1,16], fi: s32[], fz: s32[]) -> f32[8,16] {
  %fb = f32[8,16]{1,0} parameter(0)
  %fu = f32[1,16]{1,0} parameter(1)
  %fi = s32[] parameter(2)
  %fz = s32[] parameter(3)
  ROOT %dus.1 = f32[8,16]{1,0} dynamic-update-slice(%fb, %fu, %fi, %fz)
}

ENTRY %main.3 (p0: f32[8,16], p1: f32[1,16], p2: s32[]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p1 = f32[1,16]{1,0} parameter(1)
  %p2 = s32[] parameter(2)
  %z = s32[] constant(0)
  ROOT %f.1 = f32[8,16]{1,0} fusion(%p0, %p1, %p2, %z), kind=kLoop, calls=%fused_dus
}
""")


def test_dus_fusion_counts_update_not_buffer():
    """A kLoop fusion rooted at dynamic-update-slice aliases the big buffer
    in place — only the update slice moves, not the full output."""
    _, byts = hlo.program_costs(DUS_FUSION)
    assert byts == 2 * (1 * 16 * 4)      # not 2 * 8*16*4


BARE_DUS = _mod("""
ENTRY %main.4 (p0: f32[8,16], p2: s32[]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p2 = s32[] parameter(2)
  %z = s32[] constant(0)
  %u = f32[2,16]{1,0} add(%p0, %p0)
  ROOT %dus.2 = f32[8,16]{1,0} dynamic-update-slice(%p0, %u, %p2, %z)
}
""")


def test_bare_dus_counts_update_operand():
    _, byts = hlo.program_costs(BARE_DUS)
    # add output (2x 2*16*4) + DUS counted at its update operand's shape
    assert byts == 2 * (2 * 16 * 4) + 2 * (2 * 16 * 4)


# ---------------------------------------------------------------------------
# collective_bytes: ring factors, tuple -start forms, loop amplification
# ---------------------------------------------------------------------------

AR_HLO = _mod("""
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.5 (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  ROOT %ar.1 = f32[128]{0} all-reduce(%p0), replica_groups={}, to_apply=%add
}
""")


def test_all_reduce_ring_factor():
    stats = hlo.collective_bytes(AR_HLO)
    assert stats.raw_bytes == {"all-reduce": 128 * 4}
    # ring all-reduce = reduce-scatter + all-gather phases -> 2x local bytes
    assert stats.total_link_bytes == 2.0 * 128 * 4
    deflated = hlo.collective_bytes(AR_HLO, f32_deflate=True)
    assert deflated.raw_bytes == {"all-reduce": 128 * 2}


TUPLE_AG_HLO = _mod("""
ENTRY %main.6 (p0: f32[4]) -> f32[8] {
  %p0 = f32[4]{0} parameter(0)
  %ag.1 = (f32[4]{0}, f32[8]{0}) all-gather-start(%p0), dimensions={0}
  ROOT %agd = f32[8]{0} all-gather-done(%ag.1)
}
""")


def test_tuple_collective_start_counts_operand():
    """-start ops return (operand, result) tuples; the walker counts the
    first (operand) shape — the local contribution each device puts on the
    link — not the gathered result."""
    stats = hlo.collective_bytes(TUPLE_AG_HLO)
    assert stats.raw_bytes["all-gather"] == 4 * 4


WHILE_COLL = _mod("""
%wbody (bp: (s32[], f32[64])) -> (s32[], f32[64]) {
  %bp = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%bp), index=0
  %x = f32[64]{0} get-tuple-element(%bp), index=1
  %ar.2 = f32[64]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  ROOT %out = (s32[], f32[64]{0}) tuple(%i, %ar.2)
}

%wcond (cp: (s32[], f32[64])) -> pred[] {
  %cp = (s32[], f32[64]{0}) parameter(0)
  %it = s32[] get-tuple-element(%cp), index=0
  %lim = s32[] constant(4)
  ROOT %lt = pred[] compare(%it, %lim), direction=LT
}

ENTRY %main.7 (p0: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p0 = (s32[], f32[64]{0}) parameter(0)
  ROOT %w.2 = (s32[], f32[64]{0}) while(%p0), condition=%wcond, body=%wbody
}
""")


def test_collective_inside_while_amplified():
    stats = hlo.collective_bytes(WHILE_COLL)
    assert stats.raw_bytes == {"all-reduce": 4 * 64 * 4}


def test_empty_and_collective_free_programs():
    assert hlo.program_costs("") == (0.0, 0.0)
    assert hlo.collective_bytes(DOT_HLO).raw_bytes == {}
    assert hlo.collective_bytes("").total_link_bytes == 0.0


# ---------------------------------------------------------------------------
# analysis: closed-form serving-kernel predictions
# ---------------------------------------------------------------------------

def test_serving_decode_costs_no_dots():
    flops, floor = analysis.serving_decode_costs(8, 256)
    assert flops == 0.0
    assert floor == 2.0 * 8 * 256 * 4
    lo, hi = analysis.DECODE_BYTES_BAND
    assert lo <= 1.0 < hi


def test_top_matmul_params_matches_hand_count():
    cfg = configs.get("qwen3-8b", smoke=True)
    d, ff = cfg.d_model, cfg.d_ff
    attn = (d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv_heads * cfg.hd
            + cfg.n_heads * cfg.hd * d)
    for cut in (0, 1, cfg.n_layers):
        want = (cfg.n_layers - cut) * (attn + 3 * d * ff) \
            + d * cfg.padded_vocab
        assert analysis.top_matmul_params(cfg, cut) == want
    # deeper cut -> strictly fewer top-model params
    assert analysis.top_matmul_params(cfg, 1) < \
        analysis.top_matmul_params(cfg, 0)


def test_serving_step_costs_scaling():
    cfg = configs.get("qwen3-8b", smoke=True)
    state = 12_345
    flops, floor = analysis.serving_step_costs(cfg, 1, 8, 20, state)
    assert floor == 2.0 * state
    score = 2 * cfg.n_heads * cfg.hd * 20
    assert flops == 2.0 * 8 * (analysis.top_matmul_params(cfg, 1) + score)
    # flops scale linearly in arena capacity; byte floor does not move
    flops2, floor2 = analysis.serving_step_costs(cfg, 1, 16, 20, state)
    assert flops2 == 2 * flops and floor2 == floor


def test_band_constants_sane():
    for lo, hi in (analysis.DECODE_BYTES_BAND, analysis.FUSED_BYTES_BAND):
        assert 0 < lo < hi
    assert 0 < analysis.FUSED_FLOPS_RTOL < 1
