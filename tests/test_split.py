"""Split-learning semantics: cut boundary, compression on the wire,
gradient masking through the transfer, pod ppermute round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.data.pipeline import make_lm_batch
from repro.launch.steps import make_train_step
from repro.models import transformer
from repro.models.config import Runtime, SplitConfig
from repro.optim import adamw_init
from repro.split import model as split_model, protocol

RT = Runtime(mesh=None, training=True)


@pytest.mark.parametrize("comp", ["randtopk", "topk", "size_reduction",
                                  "quant", "l1", "identity"])
def test_split_train_step_all_compressors(comp):
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor=comp, k=16, alpha=0.1))
    params = transformer.init_model(jax.random.key(0), cfg)
    batch = make_lm_batch(jax.random.key(1), cfg, 2, 32)
    step = jax.jit(make_train_step(cfg, RT))
    p2, _, m = step(params, adamw_init(params), batch, jax.random.key(2))
    assert np.isfinite(float(m["loss"]))
    # all params received gradient updates (no dead bottom model)
    changed = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()) > 0, params, p2)
    assert all(jax.tree_util.tree_leaves(changed))


def test_cut_boundary_topk_sparsity():
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="topk", k=8))
    rt = Runtime(mesh=None, training=False)
    x = jax.random.normal(jax.random.key(0), (2, 16, cfg.d_model))
    y, pen = protocol.cut_boundary(x, cfg, rt, None)
    nnz = np.asarray((y != 0).sum(-1))
    assert (nnz == 8).all()
    # surviving values match the originals at the top-k support
    mag = np.abs(np.asarray(x))
    for b in range(2):
        for s in range(16):
            top_idx = np.argsort(-mag[b, s])[:8]
            np.testing.assert_allclose(np.asarray(y)[b, s, top_idx],
                                       np.asarray(x)[b, s, top_idx],
                                       rtol=1e-6)


def test_cut_boundary_gradient_masked():
    """Backward gradient crosses the wire only on the forward support."""
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="topk", k=4))
    rt = Runtime(mesh=None, training=True)
    x = jax.random.normal(jax.random.key(0), (1, 4, cfg.d_model))

    def f(x):
        y, _ = protocol.cut_boundary(x, cfg, rt, jax.random.key(1))
        return jnp.sum(y ** 2)

    g = np.asarray(jax.grad(f)(x))
    nnz = (g != 0).sum(-1)
    assert (nnz <= 4).all()


def test_split_decode_matches_unsplit_with_identity():
    cfg0 = configs.get("yi-6b", smoke=True)
    cfg1 = cfg0.with_(split=SplitConfig(cut_layer=1, compressor="identity"))
    rt = Runtime(mesh=None, training=False)
    params = transformer.init_model(jax.random.key(0), cfg0)
    tok = jnp.ones((2, 1), jnp.int32)
    c0 = transformer.init_cache(params, cfg0, rt, 2, 32)
    c1 = transformer.init_cache(params, cfg1, rt, 2, 32)
    l0, _ = transformer.decode_step(params, cfg0, rt, tok, c0)
    l1, _ = split_model.decode_step(params, cfg1, rt, tok, c1)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-5)


def test_wire_bytes_per_step_ordering():
    cfg = configs.get("yi-6b", smoke=True)
    b = {}
    for comp in ["identity", "quant", "topk", "randtopk", "size_reduction"]:
        c = cfg.with_(split=SplitConfig(cut_layer=1, compressor=comp, k=8,
                                        quant_bits=4))
        b[comp] = protocol.wire_bytes_per_step(c, 4, 32, training=False)
    assert b["randtopk"] == b["topk"]
    assert b["size_reduction"] < b["topk"] < b["quant"] < b["identity"]


def test_pod_permute_roundtrip():
    """Two ppermutes along a 2-pod axis restore the original payload."""
    os.environ.setdefault("XLA_FLAGS", "")
    if jax.device_count() < 8:
        pytest.skip("needs 8 host devices (run in subprocess env)")


def test_split_cut_layer_validation():
    cfg = configs.get("yi-6b", smoke=True).with_(
        split=SplitConfig(cut_layer=99, compressor="topk", k=4))
    params = transformer.init_model(jax.random.key(0), cfg)
    batch = make_lm_batch(jax.random.key(1), cfg, 2, 16)
    with pytest.raises(AssertionError):
        split_model.forward(params, cfg, RT, batch, key=jax.random.key(2))
