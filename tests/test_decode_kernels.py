"""Fused decode kernel family (`kernels.decode`): interpret-mode Pallas
parity against the two-pass XLA decode for every payload kind — flat rows,
the fused cut-projection epilogue, and the scalar-prefetched decode-to-slots
variant with its aliasing invariants — plus the `backend=` dispatch through
`core.compressors.payload_to_dense`."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core import wire
from repro.kernels.decode import ops as dec_ops
from repro.split import protocol

KIND_COMPRESSORS = [
    ("dense", C.make_compressor("identity")),
    ("slice", C.make_compressor("size_reduction", k=6)),
    ("sparse", C.make_compressor("randtopk", k=6)),
    ("quant", C.make_compressor("quant", bits=4)),
    ("sparse_quant", C.make_compressor("randtopk_quant", k=6, bits=8)),
    ("mask", C.make_compressor("randtopk_mask", k=6)),
]
IDS = [k for k, _ in KIND_COMPRESSORS]


def _wire_payload(comp, x):
    """Encode + full frame round trip — exactly what the server decodes."""
    p = protocol.client_encode(comp, x, key=jax.random.key(0), training=True)
    frame, _ = wire.decode_frame(wire.encode_payload_frame(0, 0, p))
    return frame.payload


def _assert_match(kind, ref, got):
    """dense/slice/sparse carry wire floats verbatim — bit-exact. Quant
    kinds run one multiply-add either compiler may contract into an FMA:
    <= 1 ulp at the largest decoded magnitude (the PR-5 convention pinned
    in tests/test_arena.py and docs/performance.md)."""
    if kind in ("quant", "sparse_quant"):
        atol = float(np.spacing(np.float32(np.abs(ref).max())))
        np.testing.assert_allclose(got, ref, rtol=0, atol=atol)
    else:
        np.testing.assert_array_equal(ref, got)


# ---------------------------------------------------------------------------
# Flat decode: fused kernel == two-pass XLA, every kind, via the backend
# dispatch in payload_to_dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS, ids=IDS)
def test_decode_rows_matches_xla(kind, comp):
    x = jnp.asarray(np.random.RandomState(0).randn(5, 1, 32).astype(
        np.float32))
    p = _wire_payload(comp, x)
    assert p.meta.kind == kind
    ref = np.asarray(C.payload_to_dense(p, backend="xla"))
    got = np.asarray(C.payload_to_dense(p, backend="pallas"))
    assert got.shape == ref.shape == (5, 1, 32)
    _assert_match(kind, ref, got)


@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS, ids=IDS)
def test_decode_rows_odd_shapes(kind, comp):
    """Leading shapes that exercise the row-block padding path (rows not a
    multiple of block_rows) and a d beyond one 8-lane register row."""
    rng = np.random.RandomState(1)
    for shape, d in [((3,), 70), ((2, 3, 1), 256), ((1, 1, 1, 1), 48)]:
        x = jnp.asarray(rng.randn(*shape, d).astype(np.float32))
        p = _wire_payload(comp, x)
        ref = np.asarray(C.payload_to_dense(p, backend="xla"))
        got = np.asarray(dec_ops.decode_rows(p))
        _assert_match(kind, ref, got)


def test_decode_rows_sparse_adversarial_support():
    """Hand-built sparse payloads: support touching both edge lanes, k=1,
    and k=d (full support) — the compare-and-select scatter must place
    every value exactly where put_along_axis does."""
    d = 64
    cases = [
        (np.array([[0, d - 1, 7]], np.uint16), 3),
        (np.array([[5]], np.uint16), 1),
        (np.arange(d, dtype=np.uint16)[None, :], d),
    ]
    rng = np.random.RandomState(2)
    for idx, k in cases:
        vals = rng.randn(1, k).astype(np.float32)
        p = C.Payload(meta=C.PayloadMeta("sparse", d=d, k=k),
                      values=jnp.asarray(vals), indices=jnp.asarray(idx))
        ref = np.asarray(C.payload_to_dense(p, backend="xla"))
        got = np.asarray(C.payload_to_dense(p, backend="pallas"))
        np.testing.assert_array_equal(ref, got)


# ---------------------------------------------------------------------------
# Fused cut-projection epilogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS, ids=IDS)
def test_decode_rows_projection_epilogue(kind, comp):
    """decode+project in one kernel == XLA decode then matmul. The fused
    `jnp.dot` may accumulate in a different contraction order, so the
    comparison is allclose at f32 matmul tolerance, not bit-exact."""
    d, proj = 32, 12
    x = jnp.asarray(np.random.RandomState(3).randn(4, 1, d).astype(
        np.float32))
    w = jnp.asarray(np.random.RandomState(4).randn(d, proj).astype(
        np.float32))
    p = _wire_payload(comp, x)
    ref = np.asarray(C.payload_to_dense(p, backend="xla")) @ np.asarray(w)
    got = np.asarray(C.payload_to_dense(p, backend="pallas", project=w))
    got_xla = np.asarray(C.payload_to_dense(p, backend="xla", project=w))
    assert got.shape == (4, 1, proj)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_xla, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Decode-to-slots: scalar-prefetched output indexing + xbuf aliasing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS, ids=IDS)
def test_decode_to_slots_kernel_matches_scatter(kind, comp):
    """The aliased kernel == decode + xbuf.at[slots].set: targeted rows
    decode in place, untouched rows keep their prior contents bit-exactly.
    Both paths run under jit, so quant kinds see the same FMA contraction
    and even they compare bit-exact here."""
    n, d, cap = 3, 32, 5
    x = jnp.asarray(np.random.RandomState(5).randn(n, 1, 1, d).astype(
        np.float32))
    p = _wire_payload(comp, x)
    # xbuf is DONATED by server_decode_to_slots — fresh handle per call
    make_xbuf = lambda: jnp.full((cap + 1, 1, 1, d), 7.0, jnp.float32)
    slots = np.array([4, 0, 2])
    ref = np.asarray(protocol.server_decode_to_slots(
        make_xbuf(), p, slots, backend="xla"))
    got = np.asarray(protocol.server_decode_to_slots(
        make_xbuf(), p, slots, backend="pallas"))
    np.testing.assert_array_equal(ref, got)
    for untouched in (1, 3, 5):
        np.testing.assert_array_equal(got[untouched], 7.0)


def test_decode_to_slots_duplicate_scratch_targets():
    """Pad rows aim at the same scratch slot: zero-payload rows decode to
    zero, so duplicate targets write identical rows (benign, by design)."""
    d, cap = 16, 3
    vals = np.zeros((4, 1), np.float32)
    idx = np.zeros((4, 1), np.uint16)
    p = C.Payload(meta=C.PayloadMeta("sparse", d=d, k=1),
                  values=jnp.asarray(vals), indices=jnp.asarray(idx))
    xbuf = jnp.full((cap + 1, d), 7.0, jnp.float32)
    slots = np.array([1, cap, cap, cap])     # one live row + 3 pads
    got = np.asarray(dec_ops.decode_rows_to_slots(xbuf, p, slots))
    np.testing.assert_array_equal(got[1], 0.0)
    np.testing.assert_array_equal(got[cap], 0.0)
    np.testing.assert_array_equal(got[0], 7.0)
    np.testing.assert_array_equal(got[2], 7.0)


def test_decode_rows_dtype_cast():
    """`dtype=` lands on the kernel's output store, matching the XLA path's
    astype semantics."""
    x = jnp.asarray(np.random.RandomState(6).randn(2, 1, 16).astype(
        np.float32))
    p = _wire_payload(C.make_compressor("identity"), x)
    ref = np.asarray(C.payload_to_dense(p, dtype=jnp.bfloat16,
                                        backend="xla"))
    got = np.asarray(C.payload_to_dense(p, dtype=jnp.bfloat16,
                                        backend="pallas"))
    assert got.dtype == ref.dtype == jnp.bfloat16
    np.testing.assert_array_equal(ref, got)
