"""Fault-injection subsystem + end-to-end chaos acceptance.

Unit level: `FaultyEndpoint` mangles deterministically under a seeded
`FaultPlan`, corruption is always caught by the CRC gate, and the benign
re-chunk fault is invisible to the frame layer.

End to end (the PR's acceptance bar): `run_streaming` and `run_fedtrain`
complete under a seeded plan mixing corrupt/truncate/drop/duplicate/reorder
faults, every injected corruption surfaces as a typed detection (zero
silent decodes), affected sessions reconnect and resume via seq replay, and
final tokens / losses / accuracy are identical to the fault-free run at
equal seeds.
"""
import numpy as np
import pytest

import jax
import repro.configs as configs
from repro.core import wire
from repro.data.synthetic import ManyClassDataset
from repro.fedtrain import run_fedtrain
from repro.models import transformer
from repro.models.config import SplitConfig
from repro.runtime import channel_pair, run_streaming
from repro.split.tabular import SplitSpec
from repro.testing import (DESTRUCTIVE_FAULTS, FaultInjector, FaultPlan,
                           FaultyEndpoint)

CHAOS_PLAN = dict(corrupt=0.06, truncate=0.03, drop=0.05, duplicate=0.05,
                  reorder=0.03, rechunk=0.15, max_faults=30)
ARQ = dict(retry_timeout=0.3, max_retries=40)


# ---------------------------------------------------------------------------
# FaultyEndpoint unit behavior
# ---------------------------------------------------------------------------

def _mangled_stream(plan: FaultPlan, frames):
    """Send `frames` through a FaultyEndpoint, return delivered raw chunks."""
    cep, sep = channel_pair()
    fep = FaultyEndpoint(cep, plan)
    for fb in frames:
        fep.send(fb)
    chunks = []
    while True:
        c = sep.recv_chunk(timeout=0.01)
        if c is None:
            return fep, chunks
        chunks.append(c)


def test_fault_injection_is_deterministic():
    frames = [wire.encode_token_frame(0, i, [i]) for i in range(40)]
    plan = FaultPlan(seed=11, **CHAOS_PLAN)
    a_ep, a = _mangled_stream(plan, frames)
    b_ep, b = _mangled_stream(plan, frames)
    assert a == b                       # chunk-for-chunk replayable
    assert a_ep.injected == b_ep.injected
    assert sum(a_ep.injected[f] for f in DESTRUCTIVE_FAULTS) > 0


def test_clean_plan_is_transparent():
    frames = [wire.encode_token_frame(0, i, [i]) for i in range(10)]
    ep, chunks = _mangled_stream(FaultPlan(seed=0), frames)
    assert chunks == frames and not ep.injected


def test_rechunk_only_plan_is_invisible_to_frame_layer():
    """Pure re-chunking stresses FrameReader reassembly but must lose
    nothing: every frame decodes exactly, in order."""
    frames = [wire.encode_token_frame(0, i, [i]) for i in range(50)]
    ep, chunks = _mangled_stream(FaultPlan(seed=3, rechunk=0.9), frames)
    assert ep.injected["rechunk"] > 10
    assert len(chunks) > len(frames)    # boundaries really moved
    reader = wire.FrameReader()
    reader.feed(b"".join(chunks))
    assert [f.seq for f in reader.frames()] == list(range(50))


def test_corruption_is_always_caught_by_crc():
    """Corrupt-only chaos: every surviving frame is bit-exact, every
    corrupted one raises — the receiver never sees a wrong token."""
    frames = [wire.encode_token_frame(0, i, [i]) for i in range(60)]
    ep, chunks = _mangled_stream(
        FaultPlan(seed=5, corrupt=0.3, max_faults=1000), frames)
    assert ep.injected["corrupt"] >= 5
    good, detected, stalled = [], 0, 0
    for c in chunks:                    # one frame per chunk (no rechunk)
        reader = wire.FrameReader()
        reader.feed(c)
        try:
            decoded = [int(f.tokens[0]) for f in reader.frames()]
        except wire.WireError:
            detected += 1
            continue
        if decoded:
            good.extend(decoded)
        else:
            stalled += 1    # flip hit the length prefix: reader waits for
            #                 bytes that never come — still not a misdecode
    # zero silent decodes: every corrupted chunk was rejected or stalled,
    # and every decoded token is one that was actually sent, in order
    assert detected + stalled == ep.injected["corrupt"]
    assert detected > 0
    assert good == sorted(good)
    assert set(good).issubset(set(range(60)))
    assert len(good) == 60 - ep.injected["corrupt"]


def test_budget_bounds_destructive_faults():
    frames = [wire.encode_token_frame(0, i, [i]) for i in range(300)]
    ep, _ = _mangled_stream(
        FaultPlan(seed=1, drop=0.9, max_faults=7), frames)
    assert sum(ep.injected[f] for f in DESTRUCTIVE_FAULTS) == 7


def test_injector_reseeds_per_connection():
    """A reconnect must not replay the exact fault stream that killed the
    previous connection (or a corrupt retry could loop forever)."""
    inj = FaultInjector(FaultPlan(seed=9, corrupt=0.5, max_faults=1000))
    frames = [wire.encode_token_frame(0, i, [i]) for i in range(30)]
    outs = []
    for _ in range(2):                  # same cid, consecutive connections
        cep, sep = channel_pair()
        fep = inj(0, cep)
        for fb in frames:
            fep.send(fb)
        chunks = []
        while (c := sep.recv_chunk(timeout=0.01)) is not None:
            chunks.append(c)
        outs.append(chunks)
    assert inj.connections == 2
    assert outs[0] != outs[1]


# ---------------------------------------------------------------------------
# End-to-end chaos: the acceptance bar
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_streaming_survives_chaos_with_identical_tokens():
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = dict(n_clients=4, prompt_len=3, gen=6, max_batch=4, max_wait=0.02,
              compressor_mix=["identity", "randtopk:k=16"], params=params)
    clean = run_streaming(cfg, **kw)
    assert all(v == 0 for v in clean["fault_counters"].values())

    inj = FaultInjector(FaultPlan(seed=3, **CHAOS_PLAN))
    chaos = run_streaming(cfg, **kw, wrap_endpoint=inj, **ARQ)
    injected = inj.injected()
    fc = chaos["fault_counters"]
    assert sum(injected[f] for f in DESTRUCTIVE_FAULTS) > 0
    # recovery machinery actually engaged...
    assert fc["replays"] > 0 and fc["reconnects"] > 0
    assert (fc["server_faults_detected"] + fc["client_faults_detected"]) > 0
    # ...and the outcome is indistinguishable from the clean run
    np.testing.assert_array_equal(chaos["tokens"], clean["tokens"])
    # payload accounting still reconciles between the parties under chaos
    for cs, ss in zip(chaos["client_stats"], chaos["server_stats"]):
        assert cs["tokens_out"] == 6


def test_fedtrain_survives_chaos_with_identical_losses():
    ds = ManyClassDataset(n_classes=10, in_dim=16, n_train=512, n_test=256,
                          noise=0.3, seed=0)
    spec = SplitSpec(in_dim=16, hidden=32, cut_dim=32, n_classes=10,
                     method="randtopk", k=3)
    kw = dict(n_clients=1, epochs=1, batch=64, seed=0)
    clean = run_fedtrain(spec, ds, **kw)
    assert all(v == 0 for v in clean["fault_counters"].values())

    inj = FaultInjector(FaultPlan(seed=7, **CHAOS_PLAN))
    chaos = run_fedtrain(spec, ds, **kw, wrap_endpoint=inj, **ARQ)
    injected = inj.injected()
    fc = chaos["fault_counters"]
    assert sum(injected[f] for f in DESTRUCTIVE_FAULTS) > 0
    assert fc["replays"] + fc["duplicates"] + fc["reconnects"] > 0
    # loss trajectory is BIT-identical: replayed steps were deduplicated,
    # the top optimizer never double-stepped, no corrupt frame was decoded
    np.testing.assert_array_equal(
        np.asarray([l for _, l in chaos["losses"][0]]),
        np.asarray([l for _, l in clean["losses"][0]]))
    assert chaos["mean_test_acc"] == clean["mean_test_acc"]
    # analytic accounting is fault-invariant (counts logical steps, not
    # retransmissions); measured bytes may only grow under chaos
    assert chaos["analytic_bytes_up"] == clean["analytic_bytes_up"]
    assert chaos["payload_bytes_up"] >= clean["payload_bytes_up"]


@pytest.mark.slow
def test_fedtrain_survives_corrupt_first_frame_heavy_chaos():
    """Regression: a corrupt FIRST frame retires the connection before the
    server ever created the session — the serve queue must stay open for
    the reconnect (expected_sessions), or the run starves at step 0. Heavy
    corruption (25% of chunks) makes this path near-certain."""
    ds = ManyClassDataset(n_classes=10, in_dim=16, n_train=512, n_test=256,
                          noise=0.3, seed=0)
    spec = SplitSpec(in_dim=16, hidden=32, cut_dim=32, n_classes=10,
                     method="randtopk", k=3)
    kw = dict(n_clients=1, epochs=1, batch=64, seed=0)
    clean = run_fedtrain(spec, ds, **kw)
    inj = FaultInjector(FaultPlan(seed=3, corrupt=0.25, truncate=0.08,
                                  drop=0.1, duplicate=0.1, reorder=0.05,
                                  rechunk=0.2, max_faults=60))
    chaos = run_fedtrain(spec, ds, **kw, wrap_endpoint=inj,
                         retry_timeout=0.2, max_retries=60)
    assert chaos["fault_counters"]["reconnects"] > 0
    np.testing.assert_array_equal(
        np.asarray([l for _, l in chaos["losses"][0]]),
        np.asarray([l for _, l in clean["losses"][0]]))


def test_fedtrain_chaos_multi_client_completes():
    """N>1 clients under chaos: every session resumes and finishes its
    step count (cross-client arrival order may differ, so no bit parity —
    completion + per-session frame counts are the contract)."""
    ds = ManyClassDataset(n_classes=10, in_dim=16, n_train=512, n_test=256,
                          noise=0.3, seed=0)
    spec = SplitSpec(in_dim=16, hidden=32, cut_dim=32, n_classes=10,
                     method="randtopk", k=3)
    inj = FaultInjector(FaultPlan(seed=21, **CHAOS_PLAN))
    r = run_fedtrain(spec, ds, n_clients=2, epochs=1, batch=64, seed=0,
                     wrap_endpoint=inj, **ARQ)
    assert r["steps"] == 4 and len(r["losses"][0]) == 4
    assert len(r["losses"][1]) == 4
    assert np.isfinite(r["mean_test_acc"])
