"""Fused encode kernel family (`kernels.encode`): interpret-mode Pallas
parity against the XLA `Compressor.encode` for every payload kind, the
device bit-packer against `core.wire._pack_bits`, and byte equality of the
device wire path (`pack_payload` -> `sections_to_bytes`) with the host
codec — including the full-frame round trip through
`protocol.client_encode_device` on both backends."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core import wire
from repro.kernels.encode import kernel as enc_kernel
from repro.kernels.encode import ops as enc_ops
from repro.split import protocol

KIND_COMPRESSORS = [
    ("dense", C.make_compressor("identity")),
    ("slice", C.make_compressor("size_reduction", k=6)),
    ("sparse", C.make_compressor("randtopk", k=6)),
    ("quant", C.make_compressor("quant", bits=4)),
    ("sparse_quant", C.make_compressor("randtopk_quant", k=6, bits=8)),
    ("mask", C.make_compressor("randtopk_mask", k=6)),
]
IDS = [k for k, _ in KIND_COMPRESSORS]
#: kinds the fused Pallas encode kernel covers (dense has no device pack
#: work beyond the f32 bitcast, so it never dispatches to the kernel)
KERNEL_KINDS = ("slice", "sparse", "quant", "sparse_quant", "mask")


def _host_payload(comp, x, *, key):
    p = comp.encode(x, key=key, training=True)
    return jax.tree.map(np.asarray, p)


def _kernel_payload(comp, x, *, key):
    """The fused-kernel half of `protocol.client_encode_device`, called
    directly so the test controls the selection key."""
    kind = comp.wire_kind
    d = x.shape[-1]
    mask = (comp._mask(x, key, True)
            if kind in ("sparse", "sparse_quant", "mask") else None)
    return enc_ops.encode_rows(x, kind, k=min(getattr(comp, "k", 0), d),
                               bits=getattr(comp, "bits", 0), mask=mask,
                               interpret=True)


def _assert_leaves_match(kind, ref, got):
    """Non-quant leaves cross the gather verbatim — bit-exact. Quant codes
    and range headers re-run the min/max + floor grid, which either
    compiler may FMA-contract: <= 1 ulp at the leaf's largest magnitude
    (the decode-side convention of tests/test_decode_kernels.py)."""
    for field in ("values", "indices", "header"):
        r, g = getattr(ref, field), getattr(got, field)
        assert (r is None) == (g is None), field
        if r is None:
            continue
        r, g = np.asarray(r), np.asarray(g)
        assert r.shape == g.shape and r.dtype == g.dtype, field
        if kind in ("quant", "sparse_quant") and field in ("values",
                                                           "header"):
            rf, gf = r.astype(np.float64), g.astype(np.float64)
            atol = float(np.spacing(np.float32(np.abs(rf).max() or 1.0)))
            np.testing.assert_allclose(gf, rf, rtol=0, atol=atol)
        else:
            np.testing.assert_array_equal(r, g, err_msg=field)


# ---------------------------------------------------------------------------
# Fused encode kernel == XLA compressor encode, every kernel kind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS, ids=IDS)
def test_encode_rows_matches_xla(kind, comp):
    if kind not in KERNEL_KINDS:
        pytest.skip("dense never dispatches to the encode kernel")
    x = jnp.asarray(np.random.RandomState(0).randn(5, 1, 32).astype(
        np.float32))
    key = jax.random.key(7)
    ref = _host_payload(comp, x, key=key)
    got = _kernel_payload(comp, x, key=key)
    assert got.meta == ref.meta
    assert got.batch_shape == ref.batch_shape == (5, 1)
    _assert_leaves_match(kind, ref, got)


@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS, ids=IDS)
def test_encode_rows_odd_shapes(kind, comp):
    """Leading shapes exercising the row-block padding path and a d that
    is not a multiple of 32 (a partial trailing bitmask word)."""
    if kind not in KERNEL_KINDS:
        pytest.skip("dense never dispatches to the encode kernel")
    rng = np.random.RandomState(1)
    for shape, d in [((3,), 70), ((2, 3, 1), 256), ((1, 1, 1, 1), 48)]:
        x = jnp.asarray(rng.randn(*shape, d).astype(np.float32))
        key = jax.random.key(d)
        ref = _host_payload(comp, x, key=key)
        got = _kernel_payload(comp, x, key=key)
        _assert_leaves_match(kind, ref, got)


# ---------------------------------------------------------------------------
# Device bit-packer == wire._pack_bits, both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [1, 4, 5, 7, 8, 12, 16, 31, 32])
def test_pack_bits_matches_host(width):
    rng = np.random.RandomState(width)
    for n in (1, 31, 32, 33, 100):
        hi = min(1 << width, 1 << 31)
        vals = rng.randint(0, hi, size=n).astype(np.uint32)
        ref = wire._pack_bits(vals, width)
        for packed in (
                enc_kernel.pack_bits_kernel(jnp.asarray(vals), width,
                                            interpret=True),
                enc_ops._pack_words_xla(jnp.asarray(vals), width)):
            buf = np.asarray(packed).tobytes()
            assert buf[:len(ref)] == ref, (width, n)
            # padding bits land strictly after the real ones and are zero
            assert not any(buf[len(ref):]), (width, n)


# ---------------------------------------------------------------------------
# Device wire path: pack_payload -> sections_to_bytes == host codec, and
# the framed bytes are identical through both client_encode halves
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS, ids=IDS)
def test_sections_match_host_codec(kind, comp):
    """Same payload leaves in -> same wire bytes out, for ANY leaf source:
    pure byte-layer equality, so it holds for every kind incl. quant."""
    rng = np.random.RandomState(2)
    for shape, d in [((4, 1), 32), ((3,), 70), ((2, 2), 48)]:
        x = jnp.asarray(rng.randn(*shape, d).astype(np.float32))
        p = comp.encode(x, key=jax.random.key(0), training=True)
        sections = enc_ops.pack_payload(p, backend="xla")
        nb = enc_ops.section_nbytes(p.meta, p.batch_shape)
        assert len(sections) == len(nb)
        body = enc_ops.sections_to_bytes(p.meta, p.batch_shape, sections)
        host = wire.encode_payload(jax.tree.map(np.asarray, p))
        assert body == host
        assert len(body) == sum(nb) == wire.payload_expected_nbytes(
            p.meta, p.batch_shape)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS, ids=IDS)
def test_client_encode_device_frame_identical(kind, comp, backend):
    """Full-frame equality of the device wire path with the host path —
    subheader, body, and CRC — on both backend dispatches. Quant kinds are
    exempt from byte equality on the Pallas branch only if the FMA ulp
    moved a code; at these shapes it does not, so frames match."""
    comp = dataclasses.replace(comp, backend=backend)
    x = jnp.asarray(np.random.RandomState(3).randn(4, 1, 64).astype(
        np.float32))
    key = jax.random.key(11)
    p_host = protocol.client_encode(comp, x, key=key, training=True)
    ref = wire.encode_payload_frame(9, 3, p_host)
    p_dev, sections = protocol.client_encode_device(comp, x, key=key,
                                                    training=True)
    body = enc_ops.sections_to_bytes(p_dev.meta, p_dev.batch_shape,
                                     sections)
    got = wire.encode_payload_frame_from_bytes(9, 3, p_dev.meta,
                                               p_dev.batch_shape, body)
    assert got == ref
    frame, consumed = wire.decode_frame(got)
    assert consumed == len(got) and frame.payload.meta == p_host.meta


def test_mask_sections_second_buffer_stays_2d():
    """The mask kind's bitmask section must stay (n, W): its rows are
    byte- but not word-aligned, so the host slices each row's exact
    `mask_row_nbytes` bytes (wire.mask_words_to_bytes)."""
    comp = C.make_compressor("randtopk_mask", k=5)
    x = jnp.asarray(np.random.RandomState(4).randn(3, 1, 40).astype(
        np.float32))
    p = comp.encode(x, key=jax.random.key(0), training=True)
    sections = enc_ops.pack_payload(p)
    assert len(sections) == 2
    assert sections[1].shape == (3, wire.mask_words(40))
    # d=40 -> 5-byte rows out of 8-byte word rows: truncation per row
    assert wire.mask_row_nbytes(40) == 5
