"""Observability layer — registry, tracer, exporters, and the determinism
contract (docs/observability.md).

Unit coverage for `repro.obs` (metrics registry, tracer event shapes,
Chrome-trace export + validation), the `protocol.HOST_DENSIFY_COUNT`
registry shim, and `LatencyStats` streaming-only demotion; then the
end-to-end pins: a seeded loadgen run with tracing ON writes byte-identical
Chrome-trace JSON across same-seed runs — clean AND under injected
`FaultInjector` chaos — whose spans form a laminar family per track, with
all seven lifecycle spans present, and `run_streaming` carries a per-run
metrics snapshot that matches the legacy `SessionStats` byte accounting.
"""
from __future__ import annotations

import json
import math

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer
from repro.models.config import SplitConfig
from repro.obs.export import (check_span_nesting, chrome_trace, dump_json,
                              validate_chrome_trace)
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import (LIFECYCLE_SPANS, NULL_TRACER, SERVE_TID, Tracer,
                             session_tid)
from repro.runtime import engine
from repro.runtime.loadgen import (ArrivalSpec, FleetSpec, LoadGenConfig,
                                   ServiceModel, SLOSpec, run_loadgen)
from repro.runtime.metrics import LatencyStats, merged_percentiles
from repro.split import protocol
from repro.testing import FaultInjector, FaultPlan, VirtualClock


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    c = reg.counter("frames_total", party="client", direction="up")
    c.inc()
    c.inc(3)
    assert c.value == 4
    assert reg.counter("frames_total", party="client",
                       direction="up") is c      # same series, same object
    with pytest.raises(ValueError):
        c.inc(-1)                                # counters are monotonic

    g = reg.gauge("queue_depth")
    g.set(7)
    g.inc(2)
    g.dec(4)
    assert g.value == 5

    h = reg.histogram("token_latency_ms")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 1.0 and s["max"] == 4.0
    assert s["mean"] == pytest.approx(2.5)
    assert 1.0 <= s["p50"] <= 4.0

    # one name is one kind: reusing it as another kind is a bug, not a series
    with pytest.raises(TypeError):
        reg.gauge("frames_total")


def test_registry_snapshot_and_text_deterministic():
    def build():
        reg = MetricsRegistry()
        # insertion order deliberately scrambled vs label sort order
        reg.counter("frames_total", party="server", direction="up").inc(2)
        reg.counter("frames_total", party="client", direction="up").inc(1)
        reg.gauge("queue_depth").set(3)
        reg.histogram("flush_fill").observe(8)
        return reg

    a, b = build(), build()
    assert a.snapshot() == b.snapshot()
    assert a.render_text() == b.render_text()
    snap = a.snapshot()
    labels = [s["labels"] for s in snap["frames_total"]["series"]]
    assert labels == sorted(labels, key=lambda d: sorted(d.items()))
    text = a.render_text()
    assert 'frames_total{direction="up",party="client"} 1' in text


# ---------------------------------------------------------------------------
# tracer + export
# ---------------------------------------------------------------------------

def test_tracer_spans_instants_and_export_shapes():
    vc = VirtualClock()
    tr = Tracer(clock=vc)
    tr.name_track(SERVE_TID, "serve loop")
    tr.name_track(SERVE_TID, "renamed")          # idempotent: first name wins
    with tr.span("outer", tid=session_tid(0), sid=0):
        vc.advance_to(1.0)
        with tr.span("inner", tid=session_tid(0)):
            vc.advance_to(1.5)
    tr.instant("qos.transition", tid=session_tid(0), frm=0, to=1)
    tr.complete("server.queue_wait", 0.25, 0.75, tid=session_tid(0))
    tr.complete("clamped", 2.0, 1.0)             # negative dur clamps to 0

    obj = chrome_trace(tr)
    assert validate_chrome_trace(obj) == []
    assert check_span_nesting(obj["traceEvents"]) == []
    by_name = {e["name"]: e for e in obj["traceEvents"]}
    assert by_name["thread_name"]["args"]["name"] == "serve loop"
    assert by_name["inner"]["ts"] == pytest.approx(1.0e6)
    assert by_name["inner"]["dur"] == pytest.approx(0.5e6)
    assert by_name["qos.transition"]["ph"] == "i"
    assert by_name["qos.transition"]["s"] == "t"
    assert by_name["clamped"]["dur"] == 0.0

    # null tracer: no events, reusable span, harmless methods
    with NULL_TRACER.span("x"):
        pass
    NULL_TRACER.instant("y")
    assert NULL_TRACER.events() == [] and not NULL_TRACER.enabled


def test_export_validation_catches_malformed_events():
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": "nope"}) != []
    bad = {"traceEvents": [
        {"ph": "X", "ts": 0, "pid": 0, "tid": 0},            # no name
        {"name": "z", "ph": "Z", "ts": 0, "pid": 0, "tid": 0},
        {"name": "n", "ph": "X", "ts": -1, "pid": 0, "tid": 0, "dur": -2},
        {"name": "i", "ph": "i", "ts": 0, "pid": 0, "tid": 0},  # no scope
    ]}
    problems = validate_chrome_trace(bad)
    assert len(problems) >= 4


def test_span_nesting_check_flags_straddles_not_abutments():
    # genuine straddle: [0, 10] vs [5, 15] on one track
    bad = [{"ph": "X", "pid": 0, "tid": 0, "ts": 0, "dur": 10, "name": "a"},
           {"ph": "X", "pid": 0, "tid": 0, "ts": 5, "dur": 10, "name": "b"}]
    assert check_span_nesting(bad) != []
    # abutting spans with sub-quantum float noise (the ts+dur error of
    # wall-clock-sized µs stamps) must NOT read as straddling
    t = 14_386_434_149.752
    ok = [{"ph": "X", "pid": 0, "tid": 0, "ts": t, "dur": 1184.044,
           "name": "step"},
          {"ph": "X", "pid": 0, "tid": 0, "ts": t + 1184.0440006,
           "dur": 92.883, "name": "reply"}]
    assert check_span_nesting(ok) == []
    # different tracks never interact
    two = [{"ph": "X", "pid": 0, "tid": 0, "ts": 0, "dur": 10, "name": "a"},
           {"ph": "X", "pid": 0, "tid": 1, "ts": 5, "dur": 10, "name": "b"}]
    assert check_span_nesting(two) == []


def test_dump_json_deterministic():
    def build():
        vc = VirtualClock()
        tr = Tracer(clock=vc)
        for i in range(5):
            vc.advance_to(i * 0.1)
            tr.instant("tick", tid=i, i=i)
        return tr

    assert dump_json(build()) == dump_json(build())
    assert dump_json(build()).endswith("\n")


# ---------------------------------------------------------------------------
# HOST_DENSIFY registry shim
# ---------------------------------------------------------------------------

def test_host_densify_counter_feeds_registry():
    from repro.obs.registry import DEFAULT_REGISTRY
    cnt = protocol.HOST_DENSIFY_COUNT
    reg_counter = DEFAULT_REGISTRY.counter("host_densify_total")
    cnt.reset()
    base = reg_counter.value
    assert cnt.value == 0 and cnt == 0
    cnt.increment()
    cnt.increment()
    assert cnt.value == 2 and int(cnt) == 2
    # the registry series is monotonic even across legacy reset()
    assert reg_counter.value == base + 2
    cnt.reset()
    assert cnt.value == 0
    assert reg_counter.value == base + 2
    with cnt.watch() as w:              # deprecated shim still works
        cnt.increment()
    assert w.delta == 1
    cnt.reset()


# ---------------------------------------------------------------------------
# LatencyStats streaming-only + merged_percentiles keys
# ---------------------------------------------------------------------------

def test_latency_stats_streaming_only_demotion():
    rng = np.random.RandomState(0)
    xs = rng.exponential(0.02, size=400)
    ls = LatencyStats(max_exact_samples=100)
    for x in xs:
        ls.add(float(x))
    assert ls.streaming_only and ls.samples == [] and len(ls) == 400
    rep = ls.report()
    assert rep["streaming_only"] is True
    assert rep["n"] == 400
    assert rep["mean_ms"] == pytest.approx(float(xs.mean()) * 1e3)
    assert rep["max_ms"] == pytest.approx(float(xs.max()) * 1e3)
    # in streaming-only mode the pXX keys ARE the P² estimates
    for tag in ("p50", "p95", "p99"):
        assert rep[f"{tag}_ms"] == rep[f"p2_{tag}_ms"]
    exact = LatencyStats()
    for x in xs:
        exact.add(float(x))
    assert exact.report()["streaming_only"] is False
    # same schema either way, and the P² p50 tracks the exact one
    assert set(rep) == set(exact.report())
    assert rep["p50_ms"] == pytest.approx(exact.report()["p50_ms"],
                                          rel=0.15)


def test_merged_percentiles_same_keys_empty_and_populated():
    full = merged_percentiles([[0.01, 0.02], [0.03]])
    empty = merged_percentiles([])
    also_empty = merged_percentiles([[], []])
    assert set(full) == set(empty) == set(also_empty) == {
        "p50_ms", "p95_ms", "p99_ms"}
    assert all(math.isnan(v) for v in empty.values())
    assert full["p50_ms"] == pytest.approx(20.0)


# ---------------------------------------------------------------------------
# end-to-end: deterministic traces, clean + chaos
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke():
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16))
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


def _lg(seed, **kw):
    base = dict(
        seed=seed, duration_s=1.5,
        arrivals=ArrivalSpec(process="mmpp", rate=12.0, burst_rate=24.0,
                             mean_calm_s=1.0, mean_burst_s=1.0),
        fleet=FleetSpec(compressors=("randtopk:k=16",), prompt_len=(2, 3),
                        gen=(3, 5), bandwidth_Bps=400_000.0),
        service=ServiceModel(flush_overhead_s=1e-3, per_row_s=1e-4,
                             per_byte_s=3e-5),
        slo=SLOSpec(p99_ms=250.0, max_reject_frac=1.0),
        capacity=8, max_batch=4, max_wait=0.004, admission_depth=16)
    base.update(kw)
    return LoadGenConfig(**base)


def _trace_bytes(smoke, tmp_path, tag, **kw):
    cfg, params = smoke
    path = tmp_path / f"{tag}.json"
    report = run_loadgen(cfg, _lg(7), params=params, trace_path=path, **kw)
    return path.read_bytes(), report


@pytest.mark.slow
def test_loadgen_trace_bit_identical_clean(smoke, tmp_path):
    b1, r1 = _trace_bytes(smoke, tmp_path, "clean1")
    b2, r2 = _trace_bytes(smoke, tmp_path, "clean2")
    assert b1 == b2
    assert r1["trace_events"] == r2["trace_events"] > 0
    obj = json.loads(b1)
    assert validate_chrome_trace(obj) == []
    assert check_span_nesting(obj["traceEvents"]) == []
    names = {e["name"] for e in obj["traceEvents"]}
    assert all(s in names for s in LIFECYCLE_SPANS)


def test_loadgen_trace_bit_identical_under_chaos(smoke, tmp_path):
    plan = FaultPlan(seed=11, corrupt=0.06, drop=0.05, duplicate=0.05,
                     reorder=0.03, max_faults=30)
    b1, r1 = _trace_bytes(smoke, tmp_path, "chaos1",
                          wrap_endpoint=FaultInjector(plan))
    b2, r2 = _trace_bytes(smoke, tmp_path, "chaos2",
                          wrap_endpoint=FaultInjector(plan))
    assert b1 == b2
    obj = json.loads(b1)
    assert validate_chrome_trace(obj) == []
    assert check_span_nesting(obj["traceEvents"]) == []
    # chaos leaves a recovery record in the trace and the registry
    faults = r1["fault_counters"]
    assert (faults["client_faults_detected"] + faults["replays"]
            + faults["duplicates"]) > 0


@pytest.mark.parametrize("seed", [1, 5, 23])
def test_span_nesting_fuzz_over_concurrent_sessions(smoke, tmp_path, seed):
    cfg, params = smoke
    path = tmp_path / f"fuzz{seed}.json"
    run_loadgen(cfg, _lg(seed, capacity=6, max_batch=3), params=params,
                trace_path=path)
    obj = json.loads(path.read_bytes())
    assert validate_chrome_trace(obj) == []
    assert check_span_nesting(obj["traceEvents"]) == []


def test_run_streaming_metrics_snapshot_matches_session_stats(smoke):
    cfg, params = smoke
    tracer = Tracer()
    res = engine.run_streaming(cfg, n_clients=3, prompt_len=3, gen=4,
                               max_batch=3, max_wait=0.01, params=params,
                               tracer=tracer)
    names = {e["name"] for e in tracer.events()}
    assert all(s in names for s in LIFECYCLE_SPANS)
    assert check_span_nesting(chrome_trace(tracer)["traceEvents"]) == []

    snap = res["metrics"]
    series = {(name, tuple(sorted(s["labels"].items()))): s
              for name in snap for s in snap[name]["series"]}

    def val(name, **labels):
        return series[(name, tuple(sorted(labels.items())))]["value"]

    up_frames = sum(s["frames_up"] for s in res["client_stats"])
    up_payload = sum(s["payload_bytes_up"] for s in res["client_stats"])
    assert val("frames_total", party="client", direction="up") == up_frames
    assert val("frames_total", party="server", direction="up") == up_frames
    assert val("payload_bytes_total", party="client",
               direction="up") == up_payload
    assert val("tokens_total", party="client") == 3 * 4
    assert val("slot_admits_total") == 3
    qw = series[("queue_wait_ms", ())]
    assert qw["count"] == up_frames


def test_run_streaming_registry_isolated_per_run(smoke):
    cfg, params = smoke
    r1 = engine.run_streaming(cfg, n_clients=2, prompt_len=2, gen=3,
                              max_batch=2, max_wait=0.01, params=params)
    r2 = engine.run_streaming(cfg, n_clients=2, prompt_len=2, gen=3,
                              max_batch=2, max_wait=0.01, params=params)
    # a fresh registry per run: identical runs, identical counters
    assert r1["metrics"]["frames_total"] == r2["metrics"]["frames_total"]
