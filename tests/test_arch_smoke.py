"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.data.pipeline import make_lm_batch
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import transformer
from repro.models.config import Runtime
from repro.optim import adamw_init

RT = Runtime(mesh=None, training=True)
RT_INF = Runtime(mesh=None, training=False)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_train_decode(arch):
    cfg = configs.get(arch, smoke=True)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = transformer.init_model(jax.random.key(0), cfg)
    batch = make_lm_batch(jax.random.key(1), cfg, 2, 32)

    logits, aux = transformer.forward(params, cfg, RT, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    step = jax.jit(make_train_step(cfg, RT))
    p2, o2, m = step(params, adamw_init(params), batch, jax.random.key(2))
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0

    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = batch["patches"]
    if cfg.family == "audio":
        extras["enc_out"] = transformer.run_encoder(params, cfg, RT_INF,
                                                    batch["frames"])
    cache = transformer.init_cache(params, cfg, RT_INF, 2, 64, extras)
    serve = jax.jit(make_serve_step(cfg, RT_INF))
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        tok, cache = serve(params, cache, tok)
    assert tok.shape == (2, 1)
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = configs.get(arch)
    expected = {
        "qwen3_moe_235b_a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, d_ff=1536, vocab=151936,
                                    n_experts=128, topk_experts=8),
        "zamba2_7b": dict(n_layers=81, d_model=3584, n_heads=32,
                          n_kv_heads=32, d_ff=14336, vocab=32000,
                          ssm_state=64),
        "granite_3_8b": dict(n_layers=40, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=12800, vocab=49155),
        "yi_6b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
                      d_ff=11008, vocab=64000),
        "granite_moe_1b_a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv_heads=8, d_ff=512, vocab=49155,
                                     n_experts=32, topk_experts=8),
        "rwkv6_1p6b": dict(n_layers=24, d_model=2048, d_ff=7168,
                           vocab=65536),
        "llama_3_2_vision_90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=28672, vocab=128256),
        "qwen3_8b": dict(n_layers=36, d_model=4096, n_heads=32,
                         n_kv_heads=8, d_ff=12288, vocab=151936,
                         qk_norm=True),
        "whisper_tiny": dict(n_layers=4, d_model=384, n_heads=6,
                             n_kv_heads=6, d_ff=1536, vocab=51865,
                             encdec=True),
        "phi3_mini_3p8b": dict(n_layers=32, d_model=3072, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab=32064),
    }[arch]
    for key, val in expected.items():
        assert getattr(cfg, key) == val, (arch, key, getattr(cfg, key), val)


def test_param_spec_tree_matches_params():
    """Sharding spec trees must be congruent with the param trees."""
    for arch in configs.ARCHS:
        cfg = configs.get(arch, smoke=True)
        params = jax.eval_shape(
            lambda: transformer.init_model(jax.random.key(0), cfg))
        spec = transformer.param_spec(cfg)
        ps = jax.tree_util.tree_structure(params)
        ss = jax.tree_util.tree_structure(
            spec, is_leaf=lambda s: isinstance(
                s, jax.sharding.PartitionSpec))
        assert ps == ss, f"{arch}: {ps} != {ss}"
