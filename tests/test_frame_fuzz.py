"""Property-based fuzz of the frame layer: chunk boundaries, garbage
prefixes, interleaved sessions, and single-byte corruption.

Built on `tests/_hypothesis_compat.py`, so the properties run (with
fixed-seed sampled examples) even without `hypothesis` installed. The core
contract under fuzz: a `FrameReader` either yields exactly the frames that
were sent, or raises a typed `wire.WireError` — it never yields a frame
that was not sent, and never hangs on a complete buffer.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
from repro.core import compressors as C, wire


def _sample_stream(seed: int, n_sessions: int = 2, steps: int = 3):
    """A deterministic multi-session byte stream + its expected frames."""
    rng = np.random.RandomState(seed)
    comp = C.make_compressor("randtopk", k=3)
    chunks, expect = [], []
    for step in range(steps):
        for sid in range(n_sessions):
            p = jax.tree.map(np.asarray, comp.encode(
                jax.numpy.asarray(rng.randn(1, 16).astype(np.float32)),
                key=jax.random.key(seed + sid), training=True))
            chunks.append(wire.encode_payload_frame(sid, step, p))
            expect.append((wire.FRAME_PAYLOAD, sid, step))
            chunks.append(wire.encode_token_frame(sid, step, [step]))
            expect.append((wire.FRAME_TOKENS, sid, step))
    for sid in range(n_sessions):
        chunks.append(wire.encode_close_frame(sid))
        expect.append((wire.FRAME_CLOSE, sid, 0))
    return b"".join(chunks), expect


def _drain(reader):
    return [(f.kind, f.session, f.seq) for f in reader.frames()]


@given(st.integers(0, 500), st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_reader_invariant_under_chunk_boundaries(seed, chunk_size):
    """Frames recovered must be identical no matter how the stream is cut —
    including interleaved sessions back-to-back in one buffer."""
    stream, expect = _sample_stream(seed % 5)
    reader = wire.FrameReader()
    got = []
    for off in range(0, len(stream), chunk_size):
        reader.feed(stream[off: off + chunk_size])
        got.extend(_drain(reader))
    assert got == expect


@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_garbage_prefix_never_yields_a_frame(seed):
    """Random garbage must never decode to a frame: either a typed
    WireError (bad length/CRC) or an incomplete-buffer wait, never a
    silent bogus frame."""
    rng = np.random.RandomState(seed)
    garbage = rng.randint(0, 256, size=rng.randint(4, 200),
                          dtype=np.uint8).tobytes()
    reader = wire.FrameReader()
    reader.feed(garbage)
    try:
        assert _drain(reader) == []
    except wire.WireError:
        # poisoned reader must keep refusing (connection-teardown contract)
        with pytest.raises(wire.WireError):
            _drain(reader)


@given(st.integers(0, 1000), st.integers(1, 255))
@settings(max_examples=40, deadline=None)
def test_single_byte_flip_never_decodes_silently(seed, xor):
    """THE integrity contract: flip any one byte of a valid framed stream
    and no decoder path may return a different frame as if it were good.
    Every outcome is either a typed WireError or a shortened/incomplete
    stream — zero silent decodes."""
    rng = np.random.RandomState(seed)
    comp = C.make_compressor("randtopk_quant", k=3, bits=8)
    p = jax.tree.map(np.asarray, comp.encode(
        jax.numpy.asarray(rng.randn(2, 16).astype(np.float32)),
        key=jax.random.key(seed), training=True))
    clean = wire.encode_payload_frame(1, 5, p)
    pos = rng.randint(len(clean))
    corrupt = bytearray(clean)
    corrupt[pos] ^= xor
    try:
        got = wire.decode_frame(bytes(corrupt))
    except wire.WireError:
        return                          # typed rejection: contract held
    # a flipped length prefix may leave the buffer "incomplete" (reader
    # would wait for more bytes) — that is not a silent decode
    assert got is None, (
        f"silent decode after flipping byte {pos} with {xor:#x}")


@given(st.integers(0, 300), st.sampled_from(
    ["identity", "topk:k=4", "randtopk:k=4", "quant:bits=4",
     "randtopk_quant:k=4,bits=8", "randtopk_mask:k=4"]))
@settings(max_examples=25, deadline=None)
def test_truncated_tail_then_valid_frame_is_detected(seed, spec):
    """A truncated frame glued to a later valid frame desyncs the stream;
    the reader must raise, not resynchronize onto garbage."""
    rng = np.random.RandomState(seed)
    comp = C.make_compressor(spec)
    p = jax.tree.map(np.asarray, comp.encode(
        jax.numpy.asarray(rng.randn(1, 32).astype(np.float32)),
        key=jax.random.key(seed), training=True))
    f1 = wire.encode_payload_frame(0, 0, p)
    f2 = wire.encode_token_frame(0, 1, [7])
    cut = rng.randint(5, len(f1))       # keep the length prefix intact
    reader = wire.FrameReader()
    reader.feed(f1[:cut] + f2)
    with pytest.raises(wire.WireError):
        while _drain(reader):
            pass
        # stream still incomplete per the (valid) length prefix: append
        # more bytes until the checksum gate must fire
        reader.feed(f2 * 8)
        _drain(reader)
