"""The shipped examples must stay runnable (subprocess, single device)."""
import subprocess
import sys

import pytest


def _run_example(path, timeout=900):
    r = subprocess.run(
        [sys.executable, path], capture_output=True, text=True,
        timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return r.stdout


@pytest.mark.slow
def test_quickstart_example():
    out = _run_example("examples/quickstart.py")
    assert "compressed size" in out
    assert "greedy decode" in out


@pytest.mark.slow
def test_two_party_vfl_example():
    out = _run_example("examples/two_party_vfl.py")
    assert "randtopk" in out and "size_reduction" in out


@pytest.mark.slow
def test_streaming_clients_example():
    out = _run_example("examples/streaming_clients.py")
    assert "identity" in out and "randtopk" in out
    assert "tok/s" in out


@pytest.mark.slow
def test_fedtrain_two_party_example():
    out = _run_example("examples/fedtrain_two_party.py")
    assert "randtopk" in out
    assert "B/step up" in out and "B/step down" in out
    assert "test acc" in out
