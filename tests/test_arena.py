"""Session-slot arena: device-side decode parity with the host-densify
path for every payload kind, zero host-side densification on the serving
and training hot paths, slot stability under chaos/reconnect, slot reuse
after close, and the active-mask no-advance invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import compressors as C
from repro.core import wire
from repro.models import transformer
from repro.models.config import Runtime, SplitConfig
from repro.runtime import run_streaming, steps
from repro.runtime.server import StreamingServer
from repro.split import protocol
from repro.testing import FaultInjector, FaultPlan

KIND_COMPRESSORS = [
    ("dense", C.make_compressor("identity")),
    ("slice", C.make_compressor("size_reduction", k=6)),
    ("sparse", C.make_compressor("randtopk", k=6)),
    ("quant", C.make_compressor("quant", bits=4)),
    ("sparse_quant", C.make_compressor("randtopk_quant", k=6, bits=8)),
]


def _smoke_cfg(**split_kw):
    split = SplitConfig(cut_layer=1, **split_kw) if split_kw else None
    return configs.get("qwen3-8b", smoke=True).with_(split=split)


def _wire_payload(comp, x):
    """Encode + full frame round trip — exactly what the server receives."""
    p = protocol.client_encode(comp, x, key=jax.random.key(0), training=True)
    frame, _ = wire.decode_frame(wire.encode_payload_frame(0, 0, p))
    return frame.payload


# ---------------------------------------------------------------------------
# Decode parity: device/slot decode == host densify, for every payload kind
# ---------------------------------------------------------------------------

def _assert_decode_match(kind, host, dev):
    """Sparse/dense/slice decode carries wire floats verbatim — bit-exact
    in every mode. Quant dequant is a multiply-add the compiled path may
    contract into an FMA, so compiled-vs-eager is pinned to <= 1 ulp (and
    test_arena_tokens_match_host_densify_path pins that served tokens do
    not move at all)."""
    if kind in ("quant", "sparse_quant"):
        # one rounding of the (code + 0.5) * step product: bounded by the
        # ulp at the largest decoded magnitude
        atol = float(np.spacing(np.float32(np.abs(host).max())))
        np.testing.assert_allclose(dev, host, rtol=0, atol=atol)
    else:
        np.testing.assert_array_equal(host, dev)


@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS,
                         ids=[k for k, _ in KIND_COMPRESSORS])
def test_device_decode_matches_host_decode(kind, comp):
    x = jnp.asarray(np.random.RandomState(1).randn(3, 1, 32).astype(
        np.float32))
    p = _wire_payload(comp, x)
    assert p.meta.kind == kind
    host = np.asarray(protocol.server_decode(p))
    dev = np.asarray(protocol.server_decode_device(p))
    _assert_decode_match(kind, host, dev)


@pytest.mark.parametrize("kind,comp", KIND_COMPRESSORS,
                         ids=[k for k, _ in KIND_COMPRESSORS])
def test_slot_decode_matches_host_decode(kind, comp):
    """Scatter-decode into arena rows == host densify, row for row; rows
    not targeted keep their prior contents; the scratch row absorbs pads."""
    n, d, cap = 3, 32, 5
    x = jnp.asarray(np.random.RandomState(2).randn(n, 1, 1, d).astype(
        np.float32))
    p = _wire_payload(comp, x)
    host = np.asarray(protocol.server_decode(p))
    xbuf = jnp.full((cap + 1, 1, 1, d), 7.0, jnp.float32)
    slots = np.array([4, 0, 2])
    out = np.asarray(protocol.server_decode_to_slots(xbuf, p, slots))
    for row, slot in enumerate(slots):
        _assert_decode_match(kind, host[row], out[slot])
    for untouched in (1, 3, 5):
        np.testing.assert_array_equal(out[untouched], 7.0)


def test_scatter_rows_pallas_matches_xla():
    """The Pallas scatter kernel (interpret) == put_along_axis for unique
    supports, across shapes and d not a multiple of the lane width."""
    rng = np.random.RandomState(3)
    for shape, d in [((4, 8), 32), ((2, 3, 5), 70), ((1, 1, 1, 16), 256)]:
        k = shape[-1]
        vals = rng.randn(*shape).astype(np.float32)
        idx = np.stack([rng.choice(d, k, replace=False)
                        for _ in range(int(np.prod(shape[:-1])))])
        idx = idx.reshape(shape).astype(np.uint16)
        meta = C.PayloadMeta("sparse", d=d, k=k)
        p = C.Payload(meta=meta, values=jnp.asarray(vals),
                      indices=jnp.asarray(idx))
        ref = np.asarray(C.payload_to_dense(p, backend="xla"))
        got = np.asarray(C.payload_to_dense(p, backend="pallas"))
        np.testing.assert_array_equal(ref, got)


# ---------------------------------------------------------------------------
# End-to-end: arena-served tokens == the pre-arena host-densify serve loop
# ---------------------------------------------------------------------------

def _reference_tokens(cfg, params, comp, prompts, gen):
    """The pre-arena serving semantics, replayed single-file: bottom step ->
    wire round trip -> HOST densify (`server_decode`) -> flush-shaped
    vmapped top step (`make_top_step`) with a stacked/unstacked cache."""
    rt = Runtime(mesh=None, training=False)
    cut = cfg.split.cut_layer if cfg.split else max(1, cfg.n_layers // 2)
    bottom = jax.jit(steps.make_bottom_step(cfg, rt, cut, comp))
    top = jax.jit(steps.make_top_step(cfg, rt, cut))
    prompt_len = prompts.shape[1]
    out = []
    for row in range(prompts.shape[0]):
        cache_b = transformer.init_cache(params, cfg, rt, 1, prompt_len + gen)
        cache_t = transformer.init_cache(params, cfg, rt, 1, prompt_len + gen)
        token = np.asarray([[prompts[row, 0]]], np.int32)
        toks = []
        for step in range(prompt_len + gen - 1):
            p, cache_b = bottom(params, cache_b, token)
            p = jax.tree.map(np.asarray, p)
            frame, _ = wire.decode_frame(
                wire.encode_payload_frame(row, step, p))
            x = np.asarray(protocol.server_decode(frame.payload,
                                                  dtype=cfg.adtype()))
            stacked = jax.tree.map(lambda a: a[None], cache_t)
            tok, new_stacked = top(params, jnp.asarray(x[None]), stacked)
            cache_t = jax.tree.map(lambda a: a[0], new_stacked)
            nxt = int(np.asarray(tok)[0, 0])
            if step + 1 < prompt_len:
                token = np.asarray([[prompts[row, step + 1]]], np.int32)
            else:
                toks.append(nxt)
                token = np.asarray([[nxt]], np.int32)
        out.append(toks)
    return np.asarray(out, np.int32)


@pytest.mark.parametrize("spec", ["identity", "size_reduction:k=8",
                                  "randtopk:k=8", "quant:bits=4",
                                  "randtopk_quant:k=8,bits=8"])
@pytest.mark.slow
def test_arena_tokens_match_host_densify_path(spec):
    """Slot-decoded, arena-stepped tokens are bit-identical to the old
    host-densify + stack/unstack serve loop, for every payload kind."""
    cfg = _smoke_cfg(compressor="randtopk", k=8)
    params = transformer.init_model(jax.random.key(0), cfg)
    prompt_len, gen, n = 2, 4, 2
    res = run_streaming(cfg, n_clients=n, prompt_len=prompt_len, gen=gen,
                        max_batch=n, params=params, seed=0,
                        compressor_mix=[spec])
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (n, prompt_len), 0, cfg.vocab))
    comp = C.make_compressor(spec)
    ref = _reference_tokens(cfg, params, comp, prompts, gen)
    np.testing.assert_array_equal(res["tokens"], ref)


# ---------------------------------------------------------------------------
# Zero host-side densification on the hot paths
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_streaming_serves_without_host_densify():
    """A full mixed-kind serving run performs ZERO host-side dense
    materializations (`protocol.server_decode` stays untouched) and keeps
    no per-session host cache — sessions own arena slots instead."""
    cfg = _smoke_cfg(compressor="randtopk", k=8)
    params = transformer.init_model(jax.random.key(0), cfg)
    with protocol.HOST_DENSIFY_COUNT.watch() as w:
        res = run_streaming(cfg, n_clients=4, prompt_len=2, gen=4,
                            max_batch=4, params=params,
                            compressor_mix=["identity", "randtopk:k=8",
                                            "quant:bits=4",
                                            "randtopk_quant:k=8,bits=8"])
        assert w.delta == 0
    assert res["tokens"].shape == (4, 4)


def test_fedtrain_trains_without_host_densify():
    from repro.data.synthetic import ManyClassDataset
    from repro.fedtrain import run_fedtrain
    from repro.split.tabular import SplitSpec

    ds = ManyClassDataset(n_classes=10, in_dim=16, n_train=256, n_test=128,
                          noise=0.3, seed=0)
    spec = SplitSpec(in_dim=16, hidden=32, cut_dim=32, n_classes=10,
                     method="randtopk", k=3)
    with protocol.HOST_DENSIFY_COUNT.watch() as w:
        r = run_fedtrain(spec, ds, n_clients=1, epochs=1, batch=64, seed=0)
        assert w.delta == 0
    assert r["steps"] > 0


# ---------------------------------------------------------------------------
# Int8 KV arena: opt-in via ArchConfig.kv_cache_bits, pinned accuracy delta
# ---------------------------------------------------------------------------

def test_int8_kv_arena_cache_layout():
    """kv_cache_bits=8 swaps the arena KV leaves to int8 codes plus f32
    per-(token,head) scale rows — the layout `attention` keys its dequant
    branch on (`"k_scale" in cache`)."""
    cfg = _smoke_cfg(compressor="randtopk", k=8)
    params = transformer.init_model(jax.random.key(0), cfg)
    rt8 = Runtime(mesh=None, training=False, kv_cache_bits=8)
    cache = transformer.init_cache(params, cfg, rt8, 1, 8)
    kv = cache["kv"]
    assert kv["k"].dtype == jnp.int8 and kv["v"].dtype == jnp.int8
    assert kv["k_scale"].dtype == jnp.float32
    assert kv["k_scale"].shape == kv["k"].shape[:-1]


@pytest.mark.slow
def test_int8_kv_arena_serving_accuracy_delta():
    """Serving with an int8 server-side KV arena stays within a pinned
    token-agreement margin of the f32 reference. The quantized run must
    also actually diverge somewhere (seed 1, gen 12 does) — otherwise a
    regression that silently ignores `kv_cache_bits` would pass the margin
    trivially. Clients keep f32 bottom caches either way."""
    cfg = _smoke_cfg(compressor="randtopk", k=8)
    assert cfg.kv_cache_bits == 0            # default: Runtime decides
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = dict(n_clients=2, prompt_len=2, gen=12, max_batch=2,
              params=params, seed=1)
    f32 = run_streaming(cfg, **kw)
    q8 = run_streaming(cfg.with_(kv_cache_bits=8), **kw)
    agree = float((f32["tokens"] == q8["tokens"]).mean())
    assert agree >= 0.75                     # measured 0.875
    assert agree < 1.0                       # int8 path demonstrably active


# ---------------------------------------------------------------------------
# Slot lifecycle: stability under chaos, reuse after close, full-arena error
# ---------------------------------------------------------------------------

def test_slots_survive_reconnect_without_double_advance():
    """Chaos (corrupt/drop/duplicate + ARQ retransmission) forces replays
    and reconnects; sessions keep their arena slot throughout and the KV
    cache never double-advances — tokens stay bit-identical to the clean
    run."""
    cfg = _smoke_cfg(compressor="randtopk", k=8)
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = dict(n_clients=3, prompt_len=2, gen=4, max_batch=2, params=params,
              seed=0)
    clean = run_streaming(cfg, **kw)

    inj = FaultInjector(FaultPlan(seed=7, corrupt=0.04, drop=0.04,
                                  duplicate=0.05, max_faults=24))
    chaos = run_streaming(cfg, wrap_endpoint=inj, retry_timeout=0.2, **kw)
    fc = chaos["fault_counters"]
    assert sum(inj.injected().values()) > 0
    assert fc["replays"] + fc["duplicates"] + fc["reconnects"] > 0
    np.testing.assert_array_equal(clean["tokens"], chaos["tokens"])


def _server(capacity, max_batch=2, **kw):
    cfg = _smoke_cfg(compressor="randtopk", k=8)
    params = transformer.init_model(jax.random.key(0), cfg)
    rt = Runtime(mesh=None, training=False)
    make_cache = lambda: transformer.init_cache(params, cfg, rt, 1, 8)
    return StreamingServer(
        params, steps.make_arena_top_step(cfg, rt, 1), make_cache,
        max_batch=max_batch, capacity=capacity,
        x_shape=(1, 1, cfg.d_model), **kw)


def test_slot_reuse_after_close_resets_state():
    """A closed session's slot is reclaimed for the next admission, and the
    serve loop resets its cache row to the fresh template before reuse."""
    server = _server(capacity=1)
    s1 = server._session_for(11, endpoint=None)
    assert s1.slot == 0
    # simulate served progress in slot 0
    server.arena.cache["pos"] = server.arena.cache["pos"].at[0].set(5)
    s1.closed = True
    s2 = server._session_for(22, endpoint=None)
    assert s2.slot == 0 and s1.slot == -1       # reclaimed, not duplicated
    assert ("reset", None, 0) in server._arena_ops
    server._process([])                          # serve loop applies resets
    assert server._arena_ops == []
    assert int(np.asarray(server.arena.cache["pos"])[0]) == 0


def test_arena_full_raises_at_admission():
    # eviction off and a zero admission timeout: the third admission has
    # no free, closed, or evictable slot and must fail loudly
    server = _server(capacity=2, evict_idle=False, admit_timeout=0.0)
    server._session_for(1, endpoint=None)
    server._session_for(2, endpoint=None)
    with pytest.raises(RuntimeError, match="arena full"):
        server._session_for(3, endpoint=None)


def test_full_arena_evicts_lru_idle_session():
    """With eviction on, a full arena LRU-evicts the idlest session's row
    to host (the serve loop fetches it before the row is reused) and a
    later frame from the evicted session re-admits it with its exact
    pre-eviction state."""
    server = _server(capacity=2)
    ev0 = server.registry.counter("slot_evictions_total").value
    re0 = server.registry.counter("slot_readmissions_total").value
    s1 = server._session_for(1, endpoint=None)
    s2 = server._session_for(2, endpoint=None)
    s1.last_active, s2.last_active = 1.0, 2.0           # s1 is the LRU
    # simulate served progress so eviction has real state to preserve
    server.arena.cache["pos"] = server.arena.cache["pos"].at[0].set(5)
    s3 = server._session_for(3, endpoint=None)
    assert s3.slot == 0 and s1.slot == -1               # s1 evicted
    assert s1.host_state is not None                    # sentinel until fetch
    server._process([])                 # serve loop: fetch -> reset
    assert int(np.asarray(s1.host_state["pos"])) == 5   # state reached host
    assert int(np.asarray(server.arena.cache["pos"])[0]) == 0   # row reset
    assert server.registry.counter("slot_evictions_total").value == ev0 + 1
    # s2 closes; s1's re-admission restores its row into the freed slot
    s2.closed = True
    with server._lock:
        server._ensure_resident(s1)
    assert s1.slot >= 0
    server._process([])                 # serve loop: restore
    assert s1.host_state is None
    assert int(np.asarray(server.arena.cache["pos"])[s1.slot]) == 5
    assert server.registry.counter("slot_readmissions_total").value == re0 + 1


def test_slot_churn_cycles_and_resets_every_row():
    """Admit/close/admit N >> capacity: the FIFO free deque cycles slot
    reuse through EVERY row (the old `list.pop(0)` + append re-issued the
    coldest id, hiding reuse-after-close bugs), each reused row is
    template-reset exactly when reused, and rows holding live sessions are
    never spuriously reset."""
    cap = 3
    server = _server(capacity=cap, evict_idle=False)
    # pin one live session for the whole churn — its row must never reset
    pinned = server._session_for(1000, endpoint=None)
    server._process([])
    server.arena.cache["pos"] = server.arena.cache["pos"].at[
        pinned.slot].set(99)
    issued = []
    for i in range(10):                     # 10 admissions over 2 free rows
        sess = server._session_for(i, endpoint=None)
        server._process([])                 # serve loop applies the ops
        pos = np.asarray(server.arena.cache["pos"])
        assert pos[sess.slot] == 0, \
            f"row {sess.slot} reused without a template reset"
        issued.append(sess.slot)
        server.arena.cache["pos"] = server.arena.cache["pos"].at[
            sess.slot].set(i + 10)          # marker: this row served i
        sess.closed = True
    free_rows = sorted(set(range(cap)) - {pinned.slot})
    # cycling: every window of len(free_rows) admissions touches them all
    for w in range(len(issued) - len(free_rows) + 1):
        assert sorted(set(issued[w:w + len(free_rows)])) == free_rows, \
            f"slot reuse not cycling: {issued}"
    assert int(np.asarray(server.arena.cache["pos"])[pinned.slot]) == 99


@pytest.mark.slow
def test_repeated_runs_do_not_grow_live_buffers():
    """`engine._serving_steps` pins compiled programs ON PURPOSE (cross-run
    warm cache) — but repeated `run_streaming` calls must not accumulate
    device buffers beyond it, and `clear_serving_steps` must release the
    cache on demand (the old unbounded `functools.lru_cache` could not)."""
    import gc

    from repro.runtime import engine

    cfg = _smoke_cfg(compressor="randtopk", k=8)
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = dict(n_clients=2, prompt_len=2, gen=3, max_batch=2, params=params)
    run_streaming(cfg, **kw)        # populate the cache, pay every compile
    gc.collect()
    n0 = len(jax.live_arrays())
    for _ in range(3):
        run_streaming(cfg, **kw)
    gc.collect()
    n1 = len(jax.live_arrays())
    assert n1 <= n0 + 8, f"live arrays grew {n0} -> {n1} across reruns"
    assert len(engine._STEP_CACHE) >= 1
    released = engine.clear_serving_steps()
    assert released >= 1 and len(engine._STEP_CACHE) == 0
    # the next run recompiles from an empty cache and still serves
    run_streaming(cfg, **kw)
    assert len(engine._STEP_CACHE) == 1


def test_inactive_slots_do_not_advance():
    """The active-slot mask: inactive rows pass through the donated step
    bit-identically — position and KV never move for a slot that received
    no frame in a flush."""
    cfg = _smoke_cfg(compressor="randtopk", k=8)
    params = transformer.init_model(jax.random.key(0), cfg)
    rt = Runtime(mesh=None, training=False)
    step = jax.jit(steps.make_arena_top_step(cfg, rt, 1))
    cache = jax.tree.map(
        lambda a: jnp.stack([a] * 3),
        transformer.init_cache(params, cfg, rt, 1, 8))
    xbuf = jnp.asarray(np.random.RandomState(0).randn(
        4, 1, 1, cfg.d_model).astype(np.float32))
    active = jnp.asarray([True, False, True])
    _, new = step(params, xbuf, cache, active)
    assert np.asarray(new["pos"]).tolist() == [1, 0, 1]
    old_kv = jax.tree.leaves(cache["kv"])
    new_kv = jax.tree.leaves(new["kv"])
    for o, n in zip(old_kv, new_kv):
        np.testing.assert_array_equal(np.asarray(o[1]), np.asarray(n[1]))
        assert not np.array_equal(np.asarray(o[0]), np.asarray(n[0]))
