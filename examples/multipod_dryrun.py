"""Production-mesh walkthrough: lower + compile one architecture on the
2-pod 512-chip mesh with the RandTopk cut transfer crossing the pod
boundary, and print its roofline terms.

    PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape]
"""
import sys

from repro.launch import dryrun  # sets XLA_FLAGS before jax init


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-8b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    roof = dryrun.run_combo(arch, shape, multi_pod=True, split="randtopk",
                            k=64)
    row = roof.row()
    print("\nsummary:", {k: row[k] for k in
                         ("arch", "shape", "mesh", "bottleneck")})


if __name__ == "__main__":
    main()
