"""Two-party vertical-federated-learning scenario — the paper's exact
setting: a feature owner and a label owner jointly train a 100-class
classifier, exchanging ONLY the compressed cut-layer payloads. Compares the
methods of the paper at matched compressed size.

    PYTHONPATH=src python examples/two_party_vfl.py
"""
from repro.data.synthetic import ManyClassDataset
from repro.split.tabular import SplitSpec, train


def main():
    ds = ManyClassDataset(n_classes=100, in_dim=64, n_train=8000,
                          n_test=2000, noise=0.3)
    print("method          k    acc    size%   train-wire(MB)")
    for method, kw in [
        ("none", {}),
        ("randtopk", dict(k=3, alpha=0.1)),
        ("topk", dict(k=3)),
        ("size_reduction", dict(k=3)),
        ("quant", dict(quant_bits=4)),
    ]:
        spec = SplitSpec(method=method, hidden=512, lr=2e-3, **kw)
        r = train(spec, ds, epochs=12, seed=0)
        print(f"{method:15s} {kw.get('k','-'):>2} {r['test_acc']:.4f} "
              f"{r['compressed_size_pct']:7.2f} "
              f"{r['train_bytes']/1e6:10.1f}")


if __name__ == "__main__":
    main()
