"""Streaming multi-client serving: N feature owners against one batching
server, every cut activation crossing the wire as framed bytes.

Eight clients — half sending dense (uncompressed) cut activations, half
sending randomized-top-k payloads — stream a short generation each through
the `repro.runtime` engine. The per-session table at the end is measured
from the actual frame bytes, so the dense/randtopk size ratio printed here
is the paper's compression claim realized on a (simulated) socket.

    PYTHONPATH=src python examples/streaming_clients.py
"""
import numpy as np

import repro.configs as configs
from repro.models.config import SplitConfig
from repro.runtime import run_streaming


def main():
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16,
                          alpha=0.1))
    print("serving 8 streaming sessions (4 dense + 4 randtopk clients), "
          "max_batch=8 ...")
    res = run_streaming(cfg, n_clients=8, prompt_len=4, gen=12,
                        max_batch=8, max_wait=0.02,
                        compressor_mix=["identity", "randtopk:k=16"])

    print(f"\n{res['tokens_per_s']:.0f} tok/s over the session mix, "
          f"mean server batch fill "
          f"{np.mean(res['batch_sizes']):.1f}/8\n")
    print(f"{'session':>7} {'compressor':>12} {'payload B/tok':>13} "
          f"{'framing B/tok':>13} {'vs dense':>9}")
    dense_bytes = cfg.d_model * 4
    for cid, (name, s) in enumerate(zip(res["compressors"],
                                        res["client_stats"])):
        payload = s["payload_bytes_up"] / s["frames_up"]
        framing = s["header_bytes_up"] / s["frames_up"]
        print(f"{cid:>7} {name:>12} {payload:>13.1f} {framing:>13.1f} "
              f"{100 * payload / dense_bytes:>8.1f}%")
    print("\nsample continuation of session 0:",
          res["tokens"][0, :8].tolist())


if __name__ == "__main__":
    main()
