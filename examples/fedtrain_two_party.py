"""Two-party split training over the wire: the paper's Figure-1 loop, live.

Two feature-owner clients train bottom models against one label-owner
server. Every step, each client streams its randomized-top-k compressed cut
activation up as framed bytes and receives the compressed cut gradient back
as a `grad` frame — so the dual-direction byte table printed at the end is
measured off a (simulated) socket, and matches the paper's Table-2 fwd+bwd
analytics exactly.

    PYTHONPATH=src python examples/fedtrain_two_party.py
"""
from repro.data.synthetic import ManyClassDataset
from repro.fedtrain import run_fedtrain
from repro.split.tabular import SplitSpec


def main():
    ds = ManyClassDataset(n_classes=20, in_dim=32, n_train=2560, n_test=1024,
                          noise=0.3, seed=0)
    spec = SplitSpec(in_dim=32, hidden=128, cut_dim=64, n_classes=20,
                     method="randtopk", k=9, lr=2e-3)
    print("training 2 clients x 3 epochs, randtopk k=9 at a d=64 cut ...")
    res = run_fedtrain(spec, ds, n_clients=2, epochs=3, batch=128, seed=0)

    steps = res["steps"]
    print(f"\n{steps} steps/client in {res['wall_s']:.1f}s, "
          f"test acc {res['mean_test_acc']:.4f}\n")
    print(f"{'client':>7} {'loss first->last':>18} {'B/step up':>10} "
          f"{'B/step down':>12}")
    for cid, (losses, cs) in enumerate(zip(res["losses"],
                                           res["client_stats"])):
        up = cs["payload_bytes_up"] / cs["frames_up"]
        down = cs["payload_bytes_down"] / cs["frames_down"]
        print(f"{cid:>7} {losses[0][1]:>8.3f} -> {losses[-1][1]:<7.3f} "
              f"{up:>10.1f} {down:>12.1f}")
    dense = spec.cut_dim * 4 * 128
    print(f"\nuncompressed would be {dense} B/step each way; measured "
          f"payload totals: {res['payload_bytes_up']} B up, "
          f"{res['payload_bytes_down']} B down "
          f"(analytic {res['analytic_bytes_up']:.0f} / "
          f"{res['analytic_bytes_down']:.0f} B)")


if __name__ == "__main__":
    main()
