"""Quickstart: train a reduced Qwen3-family model with RandTopk cut-layer
compression, then serve it — the paper's full pipeline in one file.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.data.pipeline import TokenPipeline
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import transformer
from repro.models.config import Runtime, SplitConfig
from repro.optim import adamw_init
from repro.split import protocol


def main():
    cfg = configs.get("qwen3-8b", smoke=True).with_(
        split=SplitConfig(cut_layer=1, compressor="randtopk", k=16,
                          alpha=0.1))
    rt = Runtime(mesh=None, training=True)
    params = transformer.init_model(jax.random.key(0), cfg)
    opt = adamw_init(params)
    pipe = TokenPipeline(cfg, batch=8, seq=64)
    step = jax.jit(make_train_step(cfg, rt, lr=1e-3), donate_argnums=(0, 1))

    print("training with RandTopk(k=16, alpha=0.1) at the cut layer...")
    for i in range(60):
        params, opt, m = step(params, opt, pipe.next_batch(i),
                              jax.random.fold_in(jax.random.key(1), i))
        if i % 20 == 0 or i == 59:
            print(f"  step {i:3d} loss={float(m['loss']):.4f}")
    fwd = protocol.wire_bytes_per_step(cfg, 8, 64, training=False)
    full = 8 * 64 * cfg.d_model * 4
    print(f"cut-layer wire per forward: {fwd:.0f} B vs {full} B dense "
          f"({100*fwd/full:.1f}% compressed size)")

    rt_inf = Runtime(mesh=None, training=False)
    cache = transformer.init_cache(params, cfg, rt_inf, 2, 32)
    serve = jax.jit(make_serve_step(cfg, rt_inf))
    tok = jnp.zeros((2, 1), jnp.int32)
    toks = []
    for _ in range(8):
        tok, cache = serve(params, cache, tok)
        toks.append(int(tok[0, 0]))
    print("greedy decode:", toks)


if __name__ == "__main__":
    main()
